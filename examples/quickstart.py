"""Quickstart: ADSP vs BSP on a heterogeneous 3-worker edge cluster.

Runs in ~30 s on CPU. Shows the paper's core result: with a 1:1:3 speed
spread, BSP wastes ~half of every worker's time at the barrier while ADSP
keeps all workers training and converges faster in (virtual) wall-clock.

Each run drives the unified cluster runtime: an event-driven policy
(``repro.cluster``) steered by the ClusterEngine over the virtual-clock
simulator backend — the same control plane that drives real mesh
training in ``repro.launch.train``.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.cluster import make_policy
from repro.edgesim import SimConfig, Simulator
from repro.edgesim.profiles import ratio_profiles
from repro.edgesim.tasks import svm_task


def main():
    profiles = ratio_profiles((1, 1, 3), base_v=1.0, o=0.2)
    task = svm_task(num_workers=3)
    cfg = SimConfig(gamma=20.0, epoch_seconds=200.0, base_batch=32,
                    target_loss=0.02, max_seconds=900.0)

    print(f"{'policy':16s} {'converged':9s} {'t_conv(s)':>9s} {'steps':>6s} "
          f"{'commits':>7s} {'waiting%':>8s}")
    for name, kw in [
        ("bsp", {}),
        ("ssp", {"s": 8}),
        ("fixed_adacomm", {"tau": 8}),
        ("adsp", {"search": True, "gamma": 20.0, "probe_seconds": 20.0}),
    ]:
        sim = Simulator(task, profiles, make_policy(name, **kw), cfg)
        res = sim.train()
        print(f"{name:16s} {str(res.converged):9s} {res.convergence_time:9.1f} "
              f"{res.total_steps:6d} {res.total_commits:7d} "
              f"{100*res.waiting_fraction:8.1f}")
    print("\nADSP: no waiting -> more steps/second -> faster convergence;")
    print("commit counts stay equal across workers (Theorem 2 precondition).")


if __name__ == "__main__":
    main()
