"""Paper-scale scenario: the EC2-like heterogeneous fleet (Table 1)
training the CNN application, with the full ADSP control plane — online
commit-rate search, check periods, timers — against the strongest
baseline (Fixed ADACOMM). Reports the Fig. 5-style speedup and the
search trace. ~2-4 min on CPU.

    PYTHONPATH=src python examples/heterogeneous_edge.py [--workers 8]
"""

import argparse

from repro.core.sync import make_policy
from repro.core.theory import heterogeneity_degree
from repro.edgesim import SimConfig, Simulator
from repro.edgesim.profiles import ec2_profiles
from repro.edgesim.tasks import cnn_task


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--workers", type=int, default=8)
    p.add_argument("--target-loss", type=float, default=0.8)
    args = p.parse_args()

    profiles = ec2_profiles(o=0.2, scale=0.5)[: args.workers]
    H = heterogeneity_degree([pr.v for pr in profiles])
    print(f"# {args.workers} workers, heterogeneity H={H:.2f}")
    task = cnn_task(args.workers, width=8)
    cfg = SimConfig(gamma=20.0, epoch_seconds=200.0, base_batch=32,
                    target_loss=args.target_loss, max_seconds=4000.0,
                    local_lr=0.05)

    results = {}
    for name, kw in [
        ("fixed_adacomm", {"tau": 8}),
        ("adsp", {"search": True, "gamma": 20.0, "probe_seconds": 20.0}),
    ]:
        sim = Simulator(task, profiles, make_policy(name, **kw), cfg)
        res = sim.train()
        results[name] = res
        print(f"{name:16s} t_conv={res.convergence_time:8.1f}s "
              f"steps={res.total_steps} commits={res.total_commits} "
              f"waiting={100*res.waiting_fraction:.1f}% cc={res.commit_counts}")
        if name == "adsp":
            for i, tr in enumerate(sim.policy.traces):
                print(f"  search epoch {i}: candidates={tr.candidates} -> {tr.chosen}")

    t_a = results["adsp"].convergence_time
    t_f = results["fixed_adacomm"].convergence_time
    if results["adsp"].converged and results["fixed_adacomm"].converged:
        print(f"\nADSP speedup vs Fixed ADACOMM: {100*(1 - t_a/t_f):.1f}% "
              f"(paper reports up to 62.4% at H=3.2)")


if __name__ == "__main__":
    main()
