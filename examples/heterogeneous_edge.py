"""Paper-scale scenario: the EC2-like heterogeneous fleet (Table 1)
training the CNN application, with the full ADSP control plane — online
commit-rate search, check periods, timers — against the strongest
baseline (Fixed ADACOMM). Reports the Fig. 5-style speedup and the
search trace. ~2-4 min on CPU.

With ``--churn``, the run exercises the §6 adaptability claim through
the cluster runtime's elastic events: a worker crashes mid-run, a fresh
one joins later, and a surviving worker is throttled to half speed — the
engine re-derives the commit rates (ΔC_i = C_target − c_i) on each event
and training keeps converging.

With ``--codec`` and link flags, commits become payload-aware
(``repro.transport``): the update is compressed at the worker, the push
costs O_i/2 + latency + bytes/bandwidth, and ``bytes_to_ps`` is measured
on the wire — the bandwidth-constrained-fleet scenario where the
straggler is the link, not the chip.

With ``--ps-shards K`` (K > 1) the PS is shard-partitioned (DESIGN.md
§11): per-shard payloads pipeline FIFO over each worker's link and pulls
fetch only shards whose PS version moved — ``bytes_from_ps`` shrinks on
constrained links at equal-or-better convergence time.

    PYTHONPATH=src python examples/heterogeneous_edge.py [--workers 8] [--churn] \
        [--codec int8] [--bandwidth-kbps 64] [--link-latency 0.05] [--ps-shards 4]
"""

import argparse
import math

from repro.cluster import ChurnSchedule, join, leave, make_policy, speed
from repro.control.theory import WorkerProfile, heterogeneity_degree
from repro.edgesim import SimConfig, Simulator
from repro.edgesim.profiles import ec2_profiles, with_links
from repro.edgesim.tasks import cnn_task
from repro.ps import add_shard_args
from repro.transport import add_codec_args, codec_from_args


def churn_schedule(profiles) -> ChurnSchedule:
    """Leave at t=30, join at t=60, throttle worker 0 at t=90."""
    return ChurnSchedule([
        leave(30.0, worker=len(profiles) - 1),
        join(60.0, WorkerProfile(v=profiles[0].v, o=profiles[0].o)),
        speed(90.0, worker=0, v=profiles[0].v / 2),
    ])


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--workers", type=int, default=8)
    p.add_argument("--target-loss", type=float, default=0.8)
    p.add_argument("--churn", action="store_true",
                   help="elastic scenario: worker crash / join / slowdown")
    add_codec_args(p)  # --codec / --codec-backend / --topk-frac
    add_shard_args(p)  # --ps-shards (K versioned PS shards, partial pulls)
    p.add_argument("--bandwidth-kbps", type=float, default=0.0,
                   help="uplink/downlink kilobits/s per worker (0 = unconstrained)")
    p.add_argument("--link-latency", type=float, default=0.0,
                   help="fixed one-way link latency, seconds")
    args = p.parse_args()
    codec = codec_from_args(args)

    profiles = ec2_profiles(o=0.2, scale=0.5)[: args.workers]
    profiles = with_links(
        profiles,
        # kilobits/s → bytes/s
        bandwidth=args.bandwidth_kbps * 1e3 / 8 if args.bandwidth_kbps else math.inf,
        latency=args.link_latency,
    )
    H = heterogeneity_degree([pr.v for pr in profiles])
    print(f"# {args.workers} workers, heterogeneity H={H:.2f}")
    task = cnn_task(args.workers, width=8)
    cfg = SimConfig(gamma=20.0, epoch_seconds=200.0, base_batch=32,
                    target_loss=args.target_loss, max_seconds=4000.0,
                    local_lr=0.05)

    results = {}
    for name, kw in [
        ("fixed_adacomm", {"tau": 8}),
        ("adsp", {"search": True, "gamma": 20.0, "probe_seconds": 20.0}),
    ]:
        churn = churn_schedule(profiles) if args.churn else None
        sim = Simulator(task, profiles, make_policy(name, **kw), cfg,
                        churn=churn, codec=codec, n_shards=args.ps_shards)
        res = sim.train()
        results[name] = res
        print(f"{name:16s} t_conv={res.convergence_time:8.1f}s "
              f"steps={res.total_steps} commits={res.total_commits} "
              f"waiting={100*res.waiting_fraction:.1f}% cc={res.commit_counts} "
              f"bytes_to_ps={res.bytes_to_ps/1e6:.2f}MB "
              f"bytes_from_ps={res.bytes_from_ps/1e6:.2f}MB")
        if name == "adsp":
            for i, tr in enumerate(sim.policy.traces):
                print(f"  search epoch {i}: candidates={tr.candidates} -> {tr.chosen}")

    t_a = results["adsp"].convergence_time
    t_f = results["fixed_adacomm"].convergence_time
    if results["adsp"].converged and results["fixed_adacomm"].converged:
        print(f"\nADSP speedup vs Fixed ADACOMM: {100*(1 - t_a/t_f):.1f}% "
              f"(paper reports up to 62.4% at H=3.2)")


if __name__ == "__main__":
    main()
