"""End-to-end driver: train a ~100M-parameter decoder LM with the cluster
ADSP commit layer (τ local microsteps between commit all-reduces) for a
few hundred steps on whatever devices exist.

The update rules are pluggable (repro.ps): ``--local-rule adamw`` runs
AdamW at each worker — the commit still ships accumulated parameter
deltas, showing ADSP composes with modern optimizers — and
``--rule-backend fused`` routes the commit through the Pallas
fused-HBM-pass kernels (interpret mode off-TPU).

The model is a granite-family reduction (12 layers, d_model 768, GQA 12/4,
vocab 32k ≈ 107M params). On a 32-core CPU this runs ~1 s/commit at the
default seq 64 / batch 4 / τ 2 — 300 steps in ~5 minutes. Loss should
fall from ~10.4 (ln 32768) to ≤ 5.5 on the synthetic Markov-token stream.

    PYTHONPATH=src python examples/train_lm_100m.py --steps 300
    PYTHONPATH=src python examples/train_lm_100m.py --steps 300 \
        --local-rule adamw --local-opt-lr 1e-3
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.compat import use_mesh
from repro.data.synthetic import lm_tokens
from repro.models import lm
from repro.models.config import ModelConfig
from repro.ps import (
    CommitConfig,
    add_rule_args,
    add_shard_args,
    make_train_step,
    rules_from_args,
)
from repro.transport import add_codec_args, codec_from_args


def make_100m_config() -> ModelConfig:
    base = get_config("granite_3_8b")
    return dataclasses.replace(
        base, name="granite-100m", num_layers=12, d_model=768, num_heads=12,
        num_kv_heads=4, d_ff=2048, vocab_size=32_768, head_dim=64,
        dtype="float32", adsp_granularity="data",
    )


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--tau", type=int, default=2)
    p.add_argument("--local-lr", type=float, default=0.05)
    p.add_argument("--seed", type=int, default=0)
    add_rule_args(p)
    add_codec_args(p)
    add_shard_args(p)
    args = p.parse_args()

    cfg = make_100m_config()
    rules = rules_from_args(args)
    codec = codec_from_args(args)
    print(f"# {cfg.name}: {cfg.total_params()/1e6:.1f}M params, "
          f"tau={args.tau}, seq={args.seq}, batch={args.batch}, "
          f"rules={args.local_rule}+{args.commit_rule}, codec={codec.name}, "
          f"ps_shards={args.ps_shards}")

    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
    ccfg = CommitConfig(tau=args.tau, local_lr=args.local_lr, global_lr=1.0,
                        worker_axes=("data",), n_shards=args.ps_shards)

    def loss_fn(params, mb):
        return lm.lm_loss(cfg, params, mb, remat=False)

    step = make_train_step(loss_fn, ccfg, rules, mesh=mesh, codec=codec)
    params = lm.lm_init(jax.random.PRNGKey(args.seed), cfg)
    state = step.init(params)
    step = jax.jit(step)
    tau_arr = jnp.full((len(jax.devices()),), args.tau, jnp.int32)

    t0 = time.time()
    with use_mesh(mesh):
        for i in range(args.steps):
            toks = lm_tokens(args.seed, i * 65537, args.tau * args.batch,
                             args.seq, cfg.vocab_size)[:, :-1]
            mb = {"tokens": jnp.asarray(
                toks.reshape(args.tau, args.batch, args.seq), jnp.int32)}
            state, loss = step(state, mb, tau_arr)
            if i % 20 == 0 or i == args.steps - 1:
                print(f"commit {i:4d}  loss {float(loss):7.4f}  "
                      f"({(time.time()-t0)/(i+1):.2f}s/commit)")
    print(f"# done: {args.steps} commits = {args.steps*args.tau} microsteps "
          f"in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
