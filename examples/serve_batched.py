"""Batched serving example: per-family caches (KV ring buffers for
windowed attention, O(1) recurrent state for SSM/hybrid archs) in both
launcher modes — the fixed-batch one-shot demo, then the continuous-
batching engine on an open-loop Poisson trace. Uses the reduced configs
so every family runs on CPU.

    PYTHONPATH=src python examples/serve_batched.py
"""

from repro.launch import serve


def main():
    for arch in ["rwkv6-3b", "recurrentgemma-9b", "granite-3-8b"]:
        print(f"\n=== {arch} (reduced config, one-shot) ===")
        serve.main(["--arch", arch, "--smoke", "--batch", "4",
                    "--prompt-len", "32", "--new-tokens", "12"])

    print("\n=== rwkv6-3b (continuous batching, poisson trace) ===")
    serve.main(["--arch", "rwkv6-3b", "--smoke", "--trace", "poisson",
                "--requests", "16", "--rate", "20", "--slots", "4",
                "--scheduler", "deadline", "--slo-ms", "800"])


if __name__ == "__main__":
    main()
