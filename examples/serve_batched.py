"""Batched serving example: prefill + greedy decode with per-family
caches (KV ring buffers for windowed attention, O(1) recurrent state for
SSM/hybrid archs). Uses the reduced configs so every family runs on CPU.

    PYTHONPATH=src python examples/serve_batched.py
"""

from repro.launch import serve


def main():
    for arch in ["rwkv6-3b", "recurrentgemma-9b", "granite-3-8b"]:
        print(f"\n=== {arch} (reduced config) ===")
        serve.main(["--arch", arch, "--smoke", "--batch", "4",
                    "--prompt-len", "32", "--new-tokens", "12"])


if __name__ == "__main__":
    main()
