"""Fig. 3 — (a) convergence time vs fixed commit rate ΔC_target (the
U-shaped curve), (b) implicit momentum μ_implicit from Eqn. (3) per ΔC
(monotone decreasing), (c) the search-selected rate lands near the best
fixed rate."""

from __future__ import annotations


from repro.control import theory

from .common import GAMMA, default_policy, row, run_sim, standard_profiles, standard_task

DELTAS = [1, 2, 4, 8]


def main(full: bool = False) -> list[str]:
    rows = []
    profiles = standard_profiles()
    task = standard_task(len(profiles))
    conv = {}
    for dc in DELTAS + ([16] if full else []):
        policy = default_policy("adsp_fixed", delta_per_period=dc, initial_c_target=dc)
        sim, res, wall = run_sim(task, profiles, policy)
        mu = theory.mu_implicit([dc] * len(profiles), [p.v for p in profiles], GAMMA)
        conv[dc] = res.convergence_time
        rows.append(
            row(
                f"fig3_commit_rate/dc{dc}", wall, res.elapsed,
                delta_c=dc, mu_implicit=mu,
                convergence_time=res.convergence_time,
                converged=res.converged, steps=res.total_steps,
            )
        )
    # (c) search lands near the best fixed ΔC
    policy = default_policy("adsp", search=True)
    sim, res, wall = run_sim(task, profiles, policy)
    best_dc = min(conv, key=conv.get)
    chosen = [t.chosen - t.candidates[0] + 1 for t in policy.traces]
    rows.append(
        row(
            "fig3_commit_rate/search", wall, res.elapsed,
            best_fixed_dc=best_dc,
            best_fixed_time=conv[best_dc],
            search_time=res.convergence_time,
            search_chosen_deltas="|".join(map(str, chosen)),
        )
    )
    return rows
