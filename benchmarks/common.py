"""Shared helpers for the per-figure benchmarks.

Every benchmark emits CSV rows ``name,us_per_call,derived`` where
``us_per_call`` is host wall-time per simulated virtual second (the
benchmark harness cost) and ``derived`` is a ';'-separated key=value list
holding the figure's actual quantities (convergence time, waiting
fraction, speedups, roofline terms, ...).

Policies come from the unified cluster runtime (``repro.cluster``): each
``run_sim`` drives the event-driven ClusterEngine through the simulator
backend, so benchmark numbers exercise the same Alg. 1/Alg. 2 code path
as the real mesh loop (``repro.launch.train``).
"""

from __future__ import annotations

import time

from repro.cluster import make_policy
from repro.edgesim import SimConfig, Simulator
from repro.edgesim.profiles import ratio_profiles
from repro.edgesim.tasks import cnn_task

# Benchmark-scale defaults: Γ=20 s virtual; the CNN task needs a few
# hundred check periods' worth of steps to converge — same period count
# regime as the paper's 60 s Γ over multi-hour runs.
GAMMA = 20.0
EPOCH = 200.0
TARGET_LOSS = 0.6
MAX_SECONDS = 4000.0


def time_fn(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Time ``fn(*args)``: ``warmup`` untimed calls absorb compilation and
    cache population, then ``iters`` timed calls each bracketed by
    ``jax.block_until_ready`` so JAX's async dispatch can't push device
    work past the clock. Returns mean seconds per call."""
    import jax

    out = None
    for _ in range(max(warmup, 1)):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / iters


def default_policy(name: str, **kw):
    if name == "adsp":
        kw.setdefault("gamma", GAMMA)
        kw.setdefault("probe_seconds", GAMMA)
        kw.setdefault("max_probes", 8)
    if name == "adsp_fixed":
        return make_policy("adsp", search=False, gamma=GAMMA, **kw)
    return make_policy(name, **kw)


def run_sim(task, profiles, policy, *, target_loss=TARGET_LOSS,
            max_seconds=MAX_SECONDS, seed=0, local_lr=0.05, base_batch=32):
    cfg = SimConfig(
        gamma=GAMMA, epoch_seconds=EPOCH, target_loss=target_loss,
        max_seconds=max_seconds, seed=seed, local_lr=local_lr,
        base_batch=base_batch,
    )
    t0 = time.time()
    sim = Simulator(task, profiles, policy, cfg)
    res = sim.train()
    wall = time.time() - t0
    return sim, res, wall


def row(name: str, wall_s: float, virtual_s: float, **derived) -> str:
    us = 1e6 * wall_s / max(virtual_s, 1e-9)
    kv = ";".join(f"{k}={_fmt(v)}" for k, v in derived.items())
    return f"{name},{us:.1f},{kv}"


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def standard_task(num_workers: int, seed: int = 0):
    return cnn_task(num_workers, seed=seed, width=8)


def standard_profiles():
    return ratio_profiles((1, 1, 3), base_v=1.0, o=0.2)
