"""Appendix D artifacts:

* ADSP vs ADSP⁺ (offline per-worker τ_i oracle, search time excluded) —
  verifies no-waiting is near-optimal (Fig. 8);
* bandwidth usage comparison (Fig. 10a);
* BatchTune BSP / Fixed ADACOMM (R²SP-style) comparison (Fig. 9);
* the other two applications: RNN / fatigue and SVM / chiller (Figs. 12, 13).
"""

from __future__ import annotations

import itertools

from repro.cluster import ADSPPlus
from repro.edgesim.tasks import rnn_task, svm_task

from .common import (GAMMA, default_policy, row, run_sim, standard_profiles,
                     standard_task)


def adsp_plus(full: bool) -> list[str]:
    rows = []
    profiles = standard_profiles()
    task = standard_task(len(profiles))
    _, res_adsp, wall = run_sim(task, profiles, default_policy("adsp_fixed", delta_per_period=2))
    rows.append(row("appendix_adsp_plus/adsp", wall, res_adsp.elapsed,
                    convergence_time=res_adsp.convergence_time))
    # offline oracle: grid over per-worker τ caps ≤ the no-waiting τ
    # (no-waiting τ for ΔC=2, Γ=20: fast v=1 → τ≈9, slow v=1/3 → τ≈3)
    best = (float("inf"), None)
    grid = [3, 6, 9] if not full else [2, 4, 6, 8, 10]
    for caps in itertools.product(grid, grid[:2]):
        tau_cap = (caps[0], caps[0], caps[1])
        pol = ADSPPlus(gamma=GAMMA, tau_cap=tau_cap, delta_per_period=2)
        _, res, _ = run_sim(task, profiles, pol)
        if res.convergence_time < best[0]:
            best = (res.convergence_time, tau_cap)
    rows.append(row("appendix_adsp_plus/adsp_plus_oracle", 0.0, 1.0,
                    convergence_time=best[0], tau_caps=str(best[1]).replace(",", "|"),
                    adsp_within=res_adsp.convergence_time / best[0] if best[0] else 0))
    return rows


def bandwidth(full: bool) -> list[str]:
    rows = []
    profiles = standard_profiles()
    task = standard_task(len(profiles))
    horizon = 600.0
    for name, kw in (("bsp", {}), ("ssp", {"s": 8}), ("fixed_adacomm", {"tau": 8}),
                     ("adacomm", {}), ("adsp", {"search": True})):
        _, res, wall = run_sim(task, profiles, default_policy(name, **kw),
                               target_loss=None, max_seconds=horizon)
        rows.append(row(f"appendix_bandwidth/{name}", wall, res.elapsed,
                        bytes_per_vsecond=res.bytes_to_ps / max(res.elapsed, 1e-9),
                        commits=res.total_commits))
    return rows


def batchtune(full: bool) -> list[str]:
    rows = []
    profiles = standard_profiles()
    task = standard_task(len(profiles))
    for name, kw in (("batchtune_bsp", {}), ("batchtune_fixed_adacomm", {"tau": 8}),
                     ("bsp", {}), ("fixed_adacomm", {"tau": 8}), ("adsp", {"search": True})):
        _, res, wall = run_sim(task, profiles, default_policy(name, **kw))
        rows.append(row(f"appendix_batchtune/{name}", wall, res.elapsed,
                        convergence_time=res.convergence_time, converged=res.converged))
    return rows


def other_apps(full: bool) -> list[str]:
    rows = []
    profiles = standard_profiles()
    for task_name, task_fn, target in (("rnn_fatigue", rnn_task, 0.7),
                                       ("svm_chiller", svm_task, 0.05)):
        task = task_fn(len(profiles))
        for name, kw in (("bsp", {}), ("fixed_adacomm", {"tau": 8}),
                         ("adsp", {"search": True})):
            _, res, wall = run_sim(task, profiles, default_policy(name, **kw),
                                   target_loss=target)
            rows.append(row(f"appendix_apps/{task_name}/{name}", wall, res.elapsed,
                            convergence_time=res.convergence_time,
                            converged=res.converged,
                            final_loss=float(res.losses[-1])))
    return rows


def main(full: bool = False) -> list[str]:
    return adsp_plus(full) + bandwidth(full) + batchtune(full) + other_apps(full)
