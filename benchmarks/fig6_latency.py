"""Fig. 6 — impact of network latency: convergence time under extra
communication delay O_i ∈ {0.2, 1.0, 3.0} s for BSP / SSP / Fixed
ADACOMM / ADSP. The speedup of local-update methods over BSP/SSP must
grow with delay; ADSP stays best."""

from __future__ import annotations

from repro.edgesim.profiles import ratio_profiles

from .common import default_policy, row, run_sim, standard_task

DELAYS = [0.2, 1.0, 3.0]
POLICIES = [
    ("bsp", {}),
    ("ssp", {"s": 8}),
    ("fixed_adacomm", {"tau": 8}),
    ("adsp", {"search": True}),
]


def main(full: bool = False) -> list[str]:
    rows = []
    for o in DELAYS:
        profiles = ratio_profiles((1, 1, 3), base_v=1.0, o=o)
        task = standard_task(len(profiles))
        for name, kw in POLICIES:
            sim, res, wall = run_sim(task, profiles, default_policy(name, **kw))
            rows.append(
                row(
                    f"fig6_latency/o{o}/{name}", wall, res.elapsed,
                    delay_s=o, convergence_time=res.convergence_time,
                    converged=res.converged, waiting_frac=res.waiting_fraction,
                )
            )
    return rows
