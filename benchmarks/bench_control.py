"""Control-plane benchmark: epoch-clocked vs drift-triggered re-search
(DESIGN.md §12) on a fleet that suffers a mid-run speed shift.

Scenario: the 1:1:3 fleet (commit overhead O_i = 1 s — communication
matters, so the commit-rate choice does too) trains to a target loss;
after the epoch-boundary search has locked in a C_target for the
heterogeneous fleet, the slow worker *recovers* (1/3 → 3 steps/s): the
fleet is suddenly fast and nearly homogeneous, and a much higher commit
rate pays off. The epoch-clocked scheduler (the paper's) sits on the
stale target until the next epoch boundary; the drift-triggered
scheduler re-searches within a cooldown of the shift and climbs to the
new optimum mid-epoch.

Rows report time-to-target-loss (``t_conv``), total probe windows spent
(including windows discarded by churn restarts), the number of searches,
and — for the drift modes — the virtual time of the first re-search after
the shift (``research_t``), which must land *before* the epoch boundary
(``before_epoch_end=1``). ``drift_no_later=1`` records that drift-mode
convergence is no later than epoch mode on this scenario (the §6
adaptability claim, measurable at benchmark scale).
"""

from __future__ import annotations

import dataclasses

from repro.cluster import ChurnSchedule, make_policy, speed
from repro.edgesim import SimConfig, Simulator

from .common import GAMMA, TARGET_LOSS, row, standard_profiles, standard_task

EPOCH = 400.0  # long epochs: a stale C_target hurts for most of one
SHIFT_T = 100.0  # the slow worker recovers after the t=0 search ended
COMMIT_OVERHEAD = 1.0  # O_i seconds per commit: communication-sensitive
MAX_SECONDS = 4000.0


def _run(search_mode: str, seed: int = 0):
    profiles = [dataclasses.replace(p, o=COMMIT_OVERHEAD)
                for p in standard_profiles()]
    policy = make_policy(
        "adsp", gamma=GAMMA, search=True, search_mode=search_mode,
        probe_seconds=GAMMA, max_probes=4,
        drift_threshold=0.2, drift_cooldown=2 * GAMMA,
    )
    cfg = SimConfig(gamma=GAMMA, epoch_seconds=EPOCH, base_batch=32,
                    target_loss=TARGET_LOSS, max_seconds=MAX_SECONDS,
                    seed=seed, local_lr=0.05)
    churn = ChurnSchedule([speed(SHIFT_T, worker=2, v=3.0)])
    sim = Simulator(standard_task(len(profiles)), profiles, policy, cfg,
                    churn=churn)
    import time

    t0 = time.time()
    res = sim.train()
    return sim, policy, res, time.time() - t0


def main(full: bool = False) -> list[str]:
    rows = []
    results = {}
    for mode in ("epoch", "drift", "both") if full else ("epoch", "drift"):
        sim, policy, res, wall = _run(mode)
        probes = sum(tr.probe_windows for tr in policy.traces)
        researches = [tr for tr in policy.traces if tr.t_start >= SHIFT_T]
        research_t = researches[0].t_start if researches else -1.0
        results[mode] = res
        derived = dict(
            t_conv=res.convergence_time,
            converged=res.converged,
            searches=len(policy.traces),
            probes=probes,
            research_t=research_t,
            before_epoch_end=int(0 <= research_t < EPOCH),
            c_target=policy.c_target,
        )
        if mode != "epoch" and "epoch" in results:
            # the gated claim requires BOTH runs to actually converge —
            # two timed-out runs (inf <= inf) must not read as a pass
            epoch = results["epoch"]
            derived["drift_no_later"] = (
                int(res.convergence_time <= epoch.convergence_time)
                if res.converged and epoch.converged else -1
            )
        rows.append(row(f"bench_control/{mode}", wall, res.elapsed, **derived))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
