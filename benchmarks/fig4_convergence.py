"""Fig. 4 — headline comparison: wall-clock convergence time, total steps,
and final loss for ADSP vs BSP / SSP / ADACOMM / Fixed ADACOMM (CNN task,
1:1:3 heterogeneity). Reports the paper's speedup metric
(1 − t_ADSP/t_baseline)."""

from __future__ import annotations

from .common import default_policy, row, run_sim, standard_profiles, standard_task

BASELINES = [
    ("bsp", {}),
    ("ssp", {"s": 8}),
    ("adacomm", {}),
    ("fixed_adacomm", {"tau": 8}),
]


def main(full: bool = False) -> list[str]:
    rows = []
    profiles = standard_profiles()
    task = standard_task(len(profiles))

    sim, res_adsp, wall = run_sim(task, profiles, default_policy("adsp", search=True))
    rows.append(
        row(
            "fig4_convergence/adsp", wall, res_adsp.elapsed,
            convergence_time=res_adsp.convergence_time,
            steps=res_adsp.total_steps, commits=res_adsp.total_commits,
            final_loss=float(res_adsp.losses[-1]),
            loss_per_step=(float(res_adsp.losses[0]) - float(res_adsp.losses[-1]))
            / max(res_adsp.total_steps, 1),
        )
    )
    for name, kw in BASELINES:
        sim, res, wall = run_sim(task, profiles, default_policy(name, **kw))
        speedup = 1.0 - res_adsp.convergence_time / res.convergence_time if res.converged else float("nan")
        rows.append(
            row(
                f"fig4_convergence/{name}", wall, res.elapsed,
                convergence_time=res.convergence_time,
                steps=res.total_steps, commits=res.total_commits,
                final_loss=float(res.losses[-1]),
                adsp_speedup=speedup,
            )
        )
    return rows
