"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--full`` enables the larger
paper-scale sweeps (more workers / more grid points); default sizes are
CPU-budget versions with identical structure. ``--json PATH`` also
writes the rows as structured records (name / us_per_call / derived
key-values) so the perf trajectory can be tracked as ``BENCH_*.json``
artifacts and diffed across commits. ``--snapshot`` writes the same
record to the next numbered ``BENCH_<n>.json`` in the repo root — the
append-only perf history ``benchmarks.compare`` diffs against.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

MODULES = [
    "fig1_waiting",
    "fig3_commit_rate",
    "fig4_convergence",
    "fig5_heterogeneity",
    "fig6_latency",
    "appendix_extras",
    "bench_kernels",
    "bench_transport",
    "bench_shards",
    "bench_control",
    "bench_fleet",
    "bench_serve",
    "roofline_table",
]


def next_snapshot_path(root: pathlib.Path | None = None) -> pathlib.Path:
    """Next numbered ``BENCH_<n>.json`` in the repo root (1-based)."""
    root = root or pathlib.Path(__file__).resolve().parent.parent
    taken = set()
    for p in root.glob("BENCH_*.json"):
        suffix = p.stem.split("_", 1)[1]
        if suffix.isdigit():
            taken.add(int(suffix))
    n = max(taken, default=0) + 1
    return root / f"BENCH_{n}.json"


def _parse_row(module: str, line: str) -> dict:
    """'name,us_per_call,k=v;k=v' → structured record."""
    name, us, derived = (line.split(",", 2) + ["", ""])[:3]
    rec = {"module": module, "name": name, "derived": {}}
    try:
        rec["us_per_call"] = float(us)
    except ValueError:
        rec["us_per_call"] = None
    for kv in derived.split(";"):
        if "=" in kv:
            k, v = kv.split("=", 1)
            try:
                rec["derived"][k] = float(v)
            except ValueError:
                rec["derived"][k] = v
    return rec


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", nargs="*", help="subset of modules to run")
    p.add_argument("--full", action="store_true", help="paper-scale sweeps")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="also write structured records to PATH")
    p.add_argument("--snapshot", action="store_true",
                   help="also write the records to the next numbered "
                        "BENCH_<n>.json in the repo root")
    args = p.parse_args(argv)

    mods = args.only if args.only else MODULES
    print("name,us_per_call,derived")
    records: list[dict] = []
    failures = 0
    for name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            rows = mod.main(full=args.full)
            for r in rows:
                print(r, flush=True)
                records.append(_parse_row(name, r))
            print(f"# {name}: {len(rows)} rows in {time.time()-t0:.1f}s",
                  file=sys.stderr, flush=True)
        except Exception as e:  # keep the harness running
            import traceback

            traceback.print_exc()
            print(f"{name}/HARNESS_ERROR,0,error={type(e).__name__}")
            records.append({"module": name, "name": f"{name}/HARNESS_ERROR",
                            "us_per_call": None,
                            "derived": {"error": type(e).__name__,
                                        "error_message": str(e)}})
            failures += 1
    payload = json.dumps({
        "generated_unix": time.time(),
        "modules": list(mods),
        "full": args.full,
        "failures": failures,
        "rows": records,
    }, indent=1)
    targets = []
    if args.json:
        targets.append(pathlib.Path(args.json))
    if args.snapshot:
        targets.append(next_snapshot_path())
    for out in targets:
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(payload)
        print(f"# wrote {out} ({len(records)} rows)", file=sys.stderr, flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
