"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--full`` enables the larger
paper-scale sweeps (more workers / more grid points); default sizes are
CPU-budget versions with identical structure.
"""

from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    "fig1_waiting",
    "fig3_commit_rate",
    "fig4_convergence",
    "fig5_heterogeneity",
    "fig6_latency",
    "appendix_extras",
    "bench_kernels",
    "roofline_table",
]


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", nargs="*", help="subset of modules to run")
    p.add_argument("--full", action="store_true", help="paper-scale sweeps")
    args = p.parse_args(argv)

    mods = args.only if args.only else MODULES
    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            rows = mod.main(full=args.full)
            for r in rows:
                print(r, flush=True)
            print(f"# {name}: {len(rows)} rows in {time.time()-t0:.1f}s",
                  file=sys.stderr, flush=True)
        except Exception as e:  # keep the harness running
            import traceback

            traceback.print_exc()
            print(f"{name}/HARNESS_ERROR,0,error={type(e).__name__}")
            failures += 1
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
