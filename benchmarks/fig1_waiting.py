"""Fig. 1 — training-time breakdown (computation vs waiting) and
convergence time for BSP / SSP / ADACOMM / Fixed ADACOMM / ADSP on the
CNN task with 1:1:3 worker heterogeneity.

Paper claims validated: waiting ≈ half (or more) of wall time under
BSP/SSP; much lower under ADACOMM; negligible under ADSP."""

from __future__ import annotations

from .common import default_policy, row, run_sim, standard_profiles, standard_task

POLICIES = [
    ("bsp", {}),
    ("ssp", {"s": 8}),
    ("adacomm", {}),
    ("fixed_adacomm", {"tau": 8}),
    ("adsp", {"search": True}),
]


def main(full: bool = False) -> list[str]:
    rows = []
    profiles = standard_profiles()
    task = standard_task(len(profiles))
    for name, kw in POLICIES:
        policy = default_policy(name, **kw)
        sim, res, wall = run_sim(task, profiles, policy)
        rows.append(
            row(
                f"fig1_waiting/{name}", wall, res.elapsed,
                waiting_frac=res.waiting_fraction,
                computation_s=res.computation_time,
                waiting_s=res.waiting_time,
                converged=res.converged,
                convergence_time=res.convergence_time,
                avg_step_time=res.elapsed * len(profiles) / max(res.total_steps, 1),
            )
        )
    return rows
