"""§Roofline source: aggregates results/dryrun/*.json into the per
(arch × shape × mesh) roofline table — the three terms in seconds, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and per-chip memory."""

from __future__ import annotations

import json
import pathlib

from .common import row

DRYRUN_DIR = pathlib.Path("results/dryrun")


def load_reports() -> list[dict]:
    out = []
    if DRYRUN_DIR.exists():
        for fp in sorted(DRYRUN_DIR.glob("*.json")):
            out.append(json.loads(fp.read_text()))
    return out


def main(full: bool = False) -> list[str]:
    rows = []
    reports = load_reports()
    if not reports:
        return [row("roofline_table/missing", 0.0, 1.0,
                    note="run python -m repro.launch.dryrun --all --mesh both first")]
    for d in reports:
        tag = f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}"
        if d["status"] == "skipped":
            rows.append(row(tag, 0.0, 1.0, status="skipped", reason=d.get("reason", "")))
            continue
        if d["status"] != "ok":
            rows.append(row(tag, 0.0, 1.0, status=d["status"], error=d.get("error", "")[:80]))
            continue
        r = d["roofline"]
        rows.append(
            row(
                tag, d.get("wall_s", 0.0), 1.0,
                status="ok",
                compute_s=r["compute_s"], memory_s=r["memory_s"],
                collective_s=r["collective_s"], bottleneck=r["bottleneck"],
                useful_ratio=r["useful_flops_ratio"],
                param_gb_chip=d.get("analytic_param_bytes_per_chip", 0) / 1e9,
                variant=d.get("variant_note", ""),
            )
        )
    return rows


def markdown_table() -> str:
    """Render EXPERIMENTS.md §Roofline."""
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| bottleneck | 6ND/HLO | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for d in load_reports():
        if d["status"] == "skipped":
            lines.append(
                f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — | — | "
                f"SKIPPED | — | {d.get('reason','')} |")
            continue
        if d["status"] != "ok":
            lines.append(
                f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — | — | "
                f"ERROR | — | {d.get('error','')[:60]} |")
            continue
        r = d["roofline"]
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {r['compute_s']:.3g} | {r['memory_s']:.3g} "
            f"| {r['collective_s']:.3g} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.3f} | {d.get('variant_note','')} |")
    return "\n".join(lines)
