"""Fleet orchestration benchmark (DESIGN.md §13): discovered failures vs
scripted churn, and lease-tracker scalability.

Scenario A — silent stall on a barrier fleet. A 64-worker BSP fleet
trains to a target loss; one worker *silently stalls* early (no
WorkerLeft — it just goes dark). Three runs:

  * ``oracle``    — the stall is replaced by a scripted WorkerLeft at the
    same instant: the best any failure detector could do.
  * ``lease``     — the stall stays silent, but the heartbeat/lease layer
    (``repro.fleet``) discovers the death at lease expiry and synthesizes
    the departure. Claim: time-to-target within 10 % of the oracle
    (``within_10pct=1``).
  * ``no_lease``  — the stall stays silent and nothing watches: the
    barrier waits for the dead worker forever, so the run never reaches
    the target (``stalled=1``; with a non-barrier policy this would show
    as a >2× slowdown instead).

Scenario B — scheduler value. The same fleet with the capability-aware
``proportional`` scheduler (batch shares follow heartbeat-reported
speeds) vs the static equal split: ``sched_speedup`` = t_conv(static) /
t_conv(scheduled) — on a barrier policy load-balancing the stragglers
directly shortens every round.

Scenario C — ``heartbeat_10k``: a 10 000-worker heartbeat-only fleet
(joins, scattered silent stalls, half recovering in time) driven for an
hour of virtual time directly through the ``FleetMonitor``. Lease expiry
is a *batch* check over statically computed deadlines — no per-worker
timer events — so the whole hour simulates in well under 10 s of wall
time (``under_10s=1``) and exactly the non-recovering stalls are
discovered (``expired_ok=1``).
"""

from __future__ import annotations

import math
import os
import time

import numpy as np

from repro.cluster import ChurnSchedule, churn, make_policy
from repro.control.theory import WorkerProfile
from repro.edgesim import SimConfig, Simulator
from repro.edgesim.profiles import fleet_profiles
from repro.edgesim.tasks import svm_task
from repro.fleet import FleetConfig, FleetMonitor, LeaseConfig, MetricsLog

from .common import row

M = 64
STALL_T = 10.0
STALLED = 5  # worker id that goes dark
TARGET = 0.02
LOCAL_LR = 0.01  # slow convergence so the TTL is amortized, as at scale
LEASE = LeaseConfig(ttl=6.0, heartbeat_period=2.0)
MAX_SECONDS = 600.0


def _run(actions, fleet=None, scheduler=None, metrics=None):
    cfg = SimConfig(max_seconds=MAX_SECONDS, base_batch=32, gamma=20.0,
                    epoch_seconds=300.0, target_loss=TARGET,
                    eval_interval=1.0, local_lr=LOCAL_LR)
    if scheduler is not None:
        fleet = FleetConfig(lease=LEASE, scheduler=scheduler)
    task = svm_task(M, seed=0)
    profiles = fleet_profiles(M, spread=4.0, seed=2, o=0.2)
    t0 = time.time()
    sim = Simulator(task, profiles, make_policy("bsp"), cfg,
                    churn=ChurnSchedule(actions) if actions else None,
                    fleet=fleet, metrics=metrics)
    res = sim.train()
    return sim, res, time.time() - t0


def _heartbeat_10k(m: int = 10_000, horizon: float = 3600.0):
    """Heartbeat-only fleet at 10k scale, driven straight through the
    FleetMonitor (no training physics — this measures the lease layer)."""
    lease = LeaseConfig(ttl=30.0, heartbeat_period=10.0)
    rng = np.random.default_rng(0)
    monitor = FleetMonitor(FleetConfig(lease=lease))
    profile = WorkerProfile(v=1.0, o=0.2)
    t0 = time.time()
    for wid in range(m):
        monitor.join(wid, 0.0, profile)
    stalls = rng.choice(m, size=m // 100, replace=False)
    events = []
    for i, wid in enumerate(stalls):
        ts = float(rng.uniform(0.0, horizon * 0.8))
        events.append((ts, "stall", int(wid)))
        if i % 2 == 0:  # half resume before their lease runs out
            events.append((ts + lease.ttl * 0.25, "recover", int(wid)))
    events.sort()
    discovered: list[int] = []
    for t, kind, wid in events:
        while monitor.next_expiry() <= t:
            discovered.extend(monitor.expired_due(monitor.next_expiry()))
        if kind == "stall":
            monitor.stall(wid, t)
        elif wid in monitor:
            monitor.recover(wid, t)
    while math.isfinite(monitor.next_expiry()) and monitor.next_expiry() <= horizon:
        discovered.extend(monitor.expired_due(monitor.next_expiry()))
    wall = time.time() - t0
    want = len(stalls) - (len(stalls) + 1) // 2  # non-recovering stalls
    return wall, horizon, m, len(discovered), want


def main(full: bool = False) -> list[str]:
    rows = []

    # Scenario A: oracle / lease / no-lease --------------------------------
    _, res_o, wall = _run([churn.leave(STALL_T, STALLED)])
    rows.append(row("bench_fleet/oracle", wall, res_o.elapsed,
                    t_conv=res_o.convergence_time, converged=int(res_o.converged)))

    log = MetricsLog()
    _, res_l, wall = _run([churn.stall(STALL_T, STALLED)],
                          fleet=FleetConfig(lease=LEASE), metrics=log)
    expiries = [r for r in log.of("lease") if r.event == "expired"]
    disc = [r for r in log.of("churn") if r.discovered]
    ratio = res_l.convergence_time / res_o.convergence_time
    rows.append(row(
        "bench_fleet/lease", wall, res_l.elapsed,
        t_conv=res_l.convergence_time, converged=int(res_l.converged),
        discover_t=expiries[0].t if expiries else -1.0,
        discovered=len(disc), ratio_vs_oracle=ratio,
        within_10pct=int(res_l.converged and ratio <= 1.10),
    ))

    # race-validate the lease run's event trace (repro.analysis.dynamic):
    # clock monotonicity, WorkerLeft dedupe, stale-gen deliveries, shard
    # versions — the ordering contracts the lease layer must keep while
    # it discovers the death. REPRO_FLEET_TRACE=<path> exports the JSONL
    # so CI can re-validate the persisted form standalone.
    from repro.analysis.dynamic import validate_records

    violations = validate_records(log.records)
    for v in violations:
        print(f"# bench_fleet/race: {v.render()}")
    rows.append(row(
        "bench_fleet/race", 0.0, res_l.elapsed,
        records=len(log), violations=len(violations),
        race_ok=int(not violations),
    ))
    trace = os.environ.get("REPRO_FLEET_TRACE")
    if trace:
        log.to_jsonl(trace)

    _, res_n, wall = _run([churn.stall(STALL_T, STALLED)])
    slowdown = res_n.convergence_time / res_o.convergence_time
    rows.append(row(
        "bench_fleet/no_lease", wall, res_n.elapsed,
        t_conv=res_n.convergence_time, converged=int(res_n.converged),
        stalled=int(not res_n.converged or slowdown > 2.0),
    ))

    # Scenario B: capability-aware scheduler vs static equal split ---------
    _, res_static, wall_s = _run([])
    _, res_sched, wall_p = _run([], scheduler="proportional")
    rows.append(row(
        "bench_fleet/scheduler", wall_s + wall_p,
        res_static.elapsed + res_sched.elapsed,
        t_conv_static=res_static.convergence_time,
        t_conv_sched=res_sched.convergence_time,
        sched_speedup=res_static.convergence_time / res_sched.convergence_time,
        both_converged=int(res_static.converged and res_sched.converged),
    ))

    # Scenario C: 10k-worker heartbeat-only fleet --------------------------
    m = 10_000 if not full else 50_000
    wall, horizon, workers, got, want = _heartbeat_10k(m=m)
    rows.append(row(
        "bench_fleet/heartbeat_10k", wall, horizon,
        workers=workers, host_seconds=wall, under_10s=int(wall < 10.0),
        discovered=got, expected=want, expired_ok=int(got == want),
    ))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
