"""Compare two benchmark snapshots and flag regressions.

Usage::

    python -m benchmarks.compare BASELINE.json CURRENT.json \
        [--threshold 0.2] [--strict]

Rows are matched by name. Two classes of checks:

  * **Gates** — boolean derived keys where 1 is a pass (``converged``,
    ``within_10pct``, ``expired_ok``, ...). A gate that held in the
    baseline and dropped is always a regression.
  * **Ratios** — machine-*independent* derived metrics with a known
    direction: keys containing ``t_conv``/``ratio``/``waiting`` must not
    rise by more than ``--threshold`` (default 20 %); keys containing
    ``speedup`` must not fall by more than it.
  * **Threshold gates** — keys in ``THRESHOLD_GATES`` must clear an
    absolute floor in the *current* snapshot (e.g. the §16 fused commit
    must stay ≥1.15× the chain). These are within-run ratios, so machine
    speed cancels out of them.

``us_per_call`` (and other host-time quantities) are machine-dependent —
they are reported as info lines but never fail the comparison, so a CI
runner change can't fake a perf regression.

Exit status: 0 unless ``--strict`` is given and regressions were found.
CI runs the non-strict pass on every build (visibility) and the strict
pass against the committed ``BENCH_<n>.json`` history (enforcement).
"""

from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys

# derived keys where the value 1 means "claim held"
GATE_KEYS = {
    "converged", "both_converged", "within_10pct", "expired_ok",
    "under_10s", "before_epoch_end", "drift_no_later", "roundtrip_ok",
    "stalled", "continuous_beats_static_p99",
    "version_tracking_loss_improves", "partial_lt_full", "race_ok",
    "overlap_matches", "chunked_beats_unchunked_p99", "balancer_beats_rr",
}
# derived keys gated against an absolute floor in the CURRENT snapshot
# (not baseline-relative). fused_commit_speedup is a within-run host-time
# ratio — both sides of the division ran in the same process, so machine
# speed cancels and the floor can't be tripped by a slow CI runner.
THRESHOLD_GATES = {
    "fused_commit_speedup": 1.15,
    "dispatch_speedup": 1.15,
}
LOWER_BETTER = ("t_conv", "ratio", "waiting", "probes")
HIGHER_BETTER = ("speedup",)
MACHINE_DEPENDENT = ("us_per_call", "host_seconds", "wall")


def _rows_by_name(path: pathlib.Path) -> dict[str, dict]:
    data = json.loads(path.read_text())
    return {r["name"]: r for r in data.get("rows", [])}


def _num(v):
    if isinstance(v, bool):
        return float(v)
    if isinstance(v, (int, float)):
        return float(v)
    if v == "inf":
        return math.inf
    return None


def compare(baseline: pathlib.Path, current: pathlib.Path,
            threshold: float = 0.2) -> tuple[list[str], list[str]]:
    """Returns (regressions, info_lines)."""
    base, cur = _rows_by_name(baseline), _rows_by_name(current)
    regressions, info = [], []
    for name in sorted(set(base) & set(cur)):
        b, c = base[name]["derived"], cur[name]["derived"]
        for key in sorted(set(b) & set(c)):
            bv, cv = _num(b[key]), _num(c[key])
            if bv is None or cv is None:
                continue
            if any(s in key for s in MACHINE_DEPENDENT):
                continue  # host-time quantities never gate
            if key in GATE_KEYS:
                if bv >= 1.0 > cv:
                    regressions.append(
                        f"{name}: gate {key} dropped {bv:g} -> {cv:g}")
                continue
            if key in THRESHOLD_GATES:
                floor = THRESHOLD_GATES[key]
                if cv < floor:
                    regressions.append(
                        f"{name}: {key} {cv:g} below required {floor:g}")
                elif cv != bv:
                    info.append(f"{name}: {key} {bv:g} -> {cv:g}")
                continue
            # sign-safe relative worsening: |bv| scales the allowance, so
            # negative baselines (e.g. a speedup that was already a
            # slowdown) don't flag equal-or-better values as regressions
            if any(s in key for s in LOWER_BETTER):
                if math.isfinite(bv) and cv - bv > threshold * abs(bv):
                    regressions.append(
                        f"{name}: {key} rose {bv:g} -> {cv:g} "
                        f"(>{threshold:.0%})")
                elif cv != bv:
                    info.append(f"{name}: {key} {bv:g} -> {cv:g}")
            elif any(s in key for s in HIGHER_BETTER):
                if math.isfinite(bv) and bv - cv > threshold * abs(bv):
                    regressions.append(
                        f"{name}: {key} fell {bv:g} -> {cv:g} "
                        f"(>{threshold:.0%})")
                elif cv != bv:
                    info.append(f"{name}: {key} {bv:g} -> {cv:g}")
        # host-time trajectory: informational only
        bus, cus = base[name].get("us_per_call"), cur[name].get("us_per_call")
        if bus and cus and abs(cus - bus) > 0.5 * bus:
            info.append(f"{name}: us_per_call {bus:.0f} -> {cus:.0f} (info)")
    missing = sorted(set(base) - set(cur))
    if missing:
        shown = ", ".join(missing[:5])
        more = f" … +{len(missing) - 5} more" if len(missing) > 5 else ""
        info.append(f"{len(missing)} rows only in baseline "
                    f"(not compared): {shown}{more}")
    return regressions, info


def latest_snapshot(root: pathlib.Path) -> pathlib.Path | None:
    best, best_n = None, -1
    for p in root.glob("BENCH_*.json"):
        suffix = p.stem.split("_", 1)[1]
        if suffix.isdigit() and int(suffix) > best_n:
            best, best_n = p, int(suffix)
    return best


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("baseline", nargs="?", default=None,
                   help="baseline snapshot (default: highest BENCH_<n>.json)")
    p.add_argument("current", help="snapshot to check")
    p.add_argument("--threshold", type=float, default=0.2,
                   help="relative worsening that counts as a regression")
    p.add_argument("--strict", action="store_true",
                   help="exit 1 on regressions (default: report only)")
    args = p.parse_args(argv)

    baseline = (pathlib.Path(args.baseline) if args.baseline
                else latest_snapshot(pathlib.Path(__file__).resolve().parent.parent))
    if baseline is None or not baseline.exists():
        print("# no baseline snapshot found; nothing to compare")
        return
    current = pathlib.Path(args.current)
    regressions, info = compare(baseline, current, args.threshold)
    print(f"# baseline={baseline.name} current={current.name} "
          f"threshold={args.threshold:.0%}")
    for line in info:
        print(f"INFO  {line}")
    for line in regressions:
        print(f"REGRESSION  {line}")
    if not regressions:
        print("# no regressions")
    elif args.strict:
        sys.exit(1)


if __name__ == "__main__":
    main()
