"""Transport-layer benchmark: codec micro-costs + the bytes-vs-convergence
tradeoff on a bandwidth-constrained fleet.

Two row families:

  * ``transport/codec_*`` — encode/decode wall time (µs/call, interpret
    mode on CPU: structure cost only, not TPU predictions) and the
    measured encoded payload bytes per codec/backend. These rows are the
    CI smoke gate for the transport layer.
  * ``transport/tradeoff_*`` — the ADSP simulator on a link-constrained
    heterogeneous fleet, one run per codec: wire bytes to the PS vs
    convergence time. On links where the straggler is the link, not the
    chip, compressed commits must reduce bytes without hurting (and
    typically improving) convergence time.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.edgesim import SimConfig, Simulator
from repro.edgesim.profiles import ratio_profiles, with_links
from repro.edgesim.tasks import cnn_task
from repro.transport import codec_backends, dense_nbytes, get_codec

from .common import GAMMA, row


def _time(fn, *args, iters=3):
    out = fn(*args)  # compile/warm
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def _codec_micro_rows(full: bool) -> list[str]:
    n = (1 << 20) if full else (1 << 16)
    rng = np.random.default_rng(0)
    u = {
        "w": jnp.asarray(rng.normal(size=(n,)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(257,)), jnp.float32),  # ragged tail
    }
    dense = dense_nbytes(u)
    rows = []
    for name in ("identity", "int8", "bf16", "top_k"):
        for backend in codec_backends(name):
            codec = get_codec(name, backend=backend)
            state = codec.init(u)
            enc_fn = jax.jit(codec.encode) if name != "identity" else codec.encode
            t_enc = _time(lambda: enc_fn(u, state))
            enc, _ = enc_fn(u, state)
            dec_fn = jax.jit(codec.decode) if name != "identity" else codec.decode
            t_dec = _time(lambda: dec_fn(enc, u))
            nbytes = codec.encoded_nbytes(u)
            rows.append(row(
                f"transport/codec_{name}_{codec.backend}", t_enc + t_dec, 1.0,
                encode_us=1e6 * t_enc, decode_us=1e6 * t_dec,
                encoded_bytes=nbytes, ratio=dense / max(nbytes, 1),
                elems=n + 257,
            ))
    return rows


def _tradeoff_rows(full: bool) -> list[str]:
    """ADSP on a fleet whose links, not chips, are the stragglers."""
    m = 6 if full else 3
    target = 0.75
    max_seconds = 3000.0 if full else 1500.0
    rows = []
    for name in ("identity", "int8", "top_k"):
        task = cnn_task(m, width=8)
        # size the link so a dense commit costs ~2 virtual seconds of
        # transfer (10× the fixed o/2): the link dominates the commit
        dense = dense_nbytes(task.init_params)
        profiles = with_links(
            ratio_profiles((1,) * (m - 1) + (3,), base_v=1.0, o=0.2),
            bandwidth=dense / 2.0, latency=0.01,
        )
        cfg = SimConfig(gamma=GAMMA, epoch_seconds=200.0, base_batch=32,
                        target_loss=target, max_seconds=max_seconds,
                        local_lr=0.05)
        from repro.cluster import make_policy

        t0 = time.time()
        sim = Simulator(task, profiles, make_policy("adsp", search=False, gamma=GAMMA),
                        cfg, codec=name)
        res = sim.train()
        wall = time.time() - t0
        rows.append(row(
            f"transport/tradeoff_{name}", wall, max(res.elapsed, 1e-9),
            bytes_to_ps=res.bytes_to_ps,
            encoded_bytes_per_commit=sim._enc_nbytes,
            t_conv=res.convergence_time if res.converged else float("inf"),
            converged=int(res.converged),
            final_loss=float(res.losses[-1]),
            commits=res.total_commits,
            waiting_frac=res.waiting_fraction,
        ))
    return rows


def main(full: bool = False) -> list[str]:
    return _codec_micro_rows(full) + _tradeoff_rows(full)
