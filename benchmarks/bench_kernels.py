"""Pallas kernel micro-benchmarks (interpret mode on CPU — correctness +
host-side cost only; wall numbers are NOT TPU predictions, the roofline
table carries those)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import row, time_fn as _time


def main(full: bool = False) -> list[str]:
    rows = []
    rng = np.random.default_rng(0)

    b, s, hq, hkv, d = 1, 256, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    t = _time(lambda *a: ops.flash_attention(*a, block_q=128, block_k=128), q, k, v)
    err = float(jnp.max(jnp.abs(
        ops.flash_attention(q, k, v, block_q=128, block_k=128) - ref.flash_attention(q, k, v))))
    rows.append(row("kernels/flash_attention", t, 1.0, max_err=err,
                    shape=f"b{b}s{s}h{hq}d{d}"))

    a_ = jnp.asarray(rng.uniform(0.8, 0.999, size=(2, 512, 256)), jnp.float32)
    b_ = jnp.asarray(rng.normal(size=(2, 512, 256)) * 0.1, jnp.float32)
    t = _time(lambda *x: ops.rglru_scan(*x, block_w=256, block_s=128), a_, b_)
    err = float(jnp.max(jnp.abs(ops.rglru_scan(a_, b_, block_w=256, block_s=128) - ref.rglru_scan(a_, b_))))
    rows.append(row("kernels/rglru_scan", t, 1.0, max_err=err, shape="2x512x256"))

    r = jnp.asarray(rng.normal(size=(1, 256, 2, 16)) * 0.5, jnp.float32)
    kk = jnp.asarray(rng.normal(size=(1, 256, 2, 16)) * 0.5, jnp.float32)
    vv = jnp.asarray(rng.normal(size=(1, 256, 2, 16)) * 0.5, jnp.float32)
    w = jnp.asarray(rng.uniform(0.9, 0.999, size=(1, 256, 2, 16)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(2, 16)) * 0.1, jnp.float32)
    t = _time(lambda *x: ops.rwkv6_scan(*x, block_s=64)[0], r, kk, vv, w, u)
    err = float(jnp.max(jnp.abs(ops.rwkv6_scan(r, kk, vv, w, u, block_s=64)[0]
                                - ref.rwkv6_scan(r, kk, vv, w, u)[0])))
    rows.append(row("kernels/rwkv6_scan", t, 1.0, max_err=err, shape="1x256x2x16"))

    tree = {"w": jnp.asarray(rng.normal(size=(1 << 16,)), jnp.float32)}
    g = jax.tree.map(lambda x: x * 0.3, tree)
    t = _time(lambda *x: ops.accumulate_tree(*x, 0.05), tree, g)
    rows.append(row("kernels/fused_accumulate", t, 1.0, elems=1 << 16))
    d0 = jax.tree.map(jnp.zeros_like, tree)
    t = _time(lambda *x: ops.ps_apply_tree(*x, 0.1, 0.9)[0], tree, d0, g)
    rows.append(row("kernels/fused_ps_apply", t, 1.0, elems=1 << 16))
    rows.extend(_bench_train_step_backends())
    rows.extend(_bench_fused_commit_round())
    return rows


def _bench_fused_commit_round() -> list[str]:
    """The PS pull side of one commit round, chain vs fused (§16): the
    chain is two host dispatches (codec decode, then commit apply); the
    combined ``momentum_delta@int8`` rule is one. ``fused_commit_speedup``
    is a within-run host-time ratio — both sides run in the same process
    seconds apart, so machine speed cancels and CI can gate on it."""
    from repro.ps import CommitConfig, get_commit_rule
    from repro.transport import get_codec

    rng = np.random.default_rng(0)
    n = 1 << 20
    w = {"w": jnp.asarray(rng.normal(size=(n,)), jnp.float32)}
    u = jax.tree.map(lambda x: x * 0.05 + 0.01, w)
    cfg = CommitConfig(tau=1, global_lr=0.7, worker_axes=())
    codec = get_codec("int8", backend="reference")
    enc, _ = jax.jit(codec.encode)(u, jax.tree.map(jnp.zeros_like, u))
    jax.block_until_ready(enc)
    chain_rule = get_commit_rule("momentum_delta", cfg, backend="fused")
    fused_rule = get_commit_rule("momentum_delta@int8", cfg, backend="fused")
    cstate = chain_rule.init(w)
    decode = jax.jit(lambda e: codec.decode(e, w))
    apply_chain = jax.jit(lambda p, c, uu: chain_rule.apply(p, c, uu, 0.9))
    apply_fused = jax.jit(lambda p, c, e: fused_rule.apply(p, c, e, 0.9))

    dispatches = {"ref": 0, "fused": 0}

    def ref_round():
        dispatches["ref"] += 2
        return apply_chain(w, cstate, decode(enc))

    def fused_round():
        dispatches["fused"] += 1
        return apply_fused(w, cstate, enc)

    t_ref = _time(ref_round, iters=5)
    n_ref = dispatches["ref"] / (5 + 1)  # warmup + timed calls
    t_fused = _time(fused_round, iters=5)
    n_fused = dispatches["fused"] / (5 + 1)
    return [row(
        "kernels/fused_commit_round", t_fused, 1.0,
        fused_commit_speedup=t_ref / t_fused,
        dispatch_speedup=n_ref / n_fused,
        dispatches_ref=n_ref, dispatches_fused=n_fused,
        elems=n,
    )]


def _bench_train_step_backends() -> list[str]:
    """The unified train step end-to-end, reference vs Pallas-fused rule
    backend (the fused kernels on their actual hot path, not only as
    isolated ops). Interpret mode on CPU: structure cost only."""
    from repro.compat import use_mesh
    from repro.ps import CommitConfig, UpdateRules, make_train_step

    def quad_loss(params, batch):
        x, y = batch
        return jnp.mean((x @ params["w"] - y) ** 2)

    rng = np.random.default_rng(0)
    dim = 64
    x = jnp.asarray(rng.normal(size=(32, dim)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(32, 1)), jnp.float32)
    mbs = (jnp.stack([x, x]), jnp.stack([y, y]))
    params = {"w": jnp.asarray(rng.normal(size=(dim, 1)) * 0.1, jnp.float32)}
    cfg = CommitConfig(tau=2, local_lr=0.05, worker_axes=("data",))
    mesh = jax.make_mesh((1,), ("data",))
    tau = jnp.asarray([2], jnp.int32)

    out = []
    with use_mesh(mesh):
        for backend in ("reference", "fused"):
            step_fn = make_train_step(
                quad_loss, cfg, UpdateRules(backend=backend), mesh=mesh)
            state = step_fn.init(params)
            step = jax.jit(step_fn)
            t = _time(lambda s: step(s, mbs, tau)[1], state)
            out.append(row(f"ps/train_step_sgd_{backend}", t, 1.0,
                           tau=2, dim=dim))
    return out
