"""Sharded-PS benchmark: pull bytes and convergence time vs shard count K
on a link-bound fleet (DESIGN.md §11).

One ADSP run per K, identical task/fleet/policy/seed. K=1 is the
monolithic PS (bit-identical to the pre-sharding stack): every pull
ships the full dense model. K>1 partitions the model into versioned
shards — per-shard push payloads pipeline FIFO over each worker's link
and pulls fetch only shards whose PS version moved past the worker's
local copy, so ``bytes_from_ps`` shrinks while convergence time stays
equal or improves (stale shards ship sooner, fresh shards don't ship at
all). Push bytes (``bytes_to_ps``) are invariant in K: every built-in
codec is leaf-wise, so the per-shard encodes partition the lumped one.

These rows are the CI smoke gate for the sharding layer.
"""

from __future__ import annotations

import time

from repro.cluster import make_policy
from repro.edgesim import SimConfig, Simulator
from repro.edgesim.profiles import ratio_profiles, with_links
from repro.edgesim.tasks import cnn_task
from repro.transport import dense_nbytes

from .common import GAMMA, row


def _shard_rows(full: bool) -> list[str]:
    m = 3
    target = 0.75
    max_seconds = 4000.0
    shard_counts = (1, 2, 4, 8, 16) if full else (1, 2, 4, 8)
    rows = []
    baseline_pull = baseline_per_commit = None
    for k in shard_counts:
        task = cnn_task(m, width=8)
        # strongly link-bound: a dense transfer costs ~8 virtual seconds
        # (40× the fixed o/2) — the regime where pull time is the dominant
        # commit cost and partial pulls pay off directly
        dense = dense_nbytes(task.init_params)
        profiles = with_links(
            ratio_profiles((1,) * (m - 1) + (3,), base_v=1.0, o=0.2),
            bandwidth=dense / 16.0, latency=0.01,
        )
        cfg = SimConfig(gamma=GAMMA, epoch_seconds=200.0, base_batch=32,
                        target_loss=target, max_seconds=max_seconds,
                        local_lr=0.05, eval_interval=2.0)
        t0 = time.time()
        sim = Simulator(
            task, profiles, make_policy("adsp", search=False, gamma=GAMMA),
            cfg, codec="identity", n_shards=k,
        )
        res = sim.train()
        wall = time.time() - t0
        per_commit = res.bytes_from_ps / max(res.total_commits, 1)
        if k == 1:
            baseline_pull = res.bytes_from_ps
            baseline_per_commit = per_commit
        rows.append(row(
            f"shards/K{k}", wall, max(res.elapsed, 1e-9),
            n_shards=sim.n_shards,
            bytes_from_ps=res.bytes_from_ps,
            bytes_to_ps=res.bytes_to_ps,
            pull_ratio=(res.bytes_from_ps / baseline_pull
                        if baseline_pull else float("nan")),
            pull_per_commit_ratio=(per_commit / baseline_per_commit
                                   if baseline_per_commit else float("nan")),
            t_conv=res.convergence_time if res.converged else float("inf"),
            converged=int(res.converged),
            final_loss=float(res.losses[-1]),
            commits=res.total_commits,
            waiting_frac=res.waiting_fraction,
        ))
    return rows


def _overlap_row() -> list[str]:
    """Overlapped per-shard commit on the real mesh backend (§16): one
    ADSP round as a single monolithic fused dispatch vs push + K pull
    dispatches with no host sync between shards. The wall ratio is
    informational (CPU interpret mode has no transfer to hide — the win
    is on TPU where shard k+1's payload moves while shard k applies);
    the ``overlap_matches`` gate pins that both schedules produce the
    same params to a few ulps (bit-equality across the two jit
    partitionings is up to the compiler: splitting push from pull shifts
    XLA fusion decisions inside the local scan)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.cluster import ADSP, ClusterEngine
    from repro.cluster.mesh_backend import MeshBackend, MeshTask
    from repro.compat import use_mesh

    from .common import time_fn

    rng = np.random.default_rng(0)
    dim = 256
    x = jnp.asarray(rng.normal(size=(32, dim)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(32, 1)), jnp.float32)

    def loss_fn(params, batch):
        xb, yb = batch
        return jnp.mean((xb @ params["w1"] @ params["w2"] - yb) ** 2)

    task = MeshTask(
        init_params={"w1": jnp.asarray(rng.normal(size=(dim, dim)) * 0.05,
                                       jnp.float32),
                     "w2": jnp.asarray(rng.normal(size=(dim, 1)) * 0.05,
                                       jnp.float32)},
        loss_fn=loss_fn,
        make_microbatches=lambda r, tau, n: (jnp.stack([x] * tau),
                                             jnp.stack([y] * tau)),
    )
    mesh = jax.make_mesh((1,), ("data",))
    walls, params = {}, {}
    for name, overlap in (("mono", False), ("overlap", True)):
        backend = MeshBackend(task, mesh, tau=2, codec="bf16", n_shards=2,
                              fused_commit=True, overlap_shards=overlap)
        ClusterEngine(ADSP(search=False, gamma=4.0), backend)
        assert backend.fused_commit and backend.overlap_shards == overlap
        with use_mesh(mesh):
            walls[name] = time_fn(backend.run_round, iters=5, warmup=2)
        params[name] = backend.state.params
    match = all(
        np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                    rtol=1e-5, atol=1e-6)
        for a, b in zip(jax.tree.leaves(params["mono"]),
                        jax.tree.leaves(params["overlap"])))
    return [row(
        "shards/overlap_mesh", walls["overlap"], 1.0,
        overlap_matches=int(match),
        overlap_wall_ratio=walls["overlap"] / walls["mono"],
        n_shards=2, pull_dispatches_per_round=2,
    )]


def main(full: bool = False) -> list[str]:
    return _shard_rows(full) + _overlap_row()
