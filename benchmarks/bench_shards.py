"""Sharded-PS benchmark: pull bytes and convergence time vs shard count K
on a link-bound fleet (DESIGN.md §11).

One ADSP run per K, identical task/fleet/policy/seed. K=1 is the
monolithic PS (bit-identical to the pre-sharding stack): every pull
ships the full dense model. K>1 partitions the model into versioned
shards — per-shard push payloads pipeline FIFO over each worker's link
and pulls fetch only shards whose PS version moved past the worker's
local copy, so ``bytes_from_ps`` shrinks while convergence time stays
equal or improves (stale shards ship sooner, fresh shards don't ship at
all). Push bytes (``bytes_to_ps``) are invariant in K: every built-in
codec is leaf-wise, so the per-shard encodes partition the lumped one.

These rows are the CI smoke gate for the sharding layer.
"""

from __future__ import annotations

import time

from repro.cluster import make_policy
from repro.edgesim import SimConfig, Simulator
from repro.edgesim.profiles import ratio_profiles, with_links
from repro.edgesim.tasks import cnn_task
from repro.transport import dense_nbytes

from .common import GAMMA, row


def _shard_rows(full: bool) -> list[str]:
    m = 3
    target = 0.75
    max_seconds = 4000.0
    shard_counts = (1, 2, 4, 8, 16) if full else (1, 2, 4, 8)
    rows = []
    baseline_pull = baseline_per_commit = None
    for k in shard_counts:
        task = cnn_task(m, width=8)
        # strongly link-bound: a dense transfer costs ~8 virtual seconds
        # (40× the fixed o/2) — the regime where pull time is the dominant
        # commit cost and partial pulls pay off directly
        dense = dense_nbytes(task.init_params)
        profiles = with_links(
            ratio_profiles((1,) * (m - 1) + (3,), base_v=1.0, o=0.2),
            bandwidth=dense / 16.0, latency=0.01,
        )
        cfg = SimConfig(gamma=GAMMA, epoch_seconds=200.0, base_batch=32,
                        target_loss=target, max_seconds=max_seconds,
                        local_lr=0.05, eval_interval=2.0)
        t0 = time.time()
        sim = Simulator(
            task, profiles, make_policy("adsp", search=False, gamma=GAMMA),
            cfg, codec="identity", n_shards=k,
        )
        res = sim.train()
        wall = time.time() - t0
        per_commit = res.bytes_from_ps / max(res.total_commits, 1)
        if k == 1:
            baseline_pull = res.bytes_from_ps
            baseline_per_commit = per_commit
        rows.append(row(
            f"shards/K{k}", wall, max(res.elapsed, 1e-9),
            n_shards=sim.n_shards,
            bytes_from_ps=res.bytes_from_ps,
            bytes_to_ps=res.bytes_to_ps,
            pull_ratio=(res.bytes_from_ps / baseline_pull
                        if baseline_pull else float("nan")),
            pull_per_commit_ratio=(per_commit / baseline_per_commit
                                   if baseline_per_commit else float("nan")),
            t_conv=res.convergence_time if res.converged else float("inf"),
            converged=int(res.converged),
            final_loss=float(res.losses[-1]),
            commits=res.total_commits,
            waiting_frac=res.waiting_fraction,
        ))
    return rows


def main(full: bool = False) -> list[str]:
    return _shard_rows(full)
