"""Serving benchmark (DESIGN.md §14): continuous batching vs static
rebatching, and version-tracking pulls from a live training PS.

Scenario A — ``continuous_vs_static``. The same open-loop Poisson trace
(32 requests, 40 req/s offered) is served twice on identical virtual
hardware (same ``CostModel``, same slot count): once with continuous
batching (per-step eviction + immediate backfill) and once with static
rebatching (a batch is admitted only when the pool has fully drained, so
finished slots idle until the slowest request in the batch completes).
Claim (``continuous_beats_static_p99=1``): continuous wins p99 total
latency AND goodput (SLO-attained requests per virtual second) — the
win is purely scheduling, not speed, since every decode step costs the
same in both modes.

Scenario B — ``version_tracking``. A ``ShardedTrainer`` commits AdamW
steps to a live 4-shard PS with pipelined per-shard applies while the
engine serves the same trace, polling between decode steps and pulling
only version-stale shards. Claims: the loss of the *served* params
improves over the run (``version_tracking_loss_improves=1``) and the
bytes pulled are strictly below what version-oblivious dense re-pulls
would have moved at the same poll points (``partial_lt_full=1``).
"""

from __future__ import annotations

import time

import jax

from repro.configs import get_smoke
from repro.models import lm
from repro.serve import (ReplicaSync, ServeConfig, ServeEngine, ShardedTrainer,
                         TraceConfig, make_trace)

from .common import row

ARCH = "rwkv6-3b"  # O(1) recurrent slots: the cheapest family to pool
SLOTS = 4
N_SHARDS = 4


def _trace(n_requests: int, seed: int = 0):
    return make_trace("poisson", TraceConfig(
        n_requests=n_requests, rate=40.0, prompt_lens=(8, 16),
        max_new=(4, 12), slo_ms=400.0, seed=seed))


def continuous_vs_static(full: bool):
    cfg = get_smoke(ARCH)
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    trace = _trace(64 if full else 32)
    reports, wall = {}, 0.0
    for mode in ("continuous", "static"):
        t0 = time.time()
        rep = ServeEngine(cfg, params,
                          ServeConfig(slots=SLOTS, mode=mode), trace).run()
        wall += time.time() - t0
        reports[mode] = rep
    cont, stat = reports["continuous"], reports["static"]
    ok = (cont.percentile("total", 0.99) < stat.percentile("total", 0.99)
          and cont.goodput > stat.goodput)
    return [row(
        "serve/continuous_vs_static", wall, cont.t_end + stat.t_end,
        p99_continuous=cont.percentile("total", 0.99),
        p99_static=stat.percentile("total", 0.99),
        goodput_continuous=cont.goodput,
        goodput_static=stat.goodput,
        slo_continuous=cont.slo_attainment,
        slo_static=stat.slo_attainment,
        steps_continuous=cont.decode_steps,
        steps_static=stat.decode_steps,
        continuous_beats_static_p99=int(ok),
    )]


def version_tracking(full: bool):
    cfg = get_smoke(ARCH)
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    trace = _trace(48 if full else 24, seed=1)
    trainer = ShardedTrainer(cfg, params, n_shards=N_SHARDS, commit_every=0.05)
    sync = ReplicaSync(params, lambda: trainer.state, n_shards=N_SHARDS)
    loss_first = trainer.eval_loss(params)
    t0 = time.time()
    engine = ServeEngine(
        cfg, params, ServeConfig(slots=SLOTS, sync_every=2), trace,
        sync=sync, tick=lambda eng, t: trainer.advance(t))
    rep = engine.run()
    wall = time.time() - t0
    loss_last = trainer.eval_loss(engine.params)
    return [row(
        "serve/version_tracking", wall, rep.t_end,
        loss_first=loss_first, loss_last=loss_last,
        commits=trainer.commits,
        pulls=rep.sync_pulls, polls=rep.sync_polls,
        pull_mb=rep.pull_bytes / 1e6,
        full_pull_mb=rep.full_pull_bytes / 1e6,
        version_tracking_loss_improves=int(loss_last < loss_first),
        partial_lt_full=int(0 < rep.pull_bytes < rep.full_pull_bytes),
    )]


def main(full: bool = False):
    return continuous_vs_static(full) + version_tracking(full)


if __name__ == "__main__":
    for r in main():
        print(r)
