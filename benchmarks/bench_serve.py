"""Serving benchmark (DESIGN.md §14): continuous batching vs static
rebatching, and version-tracking pulls from a live training PS.

Scenario A — ``continuous_vs_static``. The same open-loop Poisson trace
(32 requests, 40 req/s offered) is served twice on identical virtual
hardware (same ``CostModel``, same slot count): once with continuous
batching (per-step eviction + immediate backfill) and once with static
rebatching (a batch is admitted only when the pool has fully drained, so
finished slots idle until the slowest request in the batch completes).
Claim (``continuous_beats_static_p99=1``): continuous wins p99 total
latency AND goodput (SLO-attained requests per virtual second) — the
win is purely scheduling, not speed, since every decode step costs the
same in both modes.

Scenario B — ``version_tracking``. A ``ShardedTrainer`` commits AdamW
steps to a live 4-shard PS with pipelined per-shard applies while the
engine serves the same trace, polling between decode steps and pulling
only version-stale shards. Claims: the loss of the *served* params
improves over the run (``version_tracking_loss_improves=1``) and the
bytes pulled are strictly below what version-oblivious dense re-pulls
would have moved at the same poll points (``partial_lt_full=1``).

Scenario C — ``chunked_p99``. A bursty heavy-tail trace (most prompts
8–16 tokens, a few 96-token stragglers) is served twice: monolithic
prefill vs chunked prefill (16-token chunks, 2 lanes) where chunks ride
busy decode steps at marginal per-token cost (§17 piggyback pricing).
Claim (``chunked_beats_unchunked_p99=1``): chunking strictly improves
p99 total latency — a straggler prompt no longer stalls the decode pool
for its whole prefill, the serving-side analogue of ADSP's never-wait.

Scenario D — ``replica_goodput``. The same heavy-tail trace (tail up to
256 tokens) is routed to 2 engine replicas on one virtual clock by each
router policy. Claim (``balancer_beats_rr=1``): work-aware routing
(``deadline_slack``, which prices each replica's backlog through the
cost model) beats both a single replica and blind ``round_robin`` on
goodput — counting requests equally is exactly what heavy tails break.

The gated traces in C and D are identical in smoke and ``--full`` runs:
the claims are properties of a fixed deterministic scenario, not of
scale, and keeping them fixed makes the gates mode-independent.
"""

from __future__ import annotations

import time

import jax

from repro.configs import get_smoke
from repro.models import lm
from repro.serve import (LoadBalancer, ReplicaSync, ServeConfig, ServeEngine,
                         ShardedTrainer, TraceConfig, make_trace)

from .common import row

ARCH = "rwkv6-3b"  # O(1) recurrent slots: the cheapest family to pool
SLOTS = 4
N_SHARDS = 4


def _trace(n_requests: int, seed: int = 0):
    return make_trace("poisson", TraceConfig(
        n_requests=n_requests, rate=40.0, prompt_lens=(8, 16),
        max_new=(4, 12), slo_ms=400.0, seed=seed))


def continuous_vs_static(full: bool):
    cfg = get_smoke(ARCH)
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    trace = _trace(64 if full else 32)
    reports, wall = {}, 0.0
    for mode in ("continuous", "static"):
        t0 = time.time()
        rep = ServeEngine(cfg, params,
                          ServeConfig(slots=SLOTS, mode=mode), trace).run()
        wall += time.time() - t0
        reports[mode] = rep
    cont, stat = reports["continuous"], reports["static"]
    ok = (cont.percentile("total", 0.99) < stat.percentile("total", 0.99)
          and cont.goodput > stat.goodput)
    return [row(
        "serve/continuous_vs_static", wall, cont.t_end + stat.t_end,
        p99_continuous=cont.percentile("total", 0.99),
        p99_static=stat.percentile("total", 0.99),
        goodput_continuous=cont.goodput,
        goodput_static=stat.goodput,
        slo_continuous=cont.slo_attainment,
        slo_static=stat.slo_attainment,
        steps_continuous=cont.decode_steps,
        steps_static=stat.decode_steps,
        continuous_beats_static_p99=int(ok),
    )]


def version_tracking(full: bool):
    cfg = get_smoke(ARCH)
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    trace = _trace(48 if full else 24, seed=1)
    trainer = ShardedTrainer(cfg, params, n_shards=N_SHARDS, commit_every=0.05)
    sync = ReplicaSync(params, lambda: trainer.state, n_shards=N_SHARDS)
    loss_first = trainer.eval_loss(params)
    t0 = time.time()
    engine = ServeEngine(
        cfg, params, ServeConfig(slots=SLOTS, sync_every=2), trace,
        sync=sync, tick=lambda eng, t: trainer.advance(t))
    rep = engine.run()
    wall = time.time() - t0
    loss_last = trainer.eval_loss(engine.params)
    return [row(
        "serve/version_tracking", wall, rep.t_end,
        loss_first=loss_first, loss_last=loss_last,
        commits=trainer.commits,
        pulls=rep.sync_pulls, polls=rep.sync_polls,
        pull_mb=rep.pull_bytes / 1e6,
        full_pull_mb=rep.full_pull_bytes / 1e6,
        version_tracking_loss_improves=int(loss_last < loss_first),
        partial_lt_full=int(0 < rep.pull_bytes < rep.full_pull_bytes),
    )]


def _heavy_tail_trace(seed: int, rate: float = 40.0,
                      prompt_lens=(8, 16, 96), prompt_weights=(8, 8, 1)):
    """Bursty trace where most prompts are short and a few are long
    stragglers — the shape that exposes prefill head-of-line blocking
    (C) and blind round-robin routing (D). Fixed size: see docstring."""
    return make_trace("bursty", TraceConfig(
        n_requests=32, rate=rate, prompt_lens=prompt_lens,
        prompt_weights=prompt_weights, max_new=(4, 12), slo_ms=400.0,
        seed=seed, burst_factor=4.0, burst_duty=0.25, burst_period=2.0))


def chunked_p99(full: bool):
    cfg = get_smoke(ARCH)
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    trace = _heavy_tail_trace(seed=0)
    t0 = time.time()
    mono = ServeEngine(cfg, params, ServeConfig(slots=SLOTS), trace).run()
    chunked = ServeEngine(
        cfg, params,
        ServeConfig(slots=SLOTS, prefill_chunk=16, prefill_batch=2),
        trace).run()
    wall = time.time() - t0
    ok = (chunked.percentile("total", 0.99)
          < mono.percentile("total", 0.99))
    return [row(
        "serve/chunked_p99", wall, mono.t_end + chunked.t_end,
        p99_monolithic=mono.percentile("total", 0.99),
        p99_chunked=chunked.percentile("total", 0.99),
        goodput_monolithic=mono.goodput,
        goodput_chunked=chunked.goodput,
        chunk_dispatches=chunked.chunk_dispatches,
        steps_monolithic=mono.decode_steps,
        steps_chunked=chunked.decode_steps,
        chunked_beats_unchunked_p99=int(ok),
    )]


def replica_goodput(full: bool):
    cfg = get_smoke(ARCH)
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    trace = _heavy_tail_trace(seed=2, rate=60.0,
                              prompt_lens=(8, 16, 96, 256),
                              prompt_weights=(8, 8, 1, 1))
    scfg = ServeConfig(slots=SLOTS)
    t0 = time.time()
    single = ServeEngine(cfg, params, scfg, trace).run()
    rr = LoadBalancer(cfg, params, scfg, trace, n_replicas=2,
                      router="round_robin").run().merged
    ds = LoadBalancer(cfg, params, scfg, trace, n_replicas=2,
                      router="deadline_slack").run().merged
    wall = time.time() - t0
    ok = ds.goodput > max(single.goodput, rr.goodput)
    return [row(
        "serve/replica_goodput", wall, single.t_end + rr.t_end + ds.t_end,
        goodput_single=single.goodput,
        goodput_round_robin=rr.goodput,
        goodput_deadline_slack=ds.goodput,
        p99_single=single.percentile("total", 0.99),
        p99_round_robin=rr.percentile("total", 0.99),
        p99_deadline_slack=ds.percentile("total", 0.99),
        balancer_beats_rr=int(ok),
    )]


def main(full: bool = False):
    return (continuous_vs_static(full) + version_tracking(full)
            + chunked_p99(full) + replica_goodput(full))


if __name__ == "__main__":
    for r in main():
        print(r)
