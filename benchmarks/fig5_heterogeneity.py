"""Fig. 5 — adaptability to heterogeneity degree H and system scale.

(a–e) ADSP vs Fixed ADACOMM at H ∈ {1, 1.6, 2.4, 3.2} (6 workers);
(f) scalability: larger worker pool (12 workers; 18/36 with --full),
hardware mix following the paper's Table 1 distribution."""

from __future__ import annotations

from repro.edgesim.profiles import ec2_profiles, heterogeneity_profiles

from .common import default_policy, row, run_sim, standard_task

H_LEVELS = [1.0, 1.6, 2.4, 3.2]


def main(full: bool = False) -> list[str]:
    rows = []
    m = 6
    for H in H_LEVELS:
        profiles = heterogeneity_profiles(m, H, base_v=2.0, o=0.2)
        task = standard_task(m)
        times = {}
        for name, kw in (("adsp", {"search": True}), ("fixed_adacomm", {"tau": 8})):
            sim, res, wall = run_sim(task, profiles, default_policy(name, **kw))
            times[name] = res.convergence_time
            rows.append(
                row(
                    f"fig5_heterogeneity/H{H}/{name}", wall, res.elapsed,
                    H=H, convergence_time=res.convergence_time,
                    converged=res.converged, waiting_frac=res.waiting_fraction,
                )
            )
        speedup = 1 - times["adsp"] / times["fixed_adacomm"]
        rows.append(row(f"fig5_heterogeneity/H{H}/speedup", 0.0, 1.0,
                        H=H, adsp_vs_fixed_speedup=speedup))
    # scalability
    for m in ([12, 18] if full else [12]):
        profiles = ec2_profiles(o=0.2, scale=0.5)[:m]
        task = standard_task(m)
        for name, kw in (("adsp", {"search": True}), ("fixed_adacomm", {"tau": 8})):
            sim, res, wall = run_sim(task, profiles, default_policy(name, **kw))
            rows.append(
                row(
                    f"fig5_scalability/m{m}/{name}", wall, res.elapsed,
                    workers=m, convergence_time=res.convergence_time,
                    converged=res.converged,
                )
            )
    return rows
