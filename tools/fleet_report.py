"""Summarize a fleet metrics stream (JSONL) into a per-run report.

Usage::

    PYTHONPATH=src python tools/fleet_report.py run_metrics.jsonl

The stream is whatever a run's sink captured (``--metrics`` on
``repro.launch.train``, or ``MetricsLog.to_jsonl`` from a simulator run):
typed records from ``repro.fleet.metrics``. The report shows the fleet
story of the run — per-worker commit traffic and latency, shard
staleness, lease/churn life cycles, searches and drift triggers — without
re-running anything.
"""

from __future__ import annotations

import argparse
import sys
from collections import defaultdict


def _percentile(xs: list[float], q: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(len(xs) - 1, int(round(q * (len(xs) - 1))))
    return xs[i]


def summarize(records) -> dict:
    """Aggregate a record stream into plain dicts (testable core)."""
    per_worker: dict[int, dict] = defaultdict(
        lambda: {"commits": 0, "latencies": [], "push_bytes": 0.0,
                 "pull_bytes": 0.0, "stale_shards": 0, "n_shards": 0})
    out = {
        "t_end": 0.0, "evals": 0, "final_loss": None,
        "searches": 0, "drift_triggers": 0,
        "lease": defaultdict(int), "churn": defaultdict(int),
        "discovered": 0, "assigns": 0, "capability_reports": 0,
        "per_worker": per_worker,
        "serve": {"requests": 0, "tokens": 0, "slo_ok": 0,
                  "queue": [], "total": [], "t_first": None, "t_last": 0.0},
        "pulls": {"polls": 0, "nbytes": 0.0, "stale_shards": 0, "n_shards": 0},
        # replica -> same shape as "serve" (only filled when records carry
        # non-zero replica ids, i.e. a balancer run)
        "per_replica": defaultdict(
            lambda: {"requests": 0, "tokens": 0, "slo_ok": 0, "total": [],
                     "pull_bytes": 0.0, "pulls": 0}),
    }
    for r in records:
        out["t_end"] = max(out["t_end"], r.t)
        k = r.kind
        if k == "commit":
            w = per_worker[r.worker]
            w["commits"] += 1
            w["latencies"].append(r.latency)
            w["push_bytes"] += r.push_bytes
            w["pull_bytes"] += r.pull_bytes
            w["stale_shards"] += r.stale_shards
            w["n_shards"] = max(w["n_shards"], r.n_shards)
        elif k == "eval":
            out["evals"] += 1
            out["final_loss"] = r.loss
        elif k == "search":
            out["searches"] += 1
        elif k == "drift":
            out["drift_triggers"] += 1
        elif k == "lease":
            out["lease"][r.event] += 1
        elif k == "churn":
            out["churn"][r.event] += 1
            out["discovered"] += int(r.discovered)
        elif k == "assign":
            out["assigns"] += 1
        elif k == "capability":
            out["capability_reports"] += 1
        elif k == "serve":
            sv = out["serve"]
            sv["requests"] += 1
            sv["tokens"] += r.tokens
            sv["slo_ok"] += int(r.slo_ok)
            sv["queue"].append(r.queue)
            sv["total"].append(r.total)
            # request wall span: first arrival to last completion
            arrival = r.t - r.total
            sv["t_first"] = (arrival if sv["t_first"] is None
                             else min(sv["t_first"], arrival))
            sv["t_last"] = max(sv["t_last"], r.t)
            rp = out["per_replica"][r.replica]
            rp["requests"] += 1
            rp["tokens"] += r.tokens
            rp["slo_ok"] += int(r.slo_ok)
            rp["total"].append(r.total)
        elif k == "pull":
            pl = out["pulls"]
            pl["polls"] += 1
            pl["nbytes"] += r.nbytes
            pl["stale_shards"] += r.stale_shards
            pl["n_shards"] = max(pl["n_shards"], r.n_shards)
            rp = out["per_replica"][r.replica]
            rp["pulls"] += 1
            rp["pull_bytes"] += r.nbytes
    return out


def format_report(s: dict) -> str:
    lines = []
    lines.append(f"fleet report — {s['t_end']:.1f} virtual seconds, "
                 f"{len(s['per_worker'])} committing workers")
    if s["final_loss"] is not None:
        lines.append(f"  evals: {s['evals']}  final loss {s['final_loss']:.4f}")
    lines.append(f"  searches: {s['searches']}  drift triggers: "
                 f"{s['drift_triggers']}")
    if s["lease"]:
        ev = ", ".join(f"{k}={v}" for k, v in sorted(s["lease"].items()))
        lines.append(f"  lease: {ev}")
    if s["churn"]:
        ev = ", ".join(f"{k}={v}" for k, v in sorted(s["churn"].items()))
        lines.append(f"  churn: {ev} (discovered={s['discovered']})")
    if s["assigns"]:
        lines.append(f"  scheduler assignments: {s['assigns']} "
                     f"(capability reports: {s['capability_reports']})")
    sv = s["serve"]
    if sv["requests"]:
        span = max(sv["t_last"] - (sv["t_first"] or 0.0), 1e-9)
        lines.append(
            f"  serving: {sv['requests']} requests, {sv['tokens']} tokens "
            f"({sv['tokens'] / span:.1f} tok/s)")
        lines.append(
            f"    latency  queue p50 {_percentile(sv['queue'], 0.5)*1e3:.1f} ms"
            f"  p99 {_percentile(sv['queue'], 0.99)*1e3:.1f} ms"
            f"  | total p50 {_percentile(sv['total'], 0.5)*1e3:.1f} ms"
            f"  p99 {_percentile(sv['total'], 0.99)*1e3:.1f} ms")
        lines.append(
            f"    SLO attainment {100.0 * sv['slo_ok'] / sv['requests']:.1f}%"
            f"  ({sv['slo_ok']}/{sv['requests']})")
        pl = s["pulls"]
        if pl["polls"]:
            lines.append(
                f"    PS pulls: {pl['polls']} "
                f"({pl['stale_shards']} stale shards of {pl['n_shards']}-way, "
                f"{pl['nbytes']/1e6:.2f} MB)")
        # per-replica breakdown only when a balancer spread the load
        if len(s["per_replica"]) > 1:
            lines.append("    replica  requests  tokens  slo%  total_p99_ms"
                         "  pulls  MB_pulled")
            for rep in sorted(s["per_replica"]):
                rp = s["per_replica"][rep]
                slo = (100.0 * rp["slo_ok"] / rp["requests"]
                       if rp["requests"] else 0.0)
                lines.append(
                    f"    {rep:7d}  {rp['requests']:8d}  {rp['tokens']:6d}"
                    f"  {slo:4.0f}  {_percentile(rp['total'], 0.99)*1e3:12.1f}"
                    f"  {rp['pulls']:5d}  {rp['pull_bytes']/1e6:9.2f}")
    if s["per_worker"]:
        lines.append("  worker  commits  mean_lat  p95_lat    MB_up  MB_down"
                     "  stale_ratio")
        for wid in sorted(s["per_worker"]):
            w = s["per_worker"][wid]
            lats = w["latencies"]
            mean = sum(lats) / len(lats) if lats else 0.0
            stale = (w["stale_shards"] / (w["commits"] * w["n_shards"])
                     if w["commits"] and w["n_shards"] else 0.0)
            lines.append(
                f"  {wid:6d}  {w['commits']:7d}  {mean:8.2f}  "
                f"{_percentile(lats, 0.95):7.2f}  {w['push_bytes']/1e6:7.2f}"
                f"  {w['pull_bytes']/1e6:7.2f}  {stale:11.3f}")
    return "\n".join(lines)


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("stream", help="metrics JSONL file")
    args = p.parse_args(argv)
    try:
        from repro.fleet import load_jsonl
    except ImportError:
        sys.exit("run with PYTHONPATH=src (needs repro.fleet)")
    print(format_report(summarize(load_jsonl(args.stream))))


if __name__ == "__main__":
    main()
