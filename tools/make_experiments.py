"""Render EXPERIMENTS.md §Dry-run + §Roofline tables from
results/dryrun/*.json (run after `python -m repro.launch.dryrun --all
--mesh both`). The static sections (§Repro, §Perf) live in
EXPERIMENTS.md directly; this tool replaces the generated blocks between
the AUTOGEN markers. On first run it writes the static skeleton (with
empty AUTOGEN blocks); with no dry-run results it leaves the skeleton in
place and exits with a pointer to the dry-run command."""

from __future__ import annotations

import json
import pathlib
import re
import sys

DRYRUN = pathlib.Path("results/dryrun")
EXP = pathlib.Path("EXPERIMENTS.md")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

SKELETON = """\
# EXPERIMENTS

## Repro notes

(hand-written: per-figure reproduction notes go here)

## Perf iterations

(hand-written: measured hillclimb log goes here)

## Dry-run sweep

<!-- AUTOGEN:DRYRUN -->
(run `PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both`,
then `python tools/make_experiments.py`)
<!-- /AUTOGEN:DRYRUN -->

## Roofline

<!-- AUTOGEN:ROOFLINE -->
(generated with the dry-run sweep)
<!-- /AUTOGEN:ROOFLINE -->
"""


def fmt_bytes(b):
    if b is None:
        return "—"
    return f"{b/1e9:.2f} GB"


def load():
    out = {}
    for fp in sorted(DRYRUN.glob("*.json")):
        d = json.loads(fp.read_text())
        out[(d["arch"], d["shape"], d["mesh"])] = d
    return out


def dryrun_table(data):
    lines = [
        "| arch | shape | mesh | status | compile (s) | params/chip | "
        "temp/chip | HLO colls | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    archs = sorted({k[0] for k in data})
    for a in archs:
        for s in SHAPE_ORDER:
            for m in ("single", "multi"):
                d = data.get((a, s, m))
                if d is None:
                    lines.append(f"| {a} | {s} | {m} | MISSING | | | | | |")
                    continue
                if d["status"] == "skipped":
                    lines.append(f"| {a} | {s} | {m} | SKIP | | | | | {d['reason']} |")
                    continue
                if d["status"] != "ok":
                    lines.append(f"| {a} | {s} | {m} | ERROR | | | | | "
                                 f"{d.get('error','')[:70]} |")
                    continue
                mem = d.get("memory_analysis", {})
                lines.append(
                    f"| {a} | {s} | {m} | ok | {d.get('compile_s','')} "
                    f"| {fmt_bytes(d.get('analytic_param_bytes_per_chip'))} "
                    f"| {fmt_bytes(mem.get('temp_bytes'))} "
                    f"| {d.get('hlo_collective_lines','')} "
                    f"| {d.get('variant_note','')} |")
    return "\n".join(lines)


def roofline_table(data):
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) | "
        "bottleneck | 6ND/HLO | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    hints = {
        "compute": "more chips / lower-precision matmuls / fewer remat recomputes",
        "memory": "fuse attention (Pallas flash), bf16 carries, larger scan blocks",
        "collective": "raise τ (fewer commit all-reduces), bf16 commit dtype, overlap",
    }
    for (a, s, m), d in sorted(data.items()):
        if m != "single" or d["status"] != "ok":
            continue
        r = d["roofline"]
        lines.append(
            f"| {a} | {s} | {m} | {r['compute_s']:.3g} | {r['memory_s']:.3g} "
            f"| {r['collective_s']:.3g} | **{r['bottleneck']}** "
            f"| {r['useful_flops_ratio']:.3f} | {hints[r['bottleneck']]} |")
    return "\n".join(lines)


def splice(text, marker, table):
    begin, end = f"<!-- AUTOGEN:{marker} -->", f"<!-- /AUTOGEN:{marker} -->"
    block = f"{begin}\n{table}\n{end}"
    if begin in text:
        return re.sub(re.escape(begin) + r".*?" + re.escape(end), block,
                      text, flags=re.S)
    return text + "\n" + block + "\n"


def main() -> int:
    if not EXP.exists():
        EXP.write_text(SKELETON)
        print(f"created static skeleton {EXP}")
    data = load()
    if not data:
        print(
            f"no dry-run results under {DRYRUN}/ — generate them first:\n"
            "  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both\n"
            "then re-run this tool to fill the AUTOGEN tables."
        )
        return 0
    n_ok = sum(1 for d in data.values() if d["status"] == "ok")
    n_skip = sum(1 for d in data.values() if d["status"] == "skipped")
    n_err = len(data) - n_ok - n_skip
    print(f"combos: {len(data)} ok={n_ok} skip={n_skip} err={n_err}")
    text = EXP.read_text()
    text = splice(text, "DRYRUN", dryrun_table(data))
    text = splice(text, "ROOFLINE", roofline_table(data))
    EXP.write_text(text)
    print(f"wrote {EXP}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
