"""Summarize a reprolint run: findings grouped by rule and severity.

Usage::

    PYTHONPATH=src python tools/analysis_report.py            # run in-process
    PYTHONPATH=src python tools/analysis_report.py report.json  # from --json

With no argument the analyzer runs in-process over the default scan set
(src/benchmarks/tools) and applies the committed baseline; with an
argument it consumes the JSON written by ``python -m repro.analysis
--json report.json`` (so CI can report on the exact gate run). Either
way the report shows per-rule counts, the affected files, and what the
baseline is currently suppressing — the view you want when deciding
whether to fix or justify.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
from collections import Counter

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.analysis import Baseline, DEFAULT_BASELINE, all_rules, analyze  # noqa: E402


def _load(path: str | None) -> dict:
    if path is not None:
        return json.loads(pathlib.Path(path).read_text())
    project, findings = analyze()
    baseline = Baseline.load(project.root / DEFAULT_BASELINE)
    kept, suppressed, stale = baseline.apply(findings)
    return {
        "root": str(project.root),
        "findings": [f.to_dict() for f in kept],
        "suppressed": [f.to_dict() for f in suppressed],
        "stale_baseline": [e.to_dict() for e in stale],
    }


def report(data: dict) -> list[str]:
    lines: list[str] = []
    findings = data.get("findings", [])
    suppressed = data.get("suppressed", [])
    stale = data.get("stale_baseline", [])
    severity = {r.name: r.severity for r in all_rules()}

    lines.append("== reprolint report ==")
    lines.append(f"findings: {len(findings)} live, {len(suppressed)} "
                 f"baseline-suppressed, {len(stale)} stale baseline entr(y/ies)")

    by_rule = Counter(f["rule"] for f in findings)
    if by_rule:
        lines.append("")
        lines.append("-- by rule --")
        for rule, n in by_rule.most_common():
            lines.append(f"{rule:24s} {severity.get(rule, '?'):8s} {n}")
        lines.append("")
        lines.append("-- by file --")
        per_file = Counter(f["path"] for f in findings)
        for path, n in per_file.most_common():
            rules = sorted({f["rule"] for f in findings if f["path"] == path})
            lines.append(f"{path}: {n} ({', '.join(rules)})")
    else:
        lines.append("no live findings")

    if suppressed:
        lines.append("")
        lines.append("-- baseline-suppressed --")
        for f in suppressed:
            lines.append(f"{f['path']}:{f['line']}: [{f['rule']}] {f['message']}")
    if stale:
        lines.append("")
        lines.append("-- stale baseline entries (delete these) --")
        for e in stale:
            lines.append(f"[{e['rule']}] {e['path']}: {e['message']}")
    return lines


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("report", nargs="?", default=None,
                   help="JSON from `python -m repro.analysis --json` "
                        "(default: run the analyzer in-process)")
    args = p.parse_args(argv)
    for line in report(_load(args.report)):
        print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
