"""Synthetic stand-ins for the paper's three datasets (offline container).

The paper trains (i) a CNN on Cifar-10, (ii) an RNN on a high-speed-rail
fatigue dataset, (iii) a linear SVM on a chiller COP dataset. None are
available offline, so we generate statistically-similar problems whose
*relative* convergence behaviour is what the benchmarks compare:

* ``cifar_like``: 10-class 24×24×3 images. Each class k has a smooth
  class-specific template (mixture of 2-D Gaussian bumps, fixed by seed)
  plus per-sample noise and random shifts — learnable by a small CNN but
  not trivially linearly separable.
* ``fatigue_like``: sequences of "stress" readings from an AR(1) process
  whose drift/variance depend on a latent 3-level fatigue label,
  plus static covariates (age, route, temperature) — an RNN problem.
* ``chiller_like``: linear regression-ish COP labels from temperature /
  electricity / age features with heteroscedastic noise — an SVM/linear
  problem (we use hinge-free L2-regularized regression-SVM form).
* ``lm_tokens``: uniform-ish Zipf token streams for the LM architectures'
  smoke tests and the e2e 100M-parameter example.

Every generator is a pure function of (seed, index range) — workers draw
disjoint shards deterministically, so heterogeneous arrival *rates* (a
worker property) are independent from data *content*.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

__all__ = ["cifar_like", "fatigue_like", "chiller_like", "lm_tokens", "WorkerShardedStream"]


def _rng(seed: int, *salts: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, *salts]))


# ---------------------------------------------------------------------------
# CIFAR-like images
# ---------------------------------------------------------------------------

_N_CLASSES = 10
_IMG = 24


def _class_templates(seed: int, img: int = _IMG) -> np.ndarray:
    """(10, img, img, 3) smooth per-class patterns."""
    rng = _rng(seed, 101)
    yy, xx = np.mgrid[0:img, 0:img].astype(np.float64) / img
    t = np.zeros((_N_CLASSES, img, img, 3))
    for k in range(_N_CLASSES):
        for _ in range(3):
            cx, cy = rng.uniform(0.15, 0.85, size=2)
            sx, sy = rng.uniform(0.08, 0.3, size=2)
            amp = rng.uniform(0.5, 1.5, size=3)
            bump = np.exp(-((xx - cx) ** 2 / (2 * sx**2) + (yy - cy) ** 2 / (2 * sy**2)))
            t[k] += bump[..., None] * amp[None, None, :]
    t -= t.mean(axis=(1, 2, 3), keepdims=True)
    t /= t.std(axis=(1, 2, 3), keepdims=True) + 1e-8
    return t


def cifar_like(
    seed: int, start: int, count: int, noise: float = 0.8, img: int = _IMG
) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic (images[count, img, img, 3] f32, labels[count] i32)."""
    templates = _class_templates(seed, img)
    rng = _rng(seed, 202, start, count)
    labels = rng.integers(0, _N_CLASSES, size=count)
    shifts = rng.integers(-3, 4, size=(count, 2))
    x = templates[labels]
    # random circular shifts (cheap augmentation surrogate)
    i = np.arange(count)[:, None, None]
    rows = (np.arange(img)[None, :, None] + shifts[:, 0:1, None]) % img  # (N,img,1)
    cols = (np.arange(img)[None, None, :] + shifts[:, 1:2, None]) % img  # (N,1,img)
    x = x[i, rows, cols, :]
    x = x + noise * rng.standard_normal(x.shape)
    return x.astype(np.float32), labels.astype(np.int32)


# ---------------------------------------------------------------------------
# Fatigue-like sequences (RNN)
# ---------------------------------------------------------------------------

def fatigue_like(
    seed: int, start: int, count: int, seq_len: int = 32
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(stress[count, seq_len] f32, covariates[count, 4] f32, label[count] i32).

    Label ∈ {0,1,2}: fatigue level. Higher latent fatigue ⇒ higher stress
    drift + variance; covariates (age, route one-hot-ish, temperature)
    shift the thresholds.
    """
    rng = _rng(seed, 303, start, count)
    level = rng.integers(0, 3, size=count)
    age = rng.uniform(0, 1, size=count)
    route = rng.uniform(0, 1, size=count)
    temp = rng.uniform(-1, 1, size=count)
    drift = 0.05 + 0.25 * level + 0.2 * age
    sigma = 0.2 + 0.15 * level + 0.1 * np.abs(temp)
    eps = rng.standard_normal((count, seq_len))
    x = np.zeros((count, seq_len))
    prev = rng.standard_normal(count) * 0.1
    for t in range(seq_len):
        prev = 0.9 * prev + drift + sigma * eps[:, t]
        x[:, t] = prev
    cov = np.stack([age, route, temp, np.ones_like(age)], axis=1)
    return x.astype(np.float32), cov.astype(np.float32), level.astype(np.int32)


# ---------------------------------------------------------------------------
# Chiller-like tabular (linear SVM / COP prediction)
# ---------------------------------------------------------------------------

def chiller_like(seed: int, start: int, count: int) -> tuple[np.ndarray, np.ndarray]:
    """(features[count, 6] f32, cop[count] f32). Near-linear ground truth."""
    rng = _rng(seed, 404, start, count)
    outlet = rng.uniform(5, 12, size=count)
    outdoor = rng.uniform(10, 38, size=count)
    kwh = rng.uniform(50, 400, size=count)
    age = rng.uniform(0, 10, size=count)
    load = rng.uniform(0.3, 1.0, size=count)
    x = np.stack([outlet, outdoor, kwh / 100, age, load, np.ones_like(age)], axis=1)
    cop = (
        6.0
        - 0.08 * (outdoor - 24)
        + 0.12 * (outlet - 8)
        - 0.06 * age
        + 0.8 * load
        - 0.15 * (kwh / 100 - 2) ** 2 * 0.2
    )
    cop = cop + 0.15 * rng.standard_normal(count)
    mu, sd = x.mean(axis=0), x.std(axis=0) + 1e-8
    x = (x - mu) / sd
    x[:, -1] = 1.0  # keep bias column
    return x.astype(np.float32), cop.astype(np.float32)


# ---------------------------------------------------------------------------
# LM token streams
# ---------------------------------------------------------------------------

def lm_tokens(
    seed: int, start: int, batch: int, seq_len: int, vocab: int
) -> np.ndarray:
    """(batch, seq_len+1) i32 Zipf-ish token ids — slice [:, :-1] as inputs
    and [:, 1:] as labels. Markov-ish structure: each token biases the next
    token's bucket, so a model can actually reduce loss below uniform."""
    rng = _rng(seed, 505, start, batch)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / ranks**1.1
    p /= p.sum()
    toks = rng.choice(vocab, size=(batch, seq_len + 1), p=p)
    # inject copy structure: with prob .3 next token = current token
    mask = rng.uniform(size=(batch, seq_len)) < 0.3
    toks[:, 1:][mask] = toks[:, :-1][mask]
    return toks.astype(np.int32)


# ---------------------------------------------------------------------------
# Worker-sharded stream
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WorkerShardedStream:
    """Deterministic per-worker mini-batch streams over a generator.

    ``gen(seed, start, count) -> batch-tuple``; worker w's step s draws the
    half-open index range [cursor, cursor+batch) from an interleaved
    per-worker shard (disjoint across workers)."""

    gen: Callable
    seed: int
    num_workers: int

    def __call__(self, worker: int, step: int, batch_size: int):
        start = (step * self.num_workers + worker) * batch_size
        return self.gen(self.seed, start, batch_size)
