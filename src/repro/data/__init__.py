from .synthetic import (
    cifar_like,
    fatigue_like,
    chiller_like,
    lm_tokens,
    WorkerShardedStream,
)

__all__ = [
    "cifar_like",
    "fatigue_like",
    "chiller_like",
    "lm_tokens",
    "WorkerShardedStream",
]
