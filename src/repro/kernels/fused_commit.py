"""Pallas TPU kernels for the ADSP commit hot loop.

The two elementwise-fused ops that run once per microstep / commit over
every parameter in the model (hundreds of GB moved per step at scale —
pure memory-bound, so fusing them into single HBM passes matters):

  * accumulate:  U ← U + η′·g          (2 reads + 1 write per element,
                                         vs 3R+1W unfused read-mul-add)
  * ps_apply:    δ ← μ·δ − η·U ; W ← W + δ
                                        (3 reads + 2 writes, single pass)

Arrays are processed as flattened 1-D buffers tiled into (8, 1024) VMEM
blocks (8×128-lane aligned). The ops.py wrappers pad ragged tails and
reshape; per-leaf dispatch over a parameter pytree lives in ops.py too.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["accumulate", "ps_apply", "BLOCK", "block_for"]

BLOCK = (8, 1024)  # sublane × lane-aligned VMEM tile (f32: 32 KiB)


def block_for(dtype) -> tuple[int, int]:
    """VMEM tile for a dtype: the minimum sublane count doubles for
    2-byte dtypes (bf16 tiling is (16, 128)-aligned on TPU)."""
    return (16, 1024) if jnp.dtype(dtype).itemsize == 2 else BLOCK


# Hyper-params ride along as a (1, n) operand broadcast to every block —
# portable across jax versions (scalar-prefetch signatures vary).

def _accum_kernel(u_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = u_ref[...] + lr_ref[0, 0].astype(u_ref.dtype) * g_ref[...]


def accumulate(u: jax.Array, g: jax.Array, local_lr, *, interpret: bool = True):
    blk = block_for(u.dtype)
    r, c = u.shape
    grid = (r // blk[0], c // blk[1])
    lr = jnp.full((1, 1), local_lr, u.dtype)
    return pl.pallas_call(
        _accum_kernel,
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec(blk, lambda i, j: (i, j)),
            pl.BlockSpec(blk, lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec(blk, lambda i, j: (i, j)),
        interpret=interpret,
    )(u, g, lr)


def _ps_apply_kernel(w_ref, d_ref, u_ref, hp_ref, w_out, d_out):
    mu = hp_ref[0, 0]
    lr = hp_ref[0, 1]
    delta = mu.astype(d_ref.dtype) * d_ref[...] - lr.astype(u_ref.dtype) * u_ref[...]
    d_out[...] = delta
    w_out[...] = w_ref[...] + delta


def ps_apply(w, prev_delta, u, global_lr, momentum, *, interpret: bool = True):
    """Returns (new_w, new_delta); all (R, C) aligned like `accumulate`."""
    blk = block_for(w.dtype)
    r, c = w.shape
    grid = (r // blk[0], c // blk[1])
    hp = jnp.asarray([[momentum, global_lr]], jnp.float32)
    return pl.pallas_call(
        _ps_apply_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(w.shape, w.dtype),
            jax.ShapeDtypeStruct(w.shape, w.dtype),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec(blk, lambda i, j: (i, j)),
            pl.BlockSpec(blk, lambda i, j: (i, j)),
            pl.BlockSpec(blk, lambda i, j: (i, j)),
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec(blk, lambda i, j: (i, j)),
            pl.BlockSpec(blk, lambda i, j: (i, j)),
        ),
        interpret=interpret,
    )(w, prev_delta, u, hp)
