"""Pallas TPU kernels fusing the commit-transport codec passes with the
PS commit apply (DESIGN.md §16).

The unfused commit hot path runs three elementwise HBM passes per leaf
per shard — codec encode, PS-side decode, commit apply — plus the
residual add the encode folds in. Each pass is memory-bound, so at model
scale the commit round pays 3–4 full HBM round trips for arithmetic one
pass could do. These kernels collapse them:

  push (worker side, one pass):
    * quantize_int8_ef: e ← u + r ; q ← clip(round(e/s)) ; r ← e − q·s
    * encode_bf16_ef:   e ← u + r ; q ← bf16(e) ; r ← e − f32(q)
      (the error-feedback add rides inside the quantize pass, so ``e``
      is never materialized in HBM; the per-leaf scale reduction stays a
      jnp amax the compiler fuses into the read)

  pull (PS side, one pass — decode + Eqn. 1 apply / plain average):
    * int8_decode_apply:  u ← q·s ; δ ← μ·δ − η·u ; W ← W + δ
    * bf16_decode_apply:  u ← f32(q) ; δ ← μ·δ − η·u ; W ← W + δ
    * int8_decode_accum:  u ← q·s ; W ← W − η·u
    * bf16_decode_accum:  u ← f32(q) ; W ← W − η·u

The in-kernel arithmetic mirrors the reference chain cast for cast
(decode to f32, cast like the params, delta in the commit-state dtype),
so the fused pull is bit-identical to decode → apply for f32 trees —
the contract tests/test_update_rules.py pins per codec and shard count.

Tiles are (32, 1024) like ``kernels.codec`` (int8 payloads participate;
the int8 minimum sublane count is 32, a multiple of the f32/bf16
minimums). The ops.py wrappers pad ragged tails, reshape, and carry the
per-leaf scale / hyper-params as (1, n) operands broadcast to every
block, exactly like ``fused_commit`` / ``codec``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .codec import QBLOCK

__all__ = [
    "quantize_int8_ef",
    "encode_bf16_ef",
    "int8_decode_apply",
    "bf16_decode_apply",
    "int8_decode_accum",
    "bf16_decode_accum",
]


def _grid(x) -> tuple[int, int]:
    r, c = x.shape
    return (r // QBLOCK[0], c // QBLOCK[1])


def _bspec():
    return pl.BlockSpec(QBLOCK, lambda i, j: (i, j))


def _hspec(n):
    return pl.BlockSpec((1, n), lambda i, j: (0, 0))


# ---------------------------------------------------------------------------
# push side: error-feedback add fused into the encode pass
# ---------------------------------------------------------------------------

def _quantize_ef_kernel(u_ref, r_ref, s_ref, q_ref, ro_ref):
    scale = s_ref[0, 0]
    e = u_ref[...] + r_ref[...]
    q = jnp.clip(jnp.round(e / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    ro_ref[...] = e - q * scale


def quantize_int8_ef(u: jax.Array, r: jax.Array, scale: jax.Array, *,
                     interpret: bool = True):
    """(R, C) f32 update + residual → (int8 payload, next residual) with
    the error-feedback add folded into the quantize pass."""
    return pl.pallas_call(
        _quantize_ef_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(u.shape, jnp.int8),
            jax.ShapeDtypeStruct(u.shape, jnp.float32),
        ),
        grid=_grid(u),
        in_specs=[_bspec(), _bspec(), _hspec(1)],
        out_specs=(_bspec(), _bspec()),
        interpret=interpret,
    )(u, r, scale)


def _encode_bf16_ef_kernel(u_ref, r_ref, q_ref, ro_ref):
    e = u_ref[...] + r_ref[...]
    q = e.astype(jnp.bfloat16)
    q_ref[...] = q
    ro_ref[...] = e - q.astype(jnp.float32)


def encode_bf16_ef(u: jax.Array, r: jax.Array, *, interpret: bool = True):
    """(R, C) f32 update + residual → (bf16 payload, next residual)."""
    return pl.pallas_call(
        _encode_bf16_ef_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(u.shape, jnp.bfloat16),
            jax.ShapeDtypeStruct(u.shape, jnp.float32),
        ),
        grid=_grid(u),
        in_specs=[_bspec(), _bspec()],
        out_specs=(_bspec(), _bspec()),
        interpret=interpret,
    )(u, r)


# ---------------------------------------------------------------------------
# pull side: decode fused with the commit apply
# ---------------------------------------------------------------------------

def _int8_apply_kernel(w_ref, d_ref, q_ref, s_ref, hp_ref, w_out, d_out):
    mu, lr = hp_ref[0, 0], hp_ref[0, 1]
    u = (q_ref[...].astype(jnp.float32) * s_ref[0, 0]).astype(w_ref.dtype)
    delta = (mu.astype(d_ref.dtype) * d_ref[...]
             - lr.astype(u.dtype) * u).astype(d_ref.dtype)
    d_out[...] = delta
    w_out[...] = w_ref[...] + delta


def int8_decode_apply(w, prev_delta, q, scale, hp, *, interpret: bool = True):
    """δ ← μ·δ − η·(q·s) ; W ← W + δ in one pass. ``hp`` is a (1, 2) f32
    [momentum, global_lr] operand; ``scale`` the per-leaf (1, 1) f32."""
    return pl.pallas_call(
        _int8_apply_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(w.shape, w.dtype),
            jax.ShapeDtypeStruct(w.shape, prev_delta.dtype),
        ),
        grid=_grid(w),
        in_specs=[_bspec(), _bspec(), _bspec(), _hspec(1), _hspec(2)],
        out_specs=(_bspec(), _bspec()),
        interpret=interpret,
    )(w, prev_delta, q, scale, hp)


def _bf16_apply_kernel(w_ref, d_ref, q_ref, hp_ref, w_out, d_out):
    mu, lr = hp_ref[0, 0], hp_ref[0, 1]
    u = q_ref[...].astype(jnp.float32).astype(w_ref.dtype)
    delta = (mu.astype(d_ref.dtype) * d_ref[...]
             - lr.astype(u.dtype) * u).astype(d_ref.dtype)
    d_out[...] = delta
    w_out[...] = w_ref[...] + delta


def bf16_decode_apply(w, prev_delta, q, hp, *, interpret: bool = True):
    """Same single pass with the bf16-payload decode (a widening cast)."""
    return pl.pallas_call(
        _bf16_apply_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(w.shape, w.dtype),
            jax.ShapeDtypeStruct(w.shape, prev_delta.dtype),
        ),
        grid=_grid(w),
        in_specs=[_bspec(), _bspec(), _bspec(), _hspec(2)],
        out_specs=(_bspec(), _bspec()),
        interpret=interpret,
    )(w, prev_delta, q, hp)


def _int8_accum_kernel(w_ref, q_ref, s_ref, hp_ref, w_out):
    lr = hp_ref[0, 0]
    u = (q_ref[...].astype(jnp.float32) * s_ref[0, 0]).astype(w_ref.dtype)
    w_out[...] = (w_ref[...] - lr.astype(u.dtype) * u).astype(w_ref.dtype)


def int8_decode_accum(w, q, scale, hp, *, interpret: bool = True):
    """Stateless plain-average pull: W ← W − η·(q·s) in one pass."""
    return pl.pallas_call(
        _int8_accum_kernel,
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        grid=_grid(w),
        in_specs=[_bspec(), _bspec(), _hspec(1), _hspec(1)],
        out_specs=_bspec(),
        interpret=interpret,
    )(w, q, scale, hp)


def _bf16_accum_kernel(w_ref, q_ref, hp_ref, w_out):
    lr = hp_ref[0, 0]
    u = q_ref[...].astype(jnp.float32).astype(w_ref.dtype)
    w_out[...] = (w_ref[...] - lr.astype(u.dtype) * u).astype(w_ref.dtype)


def bf16_decode_accum(w, q, hp, *, interpret: bool = True):
    """Stateless plain-average pull for bf16 payloads."""
    return pl.pallas_call(
        _bf16_accum_kernel,
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        grid=_grid(w),
        in_specs=[_bspec(), _bspec(), _hspec(1)],
        out_specs=_bspec(),
        interpret=interpret,
    )(w, q, hp)
