"""RWKV6 WKV-recurrence Pallas TPU kernel (chunked over time).

Per (batch, head) program family, carrying the (N, N) state matrix in VMEM
scratch across time chunks (grid axis LAST = sequential):

    S ← diag(w_t)·S + k_tᵀ v_t
    o_t = r_t · (S_prev + u ⊙ k_tᵀ v_t)

N = 64 for all assigned RWKV configs, so the state is 64×64×4 B = 16 KiB —
comfortably VMEM-resident; r/k/v/w stream through in (block_s, N) tiles.

TPU adaptation (DESIGN.md): CUDA RWKV kernels assign one thread per
channel and keep state in registers/shared memory with warp-level
parallelism over heads. The TPU analogue is this grid-parallel (B, H)
decomposition with the state as a VMEM-resident matrix and the per-token
outer products k_tᵀv_t / row-gathers r_t·S expressed as (N, N) VPU ops —
sequential in t, vectorized in the state plane.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rwkv6_scan"]


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, stout_ref, st_ref,
                *, block_s, n_s):
    sj = pl.program_id(2)

    @pl.when(sj == 0)
    def _init():
        st_ref[...] = jnp.zeros_like(st_ref)

    r = r_ref[0, 0]  # (block_s, N)
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    w = w_ref[0, 0]
    u = u_ref[...]  # (1, N) bonus — .T below gives the (N, 1) key-axis column

    def step(t, carry):
        st, out = carry  # st: (N, N)
        kt = k[t][:, None]  # (N, 1)
        vt = v[t][None, :]  # (1, N)
        kv = kt * vt  # (N, N)
        ot = r[t] @ (st + u.T * kv)  # (N,)
        st = w[t][:, None] * st + kv
        out = jax.lax.dynamic_update_index_in_dim(out, ot, t, 0)
        return st, out

    out0 = jnp.zeros_like(v)
    st, out = jax.lax.fori_loop(0, block_s, step, (st_ref[...], out0))
    st_ref[...] = st
    o_ref[0, 0] = out

    @pl.when(sj == n_s - 1)
    def _emit_state():
        stout_ref[0, 0] = st_ref[...]


def rwkv6_scan(r, k, v, w, bonus, *, block_s: int = 256, interpret: bool = True):
    """r,k,v,w: (B, S, H, N) (w float32 decay); bonus: (H, N).

    Returns (out (B, S, H, N) float32, final_state (B, H, N, N) float32).
    S % block_s == 0 (ops.py pads with w=1, k=0 ⇒ state-preserving no-ops).
    """
    b, s, h, n = r.shape
    block_s = min(block_s, s)
    assert s % block_s == 0
    n_s = s // block_s

    # layout (B, H, S, N): head becomes a grid axis
    rt, kt, vt, wt = (
        jnp.moveaxis(t.astype(jnp.float32), 2, 1) for t in (r, k, v, w)
    )
    kernel = functools.partial(_wkv_kernel, block_s=block_s, n_s=n_s)
    out, st = pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b, h, s, n), jnp.float32),
            jax.ShapeDtypeStruct((b, h, n, n), jnp.float32),
        ),
        grid=(b, h, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, block_s, n), lambda bi, hi, sj: (bi, hi, sj, 0)),
            pl.BlockSpec((1, 1, block_s, n), lambda bi, hi, sj: (bi, hi, sj, 0)),
            pl.BlockSpec((1, 1, block_s, n), lambda bi, hi, sj: (bi, hi, sj, 0)),
            pl.BlockSpec((1, 1, block_s, n), lambda bi, hi, sj: (bi, hi, sj, 0)),
            pl.BlockSpec((1, n), lambda bi, hi, sj: (hi, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, block_s, n), lambda bi, hi, sj: (bi, hi, sj, 0)),
            pl.BlockSpec((1, 1, n, n), lambda bi, hi, sj: (bi, hi, 0, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, bonus.astype(jnp.float32))
    return jnp.moveaxis(out, 1, 2), st
