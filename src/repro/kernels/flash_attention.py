"""Blockwise (flash) attention Pallas TPU kernel — GQA, causal, optional
sliding window.

Grid: (B, Hq, S/block_q, S/block_k) with the kv-block axis LAST, i.e.
innermost-sequential on TPU. The online-softmax running state
(max m, denom l, accumulator acc) lives in VMEM scratch and is carried
across the kv-block grid steps of the same (b, h, q-block) program family;
the output block is written on the final kv step. This is the canonical
TPU flash pattern: every operand block is a proper VMEM tile —
(block_q, D) for q/out and (block_k, D) for k/v — so the working set is
~(2·block_q + 2·block_k)·D·4 B ≈ 1 MiB at 512/512/128, independent of S.

GQA is expressed in the BlockSpec index maps: kv operands for q-head h
index kv-head h // (Hq/Hkv) — no host-side head replication, no extra HBM.

Masking is positional (causal and/or sliding window). Fully-masked kv
blocks are skipped with pl.when — the block fetch still happens (grid is
static) but the MXU work is elided; the ops.py wrapper additionally trims
whole diagonals when causal by choosing block_k = block_q.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 block_q, block_k, n_kv, causal, window, scale):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level skip test (static shapes, dynamic ids)
    q_lo = qi * block_q
    q_hi = q_lo + block_q - 1
    k_lo = kj * block_k
    k_hi = k_lo + block_k - 1
    live = jnp.asarray(True)
    if causal:
        live &= k_lo <= q_hi
    if window:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (block_q, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, D)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = q @ k.T
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones_like(logits, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= qpos - kpos < window
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        l_ref[...] = l_new
        acc_ref[...] = acc_prev * alpha + p @ v

    @pl.when(kj == n_kv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = True):
    """q: (B, S, Hq, D); k, v: (B, S, Hkv, D) → (B, S, Hq, D).

    S must be a multiple of the block sizes (ops.py pads + re-masks)."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    n_kv = s // block_k

    qt = jnp.moveaxis(q, 2, 1)  # (B, Hq, S, D)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)

    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, n_kv=n_kv,
        causal=causal, window=window, scale=1.0 / np.sqrt(d),
    )
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, hq, s, d), q.dtype),
        grid=(b, hq, s // block_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, h, qi, kj: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bi, h, qi, kj: (bi, h // group, kj, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bi, h, qi, kj: (bi, h // group, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda bi, h, qi, kj: (bi, h, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)
