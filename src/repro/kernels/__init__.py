"""Pallas TPU kernels for the system's compute hot spots.

Layout per kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper with padding/dispatch), ref.py (pure-jnp oracle used by
tests). Kernels target TPU VMEM tiling and are validated on CPU with
interpret=True.
"""

from . import ops, ref

__all__ = ["ops", "ref"]
