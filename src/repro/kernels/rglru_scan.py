"""RG-LRU linear-recurrence Pallas TPU kernel (chunked scan).

Computes h_t = a_t ⊙ h_{t−1} + b_t over the time axis for (B, S, W)
inputs. Grid: (B, W/block_w, S/block_s) with the time-chunk axis LAST
(sequential); the carry h lives in a (1, block_w) VMEM scratch persisting
across time chunks of the same (batch, channel-block) program family.

Within a chunk the recurrence is unrolled as a first-order scan in
registers (time is inherently sequential; the channel dimension is the
vector axis, block_w = 1024 lanes wide). TPU-adaptation note (DESIGN.md):
GPU implementations of linear recurrences lean on warp-parallel
Blelloch scans; on TPU the VPU prefers deep vector pipelines over lane
shuffles, so we parallelize across channels/batch (embarrassingly
parallel) and keep time sequential per program — the arithmetic intensity
is O(1) FLOP/byte either way (memory-bound), so the win is tiling for
sequential HBM streams, not FLOP reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rglru_scan"]


def _rglru_kernel(a_ref, b_ref, o_ref, h_ref, *, block_s):
    sj = pl.program_id(2)

    @pl.when(sj == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0]  # (block_s, block_w)
    b = b_ref[0]
    h = h_ref[0]  # (block_w,)

    def step(t, carry):
        h, out = carry
        h = a[t] * h + b[t]
        out = jax.lax.dynamic_update_index_in_dim(out, h, t, 0)
        return h, out

    out0 = jnp.zeros_like(a)
    h, out = jax.lax.fori_loop(0, block_s, step, (h, out0))
    h_ref[0] = h
    o_ref[0] = out


def rglru_scan(a, b, *, block_w: int = 1024, block_s: int = 256,
               interpret: bool = True):
    """a, b: (B, S, W) float32 → h: (B, S, W). S % block_s == 0 and
    W % block_w == 0 (ops.py pads W; padding channels scan harmlessly)."""
    bsz, s, w = a.shape
    block_w = min(block_w, w)
    block_s = min(block_s, s)
    assert s % block_s == 0 and w % block_w == 0, (s, w, block_s, block_w)

    kernel = functools.partial(_rglru_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), a.dtype),
        grid=(bsz, w // block_w, s // block_s),
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda bi, wi, sj: (bi, sj, wi)),
            pl.BlockSpec((1, block_s, block_w), lambda bi, wi, sj: (bi, sj, wi)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w), lambda bi, wi, sj: (bi, sj, wi)),
        scratch_shapes=[pltpu.VMEM((1, block_w), a.dtype)],
        interpret=interpret,
    )(a, b)
