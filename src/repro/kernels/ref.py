"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematically-direct implementation the kernels in
this package must match (assert_allclose in tests/test_kernels.py, with
hypothesis sweeps over shapes/dtypes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "fused_accumulate",
    "fused_ps_apply",
    "quantize_int8_ef",
    "encode_bf16_ef",
    "int8_decode_apply",
    "bf16_decode_apply",
    "int8_decode_accum",
    "bf16_decode_accum",
    "flash_attention",
    "rglru_scan",
    "rwkv6_scan",
]


# ---------------------------------------------------------------------------
# ADSP commit ops (the paper's hot loop: Alg. 2 lines 7 and PS line 4)
# ---------------------------------------------------------------------------

def fused_accumulate(u: jax.Array, g: jax.Array, local_lr: float) -> jax.Array:
    """U ← U + η′ · g   (worker-side accumulative update)."""
    return u + local_lr * g


def fused_ps_apply(
    w: jax.Array,
    prev_delta: jax.Array,
    u: jax.Array,
    global_lr: float,
    momentum: float,
) -> tuple[jax.Array, jax.Array]:
    """PS update with explicit momentum (Eqn. 1, μ possibly reduced by the
    implicit-momentum correction): δ ← μ·δ_prev − η·U ; W ← W + δ."""
    delta = momentum * prev_delta - global_lr * u
    return w + delta, delta


# ---------------------------------------------------------------------------
# Fused codec+commit passes (DESIGN.md §16) — the decode/apply chain each
# single-pass kernel in fused_codec_commit.py must reproduce bit for bit
# ---------------------------------------------------------------------------

def quantize_int8_ef(u, r, scale):
    """Error-feedback int8 encode: e = u + r, symmetric quantize, next
    residual — the reference chain of add → quantize in one expression."""
    e = u.astype(jnp.float32) + r
    q = jnp.clip(jnp.round(e / scale), -127.0, 127.0).astype(jnp.int8)
    return q, e - q.astype(jnp.float32) * scale


def encode_bf16_ef(u, r):
    """Error-feedback bf16 encode: e = u + r cast and residualized."""
    e = u.astype(jnp.float32) + r
    q = e.astype(jnp.bfloat16)
    return q, e - q.astype(jnp.float32)


def int8_decode_apply(w, prev_delta, q, scale, global_lr, momentum):
    """Dequantize + Eqn. 1 PS apply: exactly decode(q)·cast-like-params
    followed by ``fused_ps_apply`` — the unfused chain the kernel fuses."""
    u = (q.astype(jnp.float32) * scale).astype(w.dtype)
    delta = (momentum * prev_delta - global_lr * u).astype(prev_delta.dtype)
    return w + delta, delta


def bf16_decode_apply(w, prev_delta, q, global_lr, momentum):
    """Widening bf16 decode + Eqn. 1 PS apply (unfused chain)."""
    u = q.astype(jnp.float32).astype(w.dtype)
    delta = (momentum * prev_delta - global_lr * u).astype(prev_delta.dtype)
    return w + delta, delta


def int8_decode_accum(w, q, scale, global_lr):
    """Dequantize + stateless plain-average pull (unfused chain)."""
    u = (q.astype(jnp.float32) * scale).astype(w.dtype)
    return (w - global_lr * u).astype(w.dtype)


def bf16_decode_accum(w, q, global_lr):
    """bf16 decode + stateless plain-average pull (unfused chain)."""
    u = q.astype(jnp.float32).astype(w.dtype)
    return (w - global_lr * u).astype(w.dtype)


# ---------------------------------------------------------------------------
# Flash attention (GQA, causal, optional sliding window)
# ---------------------------------------------------------------------------

def flash_attention(
    q: jax.Array,  # (B, S, Hq, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,  # (B, S, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    logits = logits / np.sqrt(d)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= qpos - kpos < window
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)
    return ctx.reshape(b, s, hq, d)


# ---------------------------------------------------------------------------
# RG-LRU linear recurrence
# ---------------------------------------------------------------------------

def rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array | None = None) -> jax.Array:
    """h_t = a_t ⊙ h_{t−1} + b_t, over axis 1. a, b: (B, S, W) float32."""
    bsz, s, w = a.shape
    h = h0 if h0 is not None else jnp.zeros((bsz, w), a.dtype)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)


# ---------------------------------------------------------------------------
# RWKV6 WKV recurrence
# ---------------------------------------------------------------------------

def rwkv6_scan(
    r: jax.Array,  # (B, S, H, N)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # decay ∈ (0,1), float32
    bonus: jax.Array,  # (H, N)
    state0: jax.Array | None = None,  # (B, H, N, N)
) -> tuple[jax.Array, jax.Array]:
    b, s, h, n = r.shape
    st = state0 if state0 is not None else jnp.zeros((b, h, n, n), jnp.float32)

    def step(st, xs):
        rt, kt, vt, wt = xs
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhn,bhnm->bhm", rt, st + bonus[None, :, :, None] * kv)
        st = wt[..., :, None] * st + kv
        return st, out

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    stT, outs = jax.lax.scan(step, st, xs)
    return jnp.moveaxis(outs, 0, 1), stT
