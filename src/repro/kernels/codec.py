"""Pallas TPU kernels for the commit-transport codecs (DESIGN.md §10).

Encode/decode run once per commit over every parameter in the model, so
like the fused commit ops they are pure memory-bound passes worth fusing
into single HBM trips:

  * quantize_int8:   q ← clip(round(e/s)) ; r ← e − q·s
                     (1 read + 2 writes: the int8 payload and the
                     error-feedback residual come out of one pass over e,
                     vs three unfused elementwise kernels)
  * dequantize_int8: x ← q·s
  * encode_bf16:     q ← bf16(e) ; r ← e − f32(q)   (same single-pass shape)

Arrays arrive as flattened 2-D buffers tiled into lane-aligned VMEM
blocks; because the int8 payload participates, tiles are (32, 1024)
(int8 min sublane count is 32; f32/bf16 operands are fine at any
multiple of 8/16). The ops.py wrappers pad ragged tails and reshape;
the per-leaf scale is a jnp reduction computed by the caller — only the
elementwise passes live here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quantize_int8", "dequantize_int8", "encode_bf16", "QBLOCK"]

QBLOCK = (32, 1024)  # int8-safe sublane × lane-aligned VMEM tile


def _quantize_kernel(e_ref, s_ref, q_ref, r_ref):
    scale = s_ref[0, 0]
    q = jnp.clip(jnp.round(e_ref[...] / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    r_ref[...] = e_ref[...] - q * scale


def quantize_int8(e: jax.Array, scale: jax.Array, *, interpret: bool = True):
    """(R, C) f32 → (int8 payload, f32 error-feedback residual).

    ``scale`` is a (1, 1) f32 (positive; the caller guards zero) broadcast
    to every block like the fused-commit hyperparameter operands.
    """
    blk = QBLOCK
    r, c = e.shape
    grid = (r // blk[0], c // blk[1])
    return pl.pallas_call(
        _quantize_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(e.shape, jnp.int8),
            jax.ShapeDtypeStruct(e.shape, jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec(blk, lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec(blk, lambda i, j: (i, j)),
            pl.BlockSpec(blk, lambda i, j: (i, j)),
        ),
        interpret=interpret,
    )(e, scale)


def _dequantize_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0, 0]


def dequantize_int8(q: jax.Array, scale: jax.Array, *, interpret: bool = True):
    """(R, C) int8 payload → f32 (the PS-side decode pass)."""
    blk = QBLOCK
    r, c = q.shape
    grid = (r // blk[0], c // blk[1])
    return pl.pallas_call(
        _dequantize_kernel,
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec(blk, lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec(blk, lambda i, j: (i, j)),
        interpret=interpret,
    )(q, scale)


def _encode_bf16_kernel(e_ref, q_ref, r_ref):
    q = e_ref[...].astype(jnp.bfloat16)
    q_ref[...] = q
    r_ref[...] = e_ref[...] - q.astype(jnp.float32)


def encode_bf16(e: jax.Array, *, interpret: bool = True):
    """(R, C) f32 → (bf16 payload, f32 residual) in one pass."""
    blk = QBLOCK
    r, c = e.shape
    grid = (r // blk[0], c // blk[1])
    return pl.pallas_call(
        _encode_bf16_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(e.shape, jnp.bfloat16),
            jax.ShapeDtypeStruct(e.shape, jnp.float32),
        ),
        grid=grid,
        in_specs=[pl.BlockSpec(blk, lambda i, j: (i, j))],
        out_specs=(
            pl.BlockSpec(blk, lambda i, j: (i, j)),
            pl.BlockSpec(blk, lambda i, j: (i, j)),
        ),
        interpret=interpret,
    )(e)
