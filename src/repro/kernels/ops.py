"""jit'd public wrappers around the Pallas kernels.

Handles padding to block multiples, dtype plumbing, pytree dispatch for
the commit ops, and the interpret-mode switch: ``interpret=None`` (the
default) auto-selects interpret=True unless a TPU backend is present, so
the same call sites work in the CPU container (validation) and on real
hardware (performance).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from . import codec as _cd
from . import flash_attention as _fa
from . import fused_codec_commit as _fcc
from . import fused_commit as _fc
from . import rglru_scan as _rg
from . import rwkv6_scan as _rw

__all__ = [
    "flash_attention",
    "rglru_scan",
    "rwkv6_scan",
    "accumulate_tree",
    "ps_apply_tree",
    "quantize_int8",
    "dequantize_int8",
    "encode_bf16",
    "quantize_int8_ef",
    "encode_bf16_ef",
    "int8_decode_apply",
    "bf16_decode_apply",
    "int8_decode_accum",
    "bf16_decode_accum",
    "default_interpret",
]

_TRUTHY = frozenset(("1", "true", "yes", "on"))
_FALSY = frozenset(("0", "false", "no", "off"))


@functools.lru_cache(maxsize=None)
def default_interpret() -> bool:
    """Interpret-mode default for every Pallas wrapper (and the rule
    registry in ``repro.ps``): the REPRO_PALLAS_INTERPRET env var wins
    when set (1/true/yes/on or 0/false/no/off), else interpret unless a
    TPU backend is present. Cached — the backend probe and getenv run
    once per process, not once per wrapper call (call
    ``default_interpret.cache_clear()`` after changing the env var)."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
    if env in _TRUTHY:
        return True
    if env in _FALSY:
        return False
    if env:
        raise ValueError(
            f"REPRO_PALLAS_INTERPRET={env!r}: want one of "
            f"{sorted(_TRUTHY)} / {sorted(_FALSY)}"
        )
    return jax.default_backend() != "tpu"


def _interp(interpret):
    if interpret is not None:
        return interpret
    return default_interpret()


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=512,
                    block_k=512, interpret=None):
    """(B, S, Hq, D) GQA flash attention; pads S to a block multiple.

    Padding queries attend only to padding keys (causal mask handles the
    real→pad direction; pad-query outputs are sliced off).

    Differentiable: the Pallas call carries no autodiff rule, so the
    backward recomputes through the reference attention (custom_vjp) —
    the train path can use the kernel forward today; a fused backward
    kernel is future work."""
    return _fa_vjp(q, k, v, causal, window, block_q, block_k,
                   _interp(interpret))


def _fa_primal(q, k, v, causal, window, block_q, block_k, interpret):
    s = q.shape[1]
    bq = min(block_q, max(s, 16))
    bk = min(block_k, max(s, 16))
    mult = max(bq, bk)
    qp, _ = _pad_to(q, 1, mult)
    kp, _ = _pad_to(k, 1, mult)
    vp, _ = _pad_to(v, 1, mult)
    out = _fa.flash_attention(
        qp, kp, vp, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=interpret,
    )
    return out[:, :s]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _fa_vjp(q, k, v, causal, window, block_q, block_k, interpret):
    return _fa_primal(q, k, v, causal, window, block_q, block_k, interpret)


def _fa_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    return (_fa_primal(q, k, v, causal, window, block_q, block_k, interpret),
            (q, k, v))


def _fa_bwd(causal, window, block_q, block_k, interpret, res, g):
    from . import ref as _ref  # lazy: ref is the autodiff twin, not a dep

    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.flash_attention(
            q_, k_, v_, causal=causal, window=window), q, k, v)
    return vjp(g)


_fa_vjp.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# recurrences
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block_w", "block_s", "interpret"))
def rglru_scan(a, b, *, block_w=1024, block_s=256, interpret=None):
    """(B, S, W) h_t = a_t h_{t−1} + b_t; pads W (neutral) and S (a=1, b=0)."""
    bsz, s, w = a.shape
    bw = min(block_w, w)
    bs = min(block_s, s)
    ap, padw = _pad_to(a, 2, bw)
    bp, _ = _pad_to(b, 2, bw)
    # pad time with identity steps (a=1, b=0) — state preserved
    padt = (-s) % bs
    if padt:
        ap = jnp.concatenate([ap, jnp.ones((bsz, padt, ap.shape[2]), ap.dtype)], axis=1)
        bp = jnp.concatenate([bp, jnp.zeros((bsz, padt, bp.shape[2]), bp.dtype)], axis=1)
    h = _rg.rglru_scan(ap, bp, block_w=bw, block_s=bs, interpret=_interp(interpret))
    return h[:, :s, :w]


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def rwkv6_scan(r, k, v, w, bonus, *, block_s=256, interpret=None):
    """(B, S, H, N) WKV recurrence → (out, final_state (B, H, N, N))."""
    b, s, h, n = r.shape
    bs = min(block_s, s)
    padt = (-s) % bs
    if padt:
        zeros = jnp.zeros((b, padt, h, n), r.dtype)
        ones = jnp.ones((b, padt, h, n), jnp.float32)
        r = jnp.concatenate([r, zeros], axis=1)
        k = jnp.concatenate([k, zeros], axis=1)  # k=0 ⇒ kv=0 ⇒ state kept
        v = jnp.concatenate([v, zeros], axis=1)
        w = jnp.concatenate([w, ones], axis=1)  # w=1 ⇒ no decay
    out, st = _rw.rwkv6_scan(r, k, v, w, bonus, block_s=bs, interpret=_interp(interpret))
    return out[:, :s], st


# ---------------------------------------------------------------------------
# ADSP commit ops over parameter pytrees
# ---------------------------------------------------------------------------

def _as_tiles(x, blk=None):
    """Flatten to block-aligned 2-D (dtype-dependent sublane count, or an
    explicit ``blk``); returns (tiled, orig_size). A leaf that is already
    a tile-aligned 2-D buffer passes through untouched — no pad, no
    reshape, no copy (tests pin this by object identity)."""
    if blk is None:
        blk = _fc.block_for(x.dtype)
    n = x.size
    cols = blk[1]
    rows = -(-n // cols)
    rows += (-rows) % blk[0]
    if x.ndim == 2 and x.shape == (rows, cols):
        return x, n
    flat = x.reshape(-1)
    total = rows * cols
    if total != n:  # pad only ragged tails — aligned sizes skip the copy
        flat = jnp.pad(flat, (0, total - n))
    return flat.reshape(rows, cols), n


def _from_tiles(t, n, shape, dtype):
    if t.shape == tuple(shape) and t.dtype == jnp.dtype(dtype):
        return t  # tile-aligned round trip: hand the buffer back as-is
    return t.reshape(-1)[:n].reshape(shape).astype(dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def accumulate_tree(u, g, local_lr, *, interpret=None):
    """U ← U + η′·g leaf-wise via the fused Pallas kernel."""
    interp = _interp(interpret)

    def per_leaf(ul, gl):
        t, n = _as_tiles(ul)
        gt, _ = _as_tiles(gl.astype(ul.dtype))
        out = _fc.accumulate(t, gt, local_lr, interpret=interp)
        return _from_tiles(out, n, ul.shape, ul.dtype)

    return jax.tree.map(per_leaf, u, g)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ps_apply_tree(w, prev_delta, u, global_lr, momentum, *, interpret=None):
    """W ← W + (μ·δ − η·U); returns (new_w, new_delta) pytrees."""
    interp = _interp(interpret)

    def per_leaf(wl, dl, ul):
        t, n = _as_tiles(wl)
        dt, _ = _as_tiles(dl.astype(wl.dtype))
        ut, _ = _as_tiles(ul.astype(wl.dtype))
        nw, nd = _fc.ps_apply(t, dt, ut, global_lr, momentum, interpret=interp)
        return (
            _from_tiles(nw, n, wl.shape, wl.dtype),
            _from_tiles(nd, n, wl.shape, wl.dtype),
        )

    pairs = jax.tree.map(per_leaf, w, prev_delta, u)
    new_w = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_d = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return new_w, new_d


# ---------------------------------------------------------------------------
# transport codec passes (per-array; pytree dispatch lives in repro.transport)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_int8(x, scale, *, interpret=None):
    """Symmetric int8 quantization of one array with a given positive
    scalar ``scale``: returns (q int8, error-feedback residual f32), both
    shaped like ``x``, out of a single fused HBM pass."""
    interp = _interp(interpret)
    t, n = _as_tiles(x.astype(jnp.float32), _cd.QBLOCK)
    s = jnp.full((1, 1), scale, jnp.float32)
    q, r = _cd.quantize_int8(t, s, interpret=interp)
    return (
        _from_tiles(q, n, x.shape, jnp.int8),
        _from_tiles(r, n, x.shape, jnp.float32),
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_int8(q, scale, *, interpret=None):
    """PS-side decode of an int8 payload: q·scale as f32."""
    interp = _interp(interpret)
    t, n = _as_tiles(q, _cd.QBLOCK)
    s = jnp.full((1, 1), scale, jnp.float32)
    out = _cd.dequantize_int8(t, s, interpret=interp)
    return _from_tiles(out, n, q.shape, jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def encode_bf16(x, *, interpret=None):
    """bf16 cast of one array: (q bf16, residual f32) in a single pass."""
    interp = _interp(interpret)
    t, n = _as_tiles(x.astype(jnp.float32), _cd.QBLOCK)
    q, r = _cd.encode_bf16(t, interpret=interp)
    return (
        _from_tiles(q, n, x.shape, jnp.bfloat16),
        _from_tiles(r, n, x.shape, jnp.float32),
    )


# ---------------------------------------------------------------------------
# fused codec+commit passes (DESIGN.md §16): push-side encode with the
# error-feedback add folded in; pull-side decode fused with the PS apply
# ---------------------------------------------------------------------------

def _hp2(momentum, global_lr):
    return jnp.stack([
        jnp.asarray(momentum, jnp.float32),
        jnp.asarray(global_lr, jnp.float32),
    ]).reshape(1, 2)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_int8_ef(u, r, scale, *, interpret=None):
    """Error-feedback int8 encode of one array in a single pass:
    e = u + r is formed in-register (never written to HBM), quantized
    with the given positive scalar ``scale``, and the next residual
    e − q·scale comes out of the same pass."""
    interp = _interp(interpret)
    t, n = _as_tiles(u.astype(jnp.float32), _cd.QBLOCK)
    rt, _ = _as_tiles(r, _cd.QBLOCK)
    s = jnp.full((1, 1), scale, jnp.float32)
    q, res = _fcc.quantize_int8_ef(t, rt, s, interpret=interp)
    return (
        _from_tiles(q, n, u.shape, jnp.int8),
        _from_tiles(res, n, u.shape, jnp.float32),
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def encode_bf16_ef(u, r, *, interpret=None):
    """Error-feedback bf16 encode: e = u + r cast and residualized in one
    pass, without materializing e."""
    interp = _interp(interpret)
    t, n = _as_tiles(u.astype(jnp.float32), _cd.QBLOCK)
    rt, _ = _as_tiles(r, _cd.QBLOCK)
    q, res = _fcc.encode_bf16_ef(t, rt, interpret=interp)
    return (
        _from_tiles(q, n, u.shape, jnp.bfloat16),
        _from_tiles(res, n, u.shape, jnp.float32),
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_decode_apply(w, prev_delta, q, scale, global_lr, momentum, *,
                      interpret=None):
    """Fused PS pull for an int8 payload: dequantize + Eqn. 1 apply in
    one pass. Returns (new_w, new_delta); arithmetic mirrors the
    reference decode → momentum_delta chain cast for cast."""
    interp = _interp(interpret)
    t, n = _as_tiles(w, _cd.QBLOCK)
    dt, _ = _as_tiles(prev_delta, _cd.QBLOCK)
    qt, _ = _as_tiles(q, _cd.QBLOCK)
    s = jnp.full((1, 1), scale, jnp.float32)
    nw, nd = _fcc.int8_decode_apply(t, dt, qt, s, _hp2(momentum, global_lr),
                                    interpret=interp)
    return (
        _from_tiles(nw, n, w.shape, w.dtype),
        _from_tiles(nd, n, prev_delta.shape, prev_delta.dtype),
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def bf16_decode_apply(w, prev_delta, q, global_lr, momentum, *, interpret=None):
    """Fused PS pull for a bf16 payload: widening cast + Eqn. 1 apply."""
    interp = _interp(interpret)
    t, n = _as_tiles(w, _cd.QBLOCK)
    dt, _ = _as_tiles(prev_delta, _cd.QBLOCK)
    qt, _ = _as_tiles(q, _cd.QBLOCK)
    nw, nd = _fcc.bf16_decode_apply(t, dt, qt, _hp2(momentum, global_lr),
                                    interpret=interp)
    return (
        _from_tiles(nw, n, w.shape, w.dtype),
        _from_tiles(nd, n, prev_delta.shape, prev_delta.dtype),
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_decode_accum(w, q, scale, global_lr, *, interpret=None):
    """Fused stateless pull (plain average) for an int8 payload:
    W ← W − η·(q·s) in one pass."""
    interp = _interp(interpret)
    t, n = _as_tiles(w, _cd.QBLOCK)
    qt, _ = _as_tiles(q, _cd.QBLOCK)
    s = jnp.full((1, 1), scale, jnp.float32)
    lr = jnp.full((1, 1), global_lr, jnp.float32)
    nw = _fcc.int8_decode_accum(t, qt, s, lr, interpret=interp)
    return _from_tiles(nw, n, w.shape, w.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bf16_decode_accum(w, q, global_lr, *, interpret=None):
    """Fused stateless pull (plain average) for a bf16 payload."""
    interp = _interp(interpret)
    t, n = _as_tiles(w, _cd.QBLOCK)
    qt, _ = _as_tiles(q, _cd.QBLOCK)
    lr = jnp.full((1, 1), global_lr, jnp.float32)
    nw = _fcc.bf16_decode_accum(t, qt, lr, interpret=interp)
    return _from_tiles(nw, n, w.shape, w.dtype)
