"""Virtual-clock, event-driven simulator of heterogeneous edge training.

Faithfully reproduces the paper's testbed semantics (M heterogeneous
workers + 1 PS, per-worker speeds and commit overheads, waiting-time
accounting) while doing *real* JAX gradient computation, so loss curves are
real and only wall-clock is virtual (deterministic and seeded).
"""

from .simulator import Simulator, SimConfig, TrainTask, WorkerState, SimResult
from .profiles import (
    ec2_profiles,
    ratio_profiles,
    heterogeneity_profiles,
    smartphone_profiles,
)

__all__ = [
    "Simulator",
    "SimConfig",
    "TrainTask",
    "WorkerState",
    "SimResult",
    "ec2_profiles",
    "ratio_profiles",
    "heterogeneity_profiles",
    "smartphone_profiles",
]
