"""Event-driven virtual-clock simulator of PS-based edge training.

Semantics (matching the paper's testbed + Alg. 2):

* M workers with profiles (v_i steps/sec, O_i seconds per commit round
  trip). Worker i trains mini-batches back to back; each step takes
  ``batch_scale_i / v_i`` virtual seconds (batch_scale_i = 1 for equal
  per-worker batches; BatchTune policies enlarge fast workers' batches).
* After each step the active ``SyncPolicy`` decides whether the worker
  commits its accumulated update U_i. A commit costs O_i/2 (push), the PS
  applies ``W ← W − η_global · U_i`` (immediately, or after a barrier
  collects the whole round), and the pull costs another O_i/2, after which
  the worker resumes with fresh parameters.
* The *waiting time* of a worker is everything that is not computation:
  waiting_i = elapsed − steps_i · step_time_i  (the paper's definition —
  communication counts as waiting).
* A checkpoint hook fires every Γ; epochs are driven by ``train()``.
* The global loss is evaluated (on held-out data, zero virtual cost) every
  ``eval_interval`` seconds; convergence is declared when the last
  ``converge_window`` evals vary by less than ``converge_tol`` (the
  paper's criterion) or when the loss first reaches ``target_loss``.

All randomness is seeded; two runs with the same config are bit-identical.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sync import SyncPolicy
from repro.core.theory import WorkerProfile

__all__ = ["TrainTask", "SimConfig", "WorkerState", "Simulator", "SimResult"]

Pytree = object


@dataclasses.dataclass
class TrainTask:
    """The learning problem, expressed as jitted JAX callables.

    grad_fn(params, batch) -> (loss, grads)
    eval_fn(params, batch) -> loss
    make_batch(worker_index, step, batch_size) -> batch   (seeded, cheap)
    eval_batch: held-out batch for global-loss evaluation.
    """

    init_params: Pytree
    grad_fn: Callable
    eval_fn: Callable
    make_batch: Callable
    eval_batch: object
    name: str = "task"


@dataclasses.dataclass
class SimConfig:
    gamma: float = 60.0  # check period Γ
    epoch_seconds: float = 1200.0  # paper: 20 min
    eval_interval: float = 5.0
    local_lr: float = 0.1  # η′ initial (paper default)
    local_lr_decay: float = 0.98  # exponential decay per check period
    global_lr: float | None = None  # default 1/M (paper default)
    base_batch: int = 128  # per-worker mini-batch at equal split
    max_seconds: float = 3600.0
    target_loss: float | None = None
    converge_window: int = 10
    converge_tol: float = 1e-3
    seed: int = 0


@dataclasses.dataclass
class WorkerState:
    index: int
    profile: WorkerProfile
    params: Pytree
    update: Pytree  # accumulated U_i
    steps: int = 0
    steps_since_commit: int = 0
    commits: int = 0
    computation_time: float = 0.0
    comm_time: float = 0.0
    blocked_since: float = -1.0
    delta_c_target: int = 1
    next_commit_time: float = math.inf
    status: str = "idle"  # idle | computing | committing | awaiting_release | blocked


@dataclasses.dataclass
class SimResult:
    policy: str
    times: np.ndarray  # eval times
    losses: np.ndarray  # global loss at eval times
    converged: bool
    convergence_time: float  # virtual seconds (inf if not converged)
    total_steps: int
    total_commits: int
    elapsed: float
    computation_time: float  # summed over workers
    waiting_time: float  # summed over workers (elapsed*M − computation)
    bytes_to_ps: float  # commits × model size (bandwidth proxy)
    commit_counts: list[int] = dataclasses.field(default_factory=list)

    @property
    def waiting_fraction(self) -> float:
        tot = self.computation_time + self.waiting_time
        return self.waiting_time / tot if tot > 0 else 0.0


class Simulator:
    """See module docstring."""

    def __init__(self, task: TrainTask, profiles: Sequence[WorkerProfile],
                 policy: SyncPolicy, config: SimConfig | None = None):
        self.task = task
        self.policy = policy
        self.cfg = config or SimConfig()
        self.now = 0.0
        self._heap: list = []
        self._seq = itertools.count()
        self.num_workers = len(profiles)
        self._zero = jax.tree.map(jnp.zeros_like, task.init_params)
        self.global_params = task.init_params
        self.workers = [
            WorkerState(i, p, task.init_params, self._zero)
            for i, p in enumerate(profiles)
        ]
        self.global_lr = (
            self.cfg.global_lr if self.cfg.global_lr is not None else 1.0 / self.num_workers
        )
        self.loss_history: list[tuple[float, float]] = []
        self.converged = False
        self.convergence_time = math.inf
        self.total_commits = 0
        self._barrier_buf: dict[int, Pytree] = {}
        self._param_sizes = sum(
            int(np.prod(x.shape)) for x in jax.tree.leaves(task.init_params)
        )
        self._next_eval = 0.0
        self._next_checkpoint = self.cfg.gamma
        self._local_lr = self.cfg.local_lr
        # jitted helpers -----------------------------------------------------
        self._accum = jax.jit(
            lambda u, g, lr: jax.tree.map(lambda a, b: a + lr * b, u, g)
        )
        self._sgd = jax.jit(
            lambda p, g, lr: jax.tree.map(lambda a, b: a - lr * b, p, g)
        )
        self._apply_commit = jax.jit(
            lambda w, u, lr: jax.tree.map(lambda a, b: a - lr * b, w, u)
        )
        self.policy.on_sim_start(self)
        for w in self.workers:
            self._start_step(w)
        self._eval_global()

    # ------------------------------------------------------------------ events
    def _push(self, t: float, kind: str, wid: int) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, wid))

    def _step_time(self, w: WorkerState) -> float:
        frac = self.policy.batch_fraction(self, w.index)
        batch_scale = frac * self.num_workers
        return batch_scale / w.profile.v

    def _batch_size(self, w: WorkerState) -> int:
        frac = self.policy.batch_fraction(self, w.index)
        return max(1, int(round(frac * self.num_workers * self.cfg.base_batch)))

    def _start_step(self, w: WorkerState) -> None:
        if self.policy.may_start_next_step(self, w):
            w.status = "computing"
            self._push(self.now + self._step_time(w), "step_done", w.index)
        else:
            w.status = "blocked"
            w.blocked_since = self.now

    def _retry_blocked(self) -> None:
        for w in self.workers:
            if w.status == "blocked" and self.policy.may_start_next_step(self, w):
                w.status = "computing"
                self._push(self.now + self._step_time(w), "step_done", w.index)

    # ------------------------------------------------------------------ handlers
    def _on_step_done(self, w: WorkerState) -> None:
        w.steps += 1
        w.steps_since_commit += 1
        w.computation_time += self._step_time(w)
        batch = self.task.make_batch(w.index, w.steps, self._batch_size(w))
        _loss, grads = self.task.grad_fn(w.params, batch)
        w.params = self._sgd(w.params, grads, self._local_lr)
        w.update = self._accum(w.update, grads, self._local_lr)
        if self.policy.should_commit(self, w):
            w.status = "committing"
            w.comm_time += w.profile.o
            self._push(self.now + w.profile.o / 2.0, "commit_arrive", w.index)
        else:
            self._start_step(w)
        self._retry_blocked()

    def _on_commit_arrive(self, w: WorkerState) -> None:
        if self.policy.apply_mode == "barrier":
            self._barrier_buf[w.index] = w.update
            w.status = "awaiting_release"
            if len(self._barrier_buf) == self.num_workers:
                for wid in sorted(self._barrier_buf):
                    self._do_apply(self.workers[wid])
                self._barrier_buf.clear()
                for ww in self.workers:
                    self._push(self.now + ww.profile.o / 2.0, "pull_done", ww.index)
        else:
            self._do_apply(w)
            self._push(self.now + w.profile.o / 2.0, "pull_done", w.index)

    def _do_apply(self, w: WorkerState) -> None:
        self.global_params = self._apply_commit(
            self.global_params, w.update, self.global_lr
        )
        self.total_commits += 1

    def _on_pull_done(self, w: WorkerState) -> None:
        w.params = self.global_params
        w.update = self._zero
        w.steps_since_commit = 0
        w.commits += 1
        self.policy.on_commit_applied(self, w)
        self._start_step(w)
        self._retry_blocked()

    # ------------------------------------------------------------------ loop
    def _run_until(self, t_end: float) -> None:
        while self._heap and not self.converged:
            t = self._heap[0][0]
            # Fire evals/checkpoints that precede the next worker event.
            while self._next_eval <= min(t, t_end):
                self.now = self._next_eval
                self._eval_global()
                self._next_eval += self.cfg.eval_interval
                if self.converged:
                    return
            while self._next_checkpoint <= min(t, t_end):
                self.now = self._next_checkpoint
                self._local_lr = self.cfg.local_lr * (
                    self.cfg.local_lr_decay ** (self.now / self.cfg.gamma)
                )
                self.policy.on_checkpoint(self)
                self._next_checkpoint += self.cfg.gamma
            if t > t_end:
                self.now = t_end
                return
            t, _, kind, wid = heapq.heappop(self._heap)
            self.now = t
            w = self.workers[wid]
            if kind == "step_done":
                self._on_step_done(w)
            elif kind == "commit_arrive":
                self._on_commit_arrive(w)
            elif kind == "pull_done":
                self._on_pull_done(w)
        self.now = min(t_end, self.now) if self._heap else t_end

    def _eval_global(self) -> None:
        loss = float(self.task.eval_fn(self.global_params, self.task.eval_batch))
        self.loss_history.append((self.now, loss))
        if self.cfg.target_loss is not None and loss <= self.cfg.target_loss:
            self._declare_converged()
            return
        k = self.cfg.converge_window
        if (
            len(self.loss_history) >= k
            and self.cfg.target_loss is None
            # Variance-based convergence only counts once the global model
            # has actually been trained (≥1 commit per worker on average)
            # and improved on its initial loss — otherwise the flat
            # pre-first-commit plateau would trigger it.
            and self.total_commits >= self.num_workers
            and loss < self.loss_history[0][1]
        ):
            recent = [l for _, l in self.loss_history[-k:]]
            if max(recent) - min(recent) < self.cfg.converge_tol:
                self._declare_converged()

    def _declare_converged(self) -> None:
        if not self.converged:
            self.converged = True
            self.convergence_time = self.now

    # ------------------------------------------------------------------ API
    def recent_global_loss(self) -> float | None:
        if not self.loss_history:
            return None
        tail = self.loss_history[-3:]
        return float(np.mean([l for _, l in tail]))

    def run_window(self, seconds: float) -> tuple[list[float], list[float]]:
        """Run live for `seconds`; return (times, losses) sampled within —
        the OnlineEvaluate primitive of Alg. 1."""
        start = self.now
        self._eval_global()
        self._run_until(start + seconds)
        if not self.converged:  # don't jump the clock past a finished run
            self.now = max(self.now, start + seconds)
        self._eval_global()
        ts = [t for t, _ in self.loss_history if t >= start]
        ls = [l for t, l in self.loss_history if t >= start]
        if len(ts) < 3:  # force a midpoint sample for the curve fit
            ts.insert(1, (ts[0] + ts[-1]) / 2)
            ls.insert(1, (ls[0] + ls[-1]) / 2)
        return ts, ls

    def run(self, seconds: float) -> None:
        self._run_until(self.now + seconds)

    def set_c_target(self, c: int) -> None:
        if hasattr(self.policy, "c_target"):
            self.policy.c_target = int(c)
            self.policy._assign_rates(self)

    def train(self, max_seconds: float | None = None) -> SimResult:
        """Drive epochs until convergence or the time budget."""
        budget = max_seconds if max_seconds is not None else self.cfg.max_seconds
        while self.now < budget and not self.converged:
            self.policy.on_epoch(self)  # may consume probe windows
            if self.converged:
                break
            t_epoch_end = min(self.now + self.cfg.epoch_seconds, budget)
            self._run_until(t_epoch_end)
            if not self._heap:
                break
        return self.result()

    def result(self) -> SimResult:
        times = np.array([t for t, _ in self.loss_history])
        losses = np.array([l for _, l in self.loss_history])
        comp = sum(w.computation_time for w in self.workers)
        elapsed = self.now
        waiting = max(elapsed * self.num_workers - comp, 0.0)
        return SimResult(
            policy=self.policy.name,
            times=times,
            losses=losses,
            converged=self.converged,
            convergence_time=self.convergence_time,
            total_steps=sum(w.steps for w in self.workers),
            total_commits=self.total_commits,
            elapsed=elapsed,
            computation_time=comp,
            waiting_time=waiting,
            bytes_to_ps=4.0 * self._param_sizes * self.total_commits,
            commit_counts=[w.commits for w in self.workers],
        )
