"""Event-driven virtual-clock simulator of PS-based edge training.

Semantics (matching the paper's testbed + Alg. 2):

* M workers with profiles (v_i steps/sec, O_i seconds per commit round
  trip). Worker i trains mini-batches back to back; each step takes
  ``batch_scale_i / v_i`` virtual seconds (batch_scale_i = 1 for equal
  per-worker batches; BatchTune policies enlarge fast workers' batches).
* After each step the control plane decides whether the worker commits
  its accumulated update U_i. The update is **encoded** by the configured
  transport codec (``repro.transport``; identity / int8 / bf16 / top_k,
  each with error-feedback residual carried per worker), the push costs
  ``O_i/2 + latency_i + encoded_bytes / bandwidth_i`` (the fixed protocol
  overhead plus the payload moving over the worker's link), the PS
  **decodes** and applies ``W ← W − η_global · U_i`` (immediately, or
  after a barrier collects the whole round), and the pull costs
  ``O_i/2 + latency_i + dense_bytes / bandwidth_i`` (fresh params ship
  down uncompressed), after which the worker resumes. With the identity
  codec and the default infinite-bandwidth link this reduces exactly to
  the fixed ``O_i/2 + O_i/2`` commit cost of the original model, and
  ``bytes_to_ps`` is *measured* from encoded payload sizes instead of
  the old ``4 · |params| · commits`` proxy.
* **Sharded PS** (``n_shards`` > 1, DESIGN.md §11): the model pytree is
  partitioned into K size-balanced shards by the deterministic
  ``repro.ps.ShardPlan``. A commit's per-shard payloads are serialized
  FIFO on the worker's link — shard j's transfer starts when shard j−1's
  finishes, so the PS applies early shards while later ones are still in
  flight — and each applied shard bumps a per-shard PS version counter.
  Pulls are *partial*: the worker fetches only shards whose PS version
  exceeds the version its local copy reflects. A worker's own applied
  shard does not stale its copy when no other writer interleaved (it
  knows its own decoded payload, so it tracks the PS for free), and a
  shard another worker is still mid-push with is not yet stale — on a
  link-bound fleet both effects shrink pull bytes (``bytes_from_ps``).
  The pull still teleports the PS state as of pull *completion* (the
  pre-sharding simplification); stale-set bytes are assessed at pull
  schedule time. ``n_shards=1`` (default) runs the exact pre-sharding
  monolithic code path — bit-identical timing and byte accounting.
* The *waiting time* of a worker is everything that is not computation:
  waiting_i = active − steps_i · step_time_i  (the paper's definition —
  communication counts as waiting).
* A checkpoint hook fires every Γ; epochs are driven by ``train()``.
* The global loss is evaluated (on held-out data, zero virtual cost) every
  ``eval_interval`` seconds; convergence is declared when the last
  ``converge_window`` evals vary by less than ``converge_tol`` (the
  paper's criterion) or when the loss first reaches ``target_loss``.

Control plane: the simulator is a *backend* of
``repro.cluster.ClusterEngine`` (DESIGN.md §2, §12). Every decision
point — commit-or-not, block-or-start, rates, timers, batch fractions,
the Alg. 1 search — is an event dispatched through the engine to the
active policy; the simulator only executes physics (virtual clock,
gradients, PS math). The same engine+policy pair drives the real mesh
loop, so Alg. 1/Alg. 2 logic exists exactly once. A ``Search`` runs as
an incremental ``repro.control.SearchSession`` whose probe windows are
live simulation, so churn landing mid-probe restarts the session — and
with ``ADSP(search_mode="drift"|"both")`` a churn or speed-shift event
can itself trigger a mid-epoch re-search (the engine re-enters
``_run_until`` for the probe windows; its clock guards keep time
monotone across that nesting).

Elastic churn: ``add_worker`` / ``remove_worker`` / ``set_speed`` (or a
declarative ``cluster.ChurnSchedule``) change the fleet mid-run; the
engine re-derives commit rates via WorkerJoined/WorkerLeft/SpeedChanged.

All randomness is seeded; two runs with the same config are bit-identical.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster import ChurnSchedule, ClusterEngine
from repro.control.theory import WorkerProfile
from repro.fleet import CommitRecord, EvalRecord, FleetConfig, FleetMonitor
from repro.ps.sharding import ShardPlan
from repro.transport import Codec, dense_nbytes, get_codec

__all__ = ["TrainTask", "SimConfig", "WorkerState", "Simulator", "SimResult"]

Pytree = object


@dataclasses.dataclass
class TrainTask:
    """The learning problem, expressed as jitted JAX callables.

    grad_fn(params, batch) -> (loss, grads)
    eval_fn(params, batch) -> loss
    make_batch(worker_index, step, batch_size) -> batch   (seeded, cheap)
    eval_batch: held-out batch for global-loss evaluation.
    """

    init_params: Pytree
    grad_fn: Callable
    eval_fn: Callable
    make_batch: Callable
    eval_batch: object
    name: str = "task"


@dataclasses.dataclass
class SimConfig:
    gamma: float = 60.0  # check period Γ
    epoch_seconds: float = 1200.0  # paper: 20 min
    eval_interval: float = 5.0
    local_lr: float = 0.1  # η′ initial (paper default)
    local_lr_decay: float = 0.98  # exponential decay per check period
    global_lr: float | None = None  # default 1/M (paper default)
    base_batch: int = 128  # per-worker mini-batch at equal split
    max_seconds: float = 3600.0
    target_loss: float | None = None
    converge_window: int = 10
    converge_tol: float = 1e-3
    seed: int = 0


@dataclasses.dataclass
class WorkerState:
    """Training state + control-plane bookkeeping of one worker.

    Duck-types ``repro.cluster.WorkerView`` (adds params/update/timing);
    ``index`` is a stable id — it never shifts when other workers leave.
    """

    index: int
    profile: WorkerProfile
    params: Pytree
    update: Pytree  # accumulated U_i
    steps: int = 0
    steps_since_commit: int = 0
    commits: int = 0
    computation_time: float = 0.0
    comm_time: float = 0.0
    blocked_since: float = -1.0
    delta_c_target: int = 1
    next_commit_time: float = math.inf
    batch_fraction: float | None = None  # None → equal split 1/M
    joined_at: float = 0.0
    step_started: float = -1.0  # when the in-flight step was scheduled
    step_credit: int = 0  # joiner ramp-in credit (engine.worker_joined)
    commit_credit: int = 0
    status: str = "idle"  # idle | computing | committing | awaiting_release | blocked | stalled | catching_up
    # generation counter: bumped when the worker silently stalls (and on
    # rejoin), so in-flight heap events of the frozen life are dropped
    gen: int = 0
    # metrics bookkeeping (repro.fleet): when the in-flight commit was
    # decided, and what its pull will fetch
    commit_started: float = -1.0
    pending_pull_nbytes: float = 0.0
    pending_pull_stale: int = 0
    residual: Pytree = ()  # codec error-feedback state (rule-owned)
    pending_commit: Pytree = None  # encoded payload of the in-flight commit
    # sharded PS (n_shards > 1) bookkeeping: the in-flight per-shard
    # payloads, and the PS version each local shard copy reflects
    pending_shards: list | None = None
    shard_known: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class SimResult:
    policy: str
    times: np.ndarray  # eval times
    losses: np.ndarray  # global loss at eval times
    converged: bool
    convergence_time: float  # virtual seconds (inf if not converged)
    total_steps: int
    total_commits: int
    elapsed: float
    computation_time: float  # summed over workers (incl. departed)
    waiting_time: float  # summed over workers (active − computation)
    bytes_to_ps: float  # measured: Σ encoded payload bytes over all commits
    # measured PS→worker pull bytes; with a sharded PS only stale shards
    # ship, so this shrinks with K (the monolithic PS always pulls dense)
    bytes_from_ps: float = 0.0
    commit_counts: list[int] = dataclasses.field(default_factory=list)

    @property
    def waiting_fraction(self) -> float:
        tot = self.computation_time + self.waiting_time
        return self.waiting_time / tot if tot > 0 else 0.0


class Simulator:
    """See module docstring."""

    def __init__(self, task: TrainTask, profiles: Sequence[WorkerProfile],
                 policy, config: SimConfig | None = None,
                 churn: ChurnSchedule | None = None,
                 codec: str | Codec = "identity",
                 n_shards: int = 1,
                 fleet: FleetConfig | None = None,
                 metrics=None):
        self.task = task
        self.cfg = config or SimConfig()
        self.churn = churn
        # fleet orchestration (DESIGN.md §13): heartbeat/lease failure
        # discovery + capability-aware scheduling. None → zero overhead,
        # bit-identical to the pre-fleet simulator.
        self.metrics = metrics
        self.fleet = FleetMonitor(fleet, metrics=metrics) if fleet is not None else None
        self._lease_gone: dict[int, WorkerState] = {}  # expired, may rejoin
        self._dead_time = 0.0  # offline spans of rejoined workers
        self.now = 0.0
        self._heap: list = []
        self._seq = itertools.count()
        self._next_id = itertools.count()
        self._zero = jax.tree.map(jnp.zeros_like, task.init_params)
        self.global_params = task.init_params
        # transport: codec + per-link payload timing ------------------------
        self.codec = get_codec(codec)
        self._zero_residual = self.codec.init(task.init_params)
        if self.codec.name == "identity":
            # exact passthrough — keep un-jitted so arrays flow through
            # untouched and the no-transport numerics stay bit-identical
            self._encode, self._decode = self.codec.encode, self.codec.decode
        else:
            self._encode = jax.jit(self.codec.encode)
            self._decode = jax.jit(self.codec.decode)
        self._enc_nbytes = self.codec.encoded_nbytes(task.init_params)
        self._pull_nbytes = dense_nbytes(task.init_params)
        self._bytes_to_ps = 0
        self._bytes_from_ps = 0
        # sharded PS (n_shards > 1): per-shard payload sizes + versions ----
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.plan = ShardPlan.build(task.init_params, n_shards)
        self.n_shards = self.plan.n_shards
        self._params_treedef = jax.tree.structure(task.init_params)
        self._res_sliceable = (
            jax.tree.structure(self._zero_residual) == self._params_treedef
        )
        if self.n_shards > 1:
            self._shard_enc_nbytes = [
                self.codec.encoded_nbytes(self.plan.slice(task.init_params, k))
                for k in range(self.n_shards)
            ]
            self._shard_pull_nbytes = list(self.plan.shard_nbytes())
            self._ps_version = [0] * self.n_shards
        self.workers = [
            WorkerState(next(self._next_id), p, task.init_params, self._zero,
                        residual=self._zero_residual,
                        shard_known=[0] * self.n_shards)
            for p in profiles
        ]
        self._by_id = {w.index: w for w in self.workers}
        self._departed: list[tuple[WorkerState, float]] = []  # (state, left_at)
        self._refresh_global_lr()
        self.loss_history: list[tuple[float, float]] = []
        self.converged = False
        self.convergence_time = math.inf
        self.total_commits = 0
        self._barrier_buf: dict[int, Pytree] = {}
        self._round_members = {w.index for w in self.workers}
        self._param_sizes = sum(
            int(np.prod(x.shape)) for x in jax.tree.leaves(task.init_params)
        )
        self._next_eval = 0.0
        self._next_checkpoint = self.cfg.gamma
        self._local_lr = self.cfg.local_lr
        # jitted helpers -----------------------------------------------------
        self._accum = jax.jit(
            lambda u, g, lr: jax.tree.map(lambda a, b: a + lr * b, u, g)
        )
        self._sgd = jax.jit(
            lambda p, g, lr: jax.tree.map(lambda a, b: a - lr * b, p, g)
        )
        self._apply_commit = jax.jit(
            lambda w, u, lr: jax.tree.map(lambda a, b: a - lr * b, w, u)
        )
        # control plane ------------------------------------------------------
        self.engine = ClusterEngine(policy, backend=self, metrics=metrics)
        self.policy = self.engine.policy
        self.engine.start()
        if self.fleet is not None:
            for w in self.workers:
                self.fleet.join(w.index, 0.0, w.profile)
            self.engine.execute(self.fleet.assignments(0.0))
        for w in self.workers:
            self._start_step(w)
        self._eval_global()

    # ------------------------------------------------------------ backend API
    def bind(self, engine: ClusterEngine) -> None:
        self.engine = engine

    def worker_by_id(self, index: int) -> WorkerState:
        try:
            return self._by_id[index]
        except KeyError:
            raise KeyError(f"no alive worker with id {index}") from None

    def wake(self, w: WorkerState) -> None:
        """A parked worker was resumed by the engine."""
        if w.status == "blocked" and w.index in self._by_id:
            w.status = "computing"
            w.step_started = self.now
            self._push(self.now + self._step_time(w), "step_done", w.index)

    @property
    def num_workers(self) -> int:
        return len(self.workers)

    def _refresh_global_lr(self) -> None:
        self.global_lr = (
            self.cfg.global_lr if self.cfg.global_lr is not None
            else 1.0 / max(self.num_workers, 1)
        )

    # ------------------------------------------------------------------ churn
    def add_worker(self, profile: WorkerProfile) -> WorkerState:
        """Elastic scale-out: the joiner starts from the current global
        model with an empty update buffer."""
        w = WorkerState(next(self._next_id), profile, self.global_params,
                        self._zero, joined_at=self.now,
                        residual=self._zero_residual,
                        shard_known=(list(self._ps_version)
                                     if self.n_shards > 1 else [0]))
        self.workers.append(w)
        self._by_id[w.index] = w
        self._refresh_global_lr()
        if self.fleet is not None:
            self.fleet.join(w.index, self.now, w.profile)
        self.engine.worker_joined(w)
        if self.fleet is not None:
            self.engine.execute(self.fleet.assignments(self.now))
        self._start_step(w)
        return w

    def remove_worker(self, index: int) -> None:
        """Elastic scale-in: drop the worker; its in-flight update is
        discarded (crash semantics — ADSP tolerates it, §6). Under a
        sharded PS (immediate mode) each shard apply is atomic at the PS,
        so a crash mid-push keeps the shards that already arrived (their
        wire bytes booked) and loses only the rest — the counted-commit
        ≡ enc_bytes correspondence holds per *shard*, not per commit,
        in churn runs."""
        w = self._by_id.get(index)
        if w is None:
            raise KeyError(f"no alive worker with id {index}")
        if len(self.workers) == 1:
            raise ValueError("cannot remove the last worker")
        if self.fleet is not None:
            # administrative departure: retire the lease so a pending
            # expiry can't synthesize a second WorkerLeft for this worker
            self.fleet.scripted_leave(index, self.now)
        self._remove(w, discovered=False)

    def _remove(self, w: WorkerState, discovered: bool) -> None:
        """Common tail of scripted removal and lease-expiry discovery."""
        del self._by_id[w.index]
        self.workers.remove(w)
        self._departed.append((w, self.now))
        self._barrier_buf.pop(w.index, None)
        self._round_members.discard(w.index)
        self._refresh_global_lr()
        self.engine.worker_left(w.index, discovered=discovered)
        if self.fleet is not None:
            self.engine.execute(self.fleet.assignments(self.now))
        self._maybe_release_barrier()

    def stall_worker(self, index: int) -> None:
        """Silent failure: the worker freezes with NO departure notice.
        Its in-flight events are invalidated (generation bump) and its
        heartbeats stop; the engine keeps planning around it until the
        lease layer (if any) discovers the death at lease expiry. Without
        a fleet monitor a stalled worker is simply gone dark — a barrier
        policy will wait on it forever, which is exactly the failure mode
        ``benchmarks/bench_fleet.py`` quantifies."""
        w = self._by_id.get(index)
        if w is None:
            raise KeyError(f"no alive worker with id {index}")
        if w.status == "stalled":
            return
        w.status = "stalled"
        w.gen += 1  # drop this life's in-flight step/commit/pull events
        if self.fleet is not None:
            self.fleet.stall(index, self.now)

    def recover_worker(self, index: int) -> None:
        """A stalled worker comes back. Before its lease expired the
        control plane never knew — it silently resumes stepping (in-flight
        work of the frozen life was dropped). After expiry it is a
        *discovered rejoin*: WorkerJoined(discovered=True) plus a state
        catch-up over the partial-pull path (PR 4)."""
        w = self._by_id.get(index)
        if w is not None:
            if w.status != "stalled":
                return
            if self.fleet is not None and not self.fleet.recover(index, self.now):
                # raced: the lease expired in this same instant — still
                # dead; the expiry timer will discover the departure
                return
            # crash semantics: whatever was mid-push/pull when it froze is
            # lost; locally accumulated update survives (process lived on)
            w.pending_commit = None
            w.pending_shards = None
            w.status = "idle"
            self._start_step(w)
            return
        if index in self._lease_gone:
            self._rejoin(self._lease_gone.pop(index))
            return
        raise KeyError(f"no worker with id {index} to recover")

    def _discover_departure(self, index: int) -> None:
        """Lease expiry: synthesize WorkerLeft(discovered=True) for a
        worker that never said goodbye. Parked in ``_lease_gone`` so a
        later recovery rejoins it (and so a scripted leave racing this
        discovery dedupes to exactly one WorkerLeft)."""
        w = self._by_id.get(index)
        if w is None:
            return  # already administratively removed
        if len(self.workers) == 1:
            return  # never evict the last worker; keep the run alive
        self._lease_gone[index] = w
        self._remove(w, discovered=True)

    def _rejoin(self, w: WorkerState) -> None:
        """A lease-expired worker comes back: pull it out of the departed
        accounting (its offline span must not count as waiting), re-admit
        it, and schedule a state catch-up. Like elastic joiners it loses
        uncommitted local work and re-enters through the engine's ramp-in
        credit (its pre-stall step/commit history is absorbed into the
        credit baseline)."""
        for i, (d, left_at) in enumerate(self._departed):
            if d is w:
                self._dead_time += self.now - left_at
                del self._departed[i]
                break
        w.gen += 1
        w.status = "catching_up"
        w.pending_commit = None
        w.pending_shards = None
        w.update = self._zero
        w.steps_since_commit = 0
        w.residual = self._zero_residual
        self.workers.append(w)
        self._by_id[w.index] = w
        self._refresh_global_lr()
        if self.fleet is not None:
            self.fleet.join(w.index, self.now, w.profile, rejoin=True)
        self.engine.worker_joined(w, discovered=True)
        if self.fleet is not None:
            self.engine.execute(self.fleet.assignments(self.now))
        # state catch-up: under a sharded PS only the shards whose version
        # moved while the worker was dead ship (PR 4's partial-pull path);
        # the monolithic PS re-ships dense params
        if self.n_shards > 1:
            self._schedule_partial_pull(w, kind="catchup_done")
        else:
            dur = self._pull_seconds(w)
            w.comm_time += dur
            self._bytes_from_ps += self._pull_nbytes
            self._push(self.now + dur, "catchup_done", w.index)

    def _on_catchup_done(self, w: WorkerState) -> None:
        w.params = self.global_params
        if self.n_shards > 1:
            w.shard_known = list(self._ps_version)
        w.status = "idle"
        self._start_step(w)

    def set_speed(self, index: int, v: float) -> None:
        """Mid-run speed shift (throttling, contention, recovery)."""
        w = self._by_id[index]
        w.profile = dataclasses.replace(w.profile, v=v)
        self.engine.speed_changed(w)
        if self.fleet is not None:
            # the *scheduler* only learns the new capability at the next
            # heartbeat arrival — reassignment is deferred to that report
            t_rep = self.fleet.next_report_after(index, self.now)
            if math.isfinite(t_rep):
                self._push(t_rep, "hb_report", index)

    def _on_hb_report(self, w: WorkerState) -> None:
        if self.fleet is None or w.status == "stalled":
            return
        self.fleet.report(w.index, self.now, w.profile.v)
        self.engine.execute(self.fleet.assignments(self.now))

    def _apply_churn(self, act) -> None:
        if act.kind == "join":
            self.add_worker(act.profile)
        elif act.kind == "leave":
            if act.worker in self._lease_gone:
                # the lease layer already discovered this departure —
                # scripted leave and missed lease dedupe to ONE WorkerLeft
                del self._lease_gone[act.worker]
                return
            self.remove_worker(act.worker)
        elif act.kind == "speed":
            self.set_speed(act.worker, act.v)
        elif act.kind == "stall":
            self.stall_worker(act.worker)
        else:  # "recover"
            self.recover_worker(act.worker)

    # ------------------------------------------------------------------ events
    def _push(self, t: float, kind: str, wid: int, arg: int | None = None) -> None:
        # events carry the worker's generation: a silent stall bumps it,
        # so the frozen life's in-flight events are dropped at pop
        gen = self._by_id[wid].gen if wid in self._by_id else 0
        heapq.heappush(self._heap, (t, next(self._seq), kind, wid, arg, gen))

    def _step_time(self, w: WorkerState) -> float:
        frac = self.engine.batch_fraction(w)
        batch_scale = frac * self.num_workers
        return batch_scale / w.profile.v

    def _batch_size(self, w: WorkerState) -> int:
        frac = self.engine.batch_fraction(w)
        return max(1, int(round(frac * self.num_workers * self.cfg.base_batch)))

    def _start_step(self, w: WorkerState) -> None:
        if self.engine.may_start(w):
            w.status = "computing"
            w.step_started = self.now
            self._push(self.now + self._step_time(w), "step_done", w.index)
        else:
            w.status = "blocked"
            w.blocked_since = self.now

    # ------------------------------------------------------------------ handlers
    def _on_step_done(self, w: WorkerState) -> None:
        w.steps += 1
        w.steps_since_commit += 1
        # Charge the duration the step was scheduled with — a mid-step
        # speed/batch change (churn) must not rewrite in-flight history.
        w.computation_time += (
            self.now - w.step_started if w.step_started >= 0
            else self._step_time(w)
        )
        batch = self.task.make_batch(w.index, w.steps, self._batch_size(w))
        _loss, grads = self.task.grad_fn(w.params, batch)
        w.params = self._sgd(w.params, grads, self._local_lr)
        w.update = self._accum(w.update, grads, self._local_lr)
        if self.engine.step_done(w):
            w.status = "committing"
            w.commit_started = self.now
            if self.n_shards > 1:
                self._start_sharded_push(w)
            else:
                # Encode at the worker: the codec compresses U (folding in
                # the error-feedback residual) and the push moves only the
                # encoded payload over this worker's link.
                w.pending_commit, w.residual = self._encode(w.update, w.residual)
                push = self._push_seconds(w)
                w.comm_time += push + self._pull_seconds(w)
                self._push(self.now + push, "commit_arrive", w.index)
        else:
            self._start_step(w)

    # ------------------------------------------------------------- transport
    def _push_seconds(self, w: WorkerState) -> float:
        """Worker → PS: fixed overhead + encoded payload over the link."""
        return w.profile.o / 2.0 + w.profile.transfer_seconds(self._enc_nbytes)

    def _pull_seconds(self, w: WorkerState) -> float:
        """PS → worker: fixed overhead + dense fresh params over the link."""
        return w.profile.o / 2.0 + w.profile.transfer_seconds(self._pull_nbytes)

    # ------------------------------------------------- sharded PS (K > 1)
    def _encode_shards(self, w: WorkerState) -> list:
        """Per-shard encode of ``w.update``, threading the error-feedback
        residual shard-wise (the residual partitions leaf-for-leaf with
        the params for every lossy codec; leafless residuals — identity —
        pass through whole)."""
        encs = []
        if self._res_sliceable:
            res_leaves = list(jax.tree.leaves(w.residual))
            for k in range(self.n_shards):
                idx = self.plan.shard_leaf_indices(k)
                enc, new_res = self._encode(
                    self.plan.slice(w.update, k), [res_leaves[i] for i in idx]
                )
                for i, leaf in zip(idx, new_res):
                    res_leaves[i] = leaf
                encs.append(enc)
            w.residual = jax.tree.unflatten(self._params_treedef, res_leaves)
        else:
            res = w.residual
            for k in range(self.n_shards):
                enc, res = self._encode(self.plan.slice(w.update, k), res)
                encs.append(enc)
            w.residual = res
        return encs

    def _start_sharded_push(self, w: WorkerState) -> None:
        """Serialize the K per-shard payloads FIFO on the worker's link:
        shard j's transfer starts when shard j−1's finishes, each arrival
        lands one propagation latency after its transfer completes. The
        fixed O_i/2 protocol overhead is paid once per commit, so K=1
        reproduces the lumped ``_push_seconds`` exactly."""
        w.pending_shards = self._encode_shards(w)
        base = self.now + w.profile.o / 2.0
        t = 0.0
        for k in range(self.n_shards):
            t += self._shard_enc_nbytes[k] / w.profile.bandwidth
            self._push(base + t + w.profile.latency, "shard_arrive", w.index, k)
        # push time charged now; the (partial) pull is charged when its
        # stale set — unknowable until the last shard lands — is assessed
        w.comm_time += w.profile.o / 2.0 + t + w.profile.latency

    def _apply_shard(self, w: WorkerState, k: int) -> None:
        """PS-side apply of one arrived shard payload: decode, update the
        shard's leaves, bump its version. The committing worker keeps
        tracking a shard it was current on (it knows its own decoded
        payload), so its own commit never forces a re-fetch of shards no
        other writer touched in between."""
        like = self.plan.slice(self.global_params, k)
        u = self._decode(w.pending_shards[k], like)
        new_leaves = self._apply_commit(like, u, self.global_lr)
        self.global_params = self.plan.merge(self.global_params, k, new_leaves)
        was_current = w.shard_known[k] == self._ps_version[k]
        self._ps_version[k] += 1
        if was_current:
            w.shard_known[k] = self._ps_version[k]
        self._bytes_to_ps += self._shard_enc_nbytes[k]

    def _schedule_partial_pull(self, w: WorkerState,
                               kind: str = "pull_done") -> None:
        """Pull only the shards whose PS version moved past the worker's
        local copy; the fixed O_i/2 + latency round trip (learning the
        version vector) is paid even when nothing is stale. ``kind``
        selects the completion event (``catchup_done`` for rejoin)."""
        stale = [k for k in range(self.n_shards)
                 if self._ps_version[k] > w.shard_known[k]]
        nbytes = sum(self._shard_pull_nbytes[k] for k in stale)
        dur = w.profile.o / 2.0 + w.profile.transfer_seconds(nbytes)
        w.comm_time += dur
        self._bytes_from_ps += nbytes
        w.pending_pull_nbytes = float(nbytes)
        w.pending_pull_stale = len(stale)
        self._push(self.now + dur, kind, w.index)

    def _on_shard_arrive(self, w: WorkerState, k: int) -> None:
        if self.engine.policy.apply_mode == "barrier":
            # shards accumulate at the PS but apply only at the release
            if k == self.n_shards - 1:
                self._barrier_buf[w.index] = w.pending_shards
                w.status = "awaiting_release"
                self._maybe_release_barrier()
            return
        self._apply_shard(w, k)
        if k == self.n_shards - 1:
            self.total_commits += 1
            w.pending_shards = None
            self._schedule_partial_pull(w)

    def _on_commit_arrive(self, w: WorkerState) -> None:
        if self.engine.policy.apply_mode == "barrier":
            self._barrier_buf[w.index] = w.pending_commit
            w.status = "awaiting_release"
            self._maybe_release_barrier()
        else:
            self._do_apply(w)
            self._bytes_from_ps += self._pull_nbytes
            w.pending_pull_nbytes = float(self._pull_nbytes)
            w.pending_pull_stale = 1
            self._push(self.now + self._pull_seconds(w), "pull_done", w.index)

    def _maybe_release_barrier(self) -> None:
        """Release the barrier once every *round member* has committed.

        Membership is the set of workers alive when the round started; an
        elastic joiner mid-step is folded in at the next release, so it
        neither stalls the veterans nor — crucially — gets pulled while
        still computing. Only the workers whose commits were buffered are
        pulled: pulling every alive worker (the old behaviour) zeroed a
        computing joiner's accumulated update, counted a phantom commit,
        and scheduled a second in-flight step for it.
        """
        if not self._barrier_buf:
            return
        if not self._round_members <= set(self._barrier_buf):
            return
        pulled = set(self._barrier_buf)
        for wid in sorted(self._barrier_buf):
            self._do_apply(self._by_id[wid])
        self._barrier_buf.clear()
        for ww in self.workers:
            if ww.index in pulled:
                if ww.status == "stalled":
                    # its buffered payload was applied (it arrived at the
                    # PS before the freeze) but the pull to a dead host is
                    # lost; it resumes — or is evicted — via the lease path
                    continue
                if self.n_shards > 1:
                    self._schedule_partial_pull(ww)
                else:
                    self._bytes_from_ps += self._pull_nbytes
                    ww.pending_pull_nbytes = float(self._pull_nbytes)
                    ww.pending_pull_stale = 1
                    self._push(self.now + self._pull_seconds(ww), "pull_done",
                               ww.index)
        self._round_members = set(self._by_id)

    def _do_apply(self, w: WorkerState) -> None:
        # Decode at the PS: the encoded payload becomes a dense update.
        # Wire bytes are booked per *applied* commit (matching the commit
        # counter; an in-flight payload at run end is not reported).
        if self.n_shards > 1:  # barrier release of a complete sharded commit
            for k in range(self.n_shards):
                self._apply_shard(w, k)
            self.total_commits += 1
            w.pending_shards = None
            return
        u = self._decode(w.pending_commit, self.global_params)
        self.global_params = self._apply_commit(
            self.global_params, u, self.global_lr
        )
        self.total_commits += 1
        self._bytes_to_ps += self._enc_nbytes

    def _on_pull_done(self, w: WorkerState) -> None:
        w.params = self.global_params
        w.update = self._zero
        w.steps_since_commit = 0
        w.commits += 1
        if self.metrics is not None:
            push_b = (sum(self._shard_enc_nbytes) if self.n_shards > 1
                      else self._enc_nbytes)
            self.metrics.record(CommitRecord(
                t=self.now, worker=w.index,
                latency=(self.now - w.commit_started
                         if w.commit_started >= 0 else 0.0),
                push_bytes=float(push_b),
                pull_bytes=w.pending_pull_nbytes,
                stale_shards=w.pending_pull_stale,
                n_shards=self.n_shards,
                versions=(tuple(self._ps_version) if self.n_shards > 1
                          else (self.total_commits,)),
            ))
            w.commit_started = -1.0
        if self.n_shards > 1:
            # the pull teleports the PS state as of completion, so the
            # local copy now reflects every shard's current version
            w.shard_known = list(self._ps_version)
        self.engine.commit_applied(w)
        self._start_step(w)

    # ------------------------------------------------------------------ loop
    def _fire_timers(self, horizon: float) -> bool:
        """Fire evals / churn / lease expiries / checkpoints due at or
        before ``horizon``. Returns True if the run converged while doing
        so. Lease expiries are *batch* checks: the tracker keeps a heap of
        statically computed deadlines (heartbeat streams are deterministic
        between stall/recover/speed changes), so a 10k-worker fleet costs
        O(changes·log M), not O(heartbeats)."""
        while True:
            candidates = [self._next_eval, self._next_checkpoint]
            nt = self.churn.next_time() if self.churn is not None else None
            if nt is not None:
                candidates.append(nt)
            le = self.fleet.next_expiry() if self.fleet is not None else math.inf
            if math.isfinite(le):
                candidates.append(le)
            t_min = min(candidates)
            if t_min > horizon:
                return False
            if self._heap and self._heap[0][0] < t_min:
                # a previous timer handler scheduled cluster work (lease
                # discovery released a barrier, churn rejoined a worker)
                # due before the next timer: yield to the event loop
                return False
            self.now = max(self.now, t_min)
            if t_min == self._next_eval:
                self._eval_global()
                self._next_eval += self.cfg.eval_interval
                if self.converged:
                    return True
            elif nt is not None and t_min == nt:
                # scripted churn fires before lease discovery at ties —
                # an administrative leave beats the expiry to the punch
                for act in self.churn.due(self.now):
                    self._apply_churn(act)
            elif t_min == le:
                for wid in self.fleet.expired_due(self.now):
                    self._discover_departure(wid)
            else:
                self._local_lr = self.cfg.local_lr * (
                    self.cfg.local_lr_decay ** (self.now / self.cfg.gamma)
                )
                # Advance the timer BEFORE dispatching: a drift-triggered
                # Search inside the checkpoint handler re-enters this loop
                # through its probe windows, and a stale _next_checkpoint
                # would make the nested frame fire this same checkpoint
                # again (and the outer += would then skip a later one).
                self._next_checkpoint += self.cfg.gamma
                self.engine.checkpoint()

    def _run_until(self, t_end: float) -> None:
        # Re-entrant: a drift-triggered Search executed while firing a
        # churn/checkpoint timer runs its probe windows through a nested
        # _run_until on this same heap, possibly advancing the clock past
        # this frame's t_end — the max() guards keep time monotone when
        # the outer frame resumes.
        while not self.converged:
            head = self._heap[0] if self._heap else None
            t = head[0] if head is not None else t_end
            if self._fire_timers(min(t, t_end)):
                return
            if head is None:
                if self._heap:
                    # a timer woke the cluster up (lease discovery released
                    # a deadlocked barrier, churn rejoined a worker, ...)
                    continue
                # heap empty and every timer ≤ t_end fired: the cluster is
                # idle (or deadlocked) — the clock still advances, so the
                # eval/lease/churn timers keep firing next frame
                self.now = max(self.now, t_end)
                return
            if not self._heap or self._heap[0] is not head:
                # a timer dispatch (churn → drift Search) ran a nested
                # probe window that consumed heap events: the peek is
                # stale — re-evaluate instead of popping a later event
                continue
            if t > t_end:
                self.now = max(self.now, t_end)
                return
            t, _, kind, wid, arg, gen = heapq.heappop(self._heap)
            w = self._by_id.get(wid)
            if w is None:  # event of a departed worker
                continue
            if gen != w.gen:  # event of a stalled (pre-freeze) life
                continue
            self.now = max(self.now, t)
            if kind == "step_done":
                self._on_step_done(w)
            elif kind == "commit_arrive":
                self._on_commit_arrive(w)
            elif kind == "shard_arrive":
                self._on_shard_arrive(w, arg)
            elif kind == "pull_done":
                self._on_pull_done(w)
            elif kind == "catchup_done":
                self._on_catchup_done(w)
            elif kind == "hb_report":
                self._on_hb_report(w)
        if not self._heap:
            self.now = max(self.now, t_end)

    def _eval_global(self) -> None:
        loss = float(self.task.eval_fn(self.global_params, self.task.eval_batch))
        self.loss_history.append((self.now, loss))
        if self.metrics is not None:
            self.metrics.record(EvalRecord(t=self.now, loss=loss))
        if self.cfg.target_loss is not None and loss <= self.cfg.target_loss:
            self._declare_converged()
            return
        k = self.cfg.converge_window
        if (
            len(self.loss_history) >= k
            and self.cfg.target_loss is None
            # Variance-based convergence only counts once the global model
            # has actually been trained (≥1 commit per worker on average)
            # and improved on its initial loss — otherwise the flat
            # pre-first-commit plateau would trigger it.
            and self.total_commits >= self.num_workers
            and loss < self.loss_history[0][1]
        ):
            recent = [l for _, l in self.loss_history[-k:]]
            if max(recent) - min(recent) < self.cfg.converge_tol:
                self._declare_converged()

    def _declare_converged(self) -> None:
        if not self.converged:
            self.converged = True
            self.convergence_time = self.now

    # ------------------------------------------------------------------ API
    def recent_global_loss(self) -> float | None:
        if not self.loss_history:
            return None
        tail = self.loss_history[-3:]
        return float(np.mean([l for _, l in tail]))

    def run_window(self, seconds: float) -> tuple[list[float], list[float]]:
        """Run live for `seconds`; return (times, losses) sampled within —
        the OnlineEvaluate primitive of Alg. 1."""
        start = self.now
        self._eval_global()
        self._run_until(start + seconds)
        if not self.converged:  # don't jump the clock past a finished run
            self.now = max(self.now, start + seconds)
        self._eval_global()
        from repro.control.search import pad_probe_samples

        ts = [t for t, _ in self.loss_history if t >= start]
        ls = [l for t, l in self.loss_history if t >= start]
        return pad_probe_samples(ts, ls)

    def run(self, seconds: float) -> None:
        self._run_until(self.now + seconds)

    # Alg. 1 (OnlineSystem / Scheduler) surface, delegated to the engine.
    def commit_counts(self) -> list[int]:
        return self.engine.commit_counts()

    def evaluate(self, c_target: int, probe_seconds: float):
        return self.engine.evaluate(c_target, probe_seconds)

    def set_c_target(self, c: int) -> None:
        self.engine.set_c_target(int(c))

    def train(self, max_seconds: float | None = None) -> SimResult:
        """Drive epochs until convergence or the time budget."""
        budget = max_seconds if max_seconds is not None else self.cfg.max_seconds
        while self.now < budget and not self.converged:
            self.engine.epoch_end()  # Alg. 1 search (may consume probe windows)
            if self.converged:
                break
            t_epoch_end = min(self.now + self.cfg.epoch_seconds, budget)
            self._run_until(t_epoch_end)
            if not self._heap:
                break
        return self.result()

    def result(self) -> SimResult:
        times = np.array([t for t, _ in self.loss_history])
        losses = np.array([l for _, l in self.loss_history])
        comp = sum(w.computation_time for w in self.workers)
        comp += sum(w.computation_time for w, _ in self._departed)
        steps = sum(w.steps - w.step_credit for w in self.workers)
        steps += sum(w.steps - w.step_credit for w, _ in self._departed)
        elapsed = self.now
        active = sum(elapsed - w.joined_at for w in self.workers)
        active += sum(left - w.joined_at for w, left in self._departed)
        # offline spans of lease-expired-then-rejoined workers are neither
        # computation nor waiting — the host was dead
        active -= self._dead_time
        waiting = max(active - comp, 0.0)
        return SimResult(
            policy=self.engine.policy.name,
            times=times,
            losses=losses,
            converged=self.converged,
            convergence_time=self.convergence_time,
            total_steps=steps,
            total_commits=self.total_commits,
            elapsed=elapsed,
            computation_time=comp,
            waiting_time=waiting,
            # measured on the wire: Σ encoded payload bytes (== the old
            # 4·|params|·commits proxy for the identity codec on f32 tasks)
            bytes_to_ps=float(self._bytes_to_ps),
            bytes_from_ps=float(self._bytes_from_ps),
            # real commits only — elastic joiners' ramp-in credit (used by
            # the rate rule) is subtracted for reporting
            commit_counts=[w.commits - w.commit_credit for w in self.workers],
        )
