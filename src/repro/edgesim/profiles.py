"""Heterogeneity profiles mirroring the paper's testbeds.

* Table 1 (Amazon EC2): 7× t2.large, 5× t2.xlarge, 4× t2.2xlarge,
  2× t3.xlarge workers (+1 t3.2xlarge PS — the PS is not a worker).
  We map vCPU count to relative training speed, which matches the paper's
  observed ~1:1:3 spread for the CNN workload.
* Table 2 (smartphone market share): Geekbench multi-core scores as
  relative speeds, sampled by market share.
* ``ratio_profiles``: the 1:1:3 motivating setup of Fig. 1/3.
* ``heterogeneity_profiles``: profiles with a prescribed heterogeneity
  degree H = mean(v)/min(v) (Fig. 5), built by slowing a subset of
  workers ("sleep after each step"), exactly like the paper's experiment.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.control.theory import WorkerProfile, heterogeneity_degree

__all__ = [
    "ratio_profiles",
    "ec2_profiles",
    "smartphone_profiles",
    "heterogeneity_profiles",
    "fleet_profiles",
    "with_links",
]


def with_links(
    profiles: Sequence[WorkerProfile],
    bandwidth: float | Sequence[float] = float("inf"),
    latency: float | Sequence[float] = 0.0,
) -> list[WorkerProfile]:
    """Attach a link model to existing profiles (bandwidth-constrained
    fleets: the straggler is the link, not the chip).

    ``bandwidth`` (bytes/s) and ``latency`` (s) are scalars (uniform
    links) or per-worker sequences. The default keeps transfers free —
    the pre-link-model commit cost.
    """
    m = len(profiles)
    bws = [bandwidth] * m if np.isscalar(bandwidth) else list(bandwidth)
    lats = [latency] * m if np.isscalar(latency) else list(latency)
    if len(bws) != m or len(lats) != m:
        raise ValueError(f"link params must be scalars or length-{m} sequences")
    return [
        dataclasses.replace(p, bandwidth=float(b), latency=float(l))
        for p, b, l in zip(profiles, bws, lats)
    ]


def ratio_profiles(
    ratios=(1.0, 1.0, 3.0), base_v: float = 1.0, o: float = 0.2
) -> list[WorkerProfile]:
    """Workers whose *per-step times* have the given ratios (1:1:3 means the
    third worker is 3× slower, as in the paper's Fig. 1/3 setup)."""
    return [WorkerProfile(v=base_v / r, o=o) for r in ratios]


# vCPUs of the EC2 instance types used in Table 1.
_EC2 = [
    ("t2.large", 2, 7),
    ("t2.xlarge", 4, 5),
    ("t2.2xlarge", 8, 4),
    ("t3.xlarge", 4, 2),
]


def ec2_profiles(o: float = 0.2, scale: float = 0.5) -> list[WorkerProfile]:
    """18 workers following Table 1 (the 19th instance is the PS).

    Speed ∝ vCPUs × scale (t2.large ⇒ 1 step/s at scale 0.5)."""
    out = []
    for _name, vcpus, count in _EC2:
        out.extend(WorkerProfile(v=vcpus * scale, o=o) for _ in range(count))
    return out


_PHONES = [  # (geekbench multicore, share) — Table 2
    (2759, 0.0622),
    (4459, 0.0777 + 0.0434 + 0.0389),
    (5937, 0.1205 + 0.0996),
    (6711, 0.0296),
    (11421, 0.0568 + 0.0500 + 0.0404),
]


def smartphone_profiles(
    m: int, o: float = 0.3, seed: int = 0, per_score: float = 1 / 4459
) -> list[WorkerProfile]:
    """Sample m phone-class workers by market share (Table 2)."""
    rng = np.random.default_rng(seed)
    scores = np.array([s for s, _ in _PHONES], dtype=np.float64)
    shares = np.array([w for _, w in _PHONES], dtype=np.float64)
    shares /= shares.sum()
    picks = rng.choice(len(scores), size=m, p=shares)
    return [WorkerProfile(v=float(scores[i]) * per_score, o=o) for i in picks]


def fleet_profiles(
    m: int,
    spread: float = 4.0,
    seed: int = 0,
    o: float = 0.2,
    bandwidth: float = float("inf"),
    latency: float = 0.0,
) -> list[WorkerProfile]:
    """An m-worker edge fleet with speeds log-uniform across ``spread``
    (v ∈ [1, spread], denser at the slow end — the long-tail device mix
    the fleet scheduler targets) and a uniform link model. Used by
    ``benchmarks/bench_fleet.py`` for large scheduled fleets where the
    hand-curated Table 1/2 mixes don't scale."""
    if m < 1 or spread < 1.0:
        raise ValueError("need m >= 1 and spread >= 1")
    rng = np.random.default_rng(seed)
    vs = np.exp(rng.uniform(0.0, np.log(spread), size=m))
    return [
        WorkerProfile(v=float(v), o=o, bandwidth=float(bandwidth),
                      latency=float(latency))
        for v in vs
    ]


def heterogeneity_profiles(
    m: int, H: float, base_v: float = 2.0, o: float = 0.2
) -> list[WorkerProfile]:
    """Build m workers with heterogeneity degree ≈ H (Fig. 5).

    Half the workers run at base_v, half are slowed to v_slow chosen so
    that mean(v)/min(v) = H (H ≥ 1). For H = 1 all run at base_v.
    """
    if H < 1.0:
        raise ValueError("H must be >= 1")
    if H == 1.0:
        return [WorkerProfile(v=base_v, o=o) for _ in range(m)]
    k = m // 2  # number of slow workers
    # mean = ((m-k)*base + k*slow)/m ; mean/slow = H  =>
    # slow = (m-k)*base / (m*H - k)
    denom = m * H - k
    if denom <= 0:
        raise ValueError(f"H={H} unreachable with m={m}")
    v_slow = (m - k) * base_v / denom
    if v_slow > base_v:
        raise ValueError(f"H={H} < 1 effective; increase H")
    profiles = [WorkerProfile(v=base_v, o=o)] * (m - k) + [
        WorkerProfile(v=v_slow, o=o)
    ] * k
    got = heterogeneity_degree([p.v for p in profiles])
    assert abs(got - H) < 1e-6, (got, H)
    return profiles
