"""Ready-made TrainTasks binding the paper's three applications to the
simulator: CNN/cifar-like, RNN/fatigue-like, SVM/chiller-like."""

from __future__ import annotations

import functools

import jax

from repro.data.synthetic import cifar_like, fatigue_like, chiller_like, WorkerShardedStream
from repro.models.small import CNN, RNN, LinearSVM, make_task_fns
from .simulator import TrainTask

__all__ = ["cnn_task", "rnn_task", "svm_task", "make_task"]


def cnn_task(
    num_workers: int, seed: int = 0, width: int = 16, noise: float = 2.5
) -> TrainTask:
    """noise=2.5 gives a Cifar-10-like difficulty: a few hundred steps to
    cross loss 0.5 — tens of ADSP check periods, like the paper's runs."""
    model = CNN(width=width)
    grad_fn, eval_fn = make_task_fns(model)
    params = model.init(jax.random.PRNGKey(seed))
    gen = functools.partial(cifar_like, noise=noise)
    stream = WorkerShardedStream(gen, seed, num_workers)
    ex, ey = gen(seed, 10**9, 512)  # same concept (seed), held-out index range
    return TrainTask(params, grad_fn, eval_fn, stream, (ex, ey), name="cnn_cifar_like")


def rnn_task(num_workers: int, seed: int = 0, hidden: int = 32) -> TrainTask:
    model = RNN(hidden=hidden)
    grad_fn, eval_fn = make_task_fns(model)
    params = model.init(jax.random.PRNGKey(seed))
    stream = WorkerShardedStream(fatigue_like, seed, num_workers)
    ex, ecov, ey = fatigue_like(seed, 10**9, 512)
    return TrainTask(params, grad_fn, eval_fn, stream, (ex, ecov, ey), name="rnn_fatigue_like")


def svm_task(num_workers: int, seed: int = 0) -> TrainTask:
    model = LinearSVM()
    grad_fn, eval_fn = make_task_fns(model)
    params = model.init(jax.random.PRNGKey(seed))
    stream = WorkerShardedStream(chiller_like, seed, num_workers)
    ex, ey = chiller_like(seed, 10**9, 1024)
    return TrainTask(params, grad_fn, eval_fn, stream, (ex, ey), name="svm_chiller_like")


_TASKS = {"cnn": cnn_task, "rnn": rnn_task, "svm": svm_task}


def make_task(name: str, num_workers: int, seed: int = 0, **kw) -> TrainTask:
    return _TASKS[name](num_workers, seed, **kw)
