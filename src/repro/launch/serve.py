"""Serving launcher (DESIGN.md §14).

Two modes:

  * **engine** (``--trace poisson|bursty``): drive the continuous-
    batching ``repro.serve`` engine from an open-loop arrival trace —
    bounded slot pool, per-step eviction + backfill, ``fcfs`` or
    ``deadline`` admission, per-request SLO accounting. With
    ``--track-training`` a co-running sharded trainer commits to a live
    PS and the replica pulls version-stale shards between decode steps.
    ``--prefill-chunk C`` turns on chunked prefill (C tokens per
    dispatch, interleaved with decode; ``--prefill-batch`` lanes share
    each dispatch) and ``--replicas N`` puts N engines behind a
    ``--router`` policy (§17).

        PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b \
            --smoke --trace poisson --requests 32 --rate 20 --slots 4 \
            --scheduler deadline --slo-ms 800 --metrics run.jsonl

        PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b \
            --smoke --trace bursty --requests 64 --rate 40 \
            --prefill-chunk 16 --prefill-batch 2 \
            --replicas 2 --router deadline_slack

  * **one-shot** (no ``--trace``): the original fixed-batch demo —
    prefill a batch of prompts, greedy-decode ``--new-tokens``.

        PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b \
            --smoke --batch 4 --prompt-len 32 --new-tokens 16

Both print wall timings; the engine also reports the virtual-clock
latency distribution (deterministic across hosts).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.data.synthetic import lm_tokens
from repro.models import lm


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--seed", type=int, default=0)
    # one-shot mode
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--new-tokens", type=int, default=16)
    # engine mode
    p.add_argument("--trace", default="", help="poisson|bursty — enables the "
                   "continuous-batching engine (default: one-shot demo)")
    p.add_argument("--requests", type=int, default=32)
    p.add_argument("--rate", type=float, default=16.0, help="mean arrivals/s")
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--scheduler", default="fcfs", help="fcfs|deadline")
    p.add_argument("--mode", default="continuous", help="continuous|static")
    p.add_argument("--slo-ms", type=float, default=1000.0)
    p.add_argument("--prefill-chunk", type=int, default=0,
                   help="tokens per chunked-prefill dispatch (0 = monolithic)")
    p.add_argument("--prefill-batch", type=int, default=1,
                   help="prefill lanes sharing each chunk dispatch")
    p.add_argument("--replicas", type=int, default=1,
                   help="engine replicas behind the load balancer")
    p.add_argument("--router", default="least_queue",
                   help="round_robin|least_queue|deadline_slack")
    p.add_argument("--metrics", default="", help="stream JSONL records here")
    p.add_argument("--track-training", action="store_true",
                   help="co-run a sharded trainer; pull stale shards live")
    p.add_argument("--sync-every", type=int, default=4,
                   help="decode steps between PS polls (with --track-training)")
    p.add_argument("--shards", type=int, default=4,
                   help="PS shard count (with --track-training)")
    return p


# ---------------------------------------------------------------------------
# one-shot mode (fixed-batch prefill + decode demo)
# ---------------------------------------------------------------------------


def run_oneshot(args) -> dict:
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(args.seed)
    prompts = lm_tokens(args.seed, 0, args.batch, args.prompt_len, cfg.vocab_size)[:, :-1]
    batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.num_prefix_embeddings, cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.encoder is not None:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.encoder.num_frames, cfg.encoder.d_model)) * 0.02,
            jnp.float32)

    params = lm.lm_init(jax.random.PRNGKey(args.seed), cfg)
    prefill = jax.jit(lambda p_, b: lm.lm_prefill(
        cfg, p_, b, reserve=args.new_tokens + 1))
    decode = jax.jit(lambda p_, t, c: lm.lm_decode_step(cfg, p_, t, c))

    t0 = time.time()
    last_logits, caches = prefill(params, batch)
    jax.block_until_ready(last_logits)
    t_prefill = time.time() - t0
    next_tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]

    # first generated token is the prefill argmax; the decode loop
    # produces the remaining new_tokens - 1 (zero when --new-tokens 1)
    n_decoded = max(args.new_tokens - 1, 0)
    out_tokens = [next_tok]
    t0 = time.time()
    for _ in range(n_decoded):
        logits, caches = decode(params, {"tokens": next_tok}, caches)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(next_tok)
    jax.block_until_ready(next_tok)
    t_decode = time.time() - t0

    generated = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    stats = {
        "arch": cfg.name, "batch": args.batch, "prompt_len": args.prompt_len,
        "n_decoded": n_decoded, "t_prefill": t_prefill, "t_decode": t_decode,
        "prefill_tok_s": args.batch * args.prompt_len / max(t_prefill, 1e-9),
        # decode throughput counts decode-loop tokens only — the first
        # generated token came out of prefill and is already paid there
        "decode_ms_per_token": (t_decode * 1e3 / n_decoded) if n_decoded else None,
        "decode_tok_s": (args.batch * n_decoded / max(t_decode, 1e-9)
                         if n_decoded else None),
        "generated": generated,
    }
    print(f"# arch={cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"# prefill: {t_prefill*1e3:.1f} ms "
          f"({stats['prefill_tok_s']:.0f} tok/s)")
    if n_decoded:
        print(f"# decode:  {stats['decode_ms_per_token']:.1f} ms/token "
              f"({stats['decode_tok_s']:.0f} tok/s, {n_decoded} steps)")
    else:
        print("# decode:  skipped (--new-tokens 1: the only generated token "
              "is the prefill argmax)")
    for i in range(min(args.batch, 2)):
        print(f"seq{i}: {generated[i].tolist()}")
    return stats


# ---------------------------------------------------------------------------
# engine mode (continuous batching over an open-loop trace)
# ---------------------------------------------------------------------------


def run_engine(args) -> dict:
    from repro.fleet import JsonlSink, MetricsLog
    from repro.serve import (LoadBalancer, ReplicaSync, ServeConfig,
                             ServeEngine, ShardedTrainer, TraceConfig,
                             make_trace)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    params = lm.lm_init(jax.random.PRNGKey(args.seed), cfg)
    tc = TraceConfig(n_requests=args.requests, rate=args.rate,
                     slo_ms=args.slo_ms, seed=args.seed)
    trace = make_trace(args.trace, tc)
    serve_cfg = ServeConfig(
        slots=args.slots, scheduler=args.scheduler, mode=args.mode,
        sync_every=args.sync_every if args.track_training else 0,
        seed=args.seed, prefill_chunk=args.prefill_chunk,
        prefill_batch=args.prefill_batch)

    trainer = tick = None
    make_sync = None
    loss_first = loss_last = None
    if args.track_training:
        trainer = ShardedTrainer(cfg, params, n_shards=args.shards)
        make_sync = lambda i: ReplicaSync(  # noqa: E731
            params, lambda: trainer.state, n_shards=args.shards)
        tick = lambda eng, t: trainer.advance(t)  # noqa: E731
        loss_first = trainer.eval_loss(params)

    sink = JsonlSink(args.metrics) if args.metrics else MetricsLog()
    t0 = time.time()
    balance = None
    if args.replicas > 1:
        balancer = LoadBalancer(cfg, params, serve_cfg, trace,
                                n_replicas=args.replicas, router=args.router,
                                metrics=sink, make_sync=make_sync, tick=tick)
        balance = balancer.run()
        report = balance.merged
        synced_params = balancer.engines[0].params
    else:
        engine = ServeEngine(cfg, params, serve_cfg, trace, metrics=sink,
                             sync=make_sync(0) if make_sync else None,
                             tick=tick)
        report = engine.run()
        synced_params = engine.params
    wall = time.time() - t0
    if args.track_training:
        loss_last = trainer.eval_loss(synced_params)
    if isinstance(sink, JsonlSink):
        sink.close()

    print(f"# arch={cfg.name} trace={args.trace} requests={args.requests} "
          f"rate={args.rate}/s slots={args.slots} scheduler={args.scheduler} "
          f"mode={args.mode} chunk={args.prefill_chunk} "
          f"replicas={args.replicas}")
    print(f"# served {len(report.records)} requests, "
          f"{report.total_tokens} tokens in {report.t_end:.2f} virtual s "
          f"({wall:.1f} s wall)")
    print(f"# latency total p50 {report.percentile('total', 0.5)*1e3:.1f} ms "
          f"p99 {report.percentile('total', 0.99)*1e3:.1f} ms | "
          f"queue p99 {report.percentile('queue', 0.99)*1e3:.1f} ms")
    print(f"# SLO attainment {100*report.slo_attainment:.1f}% | "
          f"goodput {report.goodput:.2f} req/s | "
          f"{report.tokens_per_s:.1f} tok/s")
    if args.prefill_chunk:
        print(f"# chunked prefill: {report.chunk_dispatches} dispatches "
              f"(chunk {args.prefill_chunk}, {args.prefill_batch} lanes)")
    if balance is not None:
        print(f"# router={balance.router} per-replica requests "
              f"{balance.per_replica_requests}")
    if args.track_training:
        print(f"# training: loss {loss_first:.4f} -> {loss_last:.4f} over "
              f"{trainer.commits} commits | pulls {report.sync_pulls}/"
              f"{report.sync_polls} polls, {report.pull_bytes/1e6:.2f} MB "
              f"(dense re-pull would be {report.full_pull_bytes/1e6:.2f} MB)")
    if args.metrics:
        print(f"# metrics -> {args.metrics}")
    return {"report": report, "loss_first": loss_first, "loss_last": loss_last,
            "trainer": trainer, "balance": balance}


def main(argv=None):
    args = build_parser().parse_args(argv)
    if args.trace:
        return run_engine(args)
    return run_oneshot(args)


if __name__ == "__main__":
    main()
