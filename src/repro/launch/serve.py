"""Serving launcher: prefill a batch of prompts, then greedy-decode with
the cached serve_step. Dev-scale on CPU with --smoke; the dry-run proves
the production shapes lower/compile on the 256/512-chip meshes.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
        --batch 4 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.data.synthetic import lm_tokens
from repro.models import lm


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--new-tokens", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(args.seed)
    prompts = lm_tokens(args.seed, 0, args.batch, args.prompt_len, cfg.vocab_size)[:, :-1]
    batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.num_prefix_embeddings, cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.encoder is not None:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.encoder.num_frames, cfg.encoder.d_model)) * 0.02,
            jnp.float32)

    params = lm.lm_init(jax.random.PRNGKey(args.seed), cfg)
    prefill = jax.jit(lambda p_, b: lm.lm_prefill(
        cfg, p_, b, reserve=args.new_tokens + 1))
    decode = jax.jit(lambda p_, t, c: lm.lm_decode_step(cfg, p_, t, c))

    t0 = time.time()
    last_logits, caches = prefill(params, batch)
    jax.block_until_ready(last_logits)
    t_prefill = time.time() - t0
    next_tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]

    out_tokens = [next_tok]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        logits, caches = decode(params, {"tokens": next_tok}, caches)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(next_tok)
    jax.block_until_ready(next_tok)
    t_decode = time.time() - t0

    generated = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"# arch={cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"# prefill: {t_prefill*1e3:.1f} ms "
          f"({args.batch * args.prompt_len / max(t_prefill, 1e-9):.0f} tok/s)")
    print(f"# decode:  {t_decode*1e3/max(args.new_tokens-1,1):.1f} ms/token "
          f"({args.batch * (args.new_tokens-1) / max(t_decode, 1e-9):.0f} tok/s)")
    for i in range(min(args.batch, 2)):
        print(f"seq{i}: {generated[i].tolist()}")


if __name__ == "__main__":
    main()
