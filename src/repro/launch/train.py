"""Training launcher: runs real ADSP training of any registered arch on
whatever devices exist (CPU host devices for development, TPU mesh in
production), with the full control plane: measured worker speeds → ADSP
rate rule → τ_i assignment → periodic commit-rate search on the live
loss curve (Alg. 1 on the cluster).

The cluster scheduler is the same Alg. 1 code the edge simulator uses —
``OnlineSystem`` here is the live training loop, ``evaluate`` probes a
candidate C_target for ``probe_steps`` commits.

Usage (CPU dev, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-moe-a2.7b \
        --smoke --steps 50 --seq 128 --batch 8 --tau 4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.core.commit import AdspState, CommitConfig
from repro.core.search import decide_commit_rate
from repro.core import theory
from repro.data.synthetic import lm_tokens
from repro.launch.steps import build_train_step
from repro.models.config import ModelConfig
from repro.models import lm
from repro.checkpoint import save_train_state

__all__ = ["TrainLoop", "main"]


class TrainLoop:
    """Owns state + step fn; exposes the OnlineSystem protocol so Alg. 1
    can steer the commit rate from live loss measurements."""

    def __init__(self, cfg: ModelConfig, mesh, *, tau: int, seq: int,
                 batch: int, local_lr: float, global_lr: float | None,
                 seed: int = 0, gamma_steps: int = 8):
        self.cfg = cfg
        self.mesh = mesh
        self.tau = tau
        self.seq = seq
        self.batch = batch
        self.gamma_steps = gamma_steps  # check period, in commit steps
        n_workers = 1
        from repro.launch.mesh import worker_axes_for
        from repro.launch.steps import _num_workers

        self.worker_axes = worker_axes_for(cfg.adsp_granularity, mesh)
        n_workers = _num_workers(mesh, self.worker_axes)
        self.n_workers = n_workers
        self.global_lr = global_lr if global_lr is not None else 1.0

        import dataclasses as dc

        bundle = build_train_step(
            cfg, mesh, shape="train_4k", tau=tau, local_lr=local_lr,
            global_lr=self.global_lr,
        )
        # dev-scale: rebuild with the requested seq/batch instead of 4k
        from repro.launch import specs as S

        spec = S.ShapeSpec("dev", "train", seq, batch)
        object.__setattr__  # noqa — spec is frozen; create directly
        self.spec = spec
        self.step_fn = None
        self._build_step(local_lr)
        params = lm.lm_init(jax.random.PRNGKey(seed), cfg)
        params = jax.tree.map(lambda x: x.astype(jnp.dtype(cfg.dtype))
                              if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
        self.state = AdspState.create(params)
        self.seed = seed
        self.commits = np.zeros(n_workers, dtype=np.int64)
        self.losses: list[tuple[float, float]] = []  # (commit_step, loss)
        self.virtual_speeds = np.linspace(1.0, 1.0, n_workers)

    def _build_step(self, local_lr):
        from repro.core.accum import make_accum_step
        from repro.core.commit import make_adsp_step
        from repro.launch.steps import _rules_for
        from jax.sharding import PartitionSpec as P

        rules = _rules_for(self.mesh, self.worker_axes)
        ccfg = CommitConfig(tau=self.tau, local_lr=local_lr,
                            global_lr=self.global_lr,
                            worker_axes=self.worker_axes)

        def loss_fn(params, mb):
            return lm.lm_loss(self.cfg, params, mb, rules=rules, remat=False)

        if self.worker_axes:
            wa = self.worker_axes
            spec = P(None, wa if len(wa) > 1 else wa[0])
            step = make_adsp_step(loss_fn, ccfg, self.mesh, batch_spec=spec)
        else:
            accum = make_accum_step(loss_fn, ccfg)

            def step(state, mb, tau_arr):
                return accum(state, mb, tau_arr[0])

        self.step_fn = jax.jit(step)

    # ----------------------------------------------------------- data
    def _batch(self, step: int):
        toks = lm_tokens(self.seed, step * 7919, self.tau * self.batch,
                         self.seq, self.cfg.vocab_size)[:, :-1]
        return {"tokens": jnp.asarray(
            toks.reshape(self.tau, self.batch, self.seq), jnp.int32)}

    # ------------------------------------------------- ADSP rate control
    def tau_per_worker(self, c_target: int) -> jnp.ndarray:
        """Rate rule: ΔC_i = C_target − c_i; τ_i ∝ v_i/ΔC_i, capped at tau."""
        dc = np.maximum(c_target - self.commits, 1)
        tau = np.minimum(
            np.maximum((self.tau * self.virtual_speeds / dc).astype(int), 1),
            self.tau,
        )
        return jnp.asarray(tau, jnp.int32)

    # ------------------------------------------------- OnlineSystem
    def commit_counts(self):
        return list(self.commits)

    def evaluate(self, c_target: int, probe_seconds: float):
        """Probe window: `probe_seconds` is measured in commit steps here
        (the scheduler treats them as opaque time units)."""
        ts, ls = [], []
        for _ in range(max(int(probe_seconds), 3)):
            loss = self.run_commit_step(c_target)
            ts.append(float(len(self.losses)))
            ls.append(loss)
        return ts, ls

    def run_commit_step(self, c_target: int | None = None) -> float:
        step_idx = len(self.losses)
        tau_arr = self.tau_per_worker(c_target or (int(self.commits.max()) + 1))
        self.state, loss = self.step_fn(self.state, self._batch(step_idx), tau_arr)
        self.commits += 1  # every worker commits at the commit point
        loss = float(loss)
        self.losses.append((float(step_idx), loss))
        return loss


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--tau", type=int, default=4)
    p.add_argument("--local-lr", type=float, default=0.02)
    p.add_argument("--global-lr", type=float, default=1.0)
    p.add_argument("--search-every", type=int, default=0,
                   help="run Alg. 1 search every N commits (0 = off)")
    p.add_argument("--checkpoint", default="")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    n = len(jax.devices())
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    loop = TrainLoop(cfg, mesh, tau=args.tau, seq=args.seq, batch=args.batch,
                     local_lr=args.local_lr, global_lr=args.global_lr,
                     seed=args.seed)
    print(f"# arch={cfg.name} params={cfg.total_params()/1e6:.1f}M "
          f"workers={loop.n_workers} tau={args.tau}")
    t0 = time.time()
    c_target = 1
    with jax.set_mesh(mesh):
        for step in range(args.steps):
            if args.search_every and step and step % args.search_every == 0:
                c_target, trace = decide_commit_rate(loop, probe_seconds=3,
                                                     max_probes=4)
                print(f"# search: candidates={trace.candidates} "
                      f"rewards={[f'{r:.3g}' for r in trace.rewards]} -> {c_target}")
            loss = loop.run_commit_step(c_target + step)
            if step % 5 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss {loss:.4f} "
                      f"({(time.time()-t0)/(step+1):.2f}s/commit)")
    if args.checkpoint:
        save_train_state(args.checkpoint, loop.state, step=args.steps,
                         extra={"arch": cfg.name})
        print(f"# saved {args.checkpoint}")


if __name__ == "__main__":
    main()
