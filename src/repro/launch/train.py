"""Training launcher: runs real ADSP training of any registered arch on
whatever devices exist (CPU host devices for development, TPU mesh in
production), with the full control plane: ADSP rate rule → τ_i assignment
→ periodic commit-rate search on the live loss curve (Alg. 1 on the
cluster).

The control plane is the *same* code the edge simulator uses: a
``repro.cluster.ADSP`` policy driven by a ``ClusterEngine`` over the
``repro.cluster.mesh_backend.MeshBackend`` (DESIGN.md §4) — Alg. 1 and
Alg. 2 exist exactly once in the repo.

Usage (CPU dev, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-moe-a2.7b \
        --smoke --steps 50 --seq 128 --batch 8 --tau 4

Commit transport: ``--codec {identity,int8,bf16,top_k}`` compresses the
per-commit update payload through ``repro.transport`` (with error
feedback; ``--codec-backend fused`` routes encode/decode through the
Pallas kernels); the header line reports the measured MB/round to the PS.
``--ps-shards K`` partitions the PS into K versioned shards (DESIGN.md
§11): the commit applies shard by shard per the deterministic ShardPlan
and the state carries per-shard version counters; 1 (default) is the
monolithic PS, bit-identical to the unsharded stack.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_train_state
from repro.cluster import ADSP, ClusterEngine
from repro.control import reward_model_names
from repro.cluster.mesh_backend import MeshBackend, MeshTask
from repro.configs import get_config, get_smoke
from repro.compat import use_mesh
from repro.control.theory import WorkerProfile
from repro.data.synthetic import lm_tokens
from repro.fleet import FleetConfig, JsonlSink, LeaseConfig, scheduler_names
from repro.models import lm
from repro.models.attention import resolve_attn_impl
from repro.models.config import ModelConfig
from repro.ps import UpdateRules, add_rule_args, add_shard_args, rules_from_args
from repro.transport import add_codec_args, codec_from_args

__all__ = ["build_mesh_task", "make_trainer", "main"]


def build_mesh_task(cfg: ModelConfig, rules, *, seq: int, batch: int,
                    seed: int = 0, attn_impl: str | None = None) -> MeshTask:
    """Bind an LM architecture + data stream into a MeshTask.

    ``attn_impl`` follows ``models.attention.resolve_attn_impl``: 'ref'
    (pure-JAX blockwise scan) / 'flash' (Pallas kernel); None picks per
    family — flash is the granite-family default on TPU.
    """
    impl = resolve_attn_impl(attn_impl, cfg.name)

    def loss_fn(params, mb):
        return lm.lm_loss(cfg, params, mb, rules=rules, attn_impl=impl,
                          remat=False)

    def make_microbatches(round_idx: int, tau: int, _n_workers: int):
        toks = lm_tokens(seed, round_idx * 7919, tau * batch, seq,
                         cfg.vocab_size)[:, :-1]
        return {"tokens": jnp.asarray(toks.reshape(tau, batch, seq), jnp.int32)}

    return MeshTask(
        init_params=None,  # filled by make_trainer (needs dtype cast)
        loss_fn=loss_fn,
        make_microbatches=make_microbatches,
        name=f"train:{cfg.name}",
    )


def make_trainer(cfg: ModelConfig, mesh, *, tau: int, seq: int, batch: int,
                 local_lr: float, global_lr: float, seed: int = 0,
                 gamma_rounds: float = 8.0, search_every: int = 0,
                 speeds=None,
                 update_rules: UpdateRules | None = None,
                 codec=None,
                 n_shards: int = 1,
                 fused_commit: bool = False,
                 overlap_shards: bool = False,
                 attn_impl: str | None = None,
                 search_mode: str = "epoch",
                 drift_threshold: float = 0.25,
                 reward_model: str = "log_slope",
                 fleet: FleetConfig | None = None,
                 metrics=None,
                 ) -> tuple[MeshBackend, ClusterEngine, ADSP]:
    """Build the (backend, engine, policy) triple for an arch on a mesh."""
    from repro.launch.mesh import worker_axes_for
    from repro.launch.steps import _rules_for

    worker_axes = worker_axes_for(cfg.adsp_granularity, mesh)
    rules = _rules_for(mesh, worker_axes)
    task = build_mesh_task(cfg, rules, seq=seq, batch=batch, seed=seed,
                           attn_impl=attn_impl)
    params = lm.lm_init(jax.random.PRNGKey(seed), cfg)
    task.init_params = jax.tree.map(
        lambda x: x.astype(jnp.dtype(cfg.dtype))
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_workers = int(np.prod([sizes[a] for a in worker_axes])) if worker_axes else 1
    speeds = speeds if speeds is not None else [1.0] * n_workers
    profiles = [WorkerProfile(v=float(v), o=0.0) for v in speeds]
    backend = MeshBackend(
        task, mesh, worker_axes=worker_axes, tau=tau,
        local_lr=local_lr, global_lr=global_lr, profiles=profiles,
        rules=update_rules, codec=codec, n_shards=n_shards,
        fused_commit=fused_commit, overlap_shards=overlap_shards,
        fleet=fleet, metrics=metrics,
    )
    # drift mode stays armed even with no epoch cadence configured: the
    # detector, not the epoch clock, decides when to search
    policy = ADSP(
        gamma=gamma_rounds,
        search=bool(search_every) or search_mode in ("drift", "both"),
        probe_seconds=3.0, max_probes=4,
        search_mode=search_mode, drift_threshold=drift_threshold,
        drift_cooldown=4 * gamma_rounds, reward_model=reward_model,
    )
    engine = ClusterEngine(policy, backend, metrics=metrics)
    return backend, engine, policy


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--tau", type=int, default=4)
    p.add_argument("--local-lr", type=float, default=0.02)
    p.add_argument("--global-lr", type=float, default=1.0)
    p.add_argument("--gamma-rounds", type=float, default=8.0,
                   help="check period Γ in commit rounds")
    p.add_argument("--search-every", type=int, default=0,
                   help="run Alg. 1 search every N commits (0 = off)")
    p.add_argument("--search-mode", default="epoch",
                   choices=["epoch", "drift", "both"],
                   help="when to re-search: on the epoch clock (paper), "
                        "on detected fleet drift, or both")
    p.add_argument("--drift-threshold", type=float, default=0.25,
                   help="speed-fraction TV distance triggering a drift "
                        "re-search (--search-mode drift|both)")
    p.add_argument("--reward-model", default="log_slope",
                   choices=reward_model_names(),
                   help="probe-window reward model (repro.control registry)")
    p.add_argument("--lease-ttl", type=float, default=0.0,
                   help="fleet lease TTL in round time (0 = no fleet layer)")
    p.add_argument("--heartbeat-period", type=float, default=0.0,
                   help="heartbeat period in round time (default ttl/3)")
    p.add_argument("--scheduler", default="",
                   choices=[""] + scheduler_names(),
                   help="capability-aware device scheduler (repro.fleet); "
                        "empty leaves batch fractions to the policy")
    p.add_argument("--metrics", default="",
                   help="write the structured fleet metrics stream (JSONL) "
                        "to this path; summarize with tools/fleet_report.py")
    p.add_argument("--fused-commit", action="store_true",
                   help="single-pass decode+apply PS commit (DESIGN.md "
                        "§16); needs --codec int8|bf16, falls back to the "
                        "chain path where the fusion is not bit-exact")
    p.add_argument("--overlap-shards", action="store_true",
                   help="with --fused-commit and --ps-shards K>1: issue "
                        "per-shard pull/decode dispatches back-to-back "
                        "with no host sync between shards")
    p.add_argument("--attn-impl", default=None, choices=["ref", "flash"],
                   help="training attention: 'ref' pure-JAX blockwise, "
                        "'flash' Pallas kernel (default: flash for the "
                        "granite family on TPU, ref elsewhere)")
    p.add_argument("--checkpoint", default="")
    p.add_argument("--seed", type=int, default=0)
    add_rule_args(p)
    add_codec_args(p)
    add_shard_args(p)
    args = p.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    n = len(jax.devices())
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    rules = rules_from_args(args)
    codec = codec_from_args(args)
    fleet = None
    if args.lease_ttl > 0 or args.scheduler:
        ttl = args.lease_ttl if args.lease_ttl > 0 else 3.0 * args.gamma_rounds
        period = args.heartbeat_period if args.heartbeat_period > 0 else ttl / 3.0
        fleet = FleetConfig(
            lease=LeaseConfig(ttl=ttl, heartbeat_period=period),
            scheduler=args.scheduler or None,
        )
    metrics = JsonlSink(args.metrics) if args.metrics else None
    backend, engine, policy = make_trainer(
        cfg, mesh, tau=args.tau, seq=args.seq, batch=args.batch,
        local_lr=args.local_lr, global_lr=args.global_lr, seed=args.seed,
        gamma_rounds=args.gamma_rounds, search_every=args.search_every,
        update_rules=rules, codec=codec, n_shards=args.ps_shards,
        fused_commit=args.fused_commit, overlap_shards=args.overlap_shards,
        attn_impl=args.attn_impl,
        search_mode=args.search_mode, drift_threshold=args.drift_threshold,
        reward_model=args.reward_model, fleet=fleet, metrics=metrics,
    )
    lr_rule, cr_rule = backend.rules
    print(f"# arch={cfg.name} params={cfg.total_params()/1e6:.1f}M "
          f"workers={len(backend.workers)} tau={args.tau} "
          f"rules={lr_rule.name}+{cr_rule.name}[{cr_rule.backend}] "
          f"codec={backend.codec.name}[{backend.codec.backend}] "
          f"ps_shards={backend.n_shards} "
          f"fused_commit={backend.fused_commit} "
          f"overlap={backend.overlap_shards} "
          f"attn={resolve_attn_impl(args.attn_impl, cfg.name)} "
          f"({backend.bytes_per_round/1e6:.2f} MB/round to PS)")
    t0 = time.time()

    def on_round(rnd, loss):
        if (rnd - 1) % 5 == 0 or rnd == args.steps:
            print(f"step {rnd - 1:4d} loss {loss:.4f} "
                  f"({(time.time() - t0) / rnd:.2f}s/commit)")

    with use_mesh(mesh):
        backend.train(args.steps, check_period=policy.gamma,
                      epoch_rounds=args.search_every, on_round=on_round)
    print(f"# bytes_to_ps={backend.bytes_to_ps/1e6:.2f} MB "
          f"over {args.steps} rounds")
    for i, tr in enumerate(policy.traces):
        print(f"# search {i}: candidates={tr.candidates} "
              f"rewards={[f'{r:.3g}' for r in tr.rewards]} -> {tr.chosen}")
    if args.checkpoint:
        save_train_state(args.checkpoint, backend.state, step=args.steps,
                         extra={"arch": cfg.name})
        print(f"# saved {args.checkpoint}")
    if metrics is not None:
        metrics.close()
        print(f"# metrics stream -> {args.metrics}")


if __name__ == "__main__":
    main()
