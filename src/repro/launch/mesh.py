"""Production mesh definitions (TPU v5e target).

Single pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is the
ADSP worker axis for replica-heavy architectures (cross-pod links are the
slow/heterogeneous resource ADSP's commit schedule protects).

Functions, not module constants — importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax

from repro.ps.train_step import worker_axes_for  # canonical home moved to ps

__all__ = ["make_production_mesh", "worker_axes_for", "WORKER_AXES"]

WORKER_AXES = {"single": ("data",), "multi": ("pod", "data")}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh((data, max(model, 1)), ("data", "model"))
