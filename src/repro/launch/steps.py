"""Step builders: bind an architecture + mesh + ADSP config into the jit-
ready train / prefill / serve step functions with full sharding pytrees.

Returns StepBundle(fn, args (abstract), in_shardings, out_shardings) — the
dry-run lowers these; launchers call them with real arrays.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.layers import default_rules
from repro.ps import AdspState, CommitConfig, UpdateRules, make_train_step
from .mesh import worker_axes_for
from . import specs as S

__all__ = ["StepBundle", "build_train_step", "build_prefill_step", "build_serve_step", "build"]


@dataclasses.dataclass
class StepBundle:
    name: str
    fn: Any  # callable (not yet jitted)
    args: tuple  # abstract ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    donate: tuple = ()  # argnums aliased in-place (train state / kv caches)
    static: dict = dataclasses.field(default_factory=dict)

    def jitted(self):
        return jax.jit(
            self.fn, in_shardings=self.in_shardings,
            out_shardings=self.out_shardings, donate_argnums=self.donate,
        )

    def lower(self):
        return self.jitted().lower(*self.args)


def _num_workers(mesh, worker_axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(np.prod([sizes[a] for a in worker_axes])) if worker_axes else 1


def _rules_for(mesh, worker_axes):
    """Model rules: 'model' axis always auto; batch over the auto data axes
    (those not consumed as manual worker axes)."""
    auto_data = tuple(a for a in mesh.axis_names if a != "model" and a not in worker_axes)
    da = auto_data if len(auto_data) > 1 else (auto_data[0] if auto_data else None)
    return default_rules("model", da)


def build_train_step(
    cfg: ModelConfig,
    mesh,
    shape: str = "train_4k",
    tau: int = 4,
    attn_impl: str = "scan",
    local_lr: float = 0.05,
    global_lr: float = 1.0,
    explicit_momentum: float = 0.0,
    remat: bool = True,
    granularity: str | None = None,
    commit_dtype: str = "float32",
    attn_block: int = 512,
    local_rule: str = "sgd",
    commit_rule: str = "momentum_delta",
    rule_backend: str | None = None,
    local_hp: dict | None = None,
    codec: str | None = None,
    n_shards: int = 1,
    fused_commit: bool = False,
) -> StepBundle:
    spec = S.SHAPES[shape]
    granularity = granularity or cfg.adsp_granularity
    worker_axes = worker_axes_for(granularity, mesh)
    n_workers = _num_workers(mesh, worker_axes)
    rules = _rules_for(mesh, worker_axes)
    ccfg = CommitConfig(
        tau=tau, local_lr=local_lr, global_lr=global_lr,
        worker_axes=worker_axes, commit_dtype=commit_dtype,
        n_shards=n_shards,
    )
    update_rules = UpdateRules(
        local=local_rule, commit=commit_rule, backend=rule_backend,
        local_hp=local_hp or {},
    )

    def loss_fn(params, mb):
        # remat=True ⇒ jax.checkpoint around each scanned layer-group body:
        # backward recomputes layer internals instead of saving stacked
        # (layers × S × S) attention buffers — without it the train step
        # stores ~86 GB/chip of probabilities (measured, §Perf iteration 1).
        return lm.lm_loss(cfg, params, mb, rules=rules, attn_impl=attn_impl,
                          remat=remat, attn_block=attn_block)

    batch_spec_manual = None
    if worker_axes:
        batch_spec_manual = jax.tree.map(
            lambda _: P(None, worker_axes if len(worker_axes) > 1 else worker_axes[0]),
            S.abstract_train_batch(cfg, spec, tau),
        )
    step = make_train_step(
        loss_fn, ccfg, update_rules,
        mesh=mesh,
        granularity=granularity,
        batch_spec=batch_spec_manual,
        explicit_momentum=explicit_momentum,
        remat=False,  # remat lives inside lm_loss (per layer group)
        codec=codec,
        fused_commit=fused_commit,
    )

    # --- abstract args + shardings ---------------------------------------
    pshard = S.param_shardings(cfg, mesh, granularity)
    ap = S.abstract_params(cfg)
    state = jax.eval_shape(step.init, ap)
    rep = NamedSharding(mesh, P())
    if jax.tree.structure(state.commit_state) == jax.tree.structure(ap):
        cshard = jax.tree.map(lambda _, s: s, state.commit_state, pshard)
    else:
        cshard = jax.tree.map(lambda _: rep, state.commit_state)
    # local optimizer state: one slot per worker along the leading dim
    # (inner dims replicated — a model-axis refinement is future work)
    wshard = NamedSharding(
        mesh, P(worker_axes if len(worker_axes) > 1 else worker_axes[0])
    ) if worker_axes else rep
    lshard = jax.tree.map(lambda _: wshard, state.local_state)
    tshard = jax.tree.map(lambda _: wshard, state.transport_state)
    # per-shard PS version counters: a tiny int32[K], replicated
    vshard = jax.tree.map(lambda _: rep, state.shard_versions)
    state_shard = AdspState(params=pshard, commit_state=cshard,
                            local_state=lshard, step=rep,
                            transport_state=tshard, shard_versions=vshard)
    batch = S.abstract_train_batch(cfg, spec, tau)
    bshard = S.batch_shardings(cfg, mesh, batch, batch_dim=1)
    tau_arr = jax.ShapeDtypeStruct((n_workers,), jnp.int32)

    return StepBundle(
        name=f"train:{cfg.name}:{shape}",
        fn=step,
        args=(state, batch, tau_arr),
        in_shardings=(state_shard, bshard, rep),
        out_shardings=(state_shard, rep),
        donate=(0,),  # AdspState updated in place
        static=dict(tau=tau, worker_axes=worker_axes, granularity=granularity,
                    n_workers=n_workers,
                    local_rule=step.rules[0].name, commit_rule=step.rules[1].name,
                    rule_backend=step.rules[1].backend,
                    codec=step.codec.name if step.codec is not None else None,
                    n_shards=step.n_shards, fused_commit=step.fused_commit),
    )


def build_prefill_step(cfg: ModelConfig, mesh, shape: str = "prefill_32k",
                       attn_impl: str = "scan") -> StepBundle:
    cfg = S.effective_config(cfg, shape)
    spec = S.SHAPES[shape]
    rules = _rules_for(mesh, ())

    def prefill(params, batch):
        return lm.lm_prefill(cfg, params, batch, rules=rules, attn_impl=attn_impl)

    ap = S.abstract_params(cfg)
    pshard = S.param_shardings(cfg, mesh, "accum")
    batch = S.abstract_prefill_batch(cfg, spec)
    bshard = S.batch_shardings(cfg, mesh, batch, batch_dim=0)
    out_logits, out_caches = jax.eval_shape(prefill, ap, batch)
    cshard = S.cache_shardings(cfg, mesh, out_caches)
    lshard = S.batch_shardings(cfg, mesh, out_logits, batch_dim=0)
    return StepBundle(
        name=f"prefill:{cfg.name}:{shape}",
        fn=prefill,
        args=(ap, batch),
        in_shardings=(pshard, bshard),
        out_shardings=(lshard, cshard),
    )


def build_serve_step(cfg: ModelConfig, mesh, shape: str = "decode_32k") -> StepBundle:
    cfg = S.effective_config(cfg, shape)
    spec = S.SHAPES[shape]
    rules = _rules_for(mesh, ())

    def serve_step(params, tokens, caches):
        logits, new_caches = lm.lm_decode_step(cfg, params, tokens, caches, rules=rules)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, new_caches

    ap = S.abstract_params(cfg)
    pshard = S.param_shardings(cfg, mesh, "accum")
    tokens, caches = S.abstract_decode_state(cfg, spec)
    tshard = S.batch_shardings(cfg, mesh, tokens, batch_dim=0)
    cshard = S.cache_shardings(cfg, mesh, caches)
    nt_shape = jax.ShapeDtypeStruct((spec.batch,), jnp.int32)
    nt_shard = S.batch_shardings(cfg, mesh, nt_shape, batch_dim=0)
    return StepBundle(
        name=f"serve:{cfg.name}:{shape}",
        fn=serve_step,
        args=(ap, tokens, caches),
        in_shardings=(pshard, tshard, cshard),
        out_shardings=(nt_shard, cshard),
        donate=(2,),  # KV caches updated in place
    )


def build(cfg: ModelConfig, mesh, shape: str, **kw) -> StepBundle:
    kind = S.SHAPES[shape].kind
    if kind == "train":
        return build_train_step(cfg, mesh, shape, **kw)
    if kind == "prefill":
        kw.pop("tau", None)
        kw.pop("n_shards", None)
        kw.pop("fused_commit", None)
        return build_prefill_step(cfg, mesh, shape, **kw)
    kw.pop("tau", None)
    kw.pop("n_shards", None)
    kw.pop("attn_impl", None)
    kw.pop("fused_commit", None)
    return build_serve_step(cfg, mesh, shape, **kw)
