"""Input shapes, abstract (no-allocation) state builders, and sharding
rules for the dry-run and the launchers.

Assigned input shapes:
    train_4k     seq 4096,   global_batch 256   (train_step)
    prefill_32k  seq 32768,  batch 32           (prefill_step)
    decode_32k   seq 32768,  batch 128          (serve_step, 1 new token)
    long_500k    seq 524288, batch 1            (serve_step, sub-quadratic)

Everything here returns jax.ShapeDtypeStruct pytrees (weak-type-correct,
shardable, zero device allocation) plus NamedSharding pytrees assembled
from generic rules:

  * params: largest dim divisible by |model| → 'model'; when the arch has
    no worker axis spanning 'data' (granularity pod/accum), an additional
    large dim is sharded over 'data' (FSDP/ZeRO-3);
  * decode caches: batch → worker axes when divisible, else replicated;
    kv-heads → 'model' when divisible, else the seq dim → 'model';
  * train batches: (tau, B, ...) with B → worker axes (manual) and, for
    pod/accum granularity, B → remaining data axes as auto sharding.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import lm
from repro.models.config import ModelConfig
from .mesh import worker_axes_for

__all__ = [
    "SHAPES",
    "ShapeSpec",
    "abstract_params",
    "abstract_train_batch",
    "abstract_decode_state",
    "param_shardings",
    "batch_shardings",
    "cache_shardings",
    "shape_supported",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(supported, reason-if-not / variant note)."""
    if shape == "long_500k":
        if cfg.is_encoder_decoder:
            return False, "enc-dec audio decoder: 512k decode out of family (see DESIGN.md)"
        if not cfg.supports_long_decode:
            kinds = set(cfg.layer_pattern)
            if kinds & {"recurrent", "rwkv"}:
                return True, ""
            return True, "variant: sliding_window(4096) attention (beyond-paper)"
    return True, ""


def effective_config(cfg: ModelConfig, shape: str) -> ModelConfig:
    """Apply the sliding-window variant for dense archs at long_500k."""
    if shape == "long_500k" and not cfg.supports_long_decode and not cfg.is_encoder_decoder:
        return dataclasses.replace(cfg, sliding_window=4096)
    return cfg


# ---------------------------------------------------------------------------
# abstract state
# ---------------------------------------------------------------------------

def _cast(tree, dtype):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, dtype)
        if jnp.issubdtype(x.dtype, jnp.floating)
        else jax.ShapeDtypeStruct(x.shape, x.dtype),
        tree,
    )


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStructs of lm_init output, cast to cfg.dtype (bf16 at
    scale: the paper's plain-SGD PS needs no f32 master copy)."""
    shapes = jax.eval_shape(partial(lm.lm_init, cfg=cfg), jax.random.PRNGKey(0))
    return _cast(shapes, jnp.dtype(cfg.dtype))


def abstract_train_batch(cfg: ModelConfig, spec: ShapeSpec, tau: int):
    b, s = spec.batch, spec.seq
    batch = {"tokens": jax.ShapeDtypeStruct((tau, b, s), jnp.int32)}
    if cfg.frontend == "vision":
        batch["patches"] = jax.ShapeDtypeStruct(
            (tau, b, cfg.num_prefix_embeddings, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    if cfg.encoder is not None:
        e = cfg.encoder
        batch["frames"] = jax.ShapeDtypeStruct(
            (tau, b, e.num_frames, e.d_model), jnp.dtype(cfg.dtype)
        )
    return batch


def abstract_prefill_batch(cfg: ModelConfig, spec: ShapeSpec):
    batch = {"tokens": jax.ShapeDtypeStruct((spec.batch, spec.seq), jnp.int32)}
    if cfg.frontend == "vision":
        batch["patches"] = jax.ShapeDtypeStruct(
            (spec.batch, cfg.num_prefix_embeddings, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    if cfg.encoder is not None:
        e = cfg.encoder
        batch["frames"] = jax.ShapeDtypeStruct(
            (spec.batch, e.num_frames, e.d_model), jnp.dtype(cfg.dtype)
        )
    return batch


def abstract_decode_state(cfg: ModelConfig, spec: ShapeSpec):
    """(tokens (B,1), caches) — cache capacity = spec.seq (the assignment:
    one new token against a KV cache of seq_len)."""
    caches = jax.eval_shape(
        partial(lm.init_decode_caches, cfg, spec.batch, spec.seq)
    )
    tokens = {"tokens": jax.ShapeDtypeStruct((spec.batch, 1), jnp.int32)}
    return tokens, caches


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def _axis_size(mesh, name) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def _pick_dim(shape, divisor, taken=()) -> int | None:
    """Largest dim divisible by divisor, preferring trailing dims."""
    best, best_size = None, 0
    for i in reversed(range(len(shape))):
        if i in taken:
            continue
        if shape[i] % divisor == 0 and shape[i] >= divisor and shape[i] > best_size:
            best, best_size = i, shape[i]
    return best


def param_shardings(cfg: ModelConfig, mesh, granularity: str | None = None):
    """NamedSharding pytree for the parameter pytree."""
    granularity = granularity or cfg.adsp_granularity
    worker_axes = worker_axes_for(granularity, mesh)
    model_n = _axis_size(mesh, "model")
    # FSDP axes: any non-model mesh axis NOT used as an ADSP worker axis.
    fsdp_axes = [a for a in mesh.axis_names if a != "model" and a not in worker_axes]
    fsdp_n = int(np.prod([_axis_size(mesh, a) for a in fsdp_axes])) if fsdp_axes else 1

    def leaf_sharding(x):
        spec = [None] * len(x.shape)
        md = _pick_dim(x.shape, model_n)
        if md is not None:
            spec[md] = "model"
        if fsdp_axes and x.size * 2 >= (1 << 22):  # FSDP only for ≥4 MiB leaves
            fd = _pick_dim(x.shape, fsdp_n, taken=(md,) if md is not None else ())
            if fd is not None:
                spec[fd] = tuple(fsdp_axes) if len(fsdp_axes) > 1 else fsdp_axes[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf_sharding, abstract_params(cfg))


def batch_shardings(cfg: ModelConfig, mesh, batch_tree, *, batch_dim: int = 1,
                    granularity: str | None = None):
    """Shard the batch dim over every non-model axis (worker axes manual +
    any remaining data axes auto — GSPMD splits them the same way)."""
    axes = tuple(a for a in mesh.axis_names if a != "model")
    n = int(np.prod([_axis_size(mesh, a) for a in axes]))

    def leaf(x):
        spec = [None] * len(x.shape)
        if x.shape[batch_dim] % n == 0:
            spec[batch_dim] = axes if len(axes) > 1 else axes[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, batch_tree)


def cache_shardings(cfg: ModelConfig, mesh, cache_tree):
    """Decode-cache sharding: batch → non-model axes when divisible;
    kv-heads → 'model' when divisible, else seq → 'model'."""
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    data_n = int(np.prod([_axis_size(mesh, a) for a in data_axes]))
    model_n = _axis_size(mesh, "model")
    da = data_axes if len(data_axes) > 1 else data_axes[0]

    def leaf(x):
        spec = [None] * len(x.shape)
        nd = len(x.shape)
        # leading dim may be the stacked-layer dim (reps) — cache leaves are
        # (reps, B, ...) for scanned groups.
        bdim = 1 if nd >= 2 else 0
        if nd >= 2 and x.shape[bdim] % data_n == 0 and x.shape[bdim] >= data_n:
            spec[bdim] = da
        # model axis: prefer a heads-like dim (size % model == 0), scanning
        # from the trailing side, skipping the batch dim.
        md = None
        for i in reversed(range(bdim + 1, nd)):
            if x.shape[i] % model_n == 0 and x.shape[i] >= model_n:
                md = i
                break
        if md is not None:
            spec[md] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, cache_tree)
