import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) combination on 512 placeholder host devices, and derive the roofline
terms from the compiled artifact.

The two lines above MUST stay first: jax locks the device count on first
init, and only the dry-run wants 512 fake devices (tests/benches see 1).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun
Writes one JSON per combination with memory/cost/roofline data.
"""

import argparse
import json
import pathlib
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.compat import SCAN_IN_PARTIAL_AUTO_BROKEN, use_mesh
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build
from repro.roofline import model_flops, roofline_terms, xla_cost_dict

SHAPE_NAMES = list(S.SHAPES)


def run_one(arch: str, shape: str, mesh_name: str, tau: int = 4,
            attn_impl: str = "scan", overrides: dict | None = None,
            smoke: bool = False) -> dict:
    from repro.configs import canonical

    arch = canonical(arch)
    cfg = get_smoke(arch) if smoke else get_config(arch)
    spec = S.SHAPES[shape]
    ok, note = S.shape_supported(cfg, shape)
    if not ok:
        return {
            "arch": arch, "shape": shape, "mesh": mesh_name,
            "status": "skipped", "reason": note,
        }
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_chips = mesh.devices.size
    overrides = dict(overrides or {})
    if (spec.kind == "train" and SCAN_IN_PARTIAL_AUTO_BROKEN
            and not overrides.get("granularity")):
        # This jax's SPMD partitioner aborts on lax.scan inside a partially
        # manual shard_map (see repro.compat); the layer-group scans make
        # worker-axis train steps uncompilable, so measure the accum
        # (no-worker-axis) variant and say so in the artifact.
        overrides["granularity"] = "accum"
        note = (note + "; " if note else "") + \
            "worker-axis step not compilable on this jax: accum fallback"
    t0 = time.time()
    with use_mesh(mesh):
        bundle = build(cfg, mesh, shape, tau=tau, attn_impl=attn_impl,
                       **overrides)
        lowered = bundle.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = xla_cost_dict(compiled)
        try:
            mem = compiled.memory_analysis()
            mem_d = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            }
        except Exception as e:  # CPU backend may not implement it
            mem_d = {"error": str(e)}
        hlo = compiled.as_text()

    mf = model_flops(S.effective_config(cfg, shape), spec,
                     tau=tau if spec.kind == "train" else 1)
    rep = roofline_terms(
        arch=arch, shape=shape, mesh_name=mesh_name, n_chips=n_chips,
        cost=cost, hlo_text=hlo, model_flops_total=mf,
    )
    out = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "status": "ok",
        "variant_note": note, "tau": tau,
        "n_chips": n_chips,
        "step": bundle.name,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem_d,
        "cost_analysis": {k: cost.get(k) for k in ("flops", "bytes accessed")},
        "roofline": rep.to_dict(),
        "hlo_collective_lines": sum(
            1 for l in hlo.splitlines()
            if any(c in l for c in ("all-reduce", "all-gather", "reduce-scatter",
                                    "all-to-all", "collective-permute"))
        ),
    }
    # analytic per-chip parameter bytes (sanity vs memory_analysis)
    ap = S.abstract_params(S.effective_config(cfg, shape))
    psh = S.param_shardings(S.effective_config(cfg, shape), mesh,
                            "accum" if spec.kind != "train" else None)
    tot = 0
    for leaf, sh in zip(jax.tree.leaves(ap), jax.tree.leaves(psh)):
        n_shards = 1
        for dim, axis in zip(leaf.shape, sh.spec + (None,) * 8):
            if axis is not None:
                names = axis if isinstance(axis, tuple) else (axis,)
                for a in names:
                    n_shards *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
        tot += leaf.size * leaf.dtype.itemsize / n_shards
    out["analytic_param_bytes_per_chip"] = int(tot)
    return out


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch")  # any alias; canonicalized below
    p.add_argument("--shape", choices=SHAPE_NAMES)
    p.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    p.add_argument("--tau", type=int, default=4)
    p.add_argument("--commit-dtype", default="float32")
    p.add_argument("--granularity", default="", help="override adsp granularity (train shapes)")
    p.add_argument("--attn-block", type=int, default=512)
    p.add_argument("--tag", default="", help="suffix for output filenames (perf iterations)")
    p.add_argument("--attn-impl", default="scan")
    p.add_argument("--all", action="store_true", help="run every arch × shape")
    p.add_argument("--out", default="results/dryrun")
    p.add_argument("--smoke", action="store_true", help="reduced configs (fast CI)")
    args = p.parse_args(argv)

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else SHAPE_NAMES

    failures = 0
    for arch in [a.replace("-", "_").replace(".", "_") for a in archs]:
        for shape in shapes:
            for mesh_name in meshes:
                tag = f"{arch}__{shape}__{mesh_name}" + (f"__{args.tag}" if args.tag else "")
                fp = outdir / f"{tag}.json"
                if fp.exists():
                    print(f"[skip existing] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                t0 = time.time()
                try:
                    over = ({"commit_dtype": args.commit_dtype,
                             "attn_block": args.attn_block}
                            if S.SHAPES[shape].kind == "train" else {})
                    if args.granularity and S.SHAPES[shape].kind == "train":
                        over["granularity"] = args.granularity
                    res = run_one(arch.replace("-", "_"), shape, mesh_name,
                                  tau=args.tau, attn_impl=args.attn_impl,
                                  smoke=args.smoke, overrides=over)
                except Exception as e:
                    traceback.print_exc()
                    res = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                res["wall_s"] = round(time.time() - t0, 1)
                fp.write_text(json.dumps(res, indent=2, default=str))
                status = res["status"]
                rl = res.get("roofline", {})
                print(f"  -> {status} ({res['wall_s']}s) "
                      f"bottleneck={rl.get('bottleneck')} "
                      f"flops/chip={rl.get('hlo_flops'):.3g}" if status == "ok"
                      else f"  -> {status}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
