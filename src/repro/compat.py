"""Version-compatibility shims over jax API drift.

The repo targets the current jax API (``jax.set_mesh``, ``jax.shard_map``
with ``axis_names``/``check_vma``) but must also run on the 0.4.x series
where those live under different names:

  * ambient mesh:   ``jax.set_mesh`` → ``jax.sharding.use_mesh`` → the
    ``Mesh`` object itself (a context manager on 0.4.x);
  * shard_map:      ``jax.shard_map(..., axis_names=, check_vma=)`` →
    ``jax.experimental.shard_map.shard_map(..., auto=, check_rep=)``
    (``auto`` is the complement of ``axis_names`` over the mesh axes).

Keep every cross-version call site in the repo routed through here so the
next drift is a one-file fix.
"""

from __future__ import annotations

import jax

__all__ = ["use_mesh", "shard_map", "ambient_mesh_axes", "SCAN_IN_PARTIAL_AUTO_BROKEN"]

# On the 0.4.x series, XLA:CPU's SPMD partitioner aborts (Check failed:
# sharding.IsManualSubgroup()) when a while-loop (lax.scan) sits inside a
# partially-manual shard_map. The τ-microstep scan is static-length, so
# affected versions fully unroll it instead (repro.ps.train_step).
SCAN_IN_PARTIAL_AUTO_BROKEN = not hasattr(jax, "shard_map")


def use_mesh(mesh: jax.sharding.Mesh):
    """Context manager making ``mesh`` the ambient mesh, any jax version."""
    if hasattr(jax, "set_mesh"):  # jax >= 0.6
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):  # late 0.5.x
        return jax.sharding.use_mesh(mesh)
    return mesh  # 0.4.x: Mesh is itself a context manager


def ambient_mesh_axes() -> dict[str, int]:
    """Axis-name → size of the ambient mesh; {} when none is active."""
    if hasattr(jax.sharding, "get_abstract_mesh"):  # jax >= 0.5
        m = jax.sharding.get_abstract_mesh()
        if m is None or m.empty:
            return {}
        return dict(zip(m.axis_names, m.axis_sizes))
    from jax._src import mesh as _mesh_lib  # 0.4.x ambient physical mesh

    m = _mesh_lib.thread_resources.env.physical_mesh
    if m is None or m.empty:
        return {}
    return dict(zip(m.axis_names, m.devices.shape))


def shard_map(f, mesh, in_specs, out_specs, axis_names=None, check=False):
    """``jax.shard_map`` with partial-manual axes, any jax version.

    ``axis_names`` are the *manual* axes (new-style); on old jax they are
    translated to the complementary ``auto`` set. ``check`` maps to
    ``check_vma`` (new) / ``check_rep`` (old).
    """
    manual = set(axis_names) if axis_names is not None else set(mesh.axis_names)
    if hasattr(jax, "shard_map"):  # jax >= 0.6
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=manual, check_vma=check,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check, auto=frozenset(mesh.axis_names) - manual,
    )
