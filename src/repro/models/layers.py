"""Shared building blocks for the architecture zoo (pure-JAX, pytree params).

Initialization mirrors common practice (truncated-normal fan-in scaling);
weights are created in float32 and cast to the config dtype at use time so
checkpoints stay full-precision while compute runs in bf16 on TPU.

``annotate`` applies logical-axis sharding constraints resolved through a
rules table (MaxText-style). Rules may only reference *auto* mesh axes —
inside the ADSP shard_map, worker axes are manual and must not appear.
"""

from __future__ import annotations

from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = [
    "default_rules",
    "annotate",
    "dense_init",
    "rmsnorm",
    "layernorm",
    "mlp_init",
    "mlp_apply",
    "rope",
    "dtype_of",
]


# Logical axis names used throughout the zoo.
def default_rules(model_axis: str = "model", data_axis: str | None = None) -> dict:
    """logical-axis → mesh-axis (or None). data_axis is only set for
    adsp_granularity 'pod'/'accum' where the batch dim is GSPMD-visible."""
    return {
        "batch": data_axis,
        "seq": None,
        "embed": None,
        "heads": model_axis,
        "kv_heads": model_axis,
        "qkv": model_axis,
        "mlp": model_axis,
        "vocab": model_axis,
        "experts": model_axis,
        "lru": model_axis,
    }


def annotate(x: jax.Array, logical: Sequence[str | None], rules: Mapping) -> jax.Array:
    """with_sharding_constraint by logical axes; divisibility-guarded."""
    if not rules:
        return x
    spec = []
    for dim, name in zip(x.shape, logical):
        axis = rules.get(name) if name else None
        spec.append(axis if axis and dim % _axis_size(axis) == 0 else None)
    if all(s is None for s in spec):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (RuntimeError, ValueError):
        return x  # no ambient mesh (plain CPU tests)


def _axis_size(axis) -> int:
    from repro.compat import ambient_mesh_axes

    sizes = ambient_mesh_axes()
    if not sizes:
        return 1 << 30  # force "not divisible" → no constraint
    names = axis if isinstance(axis, tuple) else (axis,)
    n = 1
    for a in names:
        n *= sizes.get(a, 1 << 30)
    return n


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(rng, fan_in: int, *out_dims: int, scale: float | None = None):
    """(fan_in, *out_dims) truncated-normal fan-in init, float32."""
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    shape = (fan_in, *out_dims)
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32) * scale)


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def norm_init(cfg, d: int):
    if cfg.norm_variant == "layernorm":
        return {"gamma": jnp.ones((d,), jnp.float32), "beta": jnp.zeros((d,), jnp.float32)}
    return {"gamma": jnp.zeros((d,), jnp.float32)}  # rmsnorm stores γ−1


def norm_apply(cfg, p, x):
    if cfg.norm_variant == "layernorm":
        return layernorm(x, p["gamma"], p["beta"], cfg.norm_eps)
    return rmsnorm(x, p["gamma"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_init(rng, d_model: int, d_ff: int, variant: str):
    ks = jax.random.split(rng, 3)
    if variant == "swiglu":
        return {
            "wi": dense_init(ks[0], d_model, d_ff),
            "wg": dense_init(ks[1], d_model, d_ff),
            "wo": dense_init(ks[2], d_ff, d_model),
        }
    return {
        "wi": dense_init(ks[0], d_model, d_ff),
        "wo": dense_init(ks[2], d_ff, d_model),
    }


def mlp_apply(p, x, variant: str, rules) -> jax.Array:
    dt = x.dtype
    if variant == "swiglu":
        h = jax.nn.silu(x @ p["wi"].astype(dt)) * (x @ p["wg"].astype(dt))
    else:
        h = jax.nn.gelu(x @ p["wi"].astype(dt))
    h = annotate(h, ("batch", "seq", "mlp"), rules)
    return h @ p["wo"].astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)
