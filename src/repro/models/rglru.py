"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(x_t W_r + b_r)            # recurrence gate
    i_t = sigmoid(x_t W_i + b_i)            # input gate
    a_t = exp(−c · softplus(Λ) · r_t)       # data-dependent decay, c = 8
    h_t = a_t ⊙ h_{t−1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

wrapped in Griffin's recurrent block:
    u = W_x · x ; v = gelu(W_g · x)
    u = conv1d_k4(u)  (causal, depthwise)
    y = RG-LRU(u)
    out = W_o (y ⊙ v)

Train/prefill runs the recurrence as an associative scan (h_t = a_t h_{t−1}
+ b_t is linear ⇒ jax.lax.associative_scan over (a, b) pairs — O(log S)
depth, TPU-friendly); decode carries (h,) state and a (k−1)-sample conv
tail. A Pallas chunked-scan kernel (kernels/rglru_scan.py) is the TPU
fast path for the same computation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import annotate, dense_init

__all__ = ["rglru_init", "rglru_apply", "rglru_decode", "rglru_init_state", "lru_scan"]

_C = 8.0  # decay sharpness constant from the paper


def rglru_init(rng, cfg):
    d, w = cfg.d_model, cfg.lru_width_
    ks = jax.random.split(rng, 6)
    # Λ init so that a = exp(−c·softplus(Λ)·0.5) spreads over (0.9, 0.999)
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) * 2.0 / _C))  # softplus⁻¹
    return {
        "wx": dense_init(ks[1], d, w),
        "wg": dense_init(ks[2], d, w),
        "wo": dense_init(ks[3], w, d),
        "conv": dense_init(ks[4], cfg.conv1d_width, w, scale=1.0 / np.sqrt(cfg.conv1d_width)),
        "wr": dense_init(ks[5], w, w, scale=0.02),
        "br": jnp.zeros((w,), jnp.float32),
        "wi": dense_init(jax.random.fold_in(ks[5], 1), w, w, scale=0.02),
        "bi": jnp.zeros((w,), jnp.float32),
        "lam": lam,
    }


def _gates(p, u, dt):
    r = jax.nn.sigmoid(u @ p["wr"].astype(dt) + p["br"].astype(dt))
    i = jax.nn.sigmoid(u @ p["wi"].astype(dt) + p["bi"].astype(dt))
    log_a = -_C * jax.nn.softplus(p["lam"]).astype(jnp.float32) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i.astype(jnp.float32) * u.astype(jnp.float32)
    )
    return a, b  # float32 (B, S, w)


def lru_scan(a, b, h0=None):
    """h_t = a_t h_{t−1} + b_t via associative scan over (S) axis=1."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        al, bl = l
        ar, br_ = r
        return al * ar, ar * bl + br_

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def _causal_conv(u, w):
    """Depthwise causal conv, kernel (K, width): y_t = Σ_k w[k]·u_{t−K+1+k}."""
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(pad[:, i : i + u.shape[1]] * w[i].astype(u.dtype) for i in range(k))


def rglru_apply(cfg, p, x, rules, impl: str = "scan"):
    """x: (B, S, d) → (B, S, d). Full-sequence (train / prefill)."""
    dt = x.dtype
    u = x @ p["wx"].astype(dt)
    v = jax.nn.gelu(x @ p["wg"].astype(dt))
    u = annotate(u, ("batch", "seq", "lru"), rules)
    u = _causal_conv(u, p["conv"])
    a, b = _gates(p, u, dt)
    if impl == "pallas":
        from repro.kernels import ops as kops

        h = kops.rglru_scan(a, b)
    else:
        h = lru_scan(a, b)
    y = (h.astype(dt) * v)
    return y @ p["wo"].astype(dt)


def rglru_init_state(cfg, batch: int):
    w = cfg.lru_width_
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv_tail": jnp.zeros((batch, cfg.conv1d_width - 1, w), jnp.float32),
    }


def rglru_decode(cfg, p, x, state, rules):
    """x: (B, 1, d); O(1) state update. Returns (out, new_state)."""
    dt = x.dtype
    u = x @ p["wx"].astype(dt)  # (B,1,w)
    v = jax.nn.gelu(x @ p["wg"].astype(dt))
    tail = state["conv_tail"].astype(dt)  # (B, K−1, w)
    window = jnp.concatenate([tail, u], axis=1)  # (B, K, w)
    k = cfg.conv1d_width
    uc = sum(window[:, i : i + 1] * p["conv"][i].astype(dt) for i in range(k))
    a, b = _gates(p, uc, dt)
    h = a[:, 0] * state["h"] + b[:, 0]  # (B, w)
    y = (h[:, None].astype(dt) * v) @ p["wo"].astype(dt)
    new_state = {"h": h, "conv_tail": window[:, 1:].astype(jnp.float32)}
    return y, new_state
