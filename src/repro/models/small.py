"""The paper's three application models, in plain JAX pytrees.

* ``CNN`` — TensorFlow-tutorial-style Cifar-10 CNN (2 conv + 2 dense),
  scaled down by default for CPU simulation speed (width configurable).
* ``RNN`` — GRU over stress sequences + static covariates → 3-way fatigue
  level (paper application ii).
* ``LinearSVM`` — L2-regularized multiclass/regression SVM for COP
  prediction (paper application iii). We use the squared-hinge/regression
  form so the loss is smooth (SGD-friendly), as is standard.

Each model exposes ``init(rng) -> params`` and ``apply(params, *inputs)``,
plus ``loss_fn(params, batch)`` used by the simulator's grad function.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CNN", "RNN", "LinearSVM", "make_task_fns"]


def _dense_init(rng, fan_in, fan_out, scale=None):
    scale = scale if scale is not None else float(np.sqrt(2.0 / fan_in))
    k1, _ = jax.random.split(rng)
    return {
        "w": jax.random.normal(k1, (fan_in, fan_out), jnp.float32) * scale,
        "b": jnp.zeros((fan_out,), jnp.float32),
    }


def _conv_init(rng, kh, kw, cin, cout):
    scale = float(np.sqrt(2.0 / (kh * kw * cin)))
    k1, _ = jax.random.split(rng)
    return {
        "w": jax.random.normal(k1, (kh, kw, cin, cout), jnp.float32) * scale,
        "b": jnp.zeros((cout,), jnp.float32),
    }


def _conv(x, p):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def _maxpool(x, k=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID"
    )


@dataclasses.dataclass(frozen=True)
class CNN:
    num_classes: int = 10
    width: int = 16  # conv channels (tutorial uses 64; 16 is CPU-friendly)
    dense: int = 64
    img: int = 24

    def init(self, rng):
        k = jax.random.split(rng, 4)
        flat = (self.img // 4) ** 2 * self.width
        return {
            "c1": _conv_init(k[0], 5, 5, 3, self.width),
            "c2": _conv_init(k[1], 5, 5, self.width, self.width),
            "d1": _dense_init(k[2], flat, self.dense),
            "d2": _dense_init(k[3], self.dense, self.num_classes, scale=0.01),
        }

    def apply(self, params, x):
        h = _maxpool(jax.nn.relu(_conv(x, params["c1"])))
        h = _maxpool(jax.nn.relu(_conv(h, params["c2"])))
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ params["d1"]["w"] + params["d1"]["b"])
        return h @ params["d2"]["w"] + params["d2"]["b"]

    def loss_fn(self, params, batch):
        x, y = batch
        logits = self.apply(params, x)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@dataclasses.dataclass(frozen=True)
class RNN:
    hidden: int = 32
    num_classes: int = 3
    cov_dim: int = 4

    def init(self, rng):
        k = jax.random.split(rng, 5)
        h = self.hidden
        return {
            "wz": _dense_init(k[0], 1 + h, h),
            "wr": _dense_init(k[1], 1 + h, h),
            "wh": _dense_init(k[2], 1 + h, h),
            "cov": _dense_init(k[3], self.cov_dim, h),
            "out": _dense_init(k[4], h, self.num_classes, scale=0.01),
        }

    def apply(self, params, x, cov):
        """x: (B, T) stress sequence; cov: (B, cov_dim)."""
        b = x.shape[0]
        h0 = jnp.tanh(cov @ params["cov"]["w"] + params["cov"]["b"])

        def cell(h, xt):
            inp = jnp.concatenate([xt[:, None], h], axis=1)
            z = jax.nn.sigmoid(inp @ params["wz"]["w"] + params["wz"]["b"])
            r = jax.nn.sigmoid(inp @ params["wr"]["w"] + params["wr"]["b"])
            inp2 = jnp.concatenate([xt[:, None], r * h], axis=1)
            hh = jnp.tanh(inp2 @ params["wh"]["w"] + params["wh"]["b"])
            h = (1 - z) * h + z * hh
            return h, None

        hT, _ = jax.lax.scan(cell, h0, x.T)
        return hT @ params["out"]["w"] + params["out"]["b"]

    def loss_fn(self, params, batch):
        x, cov, y = batch
        logits = self.apply(params, x, cov)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@dataclasses.dataclass(frozen=True)
class LinearSVM:
    """ε-insensitive L2 regression SVM (smooth squared form)."""

    dim: int = 6
    eps: float = 0.1
    reg: float = 1e-3

    def init(self, rng):
        return {"w": jnp.zeros((self.dim,), jnp.float32), "b": jnp.zeros((), jnp.float32)}

    def apply(self, params, x):
        return x @ params["w"] + params["b"]

    def loss_fn(self, params, batch):
        x, y = batch
        pred = self.apply(params, x)
        slack = jnp.maximum(jnp.abs(pred - y) - self.eps, 0.0)
        return jnp.mean(slack**2) + self.reg * jnp.sum(params["w"] ** 2)


def make_task_fns(model):
    """(jitted grad_fn, jitted eval_fn) for a model with loss_fn."""
    grad_fn = jax.jit(jax.value_and_grad(model.loss_fn))
    eval_fn = jax.jit(model.loss_fn)
    return grad_fn, eval_fn
