from .small import CNN, RNN, LinearSVM, make_task_fns

__all__ = ["CNN", "RNN", "LinearSVM", "make_task_fns"]
