"""RWKV-6 "Finch" time-mix + channel-mix blocks (arXiv:2404.05892).

Per head (head dim N), with per-token data-dependent decay w_t:

    S_t = diag(w_t) · S_{t−1} + k_tᵀ · v_t            (state: N×N)
    o_t = r_t · (S_{t−1} + u ⊙ (k_tᵀ v_t))            (u: learned bonus)

r, k, v, g and the decay w are produced by token-shift interpolation
(lerp between x_t and x_{t−1} with learned + data-dependent mixes, the
LoRA-style "ddlerp" of the paper, here with a single low-rank projection
per stream for tractability). The channel-mix is the standard RWKV
squared-ReLU FFN with token shift.

Train/prefill: a lax.scan over time carrying the (B, H, N, N) state —
linear in S. The Pallas kernel (kernels/rwkv6_scan.py) implements the
chunked form for TPU. Decode carries (state, last_x) and is O(1)/token.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import annotate, dense_init

__all__ = [
    "rwkv_time_init",
    "rwkv_time_apply",
    "rwkv_time_decode",
    "rwkv_channel_init",
    "rwkv_channel_apply",
    "rwkv_channel_decode",
    "rwkv_init_state",
    "wkv_scan",
]

_LORA = 32  # low-rank size of the data-dependent mixes


def rwkv_time_init(rng, cfg):
    d = cfg.d_model
    n = cfg.rwkv_head_dim
    h = d // n
    ks = jax.random.split(rng, 10)
    decay_base = jnp.linspace(-7.0, -4.5, d).astype(jnp.float32)  # per-channel
    return {
        "mix_base": jnp.full((5, d), 0.5, jnp.float32),  # r,k,v,w,g lerp bases
        "mix_lora_a": dense_init(ks[0], d, _LORA, scale=0.02),
        "mix_lora_b": dense_init(ks[1], _LORA, 5 * d, scale=0.02),
        "wr": dense_init(ks[2], d, d),
        "wk": dense_init(ks[3], d, d),
        "wv": dense_init(ks[4], d, d),
        "wg": dense_init(ks[5], d, d),
        "wo": dense_init(ks[6], d, d),
        "decay_base": decay_base,
        "decay_lora_a": dense_init(ks[7], d, _LORA, scale=0.02),
        "decay_lora_b": dense_init(ks[8], _LORA, d, scale=0.02),
        "bonus": jax.random.normal(ks[9], (h, n), jnp.float32) * 0.02,
        "ln_gamma": jnp.ones((d,), jnp.float32),  # group-norm on out
    }


def _token_shift(x, last=None):
    """x_{t−1} (zeros / `last` for t = 0). x: (B, S, d)."""
    prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if last is not None:
        prev = prev.at[:, 0].set(last)
    return prev


def _streams(p, x, prev, dt):
    """r,k,v,w,g streams via ddlerp token-shift."""
    delta = (prev - x).astype(jnp.float32)
    lora = jnp.tanh(x.astype(jnp.float32) @ p["mix_lora_a"]) @ p["mix_lora_b"]
    b, s, d = x.shape
    lora = lora.reshape(b, s, 5, d)
    mixes = p["mix_base"][None, None] + lora  # (B,S,5,d)
    xm = x.astype(jnp.float32)[:, :, None] + delta[:, :, None] * jax.nn.sigmoid(mixes)
    xr, xk, xv, xw, xg = [xm[:, :, i].astype(dt) for i in range(5)]
    r = xr @ p["wr"].astype(dt)
    k = xk @ p["wk"].astype(dt)
    v = xv @ p["wv"].astype(dt)
    g = jax.nn.silu(xg @ p["wg"].astype(dt))
    dec = p["decay_base"][None, None] + (
        jnp.tanh(xw.astype(jnp.float32) @ p["decay_lora_a"]) @ p["decay_lora_b"]
    )
    w = jnp.exp(-jnp.exp(dec))  # (B,S,d) ∈ (0,1), data-dependent decay
    return r, k, v, w, g


def _heads(x, n):
    b, s, d = x.shape
    return x.reshape(b, s, d // n, n)


def wkv_scan(r, k, v, w, bonus, state0=None):
    """Sequential WKV recurrence.

    r,k,v,w: (B, S, H, N) (w in float32); bonus: (H, N).
    Returns (out (B,S,H,N) float32, final state (B,H,N,N) float32).
    """
    b, s, h, n = r.shape
    st0 = state0 if state0 is not None else jnp.zeros((b, h, n, n), jnp.float32)

    def step(st, xs):
        rt, kt, vt, wt = xs  # (B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,N,N)
        out = jnp.einsum("bhn,bhnm->bhm", rt, st + bonus[None, :, :, None] * kv)
        st = wt[..., :, None] * st + kv
        return st, out

    xs = tuple(
        jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w)
    )
    stT, outs = jax.lax.scan(step, st0, xs)
    return jnp.moveaxis(outs, 0, 1), stT  # (B,S,H,N)


def _groupnorm(x, gamma, n):
    """Per-head layer norm on the flattened head outputs."""
    b, s, h, hd = x.shape
    mu = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + 1e-5)
    return y.reshape(b, s, h * hd) * gamma[None, None]


def rwkv_time_apply(cfg, p, x, rules, impl: str = "scan"):
    dt = x.dtype
    n = cfg.rwkv_head_dim
    prev = _token_shift(x)
    r, k, v, w, g = _streams(p, x, prev, dt)
    r, k, v, w = (_heads(t, n) for t in (r, k, v, w))
    k = k * (1.0 / math.sqrt(n))
    if impl == "pallas":
        from repro.kernels import ops as kops

        out, _ = kops.rwkv6_scan(r, k, v, w.astype(jnp.float32), p["bonus"])
    elif impl == "chunked":
        from .rwkv_chunked import wkv_chunked

        out, _ = wkv_chunked(r, k, v, w.astype(jnp.float32), p["bonus"])
    else:
        out, _ = wkv_scan(r, k, v, w.astype(jnp.float32), p["bonus"])
    y = _groupnorm(out, p["ln_gamma"], n).astype(dt) * g
    y = annotate(y, ("batch", "seq", "embed"), rules)
    return y @ p["wo"].astype(dt)


def rwkv_init_state(cfg, batch: int):
    d = cfg.d_model
    n = cfg.rwkv_head_dim
    return {
        "wkv": jnp.zeros((batch, d // n, n, n), jnp.float32),
        "last_x_time": jnp.zeros((batch, d), jnp.float32),
        "last_x_chan": jnp.zeros((batch, d), jnp.float32),
    }


def rwkv_time_decode(cfg, p, x, state, rules):
    """x: (B, 1, d) — one token; O(1) state update."""
    dt = x.dtype
    n = cfg.rwkv_head_dim
    prev = state["last_x_time"].astype(dt)[:, None]
    r, k, v, w, g = _streams(p, x, prev, dt)
    r, k, v, w = (_heads(t, n) for t in (r, k, v, w))
    k = k * (1.0 / math.sqrt(n))
    st = state["wkv"]
    rt, kt, vt, wt = (t[:, 0].astype(jnp.float32) for t in (r, k, v, w))
    kv = kt[..., :, None] * vt[..., None, :]
    out = jnp.einsum("bhn,bhnm->bhm", rt, st + p["bonus"][None, :, :, None] * kv)
    new_st = wt[..., :, None] * st + kv
    y = _groupnorm(out[:, None], p["ln_gamma"], n).astype(dt) * g
    y = y @ p["wo"].astype(dt)
    new_state = dict(state, wkv=new_st, last_x_time=x[:, 0].astype(jnp.float32))
    return y, new_state


def rwkv_channel_init(rng, cfg):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {
        "mix_k": jnp.full((d,), 0.5, jnp.float32),
        "mix_r": jnp.full((d,), 0.5, jnp.float32),
        "wk": dense_init(ks[0], d, f),
        "wr": dense_init(ks[1], d, d, scale=0.02),
        "wv": dense_init(ks[2], f, d),
    }


def _channel_core(p, x, prev, dt, rules):
    xk = x + (prev - x) * jax.nn.sigmoid(p["mix_k"]).astype(dt)
    xr = x + (prev - x) * jax.nn.sigmoid(p["mix_r"]).astype(dt)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dt)))
    k = annotate(k, ("batch", "seq", "mlp"), rules)
    r = jax.nn.sigmoid(xr @ p["wr"].astype(dt))
    return r * (k @ p["wv"].astype(dt))


def rwkv_channel_apply(cfg, p, x, rules):
    return _channel_core(p, x, _token_shift(x).astype(x.dtype), x.dtype, rules)


def rwkv_channel_decode(cfg, p, x, state, rules):
    prev = state["last_x_chan"].astype(x.dtype)[:, None]
    y = _channel_core(p, x, prev, x.dtype, rules)
    return y, dict(state, last_x_chan=x[:, 0].astype(jnp.float32))
