"""Chunked (matmul-form) RWKV6 WKV — the §Perf optimization for the
sequential scan (see EXPERIMENTS.md, rwkv6-3b × train_4k iteration).

The naive recurrence scans S tokens, moving the (B, H, N, N) state through
HBM every step: traffic ∝ S · B·H·N² — 5+ TB per train step at 4k×batch.
Within a chunk of length L the recurrence has a closed matmul form
(the GLA/linear-attention chunking):

    A_t = ∏_{u≤t} w_u                      (per-channel cumulative decay)
    o_t = (r_t ⊙ A_{t−1}) · S_in                     [carry-in term]
        + Σ_{s<t} (Σ_n r_tn · (A_{t−1,n}/A_{s,n}) · k_sn) v_s   [intra]
        + (Σ_n r_tn u_n k_tn) v_t                    [bonus diagonal]
    S_out = diag(A_L) · S_in + Σ_s (A_L/A_s ⊙ k_s)ᵀ v_s

so the outer scan runs S/L steps instead of S — state traffic drops by L
while the intra-chunk work becomes dense (L², N)-shaped einsums (MXU food
on TPU). Decay ratios are exponentiated only under the causal mask, so
nothing overflows even for strong decays.

Numerically exact (f32) vs the sequential oracle — validated in
tests/test_kernels.py::test_rwkv_chunked_matches_ref.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["wkv_chunked"]

NEG = -1e30


def wkv_chunked(r, k, v, w, bonus, state0=None, chunk: int = 32):
    """r,k,v,w: (B, S, H, N); w = decay ∈ (0,1) float32; bonus: (H, N).

    Returns (out (B,S,H,N) float32, final state (B,H,N,N) float32).
    """
    b, s, h, n = r.shape
    L = min(chunk, s)
    pad = (-s) % L
    if pad:
        zeros = jnp.zeros((b, pad, h, n), r.dtype)
        ones = jnp.ones((b, pad, h, n), jnp.float32)
        r = jnp.concatenate([r, zeros], axis=1)
        k = jnp.concatenate([k, zeros], axis=1)
        v = jnp.concatenate([v, zeros], axis=1)
        w = jnp.concatenate([w, ones], axis=1)
    sp = s + pad
    nc = sp // L

    f32 = jnp.float32
    # (B, nc, L, H, N) → scan over nc with (B, H, N, N) carry
    def to_chunks(x):
        return jnp.moveaxis(
            x.astype(f32).reshape(b, nc, L, h, n), 2, 3
        )  # (B, nc, H, L, N)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))
    u = bonus.astype(f32)  # (H, N)

    logw = jnp.log(jnp.maximum(wc, 1e-30))  # (B, nc, H, L, N)
    logA = jnp.cumsum(logw, axis=3)  # inclusive: logA_t = Σ_{u≤t} log w_u
    logA_prev = logA - logw  # logA_{t−1} (t=0 ⇒ 0)

    mask = jnp.tril(jnp.ones((L, L), bool), k=-1)  # s < t

    st0 = (
        state0.astype(f32)
        if state0 is not None
        else jnp.zeros((b, h, n, n), f32)
    )

    def step(st, xs):
        rC, kC, vC, lA, lAp = xs  # (B, H, L, N) each
        # carry-in: (r ⊙ A_{t−1}) · S_in
        rA = rC * jnp.exp(lAp)
        o1 = jnp.einsum("bhtn,bhnm->bhtm", rA, st)
        # intra-chunk: exponentiate decay ratios only where causal
        logD = lAp[:, :, :, None, :] - lA[:, :, None, :, :]  # (B,H,t,s,N)
        D = jnp.exp(jnp.where(mask[None, None, :, :, None], logD, NEG))
        tmp = jnp.einsum("bhtn,bhtsn,bhsn->bhts", rC, D, kC)
        o2 = jnp.einsum("bhts,bhsm->bhtm", tmp, vC)
        # bonus diagonal
        coeff = jnp.sum(rC * u[None, :, None, :] * kC, axis=-1)  # (B,H,L)
        o3 = coeff[..., None] * vC
        out = o1 + o2 + o3
        # state to next chunk
        lA_L = lA[:, :, -1:, :]  # (B,H,1,N)
        k_scaled = kC * jnp.exp(lA_L - lA)  # (B,H,L,N): A_L/A_s ⊙ k_s
        st_new = jnp.exp(lA_L[:, :, 0, :, None]) * st + jnp.einsum(
            "bhsn,bhsm->bhnm", k_scaled, vC
        )
        return st_new, out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rc, kc, vc, logA, logA_prev))
    stT, outs = jax.lax.scan(step, st0, xs)
    out = jnp.moveaxis(outs, 0, 1)  # (B, nc, H, L, N)
    out = jnp.moveaxis(out, 2, 3).reshape(b, sp, h, n)[:, :s]
    return out, stT
