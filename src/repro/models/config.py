"""Architecture configuration for the assigned model zoo.

One frozen dataclass describes every architecture family we support:
dense GQA decoders, MoE decoders, RWKV6 (attention-free), RG-LRU hybrids,
encoder–decoder (whisper), and VLM/audio variants whose modality frontend
is a stub (precomputed embeddings, per the assignment carve-out).

``layer_pattern`` drives composition: a cycle of block kinds, e.g.
``("recurrent", "recurrent", "local")`` for RecurrentGemma or
``("dense", "moe")`` for Llama-4 style interleaving. Layers are grouped
into repeats of the pattern and scanned (scan-over-layers) so compile time
stays bounded at 38–64 layers; a non-divisible remainder becomes a second,
shorter scan group.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["MoEConfig", "EncoderConfig", "ModelConfig", "SMOKE_OVERRIDES", "smoke_variant"]

BlockKind = Literal["global", "local", "recurrent", "rwkv", "dense", "moe"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden size
    num_shared_experts: int = 0
    d_shared: int = 0  # hidden size of the fused shared-expert MLP
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder consuming precomputed frame embeddings."""

    num_layers: int
    num_frames: int  # e.g. 1500 for whisper (30 s @ 50 Hz after conv stub)
    d_model: int
    num_heads: int
    d_ff: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""  # citation (paper / model card)
    head_dim: int = 0  # 0 ⇒ d_model // num_heads
    # --- attention ---------------------------------------------------------
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    sliding_window: int = 0  # 0 ⇒ full attention for "global" blocks
    local_window: int = 2048  # window for "local" blocks (hybrid archs)
    # --- block composition --------------------------------------------------
    layer_pattern: tuple[str, ...] = ("global",)
    mlp_variant: str = "swiglu"  # swiglu | gelu
    norm_variant: str = "rmsnorm"  # rmsnorm | layernorm
    pos_variant: str = "rope"  # rope | learned | none
    # --- families -----------------------------------------------------------
    moe: MoEConfig | None = None
    encoder: EncoderConfig | None = None
    # RG-LRU (hybrid)
    lru_width: int = 0  # 0 ⇒ d_model
    conv1d_width: int = 4
    # RWKV6
    rwkv_head_dim: int = 64
    # --- modality stub -------------------------------------------------------
    frontend: str = ""  # "" | "audio" | "vision"
    num_prefix_embeddings: int = 0  # vision patch tokens prepended
    # --- distribution --------------------------------------------------------
    adsp_granularity: str = "data"  # data | pod | accum (see repro.ps, DESIGN.md §3)
    # --- misc ----------------------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    max_seq_len: int = 524_288
    dtype: str = "bfloat16"

    # -------------------------------------------------------------- helpers
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def lru_width_(self) -> int:
        return self.lru_width or self.d_model

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the 'model' axis shards it."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def layer_groups(self) -> list[tuple[tuple[str, ...], int]]:
        """[(pattern, repeats), ...] covering num_layers; the remainder (if
        the pattern does not divide num_layers) becomes a trailing group."""
        pat = self.layer_pattern
        n = len(pat)
        full, rem = divmod(self.num_layers, n)
        groups: list[tuple[tuple[str, ...], int]] = []
        if full:
            groups.append((pat, full))
        if rem:
            groups.append((pat[:rem], 1))
        return groups

    @property
    def supports_long_decode(self) -> bool:
        """True if serve at 500k+ context is sub-quadratic AND O(seq) cache
        is avoidable: SSM/hybrid/local-attention archs natively; dense archs
        only via the sliding-window variant (flagged by the dry-run)."""
        kinds = set(self.layer_pattern)
        if kinds <= {"recurrent", "rwkv", "local"} or "global" not in kinds and "dense" not in kinds and "moe" not in kinds:
            return True
        return self.sliding_window > 0

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder is not None

    def active_params(self) -> int:
        """Approximate active parameter count (MoE: top_k experts only)."""
        return _param_count(self, active_only=True)

    def total_params(self) -> int:
        return _param_count(self, active_only=False)


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    d, hd = cfg.d_model, cfg.head_dim_
    n_q, n_kv = cfg.num_heads, cfg.num_kv_heads
    emb = cfg.padded_vocab * d * (1 if cfg.tie_embeddings else 2)
    total = emb
    mlp_mult = 3 if cfg.mlp_variant == "swiglu" else 2
    for kind in _expand_layers(cfg):
        if kind in ("global", "local", "dense"):
            attn = d * (n_q * hd + 2 * n_kv * hd) + n_q * hd * d
            total += attn + mlp_mult * d * cfg.d_ff
        elif kind == "moe":
            attn = d * (n_q * hd + 2 * n_kv * hd) + n_q * hd * d
            total += attn
            m = cfg.moe
            n_e = m.top_k if active_only else m.num_experts
            total += n_e * mlp_mult * d * m.d_expert + d * m.num_experts
            if m.num_shared_experts:
                total += mlp_mult * d * m.d_shared
        elif kind == "recurrent":
            w = cfg.lru_width_
            total += 2 * d * w + w * d + cfg.conv1d_width * w + 3 * w
            total += mlp_mult * d * cfg.d_ff
        elif kind == "rwkv":
            total += 6 * d * d + 2 * d * cfg.d_ff  # time-mix ~5dd + out, channel-mix
    if cfg.encoder is not None:
        e = cfg.encoder
        total += e.num_layers * (4 * e.d_model**2 + 2 * e.d_model * e.d_ff)
        # cross-attention in every decoder layer
        total += cfg.num_layers * 4 * d * d
    return total


def _expand_layers(cfg: ModelConfig) -> list[str]:
    out: list[str] = []
    for pat, reps in cfg.layer_groups:
        out.extend(list(pat) * reps)
    return out


# ---------------------------------------------------------------------------
# Reduced smoke variants (CPU tests): ≤2 layers of every distinct kind,
# d_model ≤ 512, ≤4 experts — same code paths, tiny tensors.
# ---------------------------------------------------------------------------

SMOKE_OVERRIDES = dict(
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    head_dim=32,
    lru_width=128,
    local_window=64,
    max_seq_len=4096,
    dtype="float32",
)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: keeps the layer pattern (one full cycle),
    shrinks every width, caps experts at 4."""
    pat = cfg.layer_pattern
    n_layers = max(2, len(pat))
    over = dict(SMOKE_OVERRIDES)
    over["num_layers"] = n_layers
    over["num_kv_heads"] = min(cfg.num_kv_heads, 2) or 1
    if cfg.num_kv_heads == cfg.num_heads:  # MHA archs stay MHA
        over["num_kv_heads"] = over["num_heads"] = 4
    if cfg.num_kv_heads == 1:
        over["num_kv_heads"] = 1
    if cfg.moe is not None:
        over["moe"] = MoEConfig(
            num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_expert=64,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            d_shared=64 if cfg.moe.num_shared_experts else 0,
            capacity_factor=2.0,
        )
    if cfg.encoder is not None:
        over["encoder"] = EncoderConfig(
            num_layers=2, num_frames=16, d_model=128, num_heads=4, d_ff=256
        )
    if cfg.sliding_window:
        over["sliding_window"] = 64
    if cfg.num_prefix_embeddings:
        over["num_prefix_embeddings"] = 8
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **over)
