"""Mixture-of-Experts FFN with capacity-based sort dispatch.

Token routing is the classic Switch/GShard scheme adapted to be
compile-friendly on TPU without ragged ops:

  1. router logits → top-k experts + gates per token;
  2. for each of the k slots, tokens are *sorted* by expert id (argsort —
     a TPU-friendly dispatch that avoids the (T, E, C) one-hot dispatch
     tensor, which at 65k tokens × 128 experts would be terabytes);
  3. each expert processes a fixed ``capacity = ceil(T/E · cf)`` slice of
     its sorted tokens — overflow tokens are dropped (standard);
  4. expert outputs are scattered back and combined with the gate weights;
  5. optional shared experts (Qwen-MoE style) run densely and are added.

An auxiliary load-balance loss (Switch §4) is returned so training keeps
routing spread out; it is weighted by cfg.moe.router_aux_weight upstream.

Expert weights are annotated onto the 'model' axis over the *expert* dim
when divisible (expert parallelism) — else over d_expert.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import annotate, dense_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(rng, cfg):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(rng, 5)
    e, de = m.num_experts, m.d_expert
    # Stacked expert weights (E, d, de) with *per-expert* fan-in scaling.
    p = {
        "router": dense_init(ks[0], d, e, scale=0.02),
        "wi": jax.random.truncated_normal(ks[1], -2, 2, (e, d, de), jnp.float32) / np.sqrt(d),
        "wg": jax.random.truncated_normal(ks[2], -2, 2, (e, d, de), jnp.float32) / np.sqrt(d),
        "wo": jax.random.truncated_normal(ks[3], -2, 2, (e, de, d), jnp.float32) / np.sqrt(de),
    }
    if m.num_shared_experts:
        ds = m.d_shared * m.num_shared_experts
        p["shared_wi"] = dense_init(ks[4], d, ds)
        p["shared_wg"] = dense_init(jax.random.fold_in(ks[4], 1), d, ds)
        p["shared_wo"] = dense_init(jax.random.fold_in(ks[4], 2), ds, d)
    return p


def moe_apply(cfg, p, x, rules):
    """x: (B, S, d) → (out (B, S, d), aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    dt = x.dtype
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9
    )

    # Switch aux loss: E · Σ_e f_e · p_e  (f = token fraction, p = mean prob)
    f = jnp.zeros((m.num_experts,), jnp.float32).at[expert_ids[:, 0]].add(1.0) / t
    aux = m.num_experts * jnp.sum(f * probs.mean(axis=0))

    capacity = int(np.ceil(t / m.num_experts * m.capacity_factor))
    # Small-T (decode) safety: with a handful of tokens the statistical
    # capacity bound is far too tight — give every expert room for up to
    # min(T, 8) tokens so single-token decode never drops.
    capacity = max(capacity, min(t, 8), 1)

    # Combine accumulates in the compute dtype and scatters expert outputs
    # straight back to token order (one scatter-add) instead of a second
    # argsort + two gathers — §Perf llama4 iteration: the (T, d) f32
    # accumulator and inverse-permutation gathers were ~1.3 GB/layer/
    # microstep of pure HBM traffic.
    out = jnp.zeros((t, d), dt)
    for slot in range(m.top_k):
        eid = expert_ids[:, slot]  # (T,)
        gate = gate_vals[:, slot]
        order = jnp.argsort(eid)  # tokens grouped by expert
        eid_s = eid[order]
        # rank within expert group: position − first index of the group
        # (eid_s is sorted, so searchsorted gives each group's start).
        first = jnp.searchsorted(eid_s, jnp.arange(m.num_experts))
        rank = jnp.arange(t) - first[eid_s]
        keep = rank < capacity
        dst = eid_s * capacity + jnp.minimum(rank, capacity - 1)  # (T,)
        disp = jnp.zeros((m.num_experts * capacity, d), dt)
        disp = disp.at[dst].add(jnp.where(keep[:, None], xt[order], 0).astype(dt))
        disp = disp.reshape(m.num_experts, capacity, d)
        disp = annotate(disp, ("experts", None, "embed"), rules)

        h = jnp.einsum("ecd,edf->ecf", disp, p["wi"].astype(dt))
        g = jnp.einsum("ecd,edf->ecf", disp, p["wg"].astype(dt))
        h = jax.nn.silu(h) * g
        eo = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))
        eo = eo.reshape(m.num_experts * capacity, d)

        contrib = (eo[dst] * keep[:, None]) * gate[order][:, None].astype(dt)
        out = out.at[order].add(contrib)  # scatter back to token order

    if m.num_shared_experts:
        h = jax.nn.silu(xt @ p["shared_wi"].astype(dt)) * (xt @ p["shared_wg"].astype(dt))
        out = out + h @ p["shared_wo"].astype(dt)

    return out.reshape(b, s, d), aux
