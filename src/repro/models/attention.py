"""GQA attention: init, train/prefill (blockwise flash in pure JAX or the
Pallas TPU kernel), and single-token decode against a KV cache.

Implementations (cfg-selectable, all numerically interchangeable):
  * ``naive``  — materializes (S, S) logits; smoke tests only.
  * ``scan``   — q-block × kv-block online-softmax flash in pure JAX
                 (lax.scan over kv blocks inside a scan over q blocks);
                 never materializes more than (block_q, block_k) logits per
                 head. Default for the dry-run so 32k-token prefill fits.
  * ``pallas`` — kernels/flash_attention.py (TPU target; interpret=True in
                 tests). Same blockwise algorithm with VMEM BlockSpecs.

Sliding-window (``window > 0``) restricts keys to (qpos − window, qpos].
Decode uses a ring-buffer cache when the window is finite (cache length =
window) and a dense cache otherwise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import annotate, dense_init, rope

__all__ = [
    "attention_init",
    "attention_apply",
    "chunk_attention_apply",
    "decode_attention_apply",
    "flash_attention_jax",
    "resolve_attn_impl",
]

NEG_INF = -1e30


def resolve_attn_impl(requested: str | None = None, model_name: str = "") -> str:
    """Map a user-facing attention choice to an ``attention_apply`` impl.

    ``"ref"`` → the pure-JAX blockwise ``"scan"``; ``"flash"`` → the
    Pallas ``"pallas"`` kernel. None/"auto" picks per model family, the
    same policy as ``repro.ps.rules.resolve_backend``: flash is the
    default training-path attention for the granite family when a TPU is
    present (the kernel compiles natively there); everything else — and
    every family off-TPU, where interpret-mode Pallas is a validation
    path, not a fast path — stays on the scan implementation.
    """
    if requested in ("naive", "scan", "pallas"):
        return requested
    if requested == "ref":
        return "scan"
    if requested == "flash":
        return "pallas"
    if requested not in (None, "auto"):
        raise ValueError(
            f"unknown attention impl {requested!r} "
            "(want 'ref', 'flash', 'naive', 'scan', 'pallas', 'auto')"
        )
    if "granite" in model_name and jax.default_backend() == "tpu":
        return "pallas"
    return "scan"


def attention_init(rng, cfg, d_model: int | None = None, num_heads: int | None = None,
                   num_kv_heads: int | None = None):
    d = d_model or cfg.d_model
    hq = num_heads or cfg.num_heads
    hkv = num_kv_heads or cfg.num_kv_heads
    hd = cfg.head_dim_
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], d, hq, hd),
        "wk": dense_init(ks[1], d, hkv, hd),
        "wv": dense_init(ks[2], d, hkv, hd),
        "wo": dense_init(ks[3], hq * hd, d, scale=1.0 / np.sqrt(hq * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, hd), jnp.float32)
        p["bk"] = jnp.zeros((hkv, hd), jnp.float32)
        p["bv"] = jnp.zeros((hkv, hd), jnp.float32)
    return p


def _project_qkv(cfg, p, x, positions, rules, use_rope=True):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if use_rope and cfg.pos_variant == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = annotate(q, ("batch", "seq", "heads", None), rules)
    k = annotate(k, ("batch", "seq", "kv_heads", None), rules)
    v = annotate(v, ("batch", "seq", "kv_heads", None), rules)
    return q, k, v


def _out_proj(p, ctx, rules):
    b, s, hq, hd = ctx.shape
    ctx = annotate(ctx, ("batch", "seq", "heads", None), rules)
    return ctx.reshape(b, s, hq * hd) @ p["wo"].astype(ctx.dtype)


# ---------------------------------------------------------------------------
# full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------

def attention_apply(cfg, p, x, positions, *, window: int = 0, causal: bool = True,
                    rules=None, impl: str = "scan", block_q: int = 512,
                    block_k: int = 512):
    """x: (B, S, d). Returns (B, S, d)."""
    rules = rules or {}
    q, k, v = _project_qkv(cfg, p, x, positions, rules)
    if impl == "pallas":
        from repro.kernels import ops as kops

        ctx = kops.flash_attention(q, k, v, causal=causal, window=window)
    elif impl == "naive":
        ctx = _naive_attention(q, k, v, positions, causal, window)
    else:
        ctx = flash_attention_jax(
            q, k, v, positions, causal=causal, window=window,
            block_q=block_q, block_k=block_k,
        )
    return _out_proj(p, ctx, rules)


def _gqa_expand(q, k):
    """Reshape q to expose the kv-group dim: (B,S,Hkv,G,hd)."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    return q.reshape(b, s, hkv, g, hd), g


def _naive_attention(q, k, v, positions, causal, window):
    b, s, hq, hd = q.shape
    qg, g = _gqa_expand(q, k)
    scale = 1.0 / np.sqrt(hd)
    logits = jnp.einsum("bqhgk,bkhk_->bhgqk_".replace("k_", "t"), qg, k) * scale
    qpos, kpos = positions[:, :, None], positions[:, None, :]
    mask = jnp.ones((b, s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= qpos - kpos < window
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bhgqt,bthk->bqhgk", w, v)
    return ctx.reshape(b, s, hq, hd)


def flash_attention_jax(q, k, v, positions, *, causal=True, window=0,
                        block_q=512, block_k=512):
    """Blockwise online-softmax attention, pure JAX (the ref algorithm the
    Pallas kernel mirrors). Pads S to a block multiple; positions carry the
    true indices so padding keys are masked out by the causal test.
    """
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = 1.0 / np.sqrt(hd)
    bq, bk = min(block_q, s), min(block_k, s)
    pad_q = (-s) % bq
    pad_k = (-s) % bk
    if pad_q or pad_k:
        qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        # padding positions: -1 for keys (always masked), big for queries
        posq = jnp.pad(positions, ((0, 0), (0, pad_q)), constant_values=2**30)
        posk = jnp.pad(positions, ((0, 0), (0, pad_k)), constant_values=-1)
    else:
        qp, kp, vp, posq, posk = q, k, v, positions, positions
    sq, sk = s + pad_q, s + pad_k
    nq, nk = sq // bq, sk // bk

    qb = qp.reshape(b, nq, bq, hkv, g, hd)
    kb = kp.reshape(b, nk, bk, hkv, hd)
    vb = vp.reshape(b, nk, bk, hkv, hd)
    pq = posq.reshape(b, nq, bq)
    pk = posk.reshape(b, nk, bk)

    def per_qblock(q_i, pq_i):
        # q_i: (b, bq, hkv, g, hd); scan over kv blocks
        def body(carry, xs):
            m, l, acc = carry
            k_j, v_j, pk_j = xs  # (b, bk, hkv, hd), ..., (b, bk)
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j) * scale
            logits = logits.astype(jnp.float32)
            valid = pk_j[:, None, :] >= 0
            mask = valid
            if causal:
                mask &= pk_j[:, None, :] <= pq_i[:, :, None]
            if window:
                mask &= pq_i[:, :, None] - pk_j[:, None, :] < window
            logits = jnp.where(mask[:, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(logits - m_new[..., None])
            l_new = l * alpha + pexp.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", pexp.astype(v_j.dtype), v_j
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, bq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.moveaxis(pk, 1, 0)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.einsum("bhgqd->bqhgd", out)  # (b, bq, hkv, g, hd)

    outs = jax.lax.map(
        lambda xs: per_qblock(*xs),
        (jnp.moveaxis(qb, 1, 0), jnp.moveaxis(pq, 1, 0)),
    )  # (nq, b, bq, hkv, g, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, hkv, g, hd)[:, :s]
    return out.reshape(b, s, hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode (single new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention_apply(cfg, p, x, cache, *, window: int = 0, rules=None):
    """x: (B, 1, d); cache: dict(k=(B, C, Hkv, hd), v=..., pos=(B,) int32).

    Returns (out (B, 1, d), new_cache). C is the cache capacity: full
    seq_len for dense attention, ``window`` (ring buffer) when windowed.
    """
    rules = rules or {}
    b, _, d = x.shape
    cap = cache["k"].shape[1]
    pos = cache["pos"]  # (B,) — number of tokens already cached
    q, k_new, v_new = _project_qkv(cfg, p, x, pos[:, None], rules)

    slot = (pos % cap) if window else jnp.minimum(pos, cap - 1)
    oh = jax.nn.one_hot(slot, cap, dtype=k_new.dtype)  # (B, C)
    k = cache["k"] * (1 - oh[..., None, None]) + oh[..., None, None] * k_new
    v = cache["v"] * (1 - oh[..., None, None]) + oh[..., None, None] * v_new

    qg, g = _gqa_expand(q, k)  # (B,1,Hkv,G,hd)
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhgd,bchd->bhgqc", qg, k) * scale  # (B,Hkv,G,1,C)
    logits = logits.astype(jnp.float32)

    if window:
        idx = jnp.arange(cap)[None, :]  # ring slots
        # slot i holds absolute position: derive from pos (tokens 0..pos)
        abs_pos = jnp.where(
            idx <= (pos[:, None] % cap), pos[:, None] - (pos[:, None] % cap) + idx,
            pos[:, None] - (pos[:, None] % cap) - cap + idx,
        )
        valid = (abs_pos >= 0) & (abs_pos <= pos[:, None]) & (
            pos[:, None] - abs_pos < window
        )
    else:
        idx = jnp.arange(cap)[None, :]
        valid = idx <= pos[:, None]
    logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    ctx = jnp.einsum("bhgqc,bchd->bqhgd", w, v)
    hq = cfg_num_heads_from(qg)
    ctx = ctx.reshape(b, 1, -1, q.shape[-1])
    out = _out_proj(p, ctx, rules)
    new_cache = {"k": k, "v": v, "pos": pos + 1}
    return out, new_cache


def cfg_num_heads_from(qg):
    return qg.shape[2] * qg.shape[3]


# ---------------------------------------------------------------------------
# chunked prefill (c new tokens against a cache, resumable)
# ---------------------------------------------------------------------------

def chunk_attention_apply(cfg, p, x, cache, positions, nv, valid, *,
                          window: int = 0, rules=None):
    """Advance a decode cache by one prefill chunk.

    x: (B, c, d) — c prompt tokens per row, of which ``nv`` (B,) are
    valid (the rest are padding; per-row ragged chunks share one
    dispatch). ``positions`` (B, c) are absolute token positions
    (``cache["pos"] + arange(c)``); ``valid`` is the (B, c) bool mask
    ``arange(c) < nv``. Each query attends to the previously cached keys
    plus the chunk's own keys under the same causal/window masks the
    full-sequence path applies, then the valid K/V land in the cache
    (ring slots when windowed, dense otherwise) and ``pos`` advances by
    ``nv``. Rows with nv = 0 are exact no-ops on the cache.

    Returns (out (B, c, d), new_cache). Requires c <= cache capacity for
    ring caches — a larger chunk would overwrite keys still inside the
    window of the chunk's own early queries.
    """
    rules = rules or {}
    b, c, _ = x.shape
    cap = cache["k"].shape[1]
    if window and c > cap:
        raise ValueError(
            f"prefill chunk {c} exceeds ring cache capacity {cap}; "
            "windowed caches can absorb at most `window` tokens per chunk"
        )
    pos = cache["pos"]  # (B,) tokens already cached per row
    q, k_new, v_new = _project_qkv(cfg, p, x, positions, rules)

    # absolute positions + validity of the *existing* cache slots, i.e.
    # the state before this chunk (last written position = pos - 1).
    idx = jnp.arange(cap)[None, :]
    e_old = pos[:, None] - 1
    if window:
        m = e_old % cap
        abs_cache = jnp.where(idx <= m, e_old - m + idx, e_old - m - cap + idx)
        valid_cache = (abs_cache >= 0) & (abs_cache <= e_old)
    else:
        abs_cache = jnp.broadcast_to(idx, (b, cap))
        valid_cache = idx < pos[:, None]

    k_all = jnp.concatenate([cache["k"], k_new], axis=1)  # (B, cap+c, Hkv, hd)
    v_all = jnp.concatenate([cache["v"], v_new], axis=1)
    abs_all = jnp.concatenate([abs_cache, positions], axis=1)  # (B, cap+c)
    valid_all = jnp.concatenate([valid_cache, valid], axis=1)

    qg, _ = _gqa_expand(q, k_all)  # (B, c, Hkv, G, hd)
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_all) * scale
    logits = logits.astype(jnp.float32)
    mask = valid_all[:, None, :] & (abs_all[:, None, :] <= positions[:, :, None])
    if window:
        mask &= positions[:, :, None] - abs_all[:, None, :] < window
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v_all.dtype)
    ctx = jnp.einsum("bhgqk,bkhd->bqhgd", w, v_all)
    ctx = ctx.reshape(b, c, -1, q.shape[-1])
    out = _out_proj(p, ctx, rules)

    # scatter the valid chunk K/V; invalid rows point past the cache and
    # mode="drop" discards them, so padding never lands in a slot.
    slots = positions % cap if window else positions
    slots = jnp.where(valid, slots, cap)
    rows = jnp.arange(b)[:, None]
    new_cache = dict(cache)
    new_cache["k"] = cache["k"].at[rows, slots].set(k_new, mode="drop")
    new_cache["v"] = cache["v"].at[rows, slots].set(v_new, mode="drop")
    new_cache["pos"] = pos + nv
    return out, new_cache
