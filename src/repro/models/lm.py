"""Architecture assembly: embeddings + scanned layer groups + LM head.

One code path serves all 10 assigned architectures:

* layer groups from cfg.layer_groups are lax.scan-ed (stacked params) so
  compile time is O(pattern) not O(num_layers);
* block kinds: "global"/"dense" (full or sliding-window GQA + MLP),
  "local" (windowed GQA + MLP), "moe" (GQA + routed experts),
  "recurrent" (RG-LRU), "rwkv" (RWKV6 time+channel mix);
* encoder–decoder (whisper): a bidirectional encoder over precomputed
  frame embeddings (modality-frontend stub) + cross-attention in every
  decoder block;
* VLM (phi-3-vision): precomputed patch embeddings prepended to the token
  sequence (vision-tower stub); loss masked to token positions.

Three entry points per architecture (all pure, jit/shard_map friendly):
  lm_loss(cfg, params, batch)                 — training objective
  lm_prefill(cfg, params, batch)              — build decode caches
  lm_decode_step(cfg, params, batch, caches)  — one token, O(1)/O(window)

Batch layout: {"tokens": (B, S) int32} plus "frames" (B, F, d) for audio
and "patches" (B, P, d) for VLM. Labels are tokens shifted by one with the
final position masked, so a (B, S) batch trains S−1 predictions.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .attention import attention_apply, attention_init, decode_attention_apply
from .config import ModelConfig
from .layers import annotate, dense_init, dtype_of, mlp_apply, mlp_init, norm_apply, norm_init
from .moe import moe_apply, moe_init
from .rglru import rglru_apply, rglru_decode, rglru_init, rglru_init_state
from .rwkv6 import (
    rwkv_channel_apply,
    rwkv_channel_decode,
    rwkv_channel_init,
    rwkv_init_state,
    rwkv_time_apply,
    rwkv_time_decode,
    rwkv_time_init,
)

__all__ = [
    "lm_init",
    "lm_loss",
    "lm_logits",
    "lm_prefill",
    "lm_prefill_chunk",
    "lm_decode_step",
    "init_decode_caches",
    "cache_slot_insert",
    "cache_slot_extract",
    "cache_slot_clear",
    "max_chunk_len",
]

ATTN_KINDS = ("global", "local", "dense", "moe")


def _rwkv_impl(attn_impl: str) -> str:
    # "scan" (the dry-run default elsewhere) maps to the chunked matmul
    # form for RWKV — the sequential scan is kept for tests/oracle use
    # via attn_impl="naive". See EXPERIMENTS.md §Perf (rwkv6 iteration).
    if attn_impl == "pallas":
        return "pallas"
    if attn_impl == "naive":
        return "scan"
    return "chunked"


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(rng, cfg: ModelConfig, kind: str, cross: bool):
    d = cfg.d_model
    ks = jax.random.split(rng, 8)
    p: dict = {"norm1": norm_init(cfg, d)}
    if kind in ("global", "local", "dense", "moe"):
        p["attn"] = attention_init(ks[0], cfg)
        p["norm2"] = norm_init(cfg, d)
        if kind == "moe":
            p["moe"] = moe_init(ks[1], cfg)
        else:
            p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_variant)
        if cross:
            p["norm_cross"] = norm_init(cfg, d)
            p["cross"] = attention_init(ks[2], cfg)
    elif kind == "recurrent":
        p["rec"] = rglru_init(ks[0], cfg)
        p["norm2"] = norm_init(cfg, d)
        p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_variant)
    elif kind == "rwkv":
        p["time"] = rwkv_time_init(ks[0], cfg)
        p["norm2"] = norm_init(cfg, d)
        p["chan"] = rwkv_channel_init(ks[1], cfg)
    else:
        raise ValueError(f"unknown block kind {kind}")
    return p


def _group_init(rng, cfg: ModelConfig, pattern, reps: int, cross: bool):
    def one(r):
        ks = jax.random.split(r, len(pattern))
        return {k_i: _block_init(ks[i], cfg, kind, cross) for i, (k_i, kind) in enumerate(_pattern_keys(pattern))}

    return jax.vmap(one)(jax.random.split(rng, reps))


def _pattern_keys(pattern):
    """Stable dict keys per sublayer: '<idx>_<kind>'."""
    return [(f"{i}_{kind}", kind) for i, kind in enumerate(pattern)]


def lm_init(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 8 + len(cfg.layer_groups))
    v = cfg.padded_vocab
    params: dict = {
        "embed": dense_init(ks[0], v, cfg.d_model, scale=1.0),
        "final_norm": norm_init(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ks[1], cfg.d_model, v)
    if cfg.pos_variant == "learned":
        params["pos_embed"] = dense_init(ks[2], cfg.max_seq_len, cfg.d_model, scale=0.02)
    cross = cfg.is_encoder_decoder
    for gi, (pattern, reps) in enumerate(cfg.layer_groups):
        params[f"group{gi}"] = _group_init(ks[3 + gi], cfg, pattern, reps, cross)
    if cfg.encoder is not None:
        e = cfg.encoder
        ecfg = dataclasses.replace(
            cfg, d_model=e.d_model, num_heads=e.num_heads,
            num_kv_heads=e.num_heads, d_ff=e.d_ff, qkv_bias=False,
            layer_pattern=("global",), num_layers=e.num_layers,
        )
        params["enc_pos"] = dense_init(ks[6], e.num_frames, e.d_model, scale=0.02)
        params["encoder"] = _group_init(ks[7], ecfg, ("global",), e.num_layers, cross=False)
        params["enc_norm"] = norm_init(ecfg, e.d_model)
        if e.d_model != cfg.d_model:
            params["enc_proj"] = dense_init(jax.random.fold_in(ks[7], 1), e.d_model, cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# forward (full sequence: train / prefill)
# ---------------------------------------------------------------------------

def _attn_window(cfg, kind):
    if kind == "local":
        return cfg.local_window
    return cfg.sliding_window  # 0 ⇒ full attention


def _block_apply(cfg, kind, p, x, positions, rules, attn_impl, enc_out=None,
                 attn_block: int = 512):
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ATTN_KINDS:
        h = norm_apply(cfg, p["norm1"], x)
        h = attention_apply(
            cfg, p["attn"], h, positions,
            window=_attn_window(cfg, kind), causal=True,
            rules=rules, impl=attn_impl,
            block_q=attn_block, block_k=attn_block,
        )
        x = x + h
        if enc_out is not None and "cross" in p:
            h = norm_apply(cfg, p["norm_cross"], x)
            h = _cross_attention(cfg, p["cross"], h, enc_out, rules)
            x = x + h
        h = norm_apply(cfg, p["norm2"], x)
        if kind == "moe":
            h, aux = moe_apply(cfg, p["moe"], h, rules)
        else:
            h = mlp_apply(p["mlp"], h, cfg.mlp_variant, rules)
        x = x + h
    elif kind == "recurrent":
        h = norm_apply(cfg, p["norm1"], x)
        x = x + rglru_apply(cfg, p["rec"], h, rules, impl=attn_impl if attn_impl == "pallas" else "scan")
        h = norm_apply(cfg, p["norm2"], x)
        x = x + mlp_apply(p["mlp"], h, cfg.mlp_variant, rules)
    elif kind == "rwkv":
        h = norm_apply(cfg, p["norm1"], x)
        x = x + rwkv_time_apply(cfg, p["time"], h, rules, impl=_rwkv_impl(attn_impl))
        h = norm_apply(cfg, p["norm2"], x)
        x = x + rwkv_channel_apply(cfg, p["chan"], h, rules)
    return x, aux


def _cross_attention(cfg, p, x, enc_out, rules):
    """Query from decoder stream, keys/values from encoder output."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bfd,dhk->bfhk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bfd,dhk->bfhk", enc_out, p["wv"].astype(dt))
    scale = 1.0 / np.sqrt(q.shape[-1])
    hq, hkv = q.shape[2], k.shape[2]
    qg = q.reshape(*q.shape[:2], hkv, hq // hkv, q.shape[-1])
    logits = jnp.einsum("bshgk,bfhk->bhgsf", qg, k) * scale
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(dt)
    ctx = jnp.einsum("bhgsf,bfhk->bshgk", w, v)
    ctx = ctx.reshape(*x.shape[:2], -1)
    return ctx @ p["wo"].astype(dt)


def _run_groups(cfg, params, x, positions, rules, attn_impl, enc_out=None, remat=True,
                attn_block: int = 512):
    """Apply all layer groups via lax.scan over stacked params."""
    total_aux = jnp.zeros((), jnp.float32)
    for gi, (pattern, reps) in enumerate(cfg.layer_groups):
        gp = params[f"group{gi}"]

        def body(carry, layer_params, _pattern=pattern):
            h, aux = carry
            for key, kind in _pattern_keys(_pattern):
                h, a = _block_apply(cfg, kind, layer_params[key], h, positions,
                                    rules, attn_impl, enc_out,
                                    attn_block=attn_block)
                aux = aux + a
            # pin the scan carry (and thus its backward cotangent, which
            # GSPMD reshards across layer iterations) to the compute dtype —
            # without this the residual-stream gradient travels in f32,
            # doubling the dominant all-gather bytes (§Perf, granite iter 2).
            return (h.astype(dtype_of(cfg)), aux), None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, total_aux), _ = jax.lax.scan(body, (x, total_aux), gp)
    return x, total_aux


def _encode(cfg, params, frames, rules, attn_impl):
    e = cfg.encoder
    dt = dtype_of(cfg)
    x = frames.astype(dt) + params["enc_pos"][None, : frames.shape[1]].astype(dt)
    ecfg = dataclasses.replace(
        cfg, d_model=e.d_model, num_heads=e.num_heads, num_kv_heads=e.num_heads,
        d_ff=e.d_ff, qkv_bias=False, pos_variant="learned", sliding_window=0,
    )
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1]), frames.shape[:2])

    def body(h, layer_params):
        hh = norm_apply(ecfg, layer_params["0_global"]["norm1"], h)
        hh = attention_apply(ecfg, layer_params["0_global"]["attn"], hh, positions,
                             causal=False, rules=rules, impl=attn_impl)
        h = h + hh
        hh = norm_apply(ecfg, layer_params["0_global"]["norm2"], h)
        h = h + mlp_apply(layer_params["0_global"]["mlp"], hh, cfg.mlp_variant, rules)
        return h, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    x = norm_apply(ecfg, params["enc_norm"], x)
    if "enc_proj" in params:
        x = x @ params["enc_proj"].astype(dt)
    return x


def _embed_inputs(cfg, params, batch, dt):
    """Token (+ prefix patch) embeddings. Returns (x, positions, n_prefix)."""
    tokens = batch["tokens"]
    x = params["embed"].astype(dt)[tokens]
    n_prefix = 0
    if cfg.frontend == "vision" and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(dt), x], axis=1)
        n_prefix = batch["patches"].shape[1]
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.pos_variant == "learned":
        x = x + params["pos_embed"].astype(dt)[None, :s]
    return x, positions, n_prefix


def lm_logits(cfg: ModelConfig, params, batch, *, rules=None, attn_impl="scan", remat=True,
              attn_block: int = 512):
    rules = rules or {}
    dt = dtype_of(cfg)
    x, positions, n_prefix = _embed_inputs(cfg, params, batch, dt)
    x = annotate(x, ("batch", "seq", "embed"), rules)
    enc_out = None
    if cfg.encoder is not None:
        enc_out = _encode(cfg, params, batch["frames"], rules, attn_impl)
    x, aux = _run_groups(cfg, params, x, positions, rules, attn_impl, enc_out, remat,
                         attn_block=attn_block)
    x = norm_apply(cfg, params["final_norm"], x)
    if n_prefix:
        x = x[:, n_prefix:]
    head = params["embed"] if cfg.tie_embeddings else params["unembed"]
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, head.astype(dt))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dt))
    return annotate(logits, ("batch", "seq", "vocab"), rules), aux


def lm_loss(cfg: ModelConfig, params, batch, *, rules=None, attn_impl="scan", remat=True,
            attn_block: int = 512):
    """Next-token cross entropy (final position masked)."""
    logits, aux = lm_logits(cfg, params, batch, rules=rules, attn_impl=attn_impl,
                            remat=remat, attn_block=attn_block)
    tokens = batch["tokens"]
    labels = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux
    return loss


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------

def _block_cache(cfg, kind, batch: int, cache_len: int, dt):
    if kind in ATTN_KINDS:
        window = _attn_window(cfg, kind)
        cap = min(window, cache_len) if window else cache_len
        hkv, hd = cfg.num_kv_heads, cfg.head_dim_
        c = {
            "k": jnp.zeros((batch, cap, hkv, hd), dt),
            "v": jnp.zeros((batch, cap, hkv, hd), dt),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
        if cfg.is_encoder_decoder:
            e = cfg.encoder
            c["cross_k"] = jnp.zeros((batch, e.num_frames, hkv, hd), dt)
            c["cross_v"] = jnp.zeros((batch, e.num_frames, hkv, hd), dt)
        return c
    if kind == "recurrent":
        return rglru_init_state(cfg, batch)
    if kind == "rwkv":
        return rwkv_init_state(cfg, batch)
    raise ValueError(kind)


def init_decode_caches(cfg: ModelConfig, batch: int, cache_len: int):
    """Stacked (per layer group) decode caches, zero-filled."""
    dt = dtype_of(cfg)
    caches = []
    for pattern, reps in cfg.layer_groups:
        one = {
            key: _block_cache(cfg, kind, batch, cache_len, dt)
            for key, kind in _pattern_keys(pattern)
        }
        caches.append(jax.tree.map(lambda x: jnp.broadcast_to(x, (reps, *x.shape)), one))
    return caches


# ---------------------------------------------------------------------------
# cache slot surgery (continuous-batching serving, repro.serve)
#
# Every stacked cache leaf carries the slot/batch dim at axis 1:
# attention k/v (reps, B, C, Hkv, hd), pos (reps, B), recurrent h
# (reps, B, w), rwkv wkv (reps, B, H, N, N), ... — so a serving slot pool
# can splice one request's state in or out with a single tree map. The
# source tree must have been built over the same cfg and cache capacity
# (lm_prefill with reserve chosen so prompt_len + reserve == pool cap).
# ---------------------------------------------------------------------------

def cache_slot_insert(caches, slot: int, src_caches, src_slot: int = 0):
    """Pool caches with ``slot`` replaced by ``src_caches[src_slot]``.

    Overwrites every leaf of the slot (attention K/V + pos, recurrent /
    rwkv states), so whatever a previous occupant left behind is gone —
    eviction needs no separate clear before the next insert."""
    return jax.tree.map(
        lambda dst, s: dst.at[:, slot].set(s[:, src_slot]), caches, src_caches
    )


def cache_slot_extract(caches, slot: int):
    """One slot's state as a batch-1 cache tree (decode-ready)."""
    return jax.tree.map(lambda x: x[:, slot : slot + 1], caches)


def cache_slot_clear(caches, slot: int):
    """Zero one slot (free-slot hygiene; inserts overwrite regardless)."""
    return jax.tree.map(lambda x: x.at[:, slot].set(jnp.zeros_like(x[:, slot])), caches)


def lm_decode_step(cfg: ModelConfig, params, batch, caches, *, rules=None):
    """One decode step. batch: {"tokens": (B, 1)}; caches from
    init_decode_caches / lm_prefill. Returns (logits (B, 1, V), caches)."""
    rules = rules or {}
    dt = dtype_of(cfg)
    tokens = batch["tokens"]
    x = params["embed"].astype(dt)[tokens]  # (B,1,d)
    if cfg.pos_variant == "learned":
        # per-row positions: slots in a continuous-batching pool sit at
        # different sequence offsets, so each row gathers its own embedding
        pos_b = _slot_positions(caches, tokens.shape[0])
        x = x + params["pos_embed"].astype(dt)[pos_b][:, None]

    new_caches = []
    for gi, (pattern, reps) in enumerate(cfg.layer_groups):
        gp = params[f"group{gi}"]

        def body(h, xs, _pattern=pattern):
            layer_params, layer_cache = xs
            new_cache = {}
            for key, kind in _pattern_keys(_pattern):
                h, new_cache[key] = _block_decode(
                    cfg, kind, layer_params[key], h, layer_cache[key], rules
                )
            return h, new_cache

        x, nc = jax.lax.scan(body, x, (gp, caches[gi]))
        new_caches.append(nc)

    x = norm_apply(cfg, params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["unembed"]
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, head.astype(dt))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dt))
    return logits, new_caches


def _slot_positions(caches, batch: int):
    """Per-row token counts (B,) from the first attention cache's ``pos``;
    zeros for position-free (pure recurrent) stacks."""
    leaf = caches[0]
    for key in leaf:
        if "pos" in leaf[key]:
            return leaf[key]["pos"][0]
    return jnp.zeros((batch,), jnp.int32)


def _block_decode(cfg, kind, p, x, cache, rules):
    if kind in ATTN_KINDS:
        h = norm_apply(cfg, p["norm1"], x)
        h, new_cache = decode_attention_apply(
            cfg, p["attn"], h, cache, window=_attn_window(cfg, kind), rules=rules
        )
        x = x + h
        if "cross" in p and "cross_k" in cache:
            h = norm_apply(cfg, p["norm_cross"], x)
            h = _cross_decode(cfg, p["cross"], h, cache)
            x = x + h
            new_cache["cross_k"] = cache["cross_k"]
            new_cache["cross_v"] = cache["cross_v"]
        h = norm_apply(cfg, p["norm2"], x)
        if kind == "moe":
            h, _ = moe_apply(cfg, p["moe"], h, rules)
        else:
            h = mlp_apply(p["mlp"], h, cfg.mlp_variant, rules)
        return x + h, new_cache
    if kind == "recurrent":
        h = norm_apply(cfg, p["norm1"], x)
        h, new_state = rglru_decode(cfg, p["rec"], h, cache, rules)
        x = x + h
        h = norm_apply(cfg, p["norm2"], x)
        return x + mlp_apply(p["mlp"], h, cfg.mlp_variant, rules), new_state
    if kind == "rwkv":
        h = norm_apply(cfg, p["norm1"], x)
        h, st = rwkv_time_decode(cfg, p["time"], h, cache, rules)
        x = x + h
        h = norm_apply(cfg, p["norm2"], x)
        h, st = rwkv_channel_decode(cfg, p["chan"], h, st, rules)
        return x + h, st
    raise ValueError(kind)


def _cross_decode(cfg, p, x, cache):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k, v = cache["cross_k"], cache["cross_v"]
    hq, hkv = q.shape[2], k.shape[2]
    qg = q.reshape(*q.shape[:2], hkv, hq // hkv, q.shape[-1])
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bshgk,bfhk->bhgsf", qg, k) * scale
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(dt)
    ctx = jnp.einsum("bhgsf,bfhk->bshgk", w, v).reshape(*x.shape[:2], -1)
    return ctx @ p["wo"].astype(dt)


# ---------------------------------------------------------------------------
# prefill: run the full-sequence forward while filling decode caches.
# For the dry-run the relevant artifact is the compiled full forward; we
# fill attention caches with the projected K/V and recurrent states with
# the final scan state.
# ---------------------------------------------------------------------------

def lm_prefill(cfg: ModelConfig, params, batch, *, rules=None, attn_impl="scan",
               reserve: int = 1):
    """Returns (last-position logits (B, V), caches ready for decode).

    Implemented as the full forward (same FLOPs as training fwd) plus
    cache extraction; recurrent/rwkv caches are rebuilt by replaying the
    per-block scans (cheap relative to the matmuls at these widths).
    ``reserve`` extra cache slots are allocated for subsequent decode
    steps (dense caches must hold prefill + decoded tokens).
    """
    rules = rules or {}
    logits, _ = lm_logits(cfg, params, batch, rules=rules, attn_impl=attn_impl, remat=False)
    b, s = batch["tokens"].shape
    if cfg.frontend == "vision" and "patches" in batch:
        s += batch["patches"].shape[1]  # prefix embeddings occupy cache slots
    caches = init_decode_caches(cfg, b, s + reserve)
    caches = _fill_caches(cfg, params, batch, caches, rules, attn_impl)
    return logits[:, -1], caches


def _fill_caches(cfg, params, batch, caches, rules, attn_impl):
    """Replay the forward, capturing K/V and recurrent states per layer."""
    dt = dtype_of(cfg)
    x, positions, n_prefix = _embed_inputs(cfg, params, batch, dt)
    enc_out = None
    if cfg.encoder is not None:
        enc_out = _encode(cfg, params, batch["frames"], rules, attn_impl)

    new_caches = []
    for gi, (pattern, reps) in enumerate(cfg.layer_groups):
        gp = params[f"group{gi}"]

        def body(carry, xs, _pattern=pattern):
            h = carry
            layer_params, layer_cache = xs
            out_cache = {}
            for key, kind in _pattern_keys(_pattern):
                p = layer_params[key]
                c = layer_cache[key]
                h, out_cache[key] = _prefill_block(cfg, kind, p, h, c, positions, rules, attn_impl, enc_out)
            return h, out_cache

        x, nc = jax.lax.scan(body, x, (gp, caches[gi]))
        new_caches.append(nc)
    return new_caches


def _prefill_block(cfg, kind, p, x, cache, positions, rules, attn_impl, enc_out):
    from .attention import _project_qkv  # reuse projections

    if kind in ATTN_KINDS:
        h = norm_apply(cfg, p["norm1"], x)
        _, k, v = _project_qkv(cfg, p["attn"], h, positions, rules)
        cap = cache["k"].shape[1]
        s = k.shape[1]
        new_cache = dict(cache)
        if s >= cap:  # keep last `cap` keys (ring layout: slot = pos % cap)
            ks_, vs_ = k[:, s - cap :], v[:, s - cap :]
            if _attn_window(cfg, kind):
                roll = (s - cap) % cap if cap else 0
                shift = (s % cap) - 0  # align slot p%cap
                ks_ = jnp.roll(ks_, shift=s % cap, axis=1)
                vs_ = jnp.roll(vs_, shift=s % cap, axis=1)
            new_cache["k"], new_cache["v"] = ks_, vs_
        else:
            new_cache["k"] = cache["k"].at[:, :s].set(k)
            new_cache["v"] = cache["v"].at[:, :s].set(v)
        new_cache["pos"] = jnp.full((x.shape[0],), s, jnp.int32)
        h2 = attention_apply(cfg, p["attn"], h, positions, window=_attn_window(cfg, kind),
                             causal=True, rules=rules, impl=attn_impl)
        x = x + h2
        if enc_out is not None and "cross" in p:
            hc = norm_apply(cfg, p["norm_cross"], x)
            x = x + _cross_attention(cfg, p["cross"], hc, enc_out, rules)
            dt = x.dtype
            new_cache["cross_k"] = jnp.einsum("bfd,dhk->bfhk", enc_out, p["cross"]["wk"].astype(dt))
            new_cache["cross_v"] = jnp.einsum("bfd,dhk->bfhk", enc_out, p["cross"]["wv"].astype(dt))
        h = norm_apply(cfg, p["norm2"], x)
        if kind == "moe":
            h, _ = moe_apply(cfg, p["moe"], h, rules)
        else:
            h = mlp_apply(p["mlp"], h, cfg.mlp_variant, rules)
        return x + h, new_cache

    if kind == "recurrent":
        from .rglru import _causal_conv, _gates, lru_scan

        h = norm_apply(cfg, p["norm1"], x)
        dt = x.dtype
        u = h @ p["rec"]["wx"].astype(dt)
        vgate = jax.nn.gelu(h @ p["rec"]["wg"].astype(dt))
        uc = _causal_conv(u, p["rec"]["conv"])
        a, bb = _gates(p["rec"], uc, dt)
        hs = lru_scan(a, bb)
        new_state = {
            "h": hs[:, -1],
            "conv_tail": u[:, -(cfg.conv1d_width - 1):].astype(jnp.float32),
        }
        y = (hs.astype(dt) * vgate) @ p["rec"]["wo"].astype(dt)
        x = x + y
        h = norm_apply(cfg, p["norm2"], x)
        return x + mlp_apply(p["mlp"], h, cfg.mlp_variant, rules), new_state

    if kind == "rwkv":
        from .rwkv6 import _heads, _streams, _token_shift, wkv_scan, _groupnorm
        from .rwkv_chunked import wkv_chunked

        h = norm_apply(cfg, p["norm1"], x)
        dt = x.dtype
        n = cfg.rwkv_head_dim
        prev = _token_shift(h)
        r, k, v, w, g = _streams(p["time"], h, prev, dt)
        r, k, v, w = (_heads(t, n) for t in (r, k, v, w))
        k = k * (1.0 / np.sqrt(n))
        _wkv = wkv_scan if attn_impl == "naive" else wkv_chunked
        out, stT = _wkv(r, k, v, w.astype(jnp.float32), p["time"]["bonus"])
        y = _groupnorm(out, p["time"]["ln_gamma"], n).astype(dt) * g
        x_after_time = x + y @ p["time"]["wo"].astype(dt)
        h2 = norm_apply(cfg, p["norm2"], x_after_time)
        y2 = rwkv_channel_apply(cfg, p["chan"], h2, rules)
        new_state = {
            "wkv": stT,
            "last_x_time": h[:, -1].astype(jnp.float32),
            "last_x_chan": h2[:, -1].astype(jnp.float32),
        }
        return x_after_time + y2, new_state
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# chunked prefill: advance decode caches by c tokens per call, resumable.
#
# The carry is the decode cache tree itself — ring K/V + pos for
# attention kinds, {h, conv_tail} for RG-LRU, {wkv, last_x_*} for RWKV —
# so a prompt can be prefetched in fixed-size chunks interleaved with
# decode steps (repro.serve), evicted mid-prefill and resumed later.
# Rows are ragged: ``n_valid`` masks each row's tail with identity
# transitions (attention: scatter dropped + keys masked; rglru: a=1,
# b=0; rwkv: w=1, k=v=0), so one dispatch advances every active lane and
# a row with n_valid = 0 is an exact no-op on its state.
# ---------------------------------------------------------------------------

def max_chunk_len(cfg: ModelConfig, cache_len: int) -> int | None:
    """Largest prefill chunk the decode caches can absorb in one call:
    the smallest ring-buffer capacity across windowed attention layers
    (a bigger chunk would overwrite keys its own early queries still
    need). None when no layer rings (dense attention / recurrent)."""
    caps = []
    for pattern, _ in cfg.layer_groups:
        for kind in pattern:
            if kind in ATTN_KINDS:
                w = _attn_window(cfg, kind)
                if w:
                    caps.append(min(w, cache_len))
    return min(caps) if caps else None


def lm_prefill_chunk(cfg: ModelConfig, params, batch, caches, start, *,
                     rules=None, attn_impl="scan", n_valid=None):
    """One prefill chunk: batch {"tokens": (B, c)}, per-row ``start``
    (B,) tokens already consumed, ``n_valid`` (B,) valid tokens in this
    chunk (None = all c). Returns (logits at each row's last valid
    position (B, V), updated caches). Token streams match monolithic
    ``lm_prefill`` + decode exactly; logits agree to float tolerance
    (reduction order differs, as with every blockwise attention)."""
    if cfg.frontend or cfg.encoder is not None:
        raise ValueError(
            "chunked prefill drives token-only decoders; "
            f"{cfg.name} needs a modality frontend at prefill"
        )
    rules = rules or {}
    dt = dtype_of(cfg)
    tokens = batch["tokens"]
    b, c = tokens.shape
    start = jnp.asarray(start, jnp.int32)
    if start.ndim == 0:
        start = jnp.broadcast_to(start, (b,))
    nv = (jnp.full((b,), c, jnp.int32) if n_valid is None
          else jnp.asarray(n_valid, jnp.int32))
    positions = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    valid = jnp.arange(c, dtype=jnp.int32)[None, :] < nv[:, None]  # (B, c)

    x = params["embed"].astype(dt)[tokens]
    if cfg.pos_variant == "learned":
        safe = jnp.clip(positions, 0, cfg.max_seq_len - 1)
        x = x + params["pos_embed"].astype(dt)[safe]

    new_caches = []
    for gi, (pattern, reps) in enumerate(cfg.layer_groups):
        gp = params[f"group{gi}"]

        def body(h, xs, _pattern=pattern):
            layer_params, layer_cache = xs
            out_cache = {}
            for key, kind in _pattern_keys(_pattern):
                h, out_cache[key] = _chunk_block(
                    cfg, kind, layer_params[key], h, layer_cache[key],
                    positions, nv, valid, rules, attn_impl,
                )
            return h, out_cache

        x, nc = jax.lax.scan(body, x, (gp, caches[gi]))
        new_caches.append(nc)

    x = norm_apply(cfg, params["final_norm"], x)
    last = jnp.clip(nv - 1, 0, c - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]  # (B, d)
    head = params["embed"] if cfg.tie_embeddings else params["unembed"]
    if cfg.tie_embeddings:
        logits = jnp.einsum("bd,vd->bv", x_last, head.astype(dt))
    else:
        logits = jnp.einsum("bd,dv->bv", x_last, head.astype(dt))
    return logits, new_caches


def _chunk_block(cfg, kind, p, x, cache, positions, nv, valid, rules, attn_impl):
    from .attention import chunk_attention_apply

    if kind in ATTN_KINDS:
        h = norm_apply(cfg, p["norm1"], x)
        h2, new_cache = chunk_attention_apply(
            cfg, p["attn"], h, cache, positions, nv, valid,
            window=_attn_window(cfg, kind), rules=rules,
        )
        x = x + h2
        h = norm_apply(cfg, p["norm2"], x)
        if kind == "moe":
            h, _ = moe_apply(cfg, p["moe"], h, rules)
        else:
            h = mlp_apply(p["mlp"], h, cfg.mlp_variant, rules)
        return x + h, new_cache

    if kind == "recurrent":
        from .rglru import _gates, lru_scan

        h = norm_apply(cfg, p["norm1"], x)
        dt = x.dtype
        c = x.shape[1]
        u = h @ p["rec"]["wx"].astype(dt)  # (B, c, w)
        vgate = jax.nn.gelu(h @ p["rec"]["wg"].astype(dt))
        kw = cfg.conv1d_width
        tail = cache["conv_tail"].astype(dt)  # (B, K-1, w)
        win = jnp.concatenate([tail, u], axis=1)  # (B, K-1+c, w)
        uc = sum(win[:, i : i + c] * p["rec"]["conv"][i].astype(dt)
                 for i in range(kw))
        a, bb = _gates(p["rec"], uc, dt)
        vm = valid[..., None]
        a = jnp.where(vm, a, 1.0)  # identity transition on padding rows
        bb = jnp.where(vm, bb, 0.0)
        hs = lru_scan(a, bb, h0=cache["h"])
        # new conv tail = raw u at the last K-1 *valid* positions (win
        # index nv maps to u index nv-(K-1); nv < K-1 keeps old tail).
        tail_idx = nv[:, None, None] + jnp.arange(kw - 1)[None, :, None]
        new_tail = jnp.take_along_axis(win.astype(jnp.float32), tail_idx, axis=1)
        new_tail = jnp.where((nv > 0)[:, None, None], new_tail, cache["conv_tail"])
        new_state = {"h": hs[:, -1], "conv_tail": new_tail}
        y = (hs.astype(dt) * vgate) @ p["rec"]["wo"].astype(dt)
        x = x + y
        h = norm_apply(cfg, p["norm2"], x)
        return x + mlp_apply(p["mlp"], h, cfg.mlp_variant, rules), new_state

    if kind == "rwkv":
        from .rwkv6 import _channel_core, _groupnorm, _heads, _streams, _token_shift, wkv_scan
        from .rwkv_chunked import wkv_chunked

        h = norm_apply(cfg, p["norm1"], x)
        dt = x.dtype
        n = cfg.rwkv_head_dim
        c = x.shape[1]
        prev = _token_shift(h, last=cache["last_x_time"].astype(dt))
        r, k, v, w, g = _streams(p["time"], h, prev, dt)
        r, k, v, w = (_heads(t, n) for t in (r, k, v, w))
        k = k * (1.0 / np.sqrt(n))
        vm = valid[..., None, None]
        k = jnp.where(vm, k, 0.0)  # identity state transition on padding
        v = jnp.where(vm, v, 0.0)
        w = jnp.where(vm, w.astype(jnp.float32), 1.0)
        _wkv = wkv_scan if attn_impl == "naive" else wkv_chunked
        out, stT = _wkv(r, k, v, w, p["time"]["bonus"], state0=cache["wkv"])
        y = _groupnorm(out, p["time"]["ln_gamma"], n).astype(dt) * g
        x_after_time = x + y @ p["time"]["wo"].astype(dt)
        h2 = norm_apply(cfg, p["norm2"], x_after_time)
        prev2 = _token_shift(h2, last=cache["last_x_chan"].astype(h2.dtype))
        y2 = _channel_core(p["chan"], h2, prev2, h2.dtype, rules)
        last = jnp.clip(nv - 1, 0, c - 1)[:, None, None]
        any_v = (nv > 0)[:, None]

        def at_last(t):
            return jnp.take_along_axis(t, last, axis=1)[:, 0].astype(jnp.float32)

        new_state = {
            "wkv": stT,
            "last_x_time": jnp.where(any_v, at_last(h), cache["last_x_time"]),
            "last_x_chan": jnp.where(any_v, at_last(h2), cache["last_x_chan"]),
        }
        return x_after_time + y2, new_state
    raise ValueError(kind)
