"""Fleet orchestration & observability (DESIGN.md §13).

The fleet subsystem makes failures *discovered* instead of scripted and
every run analyzable after the fact:

  * ``lease`` — heartbeat/lease failure detection on the worker's link
    model (missed lease ⇒ synthesized ``WorkerLeft(discovered=True)``,
    rejoin ⇒ ``WorkerJoined(discovered=True)`` with partial-shard-pull
    state catch-up), with batch expiry checks so 10k-worker fleets
    simulate in seconds;
  * ``scheduler`` — capability-aware batch/data-share assignment from
    heartbeat-reported speeds, applied via ``SetBatchFraction``;
  * ``metrics`` — the typed, append-only metrics stream (commit latency,
    push/pull bytes, shard staleness, search/drift/lease/churn events)
    shared by the simulator, the mesh backend, and the engine;
  * ``monitor`` — the PS-side ``FleetMonitor`` composing the three.
"""

from .lease import LeaseConfig, LeaseTracker, heartbeat_delay
from .metrics import (
    AssignRecord,
    CapabilityRecord,
    ChurnRecord,
    CommitRecord,
    DriftRecord,
    EvalRecord,
    JsonlSink,
    LeaseRecord,
    MetricRecord,
    MetricsLog,
    MetricsSink,
    PullRecord,
    SearchRecord,
    ServeRecord,
    from_dict,
    load_jsonl,
    record_kinds,
    to_dict,
)
from .monitor import FleetConfig, FleetMonitor
from .scheduler import (
    DeviceScheduler,
    FleetAssignment,
    ProportionalScheduler,
    SqrtScheduler,
    UniformScheduler,
    get_scheduler,
    register_scheduler,
    scheduler_names,
)

__all__ = [
    # lease
    "LeaseConfig", "LeaseTracker", "heartbeat_delay",
    # monitor
    "FleetConfig", "FleetMonitor",
    # scheduler
    "DeviceScheduler", "FleetAssignment", "UniformScheduler",
    "ProportionalScheduler", "SqrtScheduler",
    "register_scheduler", "get_scheduler", "scheduler_names",
    # metrics
    "MetricRecord", "CommitRecord", "EvalRecord", "SearchRecord",
    "DriftRecord", "LeaseRecord", "ChurnRecord", "CapabilityRecord",
    "AssignRecord", "ServeRecord", "PullRecord",
    "MetricsSink", "MetricsLog", "JsonlSink",
    "record_kinds", "to_dict", "from_dict", "load_jsonl",
]
