"""Structured metrics stream (DESIGN.md §13).

Every fleet-visible occurrence — a commit round trip, a search, a drift
trigger, a lease grant/expiry, a churn event — is a typed, append-only
record emitted into a shared sink. Producers are the ``ClusterEngine``
(search/drift/churn), the edge simulator (commit latency, push/pull
bytes, shard staleness, lease events), and the mesh backend (per-round
commit records); consumers are ``benchmarks/`` and
``tools/fleet_report.py``.

Records follow the repo's registry idiom (``repro.ps`` rules,
``repro.transport`` codecs): each record class registers under a string
``kind`` and round-trips losslessly through ``to_dict``/``from_dict``,
so a run's stream can be persisted as JSONL and re-loaded for analysis.
Sinks are anything with ``record(rec)``; ``MetricsLog`` keeps the stream
in memory, ``JsonlSink`` appends to a file as the run executes. A ``None``
sink everywhere means "don't record" — producers guard every emission so
an uninstrumented run pays nothing.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Iterable, Protocol, runtime_checkable

__all__ = [
    "MetricRecord", "CommitRecord", "EvalRecord", "SearchRecord",
    "DriftRecord", "LeaseRecord", "ChurnRecord", "CapabilityRecord",
    "AssignRecord", "ServeRecord", "PullRecord",
    "MetricsSink", "MetricsLog", "JsonlSink",
    "record_kinds", "to_dict", "from_dict", "load_jsonl",
]


@dataclasses.dataclass(frozen=True)
class MetricRecord:
    """Base class; all records are immutable and carry the (virtual) time
    ``t`` they describe. ``kind`` is the registry key (class attribute)."""

    t: float

    kind = "base"


_KINDS: dict[str, type] = {}


def _register(kind: str):
    def deco(cls):
        cls.kind = kind
        _KINDS[kind] = cls
        return cls
    return deco


def record_kinds() -> list[str]:
    return sorted(_KINDS)


@_register("commit")
@dataclasses.dataclass(frozen=True)
class CommitRecord(MetricRecord):
    """One complete commit round trip (push → apply → pull), stamped at
    pull completion. ``latency`` spans commit decision to pull done —
    barrier waits included, which is what makes it worth recording."""

    worker: int
    latency: float
    push_bytes: float
    pull_bytes: float
    stale_shards: int  # shards the pull actually fetched
    n_shards: int
    # per-shard PS commit counters the pull reflected, in shard order
    # (len n_shards; empty for producers that don't track versions).
    # Element-wise monotone in stream order — the race validator
    # (repro.analysis.dynamic) checks exactly that.
    versions: tuple = ()

    def __post_init__(self):
        if not isinstance(self.versions, tuple):
            object.__setattr__(self, "versions", tuple(self.versions))


@_register("eval")
@dataclasses.dataclass(frozen=True)
class EvalRecord(MetricRecord):
    """A global-loss evaluation (simulator eval clock / mesh round)."""

    loss: float


@_register("search")
@dataclasses.dataclass(frozen=True)
class SearchRecord(MetricRecord):
    """An Alg. 1 SearchSession finished (t = completion time)."""

    chosen: int
    windows: int
    restarts: int
    aborted: bool


@_register("drift")
@dataclasses.dataclass(frozen=True)
class DriftRecord(MetricRecord):
    """A mid-epoch re-search was triggered outside the epoch clock;
    ``cause`` names the event type that carried the Search command."""

    cause: str


@_register("lease")
@dataclasses.dataclass(frozen=True)
class LeaseRecord(MetricRecord):
    """Lease lifecycle: granted | stalled | expired | rejoined."""

    worker: int
    event: str


@_register("churn")
@dataclasses.dataclass(frozen=True)
class ChurnRecord(MetricRecord):
    """Fleet membership changed. ``discovered`` distinguishes failures
    found by the lease layer from scripted/administrative changes."""

    worker: int
    event: str  # "join" | "leave"
    discovered: bool


@_register("capability")
@dataclasses.dataclass(frozen=True)
class CapabilityRecord(MetricRecord):
    """A worker's heartbeat-reported capability (speed v) reached the PS."""

    worker: int
    v: float


@_register("assign")
@dataclasses.dataclass(frozen=True)
class AssignRecord(MetricRecord):
    """The device scheduler (re)assigned a worker's batch/data share."""

    worker: int
    fraction: float
    data_share: float


@_register("serve")
@dataclasses.dataclass(frozen=True)
class ServeRecord(MetricRecord):
    """One inference request completed (``repro.serve`` engine), stamped
    at completion. Latencies decompose the request's life:
    queue (arrival → slot admission) + prefill + decode = total.
    ``version`` is the replica's model version at completion (total shard
    commits reflected; 0 when not tracking training). ``replica`` is the
    serving replica that handled the request (0 for a single engine);
    the default keeps pre-balancer JSONL streams loadable."""

    req: int
    queue: float
    prefill: float
    decode: float
    total: float
    tokens: int
    slo: float
    slo_ok: bool
    version: int
    replica: int = 0


@_register("pull")
@dataclasses.dataclass(frozen=True)
class PullRecord(MetricRecord):
    """A serving replica pulled version-stale shards from the training PS
    between decode steps (``repro.serve.sync``). ``replica`` keeps the
    per-replica pull-bytes story separable under a load balancer."""

    stale_shards: int
    n_shards: int
    nbytes: float
    replica: int = 0


# ---------------------------------------------------------------------------
# Serialization
# ---------------------------------------------------------------------------


def to_dict(rec: MetricRecord) -> dict:
    d = dataclasses.asdict(rec)
    d = {k: list(v) if isinstance(v, tuple) else v for k, v in d.items()}
    d["kind"] = rec.kind
    return d


def from_dict(d: dict) -> MetricRecord:
    d = dict(d)
    kind = d.pop("kind")
    try:
        cls = _KINDS[kind]
    except KeyError:
        raise KeyError(f"unknown metric kind {kind!r}; known: {record_kinds()}")
    return cls(**d)


def load_jsonl(path) -> list[MetricRecord]:
    out = []
    for line in pathlib.Path(path).read_text().splitlines():
        line = line.strip()
        if line:
            out.append(from_dict(json.loads(line)))
    return out


# ---------------------------------------------------------------------------
# Sinks
# ---------------------------------------------------------------------------


@runtime_checkable
class MetricsSink(Protocol):
    def record(self, rec: MetricRecord) -> None: ...


class MetricsLog:
    """In-memory append-only sink with query helpers."""

    def __init__(self):
        self.records: list[MetricRecord] = []

    def record(self, rec: MetricRecord) -> None:
        self.records.append(rec)

    def of(self, kind: str) -> list[MetricRecord]:
        return [r for r in self.records if r.kind == kind]

    def __len__(self) -> int:
        return len(self.records)

    def to_jsonl(self, path) -> None:
        pathlib.Path(path).write_text(
            "".join(json.dumps(to_dict(r)) + "\n" for r in self.records)
        )

    @classmethod
    def from_records(cls, records: Iterable[MetricRecord]) -> "MetricsLog":
        log = cls()
        for r in records:
            log.record(r)
        return log


class JsonlSink:
    """Streaming JSONL sink: one record per line, flushed as emitted so a
    crashed run still leaves an analyzable prefix."""

    def __init__(self, path):
        self.path = pathlib.Path(path)
        self._fh = self.path.open("w")

    def record(self, rec: MetricRecord) -> None:
        self._fh.write(json.dumps(to_dict(rec)) + "\n")
        self._fh.flush()

    def close(self) -> None:
        self._fh.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
