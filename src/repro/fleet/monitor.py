"""FleetMonitor: the PS-side fleet orchestrator (DESIGN.md §13).

Owns the pieces a real parameter server's control plane would: the
``LeaseTracker`` (who is alive, by heartbeat evidence), the capability
table (what each device last *reported*, not what it truly is), the
optional ``DeviceScheduler`` (how batch/data shares follow capabilities),
and the metrics sink every fleet event is recorded into. Backends call
its transition methods from their own clocks; it never touches training
state and emits only plain records and ``SetBatchFraction`` commands.
"""

from __future__ import annotations

import dataclasses
import math

from repro.cluster.protocol import Command, SetBatchFraction

from .lease import LeaseConfig, LeaseTracker, heartbeat_delay
from .metrics import AssignRecord, CapabilityRecord, LeaseRecord, MetricsSink
from .scheduler import DeviceScheduler, get_scheduler

__all__ = ["FleetConfig", "FleetMonitor"]


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Fleet-orchestration knobs for a backend run.

    ``scheduler=None`` leaves batch fractions to the policy (the status
    quo); a scheduler name activates capability-aware assignment on every
    membership change and capability report."""

    lease: LeaseConfig = dataclasses.field(default_factory=LeaseConfig)
    scheduler: str | None = None
    scheduler_kwargs: dict = dataclasses.field(default_factory=dict)


class FleetMonitor:
    """See module docstring. ``metrics`` may be None (record nothing)."""

    def __init__(self, config: FleetConfig, metrics: MetricsSink | None = None):
        self.config = config
        self.metrics = metrics
        self.leases = LeaseTracker()
        self.reported_v: dict[int, float] = {}
        self.scheduler: DeviceScheduler | None = (
            get_scheduler(config.scheduler, **config.scheduler_kwargs)
            if config.scheduler is not None else None
        )

    # ------------------------------------------------------------- helpers
    def _emit(self, rec) -> None:
        if self.metrics is not None:
            self.metrics.record(rec)

    def delay_for(self, profile) -> float:
        return heartbeat_delay(profile, self.config.lease.hb_nbytes)

    def __contains__(self, wid: int) -> bool:
        return wid in self.leases

    # ---------------------------------------------------------- transitions
    def join(self, wid: int, now: float, profile, *, rejoin: bool = False) -> None:
        """Admit a worker: grant its lease and take its join-time
        capability report (the join handshake carries one)."""
        self.leases.grant(wid, now, self.config.lease, self.delay_for(profile))
        self.reported_v[wid] = float(profile.v)
        self._emit(LeaseRecord(t=now, worker=wid,
                               event="rejoined" if rejoin else "granted"))
        self._emit(CapabilityRecord(t=now, worker=wid, v=float(profile.v)))

    def stall(self, wid: int, now: float) -> None:
        """The worker went silent — no departure notice, heartbeats stop.
        Its stale capability report lingers until the lease expires."""
        self.leases.stall(wid, now)
        self._emit(LeaseRecord(t=now, worker=wid, event="stalled"))

    def recover(self, wid: int, now: float) -> bool:
        """Heartbeats resumed; False means the lease already expired and
        the caller must re-admit through the rejoin path."""
        return self.leases.recover(wid, now)

    def scripted_leave(self, wid: int, now: float) -> None:
        """Administrative departure: the PS was told, so the lease is
        dropped and can never also expire (the scripted-vs-discovered
        dedupe guarantee)."""
        self.leases.forget(wid)
        self.reported_v.pop(wid, None)

    def expired_due(self, now: float) -> list[int]:
        """Batch-drain expired leases; each is a discovered failure."""
        gone = self.leases.pop_expired(now)
        for wid in gone:
            self.reported_v.pop(wid, None)
            self._emit(LeaseRecord(t=now, worker=wid, event="expired"))
        return gone

    def next_expiry(self) -> float:
        return self.leases.next_expiry()

    # -------------------------------------------------- capability reports
    def report(self, wid: int, now: float, v: float) -> None:
        """A heartbeat carrying a fresh capability reached the PS."""
        if wid in self.leases:
            self.reported_v[wid] = float(v)
            self._emit(CapabilityRecord(t=now, worker=wid, v=float(v)))

    def next_report_after(self, wid: int, now: float) -> float:
        return self.leases.next_report_after(wid, now)

    # ----------------------------------------------------------- scheduling
    def assignments(self, now: float) -> list[Command]:
        """Scheduler pass over the current capability table, as
        SetBatchFraction commands (empty without a scheduler)."""
        if self.scheduler is None or not self.reported_v:
            return []
        asg = self.scheduler.assign(self.reported_v)
        cmds: list[Command] = []
        for wid, frac in sorted(asg.fractions.items()):
            if not math.isfinite(frac) or frac <= 0:
                continue
            cmds.append(SetBatchFraction(wid, frac))
            self._emit(AssignRecord(t=now, worker=wid, fraction=frac,
                                    data_share=asg.data_shares[wid]))
        return cmds
