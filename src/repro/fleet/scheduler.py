"""Capability-aware device scheduling (DESIGN.md §13).

A ``DeviceScheduler`` maps the fleet's *reported* capabilities — the
speeds carried by heartbeats, not the simulator's ground-truth profiles —
to per-worker batch fractions and data shares. Assignments are applied
through the existing ``SetBatchFraction`` command, so schedulers compose
with every backend exactly like the BatchTune policies do; unlike those,
a scheduler sees only what the PS could actually know (capability reports
lag reality by up to one heartbeat period, and a stalled worker's last
report lingers until its lease expires).

Registry idiom mirrors ``repro.ps`` / ``repro.transport``: schedulers
register under a string name and are built by ``get_scheduler(name)``.

In this codebase a worker's *data share* is realized through its batch
fraction (``make_batch`` draws ``fraction · M · base_batch`` examples
from the worker's stream), so ``FleetAssignment.data_shares`` equals the
fractions for the built-in schedulers; the two are kept as separate
fields because a scheduler may legitimately split them (e.g. rebalancing
a non-IID corpus without growing a device's step time).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping

__all__ = [
    "FleetAssignment", "DeviceScheduler",
    "UniformScheduler", "ProportionalScheduler", "SqrtScheduler",
    "register_scheduler", "get_scheduler", "scheduler_names",
]


@dataclasses.dataclass(frozen=True)
class FleetAssignment:
    """Per-worker shares, each a dict keyed by stable worker id; both
    sum to 1 over the fleet the scheduler was given."""

    fractions: dict[int, float]
    data_shares: dict[int, float]


class DeviceScheduler:
    """Base contract: ``assign`` is a pure function of the reported
    capability table (worker id → reported speed v)."""

    name = "base"

    def assign(self, reported_v: Mapping[int, float]) -> FleetAssignment:
        raise NotImplementedError


_SCHEDULERS: dict[str, type] = {}


def register_scheduler(cls: type) -> type:
    _SCHEDULERS[cls.name] = cls
    return cls


def scheduler_names() -> list[str]:
    return sorted(_SCHEDULERS)


def get_scheduler(name: str, **kwargs) -> DeviceScheduler:
    try:
        cls = _SCHEDULERS[name]
    except KeyError:
        raise KeyError(
            f"unknown scheduler {name!r}; known: {scheduler_names()}"
        )
    return cls(**kwargs)


def _normalized(weights: Mapping[int, float]) -> dict[int, float]:
    total = sum(weights.values())
    if total <= 0 or not math.isfinite(total):
        n = max(len(weights), 1)
        return {i: 1.0 / n for i in weights}
    return {i: w / total for i, w in weights.items()}


@register_scheduler
class UniformScheduler(DeviceScheduler):
    """Equal split — the static 1/M assignment every policy defaults to."""

    name = "uniform"

    def assign(self, reported_v):
        frac = _normalized({i: 1.0 for i in reported_v})
        return FleetAssignment(fractions=frac, data_shares=dict(frac))


@dataclasses.dataclass
@register_scheduler
class ProportionalScheduler(DeviceScheduler):
    """Shares ∝ reported speed, with a starvation floor: every worker is
    guaranteed ``floor``/M of the global batch (floor ∈ [0, 1)), the rest
    is divided proportionally. floor=0 is pure speed-proportional
    (BatchTune's assignment, but from reports instead of ground truth)."""

    floor: float = 0.25
    name = "proportional"

    def __post_init__(self):
        if not 0.0 <= self.floor < 1.0:
            raise ValueError(f"floor must be in [0, 1), got {self.floor}")

    def assign(self, reported_v):
        prop = _normalized(dict(reported_v))
        m = max(len(prop), 1)
        frac = {i: self.floor / m + (1.0 - self.floor) * p
                for i, p in prop.items()}
        return FleetAssignment(fractions=frac, data_shares=dict(frac))


@dataclasses.dataclass
@register_scheduler
class SqrtScheduler(DeviceScheduler):
    """Shares ∝ √(reported speed): a compromise that shortens the
    straggler's step without concentrating the dataset on fast devices
    (the concentration concern of the fog-learning literature)."""

    name = "sqrt"

    def assign(self, reported_v):
        frac = _normalized({i: math.sqrt(max(v, 0.0))
                            for i, v in reported_v.items()})
        return FleetAssignment(fractions=frac, data_shares=dict(frac))
