"""Heartbeat/lease failure detection (DESIGN.md §13).

Every worker holds a *lease*: it is granted at join for ``ttl`` (virtual)
seconds and renewed each time a heartbeat **arrives** at the PS.
Heartbeats are ordinary traffic on the worker's link model — sent every
``heartbeat_period`` and delivered one link delay later (latency plus the
small payload over the worker's bandwidth) — so a congested or
high-latency link can miss the TTL and look exactly like a death. A
missed lease synthesizes ``WorkerLeft(discovered=True)``; a later rejoin
synthesizes ``WorkerJoined(discovered=True)`` with state catch-up over
the partial-shard-pull path.

Scale: the tracker never materializes one timer event per worker per
period. Healthy heartbeat streams are deterministic — worker ``i``'s
k-th heartbeat arrives at ``anchor + k·period + delay`` — so the lease
of a healthy worker can only expire at a *statically computable* time
(at grant, when the first arrival or the steady-state inter-arrival gap
overshoots the TTL) or when its stream is interrupted (``stall``). Only
those finitely many expiry candidates enter a heap, entries are lazily
invalidated by a per-worker token, and ``pop_expired`` drains everything
due in one batch. A 10k-worker heartbeat-only fleet therefore costs
O(changes·log M), not O(workers · time/period) — the difference between
seconds and minutes in ``benchmarks/bench_fleet.py``.
"""

from __future__ import annotations

import dataclasses
import heapq
import math

__all__ = ["LeaseConfig", "LeaseTracker", "heartbeat_delay"]


@dataclasses.dataclass(frozen=True)
class LeaseConfig:
    """Lease protocol knobs (virtual seconds / bytes).

    ``ttl`` must comfortably exceed ``heartbeat_period`` plus the worst
    link delay, or healthy workers flap (the tracker models that
    faithfully rather than forbidding it — see the false-positive tests).
    """

    ttl: float = 15.0
    heartbeat_period: float = 5.0
    hb_nbytes: int = 256  # heartbeat payload (capability report) on the link

    def __post_init__(self):
        if self.ttl <= 0 or self.heartbeat_period <= 0:
            raise ValueError("ttl and heartbeat_period must be positive")


def heartbeat_delay(profile, hb_nbytes: int) -> float:
    """One-way delivery time of a heartbeat over a worker's link."""
    return profile.transfer_seconds(hb_nbytes)


@dataclasses.dataclass
class _Lease:
    anchor: float  # when the current heartbeat phase started (join/recover)
    period: float
    delay: float
    ttl: float
    token: int = 0
    expiry: float = math.inf  # currently scheduled expiry (inf = healthy)
    stalled_at: float | None = None


class LeaseTracker:
    """See module docstring. All times are the caller's virtual clock."""

    def __init__(self):
        self._info: dict[int, _Lease] = {}
        self._heap: list[tuple[float, int, int]] = []  # (deadline, wid, token)

    # ------------------------------------------------------------ queries
    def __contains__(self, wid: int) -> bool:
        return wid in self._info

    def __len__(self) -> int:
        return len(self._info)

    def stalled(self, wid: int) -> bool:
        info = self._info.get(wid)
        return info is not None and info.stalled_at is not None

    def next_expiry(self) -> float:
        """Earliest pending lease expiry (inf if every lease is healthy)."""
        while self._heap:
            deadline, wid, token = self._heap[0]
            info = self._info.get(wid)
            if info is None or info.token != token:
                heapq.heappop(self._heap)
                continue
            return deadline
        return math.inf

    def pop_expired(self, now: float) -> list[int]:
        """Batch-drain every lease expired at or before ``now``. Expired
        workers are forgotten; re-admission goes through ``grant``."""
        out = []
        while self._heap and self._heap[0][0] <= now:
            _deadline, wid, token = heapq.heappop(self._heap)
            info = self._info.get(wid)
            if info is None or info.token != token:
                continue
            del self._info[wid]
            out.append(wid)
        return out

    def next_report_after(self, wid: int, now: float) -> float:
        """Arrival time of the first heartbeat sent strictly after ``now``
        (how long a capability change takes to reach the PS). inf while
        the worker is stalled or unknown."""
        info = self._info.get(wid)
        if info is None or info.stalled_at is not None:
            return math.inf
        k = max(1, math.floor((now - info.anchor) / info.period) + 1)
        return info.anchor + k * info.period + info.delay

    # ------------------------------------------------------- transitions
    def grant(self, wid: int, now: float, cfg: LeaseConfig, delay: float) -> None:
        """Admit ``wid``: lease until ``now + ttl``, renewals from its
        periodic heartbeat stream. Re-granting an existing worker resets
        its schedule (used by rejoin)."""
        info = _Lease(anchor=now, period=cfg.heartbeat_period, delay=delay,
                      ttl=cfg.ttl,
                      token=self._bump(wid))
        self._info[wid] = info
        self._schedule_steady_state(wid, info, first_deadline=now + cfg.ttl)

    def stall(self, wid: int, now: float) -> None:
        """The worker silently stopped (no departure notice): heartbeats
        sent at or before ``now`` still deliver, nothing after."""
        info = self._info.get(wid)
        if info is None or info.stalled_at is not None:
            return
        info.stalled_at = now
        last_k = math.floor((now - info.anchor) / info.period)
        if last_k >= 1:
            last_arrival = info.anchor + last_k * info.period + info.delay
            deadline = last_arrival + info.ttl
        else:  # stalled before its first heartbeat: only the grant holds
            deadline = info.anchor + info.ttl
        # an already-scheduled earlier expiry (TTL misconfiguration) wins
        deadline = min(deadline, info.expiry)
        info.token = self._bump(wid)
        info.expiry = deadline
        heapq.heappush(self._heap, (deadline, wid, info.token))

    def recover(self, wid: int, now: float) -> bool:
        """The worker resumed sending (phase re-anchored at ``now``).
        Returns False if its lease already expired — the caller must take
        the rejoin path instead. Recovering *before* expiry cancels the
        pending expiry iff the first new heartbeat lands in time."""
        info = self._info.get(wid)
        if info is None:
            return False
        if now >= info.expiry:
            # the deadline already passed (or ties): the expiry stands —
            # the caller's next batch check will pop it as a discovery
            return False
        info.stalled_at = None
        info.anchor = now
        first_deadline = info.expiry if info.expiry < math.inf else now + info.ttl
        info.token = self._bump(wid)
        self._schedule_steady_state(wid, info, first_deadline=first_deadline)
        return True

    def forget(self, wid: int) -> None:
        """Administrative departure (scripted leave): drop the lease so no
        expiry is ever synthesized for this worker."""
        self._info.pop(wid, None)

    # -------------------------------------------------------------- internals
    def _bump(self, wid: int) -> int:
        info = self._info.get(wid)
        return info.token + 1 if info is not None else 0

    def _schedule_steady_state(self, wid: int, info: _Lease,
                               first_deadline: float) -> None:
        """Given a healthy periodic stream anchored at ``info.anchor`` and
        a lease currently valid until ``first_deadline``, schedule the one
        expiry the deterministic schedule implies (or none)."""
        a1 = info.anchor + info.period + info.delay
        if a1 > first_deadline:
            info.expiry = first_deadline  # first renewal arrives too late
        elif info.period > info.ttl:
            info.expiry = a1 + info.ttl  # renewals can't keep up
        else:
            info.expiry = math.inf
            return
        heapq.heappush(self._heap, (info.expiry, wid, info.token))
