"""Determinism rules: the virtual-clock subsystems must be pure
functions of the clock and their seeds, and hot paths must never sync
the host (DESIGN.md §15).

* ``wall-clock-in-sim`` — ``time.time``/``datetime.now``/unseeded RNG
  anywhere under ``edgesim/``, ``cluster/``, ``fleet/`` or the virtual
  serving core breaks bit-identical replay (the convergence claims in
  BENCH_*.json are only as trustworthy as the determinism of the harness
  that produced them). ``launch/`` and ``benchmarks/`` time the *host*
  on purpose and are not scanned.
* ``host-sync-in-hot-path`` — ``.item()`` / ``jax.device_get`` /
  ``block_until_ready`` / ``np.asarray`` on a traced value inside the
  train step, the kernels, or the model forward paths forces a device
  round trip per call (and breaks under jit on values that are tracers).
  The serving decode/chunk loops (``serve/engine.py``,
  ``serve/balance.py``) are scanned too, with a narrower contract: a
  per-step host copy of small token ids is the loop's job, but any host
  sync touching *logits* ships a (slots, vocab) tensor per step — the
  argmax belongs inside the jit.
"""

from __future__ import annotations

import ast
from typing import Iterator

from .core import Finding, Project, Rule, dotted_name, register_rule

__all__ = ["WallClockInSim", "HostSyncInHotPath"]

# Directories whose code runs on the virtual clock. launch/ and
# benchmarks/ are deliberately absent: host timing is their job.
SIM_SCOPES = (
    "src/repro/edgesim/",
    "src/repro/cluster/",
    "src/repro/fleet/",
    "src/repro/serve/engine.py",
    "src/repro/serve/cache.py",
    "src/repro/serve/sync.py",
)

_WALL_CLOCK = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.process_time",
    "time.process_time_ns",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today",
}

# module-level RNG entry points draw from unseeded global state
_GLOBAL_RNG_PREFIXES = ("np.random.", "numpy.random.", "random.")
_GLOBAL_RNG_SEEDED = {
    "np.random.default_rng", "numpy.random.default_rng",
    "np.random.SeedSequence", "numpy.random.SeedSequence",
    "np.random.Generator", "numpy.random.Generator",
    "random.Random",
}

HOT_PATHS = (
    "src/repro/ps/train_step.py",
    "src/repro/kernels/",
    "src/repro/models/",
)

# The serving decode/chunk loops run one host round trip per *step*, so
# they may ship small (slots,) token-id arrays — but never logits: a
# host copy of a (slots, vocab) logits tensor per step is exactly the
# sync the engine's device-side argmax exists to remove (§17). These
# files are scanned for host syncs whose expression touches logits.
SERVE_HOT_PATHS = (
    "src/repro/serve/engine.py",
    "src/repro/serve/balance.py",
)

_HOST_SYNC_DOTTED = {"jax.device_get"}
_HOST_COPY_DOTTED = {"np.asarray", "numpy.asarray", "np.array", "numpy.array"}


@register_rule
class WallClockInSim(Rule):
    name = "wall-clock-in-sim"
    severity = "error"
    description = (
        "virtual-clock code (edgesim/cluster/fleet/serve core) must not "
        "read the wall clock or draw from unseeded RNG state"
    )

    def check(self, project: Project) -> Iterator[Finding]:
        for sf in project.files_under(*SIM_SCOPES):
            if sf.tree is None:
                continue
            for node in ast.walk(sf.tree):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func)
                if name is None:
                    continue
                if name in _WALL_CLOCK:
                    yield self.finding(sf, node, (
                        f"{name}() reads the wall clock inside virtual-clock "
                        "code; use the simulator's `now` (or plumb a clock in)"
                    ))
                elif name in _GLOBAL_RNG_SEEDED:
                    if not node.args and not node.keywords:
                        yield self.finding(sf, node, (
                            f"{name}() with no seed is nondeterministic; pass "
                            "an explicit seed/SeedSequence"
                        ))
                elif name.startswith(_GLOBAL_RNG_PREFIXES):
                    yield self.finding(sf, node, (
                        f"{name}() draws from the unseeded global RNG; use a "
                        "seeded np.random.default_rng(seed) generator"
                    ))


@register_rule
class HostSyncInHotPath(Rule):
    name = "host-sync-in-hot-path"
    severity = "error"
    description = (
        "train step / kernels / model forward paths must not host-sync "
        "(.item(), jax.device_get, block_until_ready, np.asarray on arrays)"
    )

    def check(self, project: Project) -> Iterator[Finding]:
        for sf in project.files_under(*HOT_PATHS):
            if sf.tree is None:
                continue
            for node in ast.walk(sf.tree):
                if not isinstance(node, ast.Call):
                    continue
                if isinstance(node.func, ast.Attribute):
                    attr = node.func.attr
                    if attr == "item" and not node.args and not node.keywords:
                        yield self.finding(sf, node, (
                            ".item() synchronizes device→host per call; "
                            "compute the scalar in Python (math.*) or keep "
                            "it on device"
                        ))
                        continue
                    if attr == "block_until_ready":
                        yield self.finding(sf, node, (
                            ".block_until_ready() stalls the dispatch "
                            "pipeline; hot paths must stay async"
                        ))
                        continue
                name = dotted_name(node.func)
                if name in _HOST_SYNC_DOTTED or (
                    name is not None and name.endswith(".device_get")
                ):
                    yield self.finding(sf, node, (
                        f"{name}() copies device→host; hot paths must not "
                        "materialize arrays on host"
                    ))
                elif name in _HOST_COPY_DOTTED:
                    yield self.finding(sf, node, (
                        f"{name}() forces a host copy (and fails on traced "
                        "values under jit); use jnp.asarray or restructure"
                    ))
        yield from self._check_serve(project)

    def _check_serve(self, project: Project) -> Iterator[Finding]:
        """Serve decode loops: host syncs are per-step, so they must ship
        token ids, never logits — argmax belongs inside the jit."""
        for sf in project.files_under(*SERVE_HOT_PATHS):
            if sf.tree is None:
                continue
            for node in ast.walk(sf.tree):
                if not isinstance(node, ast.Call):
                    continue
                is_sync = False
                if isinstance(node.func, ast.Attribute):
                    attr = node.func.attr
                    if (attr == "item" and not node.args and not node.keywords
                            ) or attr == "block_until_ready":
                        is_sync = True
                if not is_sync:
                    name = dotted_name(node.func)
                    is_sync = (name in _HOST_SYNC_DOTTED
                               or name in _HOST_COPY_DOTTED
                               or (name is not None
                                   and name.endswith(".device_get")))
                if is_sync and "logits" in ast.unparse(node):
                    yield self.finding(sf, node, (
                        "host sync on logits in the serving loop: a "
                        "(slots, vocab) device→host copy per decode step; "
                        "argmax on device and ship token ids instead"
                    ))
