"""reprolint core: typed findings, the project model, and the rule
registry (DESIGN.md §15).

ADSP's correctness rests on invariants the Python type system cannot
see: the simulator must be a pure function of the virtual clock and its
seeds, every protocol record must have a dispatch arm, every fused
Pallas backend must have a bit-for-bit reference twin, hot paths must
never host-sync. PRs 1–7 enforced these one regression test at a time;
this package checks them mechanically.

The shapes mirror the repo's registry idiom (``repro.ps`` rules,
``repro.transport`` codecs, ``repro.fleet`` metrics):

  * ``Finding`` — a typed frozen record with lossless
    ``to_dict``/``from_dict`` round-trip (the ``--json`` output and the
    baseline file are built from these);
  * ``Rule``    — the checker contract: a named, severity-tagged object
    whose ``check(project)`` yields findings. Rules register under their
    string name via ``register_rule`` so the CLI, the tests, and the
    baseline all refer to one catalogue.

A ``Project`` is the parsed view of the repo: the scan set (what the
CLI was pointed at) for per-file rules, plus an on-demand loader so
cross-file rules (handler exhaustiveness, registry parity) can resolve
their anchor files from the repo root even when the scan set is narrow.

Inline suppression: a source line carrying ``# reprolint: ignore`` (all
rules) or ``# reprolint: ignore[rule-a,rule-b]`` is exempt. Whole-repo
suppression with a justification lives in ``analysis_baseline.json``
(see ``repro.analysis.baseline``).
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
from typing import Iterable, Iterator

__all__ = [
    "Finding",
    "SourceFile",
    "Project",
    "Rule",
    "register_rule",
    "rule_names",
    "get_rule",
    "all_rules",
    "run_rules",
    "dotted_name",
    "find_repo_root",
]

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a repo-relative file and line.

    ``key`` deliberately excludes the line number: baseline entries must
    survive unrelated edits above the offending code.
    """

    rule: str
    severity: str
    path: str  # repo-relative posix path
    line: int
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Finding":
        return cls(**d)

    @property
    def key(self) -> str:
        return f"{self.rule}::{self.path}::{self.message}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}/{self.severity}] {self.message}"


class SourceFile:
    """One parsed Python file. ``tree`` is lazy and returns None on a
    syntax error (recorded as ``parse_error`` so rules need not guard)."""

    def __init__(self, root: pathlib.Path, path: pathlib.Path):
        self.path = path
        self.rel = path.resolve().relative_to(root.resolve()).as_posix()
        self._text: str | None = None
        self._tree: ast.AST | None = None
        self._parsed = False
        self.parse_error: SyntaxError | None = None

    @property
    def text(self) -> str:
        if self._text is None:
            self._text = self.path.read_text()
        return self._text

    @property
    def lines(self) -> list[str]:
        return self.text.splitlines()

    @property
    def tree(self) -> ast.AST | None:
        if not self._parsed:
            self._parsed = True
            try:
                self._tree = ast.parse(self.text, filename=self.rel)
            except SyntaxError as e:
                self.parse_error = e
        return self._tree

    def line_text(self, lineno: int) -> str:
        lines = self.lines
        return lines[lineno - 1] if 1 <= lineno <= len(lines) else ""


def find_repo_root(start: pathlib.Path | None = None) -> pathlib.Path:
    """Nearest ancestor carrying pyproject.toml or .git (else ``start``)."""
    p = (start or pathlib.Path.cwd()).resolve()
    if p.is_file():
        p = p.parent
    for cand in (p, *p.parents):
        if (cand / "pyproject.toml").exists() or (cand / ".git").exists():
            return cand
    return p


class Project:
    """The analysis context: a scan set of parsed files plus on-demand
    access to any file under the repo root (cross-file rules resolve
    their anchors — protocol.py, tests/ — independent of the scan set)."""

    def __init__(self, root: pathlib.Path, paths: Iterable[pathlib.Path] | None = None):
        self.root = pathlib.Path(root).resolve()
        self._cache: dict[str, SourceFile] = {}
        scan = [pathlib.Path(p) for p in paths] if paths else [self.root / "src"]
        files: dict[str, SourceFile] = {}
        for p in scan:
            p = p if p.is_absolute() else self.root / p
            for f in sorted(p.rglob("*.py")) if p.is_dir() else [p]:
                if "__pycache__" in f.parts or not f.exists():
                    continue
                sf = self._load(f)
                files[sf.rel] = sf
        self.files: list[SourceFile] = [files[k] for k in sorted(files)]

    def _load(self, path: pathlib.Path) -> SourceFile:
        sf = SourceFile(self.root, path)
        return self._cache.setdefault(sf.rel, sf)

    def file(self, rel: str) -> SourceFile | None:
        """Load ``rel`` (repo-relative) whether or not it was scanned."""
        if rel in self._cache:
            return self._cache[rel]
        path = self.root / rel
        return self._load(path) if path.exists() else None

    def files_under(self, *prefixes: str) -> list[SourceFile]:
        """Scanned files whose repo-relative path starts with a prefix
        (or equals it exactly, for single-file targets)."""
        return [
            sf for sf in self.files
            if any(sf.rel == p or sf.rel.startswith(p) for p in prefixes)
        ]

    def glob(self, pattern: str) -> list[SourceFile]:
        """Load files matching ``pattern`` from the repo root, scanned
        or not (used by cross-file rules to reach tests/)."""
        return [
            self._load(f)
            for f in sorted(self.root.glob(pattern))
            if f.is_file() and "__pycache__" not in f.parts
        ]


# ---------------------------------------------------------------------------
# Rule contract + registry
# ---------------------------------------------------------------------------


class Rule:
    """One named checker. Subclasses set ``name``/``severity`` and
    implement ``check``; ``finding`` builds correctly-anchored records."""

    name = "base"
    severity = "error"
    description = ""

    def check(self, project: Project) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, sf: SourceFile, node, message: str) -> Finding:
        line = node if isinstance(node, int) else getattr(node, "lineno", 0)
        return Finding(rule=self.name, severity=self.severity,
                       path=sf.rel, line=int(line), message=message)


_RULES: dict[str, type] = {}


def register_rule(cls: type) -> type:
    if not issubclass(cls, Rule) or cls.name == "base":
        raise TypeError(f"not a registerable rule: {cls!r}")
    if cls.severity not in SEVERITIES:
        raise ValueError(f"rule {cls.name!r}: severity must be one of {SEVERITIES}")
    _RULES[cls.name] = cls
    return cls


def rule_names() -> list[str]:
    return sorted(_RULES)


def get_rule(name: str) -> Rule:
    try:
        return _RULES[name]()
    except KeyError:
        raise KeyError(f"unknown rule {name!r}; registered: {rule_names()}") from None


def all_rules() -> list[Rule]:
    return [_RULES[n]() for n in rule_names()]


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------

_IGNORE_RE = re.compile(r"#\s*reprolint:\s*ignore(?:\[([^\]]*)\])?")


def _inline_ignored(project: Project, f: Finding) -> bool:
    sf = project.file(f.path)
    if sf is None or f.line <= 0:
        return False
    m = _IGNORE_RE.search(sf.line_text(f.line))
    if m is None:
        return False
    names = m.group(1)
    if names is None:
        return True
    return f.rule in {n.strip() for n in names.split(",") if n.strip()}


def run_rules(project: Project, rules: Iterable[Rule] | None = None) -> list[Finding]:
    """Run rules over the project; returns findings sorted by location,
    with syntax errors surfaced as ``parse_error`` findings and inline
    ``# reprolint: ignore`` suppressions already applied."""
    out: list[Finding] = []
    for sf in project.files:
        if sf.tree is None and sf.parse_error is not None:
            out.append(Finding(rule="parse_error", severity="error", path=sf.rel,
                               line=int(sf.parse_error.lineno or 0),
                               message=f"syntax error: {sf.parse_error.msg}"))
    for rule in (rules if rules is not None else all_rules()):
        out.extend(rule.check(project))
    out = [f for f in out if not _inline_ignored(project, f)]
    return sorted(out, key=lambda f: (f.path, f.line, f.rule, f.message))


# ---------------------------------------------------------------------------
# AST helpers shared by the rules
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> str | None:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None
