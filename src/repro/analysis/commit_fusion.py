"""separate-dispatch-in-commit-path: the commit hot loop should not
decode a codec payload and then apply the commit rule as two separate
calls — the combined decode+apply rules (``repro.ps.fused_codec``,
DESIGN.md §16) exist exactly so the PS never materializes the dense
update between the two passes.

Scope is deliberately narrow: the train-step builders
(``ps/train_step.py``, ``launch/steps.py``) — the two files that
assemble the commit path. A function that mentions ``fused`` anywhere in
its body is taken to be fusion-aware (it either routes through the
combined rule or deliberately falls back) and is not flagged; the rule
is a *warning* because the chain is still the correctness contract and
legitimate in non-fusable configurations.
"""

from __future__ import annotations

import ast
from typing import Iterator

from .core import Finding, Project, Rule, SourceFile, dotted_name, register_rule

__all__ = ["SeparateDispatchInCommitPath"]

_SCOPE_BASENAMES = ("train_step.py", "steps.py")


def _calls_matching(fn: ast.AST, stem: str) -> list[ast.Call]:
    """Call nodes under ``fn`` whose callee's last segment contains
    ``stem``. Nested defs are included — each also gets its own scope
    pass, and the enclosing function's ``fused`` text check covers both."""
    out: list[ast.Call] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if name is not None and stem in name.rsplit(".", 1)[-1]:
                out.append(node)
    return out


def _function_scopes(tree: ast.AST) -> Iterator[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _segment_text(sf: SourceFile, node: ast.AST) -> str:
    lines = sf.text.splitlines()
    end = getattr(node, "end_lineno", node.lineno)
    return "\n".join(lines[node.lineno - 1:end])


@register_rule
class SeparateDispatchInCommitPath(Rule):
    name = "separate-dispatch-in-commit-path"
    severity = "warning"
    description = (
        "codec decode followed by commit apply as two calls in the "
        "commit path where a combined decode+apply rule is available"
    )

    def check(self, project: Project) -> Iterator[Finding]:
        for sf in project.files_under("src/"):
            if sf.tree is None:
                continue
            if not any(sf.rel.endswith(b) for b in _SCOPE_BASENAMES):
                continue
            for fn in _function_scopes(sf.tree):
                text = _segment_text(sf, fn)
                if "fused" in text:
                    continue  # fusion-aware: routes or falls back on purpose
                decodes = _calls_matching(fn, "decode")
                applies = _calls_matching(fn, "apply")
                if not decodes or not applies:
                    continue
                first_dec = min(decodes, key=lambda c: c.lineno)
                if any(a.lineno >= first_dec.lineno for a in applies):
                    yield self.finding(sf, first_dec.lineno, (
                        f"function {fn.name!r} decodes the codec payload "
                        "and applies the commit rule as two dispatches; "
                        "the combined decode+apply rules in "
                        "repro.ps.fused_codec (§16) do both in one pass — "
                        "route through them or mark the fallback fused-aware"
                    ))
