"""Registry-parity rule: every fused/Pallas backend must have a
bit-for-bit reference twin, and a test that names it (DESIGN.md §15).

The repo's performance claim structure is: the *reference* backend is
the correctness contract (pure JAX, bit-compared against the seed), and
the *fused* backend is the speed path, parity-tested against reference.
A fused registration without a reference twin has no contract to be
tested against; a pair no test names by its registry string is parity
coverage that can silently rot.

Sources of truth (all resolved statically, no imports):

  * ``@register_local_rule(name, backend)`` / ``@register_commit_rule``
    (``repro.ps``) and ``@register_codec`` (``repro.transport``)
    decorator sites anywhere under ``src/``;
  * the public kernel wrappers in ``kernels/ops.py`` (``__all__``),
    whose reference twins live in ``kernels/ref.py`` or — for the codec
    passes — in the reference codecs of ``transport/codecs.py``.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Iterator

from .core import Finding, Project, Rule, SourceFile, dotted_name, register_rule

__all__ = ["RegistryParity", "registered_backends"]

_REGISTRARS = {
    "register_local_rule": "ps.local",
    "register_commit_rule": "ps.commit",
    "register_codec": "transport.codec",
}
_FUSED = ("fused", "pallas")

KERNEL_OPS = "src/repro/kernels/ops.py"
KERNEL_REF = "src/repro/kernels/ref.py"
CODEC_REF = "src/repro/transport/codecs.py"
_OPS_HELPERS = {"default_interpret"}


@dataclasses.dataclass(frozen=True)
class Registration:
    registry: str
    rule_name: str
    backend: str
    path: str
    line: int


def registered_backends(project: Project) -> list[Registration]:
    """Every (registry, name, backend) decorator site under src/."""
    out: list[Registration] = []
    for sf in project.files_under("src/"):
        if sf.tree is None:
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = dotted_name(node.func)
            if fn is None:
                continue
            registrar = _REGISTRARS.get(fn.rsplit(".", 1)[-1])
            if registrar is None:
                continue
            consts = [a.value for a in node.args
                      if isinstance(a, ast.Constant) and isinstance(a.value, str)]
            if not consts:
                continue
            name = consts[0]
            backend = consts[1] if len(consts) > 1 else "reference"
            out.append(Registration(registrar, name, backend, sf.rel, node.lineno))
    return out


def _test_text(project: Project) -> str:
    return "\n".join(sf.text for sf in project.glob("tests/**/*.py"))


def _ops_public_names(sf: SourceFile) -> list[tuple[str, int]]:
    """(name, line) for each ``__all__`` entry of kernels/ops.py."""
    if sf.tree is None:
        return []
    for node in ast.walk(sf.tree):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "__all__"
                and isinstance(node.value, (ast.List, ast.Tuple))):
            return [
                (e.value, e.lineno) for e in node.value.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)
            ]
    return []


@register_rule
class RegistryParity(Rule):
    name = "registry-parity"
    severity = "error"
    description = (
        "every fused/pallas registration needs a reference twin, and "
        "every fused-capable name needs a test referencing it by name"
    )

    def check(self, project: Project) -> Iterator[Finding]:
        regs = registered_backends(project)
        have = {(r.registry, r.rule_name, r.backend) for r in regs}
        tests = _test_text(project)

        for r in regs:
            if r.backend not in _FUSED:
                continue
            sf = project.file(r.path)
            if (r.registry, r.rule_name, "reference") not in have:
                yield self.finding(sf, r.line, (
                    f"{r.registry} registration ({r.rule_name!r}, "
                    f"{r.backend!r}) has no ({r.rule_name!r}, 'reference') "
                    "twin — the fused kernel has no correctness contract "
                    "to be parity-tested against"
                ))
            if f'"{r.rule_name}"' not in tests and f"'{r.rule_name}'" not in tests:
                yield self.finding(sf, r.line, (
                    f"no test under tests/ references the fused-capable "
                    f"{r.registry} name {r.rule_name!r} as a string — the "
                    "reference/fused pair has no named parity coverage"
                ))

        # kernels: public Pallas wrappers need a pure-JAX twin + a test
        ops = project.file(KERNEL_OPS)
        if ops is None:
            return
        ref = project.file(KERNEL_REF)
        codecs = project.file(CODEC_REF)
        twin_text = (ref.text if ref is not None else "") + (
            codecs.text if codecs is not None else "")
        for name, line in _ops_public_names(ops):
            if name in _OPS_HELPERS:
                continue
            stem = name[:-len("_tree")] if name.endswith("_tree") else name
            if stem not in twin_text:
                yield self.finding(ops, line, (
                    f"kernel op {name!r} has no reference twin (searched "
                    f"{KERNEL_REF} and the reference codecs in {CODEC_REF})"
                ))
            if name not in tests:
                yield self.finding(ops, line, (
                    f"no test under tests/ references kernel op {name!r} "
                    "by name — the Pallas/reference pair has no parity "
                    "coverage"
                ))
