"""Protocol contract rules: the typed event/command vocabulary and the
metrics stream must stay total and immutable (DESIGN.md §15).

* ``handler-exhaustiveness`` — every ``Event``/``Command`` subclass in
  ``cluster/protocol.py`` must be matched by an ``isinstance`` dispatch
  arm somewhere in the dispatch triad (protocol's ``handle``, engine's
  ``execute``/``dispatch``, the simulator's event loop). The protocol
  base classes raise ``TypeError`` on unknown records, but only at
  runtime on the path actually hit — a new event type that silently
  falls through a ``handle`` chain is exactly the bug class PR 6's gen
  counters existed to catch.
* ``frozen-protocol`` — every protocol record (Event/Command) and every
  metrics record must be a ``frozen=True`` dataclass; metric records
  must also be registered (``@_register("kind")``) or they silently lose
  the JSONL round-trip the fleet stream is built on.
"""

from __future__ import annotations

import ast
from typing import Iterator

from .core import Finding, Project, Rule, SourceFile, dotted_name, register_rule

__all__ = ["HandlerExhaustiveness", "FrozenProtocol"]

PROTOCOL_FILE = "src/repro/cluster/protocol.py"
DISPATCH_FILES = (
    "src/repro/cluster/protocol.py",
    "src/repro/cluster/engine.py",
    "src/repro/edgesim/simulator.py",
)
METRICS_FILE = "src/repro/fleet/metrics.py"


def _base_names(cls: ast.ClassDef) -> set[str]:
    out = set()
    for b in cls.bases:
        name = dotted_name(b)
        if name:
            out.add(name.rsplit(".", 1)[-1])
    return out


def protocol_subclasses(sf: SourceFile, bases: tuple[str, ...]) -> list[ast.ClassDef]:
    """Direct subclasses of the given base names, in definition order."""
    if sf.tree is None:
        return []
    return [
        n for n in ast.walk(sf.tree)
        if isinstance(n, ast.ClassDef) and _base_names(n) & set(bases)
    ]


def _isinstance_targets(sf: SourceFile) -> set[str]:
    """Every class name used as an isinstance() second argument (or a
    match-case class pattern) in the file."""
    out: set[str] = set()
    if sf.tree is None:
        return out
    for node in ast.walk(sf.tree):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "isinstance" and len(node.args) == 2):
            spec = node.args[1]
            elts = spec.elts if isinstance(spec, ast.Tuple) else [spec]
            for e in elts:
                name = dotted_name(e)
                if name:
                    out.add(name.rsplit(".", 1)[-1])
        elif isinstance(node, ast.MatchClass):
            name = dotted_name(node.cls)
            if name:
                out.add(name.rsplit(".", 1)[-1])
    return out


def _is_frozen_dataclass(cls: ast.ClassDef) -> bool:
    for deco in cls.decorator_list:
        if isinstance(deco, ast.Call):
            name = dotted_name(deco.func)
            if name and name.rsplit(".", 1)[-1] == "dataclass":
                for kw in deco.keywords:
                    if (kw.arg == "frozen" and isinstance(kw.value, ast.Constant)
                            and kw.value.value is True):
                        return True
    return False


def _is_registered_metric(cls: ast.ClassDef) -> bool:
    for deco in cls.decorator_list:
        if isinstance(deco, ast.Call):
            name = dotted_name(deco.func)
            if name and name.rsplit(".", 1)[-1] == "_register":
                return True
    return False


@register_rule
class HandlerExhaustiveness(Rule):
    name = "handler-exhaustiveness"
    severity = "error"
    description = (
        "every Event/Command subclass in cluster/protocol.py needs an "
        "isinstance dispatch arm in protocol.handle / engine / simulator"
    )

    def check(self, project: Project) -> Iterator[Finding]:
        proto = project.file(PROTOCOL_FILE)
        if proto is None or proto.tree is None:
            return
        dispatched: set[str] = set()
        for rel in DISPATCH_FILES:
            sf = project.file(rel)
            if sf is not None:
                dispatched |= _isinstance_targets(sf)
        for kind in ("Event", "Command"):
            for cls in protocol_subclasses(proto, (kind,)):
                if cls.name not in dispatched:
                    yield self.finding(proto, cls, (
                        f"{kind} subclass {cls.name} has no isinstance "
                        f"dispatch arm in any of {', '.join(DISPATCH_FILES)} "
                        "— it would silently fall through to the TypeError "
                        "tail (or worse, be dropped)"
                    ))


@register_rule
class FrozenProtocol(Rule):
    name = "frozen-protocol"
    severity = "error"
    description = (
        "protocol events/commands and metric records must be frozen "
        "dataclasses; metric records must be registered for round-trip"
    )

    def check(self, project: Project) -> Iterator[Finding]:
        proto = project.file(PROTOCOL_FILE)
        if proto is not None:
            for cls in protocol_subclasses(proto, ("Event", "Command")):
                if not _is_frozen_dataclass(cls):
                    yield self.finding(proto, cls, (
                        f"protocol record {cls.name} must be a "
                        "@dataclasses.dataclass(frozen=True) — events and "
                        "commands are immutable by contract"
                    ))
        metrics = project.file(METRICS_FILE)
        if metrics is not None:
            for cls in protocol_subclasses(metrics, ("MetricRecord",)):
                if not _is_frozen_dataclass(cls):
                    yield self.finding(metrics, cls, (
                        f"metric record {cls.name} must be a "
                        "@dataclasses.dataclass(frozen=True)"
                    ))
                if not _is_registered_metric(cls):
                    yield self.finding(metrics, cls, (
                        f"metric record {cls.name} is not registered "
                        "(@_register(kind)) — it would not survive the "
                        "to_dict/from_dict JSONL round trip"
                    ))
