"""Hygiene rules: silent failure handling, aliased defaults, and
kernel-body control flow on traced values (DESIGN.md §15).

* ``broad-except`` — a bare ``except:`` / ``except Exception:`` that
  neither re-raises nor records the caught exception swallows the error
  class entirely; ~30 CHANGES.md bugfixes started life as a swallowed
  exception.
* ``mutable-default`` — a mutable literal as a function default or
  dataclass field default aliases one object across calls/instances;
  dataclasses raise for list/dict/set but not for arbitrary mutables,
  and plain functions never raise.
* ``tracer-branch`` — Python ``if``/``while`` on a value loaded from a
  kernel ref runs fine in interpret mode (concrete values) and fails —
  or silently specializes — when compiled for TPU. Taint is tracked
  from ``*_ref`` parameters / ``pl.load`` through assignments.
"""

from __future__ import annotations

import ast
from typing import Iterator

from .core import Finding, Project, Rule, SourceFile, dotted_name, register_rule

__all__ = ["BroadExcept", "MutableDefault", "TracerBranch"]

_BROAD = {"Exception", "BaseException"}
KERNEL_SCOPE = "src/repro/kernels/"


@register_rule
class BroadExcept(Rule):
    name = "broad-except"
    severity = "warning"
    description = (
        "bare except / except Exception without re-raise or a recorded "
        "error type swallows failures silently"
    )

    def _is_broad(self, handler: ast.ExceptHandler) -> bool:
        if handler.type is None:
            return True
        types = (handler.type.elts if isinstance(handler.type, ast.Tuple)
                 else [handler.type])
        for t in types:
            name = dotted_name(t)
            if name and name.rsplit(".", 1)[-1] in _BROAD:
                return True
        return False

    def _handled(self, handler: ast.ExceptHandler) -> bool:
        """Re-raises, or binds the exception and actually uses it."""
        for node in ast.walk(ast.Module(body=handler.body, type_ignores=[])):
            if isinstance(node, ast.Raise):
                return True
            if (handler.name is not None and isinstance(node, ast.Name)
                    and node.id == handler.name
                    and isinstance(node.ctx, ast.Load)):
                return True
        return False

    def check(self, project: Project) -> Iterator[Finding]:
        for sf in project.files:
            if sf.tree is None:
                continue
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.ExceptHandler):
                    if self._is_broad(node) and not self._handled(node):
                        what = ("bare except:" if node.type is None
                                else "except Exception")
                        yield self.finding(sf, node, (
                            f"{what} swallows the error without re-raising "
                            "or recording the exception type; catch the "
                            "specific exceptions or log/record the error"
                        ))


def _is_mutable_literal(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        name = dotted_name(node.func)
        return name in {"list", "dict", "set", "bytearray",
                        "collections.defaultdict", "defaultdict"}
    return False


def _is_dataclass(cls: ast.ClassDef) -> bool:
    for deco in cls.decorator_list:
        target = deco.func if isinstance(deco, ast.Call) else deco
        name = dotted_name(target)
        if name and name.rsplit(".", 1)[-1] == "dataclass":
            return True
    return False


@register_rule
class MutableDefault(Rule):
    name = "mutable-default"
    severity = "error"
    description = (
        "mutable literals as function defaults or dataclass field "
        "defaults alias one object across calls/instances"
    )

    def check(self, project: Project) -> Iterator[Finding]:
        for sf in project.files:
            if sf.tree is None:
                continue
            for node in ast.walk(sf.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)):
                    args = node.args
                    for d in (*args.defaults, *args.kw_defaults):
                        if d is not None and _is_mutable_literal(d):
                            yield self.finding(sf, d, (
                                "mutable default argument is shared across "
                                "calls; default to None (or use a factory)"
                            ))
                elif isinstance(node, ast.ClassDef) and _is_dataclass(node):
                    for stmt in node.body:
                        if (isinstance(stmt, ast.AnnAssign)
                                and stmt.value is not None
                                and _is_mutable_literal(stmt.value)):
                            yield self.finding(sf, stmt, (
                                "mutable dataclass field default; use "
                                "dataclasses.field(default_factory=...)"
                            ))


def _names_in(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _is_pl_load(node: ast.AST) -> bool:
    if isinstance(node, ast.Call):
        name = dotted_name(node.func)
        return name is not None and name.rsplit(".", 1)[-1] == "load"
    return False


@register_rule
class TracerBranch(Rule):
    name = "tracer-branch"
    severity = "error"
    description = (
        "Python if/while on a value loaded from a kernel ref only works "
        "in interpret mode; use jnp.where / pl.when / lax.cond"
    )

    def check(self, project: Project) -> Iterator[Finding]:
        for sf in project.files_under(KERNEL_SCOPE):
            if sf.tree is None:
                continue
            for fn in ast.walk(sf.tree):
                if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                tainted = {
                    a.arg
                    for a in (*fn.args.posonlyargs, *fn.args.args,
                              *fn.args.kwonlyargs)
                    if a.arg.endswith("_ref")
                }
                if not tainted:
                    continue
                yield from self._scan_body(sf, fn.body, tainted)

    def _scan_body(self, sf: SourceFile, body: list[ast.stmt],
                   tainted: set[str]) -> Iterator[Finding]:
        for stmt in body:
            if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                value = stmt.value
                if value is not None and (
                    _names_in(value) & tainted
                    or any(_is_pl_load(n) for n in ast.walk(value))
                ):
                    targets = (stmt.targets if isinstance(stmt, ast.Assign)
                               else [stmt.target])
                    for t in targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name):
                                tainted.add(n.id)
            elif isinstance(stmt, (ast.If, ast.While)):
                hit = sorted(_names_in(stmt.test) & tainted)
                if hit:
                    kw = "while" if isinstance(stmt, ast.While) else "if"
                    yield self.finding(sf, stmt, (
                        f"Python `{kw}` on ref-loaded value(s) "
                        f"{', '.join(hit)} — concrete only in interpret "
                        "mode; compiled kernels need jnp.where / pl.when / "
                        "lax.cond"
                    ))
                yield from self._scan_body(sf, stmt.body, tainted)
                yield from self._scan_body(sf, stmt.orelse, tainted)
            elif isinstance(stmt, (ast.For, ast.With)):
                yield from self._scan_body(sf, stmt.body, tainted)
            elif isinstance(stmt, ast.FunctionDef):
                # nested helper (fori_loop body): refs visible via closure
                yield from self._scan_body(sf, stmt.body, set(tainted))
