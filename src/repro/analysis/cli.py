"""reprolint CLI: ``python -m repro.analysis [--strict] [--json] [paths]``.

Default scan set is ``src``, ``benchmarks``, ``tools`` under the repo
root (found via pyproject.toml/.git from the first path or cwd). Exit
status: 0 when every finding is suppressed (baseline or inline), 1 when
unsuppressed findings remain — and, under ``--strict``, when the
baseline carries stale entries (suppressions that no longer match).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

from .baseline import DEFAULT_BASELINE, Baseline, BaselineEntry
from .core import Project, all_rules, find_repo_root, get_rule, rule_names, run_rules

__all__ = ["main", "analyze"]

DEFAULT_PATHS = ("src", "benchmarks", "tools")


def analyze(paths=None, root=None, rules=None):
    """Library entry point: returns (project, findings) with no baseline
    applied (callers decide suppression policy)."""
    first = pathlib.Path(paths[0]) if paths else None
    root = pathlib.Path(root) if root is not None else find_repo_root(first)
    scan = [pathlib.Path(p) for p in paths] if paths else [
        root / p for p in DEFAULT_PATHS if (root / p).exists()
    ]
    project = Project(root, scan)
    return project, run_rules(project, rules)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis (reprolint)")
    p.add_argument("paths", nargs="*",
                   help=f"files/dirs to scan (default: {'/'.join(DEFAULT_PATHS)} "
                        "under the repo root)")
    p.add_argument("--strict", action="store_true",
                   help="also fail on stale baseline entries")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write findings as JSON ('-' for stdout)")
    p.add_argument("--baseline", metavar="PATH", default=None,
                   help=f"suppression file (default: <root>/{DEFAULT_BASELINE})")
    p.add_argument("--update-baseline", action="store_true",
                   help="rewrite the baseline to suppress all current findings")
    p.add_argument("--rules", nargs="*", metavar="RULE", default=None,
                   help="run only these rules")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalogue and exit")
    args = p.parse_args(argv)

    if args.list_rules:
        for r in all_rules():
            print(f"{r.name:24s} {r.severity:8s} {r.description}")
        return 0

    rules = [get_rule(n) for n in args.rules] if args.rules is not None else None
    project, findings = analyze(args.paths or None, rules=rules)

    baseline_path = (pathlib.Path(args.baseline) if args.baseline
                     else project.root / DEFAULT_BASELINE)
    if args.update_baseline:
        bl = Baseline.load(baseline_path)
        just = {e.key: e.justification for e in bl.entries}
        bl.entries = [
            BaselineEntry.from_finding(f, just.get(f.key, "TODO: justify"))
            for f in findings
        ]
        bl.save(baseline_path)
        print(f"wrote {baseline_path} ({len(bl.entries)} entries)")
        return 0

    baseline = Baseline.load(baseline_path)
    kept, suppressed, stale = baseline.apply(findings)

    if args.json:
        payload = json.dumps({
            "root": str(project.root),
            "rules": args.rules if args.rules is not None else rule_names(),
            "findings": [f.to_dict() for f in kept],
            "suppressed": [f.to_dict() for f in suppressed],
            "stale_baseline": [e.to_dict() for e in stale],
        }, indent=1)
        if args.json == "-":
            print(payload)
        else:
            pathlib.Path(args.json).write_text(payload)

    for f in kept:
        print(f.render())
    errors = sum(1 for f in kept if f.severity == "error")
    warnings = len(kept) - errors
    tail = (f"{errors} error(s), {warnings} warning(s)"
            f" ({len(suppressed)} baseline-suppressed)")
    status = 0
    if kept:
        status = 1
    if stale:
        for e in stale:
            print(f"stale baseline entry: [{e.rule}] {e.path}: {e.message}",
                  file=sys.stderr)
        tail += f"; {len(stale)} stale baseline entr(y/ies)"
        if args.strict:
            status = 1
    print(("FAIL: " if status else "OK: ") + tail)
    return status


if __name__ == "__main__":
    sys.exit(main())
