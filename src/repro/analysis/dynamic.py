"""Event-trace race validator: replay a metrics stream and check the
orderings the control plane promises (DESIGN.md §15).

The static rules prove the *code* can't read the wall clock or drop a
protocol record; this validator proves a given *run* kept its ordering
contracts. It replays a ``MetricsLog`` (or its JSONL persistence) and
asserts:

  * **clock monotonicity** — record timestamps never go backwards in
    stream order (the simulator's re-entrant ``_run_until`` clock guards
    exist precisely to keep this true across nested probe windows);
  * **exactly-one-WorkerLeft** — a worker's leave/join churn records
    alternate: a second leave without an intervening join means a
    scripted departure raced a lease expiry past the dedupe (the PR 6
    bug class);
  * **no stale-gen deliveries** — no commit/capability/assign record for
    a worker inside its dead window (after leave, before rejoin): a
    record there means an event of an expired life (``w.gen``) was
    delivered anyway;
  * **per-shard version monotonicity** — the ``versions`` vector on
    commit records (the PS shard versions the worker's pull reflected)
    never decreases element-wise: a decrease means a stale shard state
    overwrote a newer one.

``python -m repro.analysis.dynamic trace.jsonl`` exits 1 on violations;
CI runs it over the bench_fleet metrics trace.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Iterable, Sequence

__all__ = ["Violation", "validate_records", "validate_jsonl", "main"]

# record kinds attributed to one worker's *live* lifetime; lease records
# are exempt (the lease layer legitimately reports on dead workers —
# "expired" precedes the leave, "rejoined" precedes the join), and churn
# records are the lifetime boundaries themselves.
_LIFE_KINDS = ("commit", "capability", "assign")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One ordering-contract breach, anchored to the stream index."""

    check: str  # clock | dedupe | stale-gen | shard-version
    index: int  # position in the record stream
    t: float
    message: str
    worker: int | None = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Violation":
        return cls(**d)

    def render(self) -> str:
        who = f" worker={self.worker}" if self.worker is not None else ""
        return f"record #{self.index} t={self.t:.6g}{who}: [{self.check}] {self.message}"


def validate_records(records: Iterable) -> list[Violation]:
    """Replay typed ``MetricRecord``s (e.g. ``MetricsLog.records`` or
    ``repro.fleet.load_jsonl(path)``) and return every violation."""
    out: list[Violation] = []
    last_t = float("-inf")
    alive: dict[int, bool] = {}  # first sight ⇒ implicitly alive
    last_versions: Sequence[int] | None = None

    for i, rec in enumerate(records):
        kind = getattr(rec, "kind", None)
        t = float(getattr(rec, "t", 0.0))
        if t < last_t:
            out.append(Violation(
                check="clock", index=i, t=t,
                message=f"timestamp went backwards: {t:.6g} after {last_t:.6g}"))
        else:
            last_t = t

        wid = getattr(rec, "worker", None)
        if kind == "churn":
            if rec.event == "leave":
                if not alive.get(wid, True):
                    out.append(Violation(
                        check="dedupe", index=i, t=t, worker=wid,
                        message="second WorkerLeft without an intervening "
                                "join (scripted leave raced lease expiry "
                                "past the dedupe)"))
                alive[wid] = False
            elif rec.event == "join":
                if alive.get(wid) is True:
                    out.append(Violation(
                        check="dedupe", index=i, t=t, worker=wid,
                        message="join for an already-alive worker"))
                alive[wid] = True
        elif kind in _LIFE_KINDS and wid is not None:
            if alive.get(wid) is False:
                out.append(Violation(
                    check="stale-gen", index=i, t=t, worker=wid,
                    message=f"{kind} record delivered inside the worker's "
                            "dead window (after leave, before rejoin) — an "
                            "expired-generation event got through"))

        versions = tuple(getattr(rec, "versions", ()) or ())
        if kind == "commit" and versions:
            n_shards = int(getattr(rec, "n_shards", len(versions)))
            if len(versions) != n_shards:
                out.append(Violation(
                    check="shard-version", index=i, t=t, worker=wid,
                    message=f"versions vector has {len(versions)} entries "
                            f"but n_shards={n_shards}"))
            elif last_versions is not None and len(last_versions) == len(versions):
                for k, (prev, cur) in enumerate(zip(last_versions, versions)):
                    if cur < prev:
                        out.append(Violation(
                            check="shard-version", index=i, t=t, worker=wid,
                            message=f"shard {k} version went backwards: "
                                    f"{cur} after {prev} — a stale shard "
                                    "state overwrote a newer one"))
            if last_versions is None or len(last_versions) == len(versions):
                last_versions = tuple(
                    max(p, c) for p, c in zip(last_versions, versions)
                ) if last_versions is not None else versions
    return out


def validate_jsonl(path) -> list[Violation]:
    """Validate a persisted ``MetricsLog.to_jsonl``/``JsonlSink`` file.

    Lines are decoded through the typed registry (``fleet.from_dict``)
    so unknown kinds fail loudly rather than being skipped."""
    from repro.fleet.metrics import load_jsonl

    return validate_records(load_jsonl(path))


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.dynamic",
        description="event-trace race validator over a metrics JSONL")
    p.add_argument("traces", nargs="+", help="metrics JSONL file(s)")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write violations as JSON ('-' for stdout)")
    args = p.parse_args(argv)

    failed = 0
    all_violations: dict[str, list[dict]] = {}
    for path in args.traces:
        violations = validate_jsonl(path)
        all_violations[path] = [v.to_dict() for v in violations]
        for v in violations:
            print(f"{path}: {v.render()}")
        if violations:
            failed += 1
        else:
            print(f"{path}: OK (no ordering violations)")
    if args.json:
        payload = json.dumps(all_violations, indent=1)
        if args.json == "-":
            print(payload)
        else:
            import pathlib

            pathlib.Path(args.json).write_text(payload)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
