"""Committed suppression baseline for reprolint (DESIGN.md §15).

The baseline is how the ``--strict`` CI gate stays green while a rule
lands before every violation is fixed: each entry suppresses exactly one
finding (matched by the finding's line-independent ``key``) and must
carry a human ``justification``. The workflow:

  1. a new rule fires on existing code → either fix the code in the same
     PR (preferred) or run ``--update-baseline`` and edit in a
     justification per entry;
  2. the gate fails when a *new* finding appears (not in the baseline)
     — and, under ``--strict``, when a baseline entry no longer matches
     anything (stale suppressions must be deleted, or they hide the
     next real regression at that key).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib

from .core import Finding

__all__ = ["BaselineEntry", "Baseline", "DEFAULT_BASELINE"]

DEFAULT_BASELINE = "analysis_baseline.json"


@dataclasses.dataclass(frozen=True)
class BaselineEntry:
    rule: str
    path: str
    message: str
    justification: str = ""

    @property
    def key(self) -> str:
        return f"{self.rule}::{self.path}::{self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "BaselineEntry":
        return cls(**d)

    @classmethod
    def from_finding(cls, f: Finding, justification: str = "") -> "BaselineEntry":
        return cls(rule=f.rule, path=f.path, message=f.message,
                   justification=justification)


class Baseline:
    """The committed suppression set; lossless load/save round trip."""

    def __init__(self, entries: list[BaselineEntry] | None = None):
        self.entries = list(entries or [])

    @classmethod
    def load(cls, path) -> "Baseline":
        path = pathlib.Path(path)
        if not path.exists():
            return cls()
        data = json.loads(path.read_text())
        if not isinstance(data, dict) or "entries" not in data:
            raise ValueError(
                f"{path}: expected {{'version': 1, 'entries': [...]}}")
        return cls([BaselineEntry.from_dict(e) for e in data["entries"]])

    def save(self, path) -> None:
        payload = {
            "version": 1,
            "entries": [e.to_dict() for e in sorted(
                self.entries, key=lambda e: (e.path, e.rule, e.message))],
        }
        pathlib.Path(path).write_text(json.dumps(payload, indent=1) + "\n")

    def apply(self, findings: list[Finding]) -> tuple[
            list[Finding], list[Finding], list[BaselineEntry]]:
        """Partition into (kept, suppressed, stale-entries). Each entry
        suppresses every finding at its key (a key is line-independent,
        so one justified entry covers the violation wherever it moves)."""
        keys = {e.key for e in self.entries}
        kept = [f for f in findings if f.key not in keys]
        suppressed = [f for f in findings if f.key in keys]
        live = {f.key for f in findings}
        stale = [e for e in self.entries if e.key not in live]
        return kept, suppressed, stale
