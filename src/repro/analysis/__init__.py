"""repro.analysis: repo-specific static analysis (reprolint) plus the
event-trace race validator (DESIGN.md §15).

Static: ``python -m repro.analysis [--strict] [--json PATH] [paths...]``
runs the registered rule catalogue over ``src``/``benchmarks``/``tools``
and gates CI; suppressions live in ``analysis_baseline.json`` (with a
justification each) or inline as ``# reprolint: ignore[rule]``.

Dynamic: ``python -m repro.analysis.dynamic trace.jsonl`` replays a
metrics JSONL and asserts the ordering contracts (clock monotonicity,
WorkerLeft dedupe, no stale-generation deliveries, per-shard version
monotonicity).
"""

from .core import (
    Finding,
    Project,
    Rule,
    SourceFile,
    all_rules,
    get_rule,
    register_rule,
    rule_names,
    run_rules,
)
from .baseline import Baseline, BaselineEntry, DEFAULT_BASELINE
from .cli import analyze, main

# importing the rule modules populates the registry
from . import commit_fusion, hygiene, parity, protocol_rules, purity  # noqa: F401

_DYNAMIC = ("Violation", "validate_records", "validate_jsonl")


def __getattr__(name):
    # lazy: `python -m repro.analysis.dynamic` must not find the module
    # pre-imported by its own package (runpy double-import warning)
    if name in _DYNAMIC:
        from . import dynamic

        return getattr(dynamic, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "Finding", "Project", "Rule", "SourceFile",
    "register_rule", "rule_names", "get_rule", "all_rules", "run_rules",
    "Baseline", "BaselineEntry", "DEFAULT_BASELINE",
    "analyze", "main",
    "Violation", "validate_records", "validate_jsonl",
]
