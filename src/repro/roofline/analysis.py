"""Three-term roofline analysis from compiled dry-run artifacts.

    compute    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory     = HLO_bytes / HBM_bw               (per chip)
    collective = collective_bytes / link_bw       (per chip)

Sources:
  * ``compiled.cost_analysis()`` → flops & bytes. The compiled module is
    the per-device SPMD program, so these are already per-chip quantities
    (verified empirically in tests/test_roofline.py: partitioning a matmul
    over n devices divides reported flops by ~n).
  * collective bytes are parsed from the optimized HLO text: we sum the
    result-shape bytes of every all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute (start/done fusions included once).
    Ring-algorithm factors: all-reduce moves ≈2× its shard bytes over the
    slowest link; all-gather/reduce-scatter ≈1× their result/operand
    bytes; permute/all-to-all ≈1×.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s
per ICI link.
"""

from __future__ import annotations

import dataclasses
import re

__all__ = [
    "HW_V5E",
    "collective_bytes",
    "roofline_terms",
    "model_flops",
    "RooflineReport",
    "xla_cost_dict",
]


def xla_cost_dict(cost) -> dict:
    """Normalize ``compiled.cost_analysis()`` output to a flat dict.

    XLA's API has drifted: older jax returns a single properties dict,
    newer jax returns a per-program list of dicts (usually length 1).
    Accepts either form — or the Compiled object itself — and merges
    numeric entries by summation so multi-program modules stay additive.
    """
    if hasattr(cost, "cost_analysis"):
        cost = cost.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, dict):
        return dict(cost)
    if isinstance(cost, (list, tuple)):
        out: dict = {}
        for d in cost:
            for k, v in (d or {}).items():
                if isinstance(v, (int, float)) and isinstance(out.get(k), (int, float)):
                    out[k] += v
                else:
                    out[k] = v
        return out
    raise TypeError(f"unrecognized cost_analysis payload: {type(cost)!r}")

HW_V5E = {
    "peak_flops": 197e12,  # bf16
    "hbm_bw": 819e9,
    "link_bw": 50e9,  # intra-pod ICI
    "dcn_bw": 25e9,  # cross-pod per-chip share (data-center network)
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = {
    "all-reduce": 2.0,  # ring: reduce-scatter + all-gather passes
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# `%name = TYPE op-name(` — TYPE may be a tuple. -start variants only (the
# -done op repeats the same transfer); plain ops counted directly.
_OP_RE = re.compile(
    r"=\s+(\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Weighted per-device collective bytes by op kind (+ 'total')."""
    seen_done = set()
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    raw: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        type_str, op, _start = m.group(1), m.group(2), m.group(3)
        b = _shape_bytes(type_str)
        out[op] += b * _COLLECTIVES[op]
        raw[op] += b
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["raw_total"] = sum(raw[k] for k in _COLLECTIVES)
    return out


def model_flops(cfg, spec, tau: int = 1) -> float:
    """MODEL_FLOPS = 6·N_active·D for training, 2·N_active·D for inference
    (D = tokens processed). Per the assignment, N is *active* params."""
    n = cfg.active_params()
    if spec.kind == "train":
        tokens = spec.batch * spec.seq * tau
        return 6.0 * n * tokens
    if spec.kind == "prefill":
        return 2.0 * n * spec.batch * spec.seq
    return 2.0 * n * spec.batch  # decode: 1 token per sequence


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float  # per chip
    hlo_bytes: float  # per chip
    coll_bytes: float  # per chip (weighted)
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_total: float
    useful_flops_ratio: float  # MODEL_FLOPS / (HLO_FLOPs × chips)
    coll_by_kind: dict = dataclasses.field(default_factory=dict)

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(
    *, arch: str, shape: str, mesh_name: str, n_chips: int,
    cost: dict, hlo_text: str, model_flops_total: float, hw: dict = HW_V5E,
) -> RooflineReport:
    # Trip-count-aware HLO cost model (XLA's cost_analysis counts loop
    # bodies once — see hlo_cost.py; raw numbers kept in `cost` upstream).
    from .hlo_cost import module_cost

    boundary = 256 if mesh_name == "multi" else 0
    mc = module_cost(hlo_text, pod_boundary=boundary)
    flops = mc.flops
    bytes_ = mc.bytes
    coll = dict(mc.coll)
    coll["total"] = mc.coll_total
    coll["raw_total"] = mc.coll_total
    coll["cross_pod"] = mc.coll_cross
    compute_s = flops / hw["peak_flops"]
    memory_s = bytes_ / hw["hbm_bw"]
    # intra-pod traffic on ICI; cross-pod (groups spanning the 256-chip
    # boundary) on the slower DCN — ADSP's commit all-reduce lives there.
    intra = coll["total"] - mc.coll_cross
    collective_s = intra / hw["link_bw"] + mc.coll_cross / hw["dcn_bw"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    ratio = model_flops_total / (flops * n_chips) if flops else 0.0
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_chips=n_chips,
        hlo_flops=flops, hlo_bytes=bytes_, coll_bytes=coll["total"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops_total=model_flops_total,
        useful_flops_ratio=ratio, coll_by_kind=coll,
    )
