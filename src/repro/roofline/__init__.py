from .analysis import (
    HW_V5E,
    collective_bytes,
    roofline_terms,
    model_flops,
    RooflineReport,
    xla_cost_dict,
)

__all__ = [
    "HW_V5E",
    "collective_bytes",
    "roofline_terms",
    "model_flops",
    "RooflineReport",
    "xla_cost_dict",
]
