"""Trip-count-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE (verified in
tests/test_roofline.py: a 10-step lax.scan of a matmul reports 10× fewer
flops than its unrolled twin). Every production-relevant program here is
scan-over-layers × scan-over-τ, so the built-in numbers are off by one to
two orders of magnitude. This module re-derives per-device cost from the
compiled module text with loop multipliers applied:

  * computations are parsed into op lists with a per-computation symbol
    table (operand shapes resolve by name — optimized CPU HLO does not
    print operand types inline);
  * call sites (while/call/fusion/conditional) recurse with a multiplier:
    while trip count = the integer constant in the loop-condition
    computation (scan lowers to `compare(iv, constant(N)), direction=LT`);
  * FLOPs: dot = 2·|out|·|contracting dims|; reduce/elementwise = |shape|;
  * HBM bytes: per top-level op (a fusion counts once: its operands +
    result; fusion internals contribute flops only): Σ operand bytes +
    result bytes. Parameters/constants/tuple/GTE/bitcast are free; `copy`
    counts (it moves memory);
  * collectives: result bytes × ring factor (all-reduce 2×, others 1×),
    times the enclosing loop multipliers.

Approximate by construction, but *consistent* across baseline and
optimized variants — which is what the §Perf iteration compares.
Cross-validated against XLA's own numbers on loop-free programs.
"""

from __future__ import annotations

import dataclasses
import re

__all__ = ["parse_module", "module_cost", "Cost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_CALL_ATTR = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_ATTR = re.compile(r"condition=%?([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_OPERAND_NAME = re.compile(r"%([\w\.\-]+)")
_RG_EXPLICIT = re.compile(r"replica_groups=\{\{([0-9,{} ]*)\}\}")
_RG_IOTA = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")

_COLL_FACTOR = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}
_COLL_OPS = set(_COLL_FACTOR) | {k + "-start" for k in _COLL_FACTOR}

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator",
    "opt-barrier",
}


def _shapes_in(type_str: str):
    return [
        (dt, [int(d) for d in dims.split(",")] if dims else [])
        for dt, dims in _SHAPE_RE.findall(type_str)
    ]


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shapes_in(type_str):
        if dt in _DTYPE_BYTES:
            n = 1
            for d in dims:
                n *= d
            total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> int:
    total = 0
    for _dt, dims in _shapes_in(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    args: str  # raw text inside the top-level parens
    attrs: str  # text after the closing paren


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)
    coll_cross: float = 0.0  # collective bytes whose groups span pods

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k,
                    {kk: v * k for kk, v in self.coll.items()},
                    self.coll_cross * k)

    def add(self, other: "Cost") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        self.coll_cross += other.coll_cross
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


def _split_op(line: str):
    m = _DEF_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    # result type: either a (possibly /*index=N*/-commented) tuple, or a
    # single shape like f32[2,64]{1,0} — scan with bracket matching.
    if i < len(line) and line[i] == "(":
        depth = 0
        k = i
        while k < len(line):
            if line[k] == "(":
                depth += 1
            elif line[k] == ")":
                depth -= 1
                if depth == 0:
                    k += 1
                    break
            k += 1
        rtype = line[i:k]
    else:
        ms = re.match(r"[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?", line[i:])
        if not ms:
            return None
        rtype = ms.group(0)
        k = i + ms.end()
    mo = _OPCODE_RE.match(line[k:])
    if not mo:
        return None
    opcode = mo.group(1)
    j = k + mo.end()  # just past the '('
    depth = 1
    p = j
    while p < len(line) and depth:
        if line[p] == "(":
            depth += 1
        elif line[p] == ")":
            depth -= 1
        p += 1
    return Op(name, rtype, opcode, line[j : p - 1], line[p:])


def parse_module(hlo: str) -> dict[str, list[Op]]:
    comps: dict[str, list[Op]] = {}
    cur: list[Op] | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            # computation header: `%name (args...) -> type {` — op lines
            # always have `= ` straight after the name instead. Parameter
            # tuples may contain /*index=N*/ comments, so don't test for '='.
            if s.endswith("{") and "->" in s:
                m = re.match(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(", s)
                if m:
                    cur = comps.setdefault(m.group(1), [])
            continue
        if s == "}":
            cur = None
            continue
        op = _split_op(line)
        if op:
            cur.append(op)
    return comps


def _group_crosses_boundary(attrs: str, boundary: int) -> bool:
    """True if any replica group mixes device ids below/above `boundary`
    (pod edge). Handles explicit {{...}} and iota [G,N]<=[dims]T(perm)."""
    m = _RG_IOTA.search(attrs)
    if m:
        import numpy as _np

        g, n = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = _np.arange(int(_np.prod(dims))).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(x) for x in m.group(4).split(",")])
        ids = ids.reshape(g, n)
        lo = ids < boundary
        return bool(_np.any(lo.any(axis=1) & (~lo).any(axis=1)))
    m = _RG_EXPLICIT.search(attrs)
    if m:
        for grp in m.group(1).split("},{"):
            vals = [int(x) for x in grp.replace("{", "").replace("}", "").split(",") if x.strip()]
            if vals and any(v < boundary for v in vals) and any(v >= boundary for v in vals):
                return True
    return False


def _trip_count(cond_ops: list[Op]) -> int:
    best = 1
    for op in cond_ops:
        for m in _CONST_INT.finditer(op.args + op.attrs):
            best = max(best, int(m.group(1)))
        if op.opcode == "constant":
            mm = re.search(r"constant\((\d+)\)", f"constant({op.args})")
            if mm:
                best = max(best, int(mm.group(1)))
    return best


def module_cost(hlo: str, entry: str | None = None, pod_boundary: int = 0) -> Cost:
    comps = parse_module(hlo)
    if not comps:
        return Cost()
    symtab = {op.name: op.result_type for ops in comps.values() for op in ops}

    if entry is None:
        called = set()
        for ops in comps.values():
            for op in ops:
                for m in _CALL_ATTR.finditer(op.attrs):
                    called.add(m.group(1))
                for m in _COND_ATTR.finditer(op.attrs):
                    called.add(m.group(1))
        roots = [c for c in comps if c not in called]
        entry = max(roots or list(comps), key=lambda c: len(comps[c]))

    def operand_bytes(op: Op) -> float:
        total = 0.0
        for m in _OPERAND_NAME.finditer(op.args):
            t = symtab.get(m.group(1))
            if t:
                total += _type_bytes(t)
        return total

    _SLICE_OPS = ("dynamic-slice", "slice", "gather")

    def fusion_bytes(op: Op) -> float:
        """HBM traffic of a fusion: per fused-computation parameter, charge
        the slice actually read when every consumer is a slicing op (XLA
        fuses dynamic-slice into consumers — billing the whole stacked
        tensor would overcount a scan body by the layer count); otherwise
        the full parameter. Interior intermediates stay in registers.
        Root dynamic-update-slice aliases its buffer: charge the region."""
        m = _CALL_ATTR.search(op.attrs)
        if not m:
            return operand_bytes(op) + _type_bytes(op.result_type)
        inner_ops = comps.get(m.group(1), [])
        consumers: dict[str, list[Op]] = {}
        for iop in inner_ops:
            for mm in _OPERAND_NAME.finditer(iop.args):
                consumers.setdefault(mm.group(1), []).append(iop)
        root = inner_ops[-1] if inner_ops else None
        root_is_dus = root is not None and root.opcode in ("dynamic-update-slice", "scatter")
        total = 0.0
        for iop in inner_ops:
            if iop.opcode != "parameter":
                continue
            cons = consumers.get(iop.name, [])
            # the in-place destination of a root dynamic-update-slice is
            # aliased — no read/write of the untouched region. Identify it
            # as a parameter only consumed by the root whose size matches
            # the fusion result (the buffer passed through).
            if (root_is_dus and all(c is root or c.opcode == "bitcast" for c in cons)
                    and _type_bytes(iop.result_type) == _type_bytes(op.result_type)):
                continue
            if cons and all(c.opcode in _SLICE_OPS for c in cons):
                total += sum(_type_bytes(c.result_type) for c in cons)
            else:
                total += _type_bytes(iop.result_type)
        if root_is_dus:
            names = _OPERAND_NAME.findall(root.args)
            upd = _type_bytes(symtab.get(names[1], "")) if len(names) > 1 else 0
            total += 3.0 * upd  # read update; read+write destination region
        else:
            total += _type_bytes(op.result_type)
        return total

    def op_bytes(op: Op) -> float:
        oc = op.opcode
        if oc in _FREE_OPS:
            return 0.0
        r = _type_bytes(op.result_type)
        # Slicing ops touch only the slice, not the whole operand — charging
        # full operands would bill a scan body for the entire stacked-params
        # tensor on every iteration.
        if oc in ("dynamic-slice", "slice", "gather"):
            return 2.0 * r  # read slice + write result
        if oc in ("dynamic-update-slice", "scatter"):
            # read+write the updated region (operand 1); the untouched rest
            # of the buffer is aliased in place by XLA.
            names = _OPERAND_NAME.findall(op.args)
            upd = _type_bytes(symtab.get(names[1], "")) if len(names) > 1 else r
            return 3.0 * upd  # read update, read+write region
        return operand_bytes(op) + r

    def dot_flops(op: Op) -> float:
        out = _type_elems(op.result_type)
        m = _CONTRACT_RE.search(op.attrs)
        first = _OPERAND_NAME.search(op.args)
        lhs_t = symtab.get(first.group(1)) if first else None
        if not m or not lhs_t:
            return 2.0 * out
        shapes = _shapes_in(lhs_t)
        if not shapes:
            return 2.0 * out
        _, lhs_dims = shapes[0]
        contract = 1
        for idx in (int(x) for x in m.group(1).split(",") if x):
            if idx < len(lhs_dims):
                contract *= lhs_dims[idx]
        return 2.0 * out * contract

    memo: dict[str, Cost] = {}

    def comp_cost(name: str, flops_only: bool = False) -> Cost:
        key = name + ("|f" if flops_only else "")
        if key in memo:
            return memo[key]
        memo[key] = Cost()  # cycle guard
        total = Cost()
        for op in comps.get(name, []):
            oc = op.opcode
            if oc == "while":
                body = _CALL_ATTR.search(op.attrs)
                cond = _COND_ATTR.search(op.attrs)
                trips = _trip_count(comps.get(cond.group(1), [])) if cond else 1
                if body:
                    total.add(comp_cost(body.group(1), flops_only).scaled(trips))
                continue
            if oc in ("call", "conditional", "async-start"):
                for m in _CALL_ATTR.finditer(op.attrs):
                    total.add(comp_cost(m.group(1), flops_only))
                continue
            if oc == "fusion":
                m = _CALL_ATTR.search(op.attrs)
                if m:
                    inner = comp_cost(m.group(1), flops_only=True)
                    total.add(Cost(inner.flops, 0.0, dict(inner.coll)))
                if not flops_only:
                    total.add(Cost(0.0, fusion_bytes(op), {}))
                continue
            if oc in _COLL_OPS:
                base = oc.removesuffix("-start")
                b = _type_bytes(op.result_type) * _COLL_FACTOR[base]
                cross = b if (
                    pod_boundary and _group_crosses_boundary(op.attrs, pod_boundary)
                ) else 0.0
                total.add(Cost(0.0, 0.0 if flops_only else op_bytes(op),
                               {base: b}, cross))
                continue
            if oc == "dot":
                total.add(Cost(dot_flops(op), 0.0 if flops_only else op_bytes(op), {}))
            elif oc == "convolution":
                total.add(Cost(2.0 * _type_elems(op.result_type) * 32,
                               0.0 if flops_only else op_bytes(op), {}))
            elif oc in _FREE_OPS:
                continue
            else:
                total.add(Cost(float(_type_elems(op.result_type)),
                               0.0 if flops_only else op_bytes(op), {}))
        memo[key] = total
        return total

    return comp_cost(entry)
