"""Pluggable update-rule API for the ADSP data plane (DESIGN.md §9).

Public surface:

  * ``make_train_step`` — one factory for every granularity
    (accum/data/pod) and rule backend (reference / Pallas-fused);
  * ``LocalRule`` / ``CommitRule`` + the registry
    (``get_local_rule``/``get_commit_rule``/``register_*``);
  * ``UpdateRules`` — the (local, commit, backend) bundle callers pass;
  * ``CommitConfig`` / ``AdspState`` / ``effective_momentum`` — commit
    behaviour and rule-owned training state;
  * ``ShardPlan`` — the deterministic leaf→shard partition behind the
    sharded PS (DESIGN.md §11): per-shard commit apply in the train
    step, per-shard versions on ``AdspState``, pipelined per-shard
    push/pull in the edge simulator.
"""

from .cli import add_rule_args, add_shard_args, rules_from_args
from .sharding import ShardPlan
from .rules import (
    CommitRule,
    LocalRule,
    UpdateRules,
    commit_rule_names,
    get_commit_rule,
    get_local_rule,
    local_rule_names,
    register_commit_rule,
    register_local_rule,
    resolve_backend,
    rule_backends,
)
from .state import AdspState, CommitConfig, effective_momentum
from .train_step import (
    make_local_update,
    make_sharded_apply,
    make_train_step,
    worker_axes_for,
)

# importing these registers the built-in rules
from . import commit_rules as _commit_rules  # noqa: F401
from . import fused_codec as _fused_codec  # noqa: F401
from . import local as _local  # noqa: F401

__all__ = [
    "AdspState",
    "CommitConfig",
    "ShardPlan",
    "add_rule_args",
    "add_shard_args",
    "rules_from_args",
    "CommitRule",
    "LocalRule",
    "UpdateRules",
    "commit_rule_names",
    "effective_momentum",
    "get_commit_rule",
    "get_local_rule",
    "local_rule_names",
    "make_local_update",
    "make_sharded_apply",
    "make_train_step",
    "register_commit_rule",
    "register_local_rule",
    "resolve_backend",
    "rule_backends",
    "worker_axes_for",
]
