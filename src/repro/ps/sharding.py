"""Shard-partitioned parameter server: the ``ShardPlan`` (DESIGN.md §11).

The monolithic PS of the seed moves the whole model on every commit:
push encodes the full update, pull ships the full dense parameter set,
so transfer cost scales with model size regardless of how little of the
model a peer actually needs refreshed. Production PS designs shard the
parameter space so (a) a commit's per-shard payloads pipeline over the
worker's link — the PS applies shard j while shard j+1 is still in
flight — and (b) pulls become *partial*: a worker refreshes only shards
whose PS version exceeds the version its local copy reflects.

``ShardPlan`` is the one source of truth for that partition: a
deterministic, size-balanced assignment of the model pytree's leaves to
K shards. Leaves are the atom (a single giant embedding cannot be
split), assignment is greedy best-fit by descending byte size with the
leaf key-path as the tie-breaker — a pure function of the tree's
shapes/dtypes/structure, so every layer (train step, simulator, mesh
backend, benchmarks) independently derives the identical plan, and
abstract ``ShapeDtypeStruct`` trees work as well as concrete ones.

K = 1 degenerates to the monolithic PS: one shard holding every leaf,
used by callers to keep the unsharded code paths bit-identical.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Sequence

import jax
import numpy as np

__all__ = ["ShardPlan"]

Pytree = Any


def _leaf_nbytes(leaf) -> int:
    shape = getattr(leaf, "shape", ())
    dtype = getattr(leaf, "dtype", np.dtype(np.float32))
    return int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Deterministic leaf→shard partition of one model pytree.

    Attributes:
      n_shards: number of shards K (≥ 1; clamped to the leaf count at
        build time — an empty shard would be a zero-byte no-op message).
      assignment: shard id per leaf, in pytree-flatten (tree) order.
      leaf_nbytes: dense byte size per leaf, same order.

    Slicing/merging preserve tree order within a shard, so a K-sharded
    apply of any leaf-wise rule reproduces the unsharded apply bit for
    bit — sharding reorganizes transport, never numerics.
    """

    n_shards: int
    assignment: tuple[int, ...]
    leaf_nbytes: tuple[int, ...]

    @classmethod
    def build(cls, tree: Pytree, n_shards: int) -> "ShardPlan":
        """Partition ``tree``'s leaves into ``n_shards`` size-balanced
        shards. Deterministic: greedy best-fit over leaves sorted by
        (−nbytes, key path); ties in bin load go to the lowest shard id.
        ``tree`` may be abstract (ShapeDtypeStructs)."""
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        if not flat:
            raise ValueError("cannot build a ShardPlan over an empty pytree")
        paths = [jax.tree_util.keystr(kp) for kp, _ in flat]
        nbytes = [_leaf_nbytes(leaf) for _, leaf in flat]
        k = min(n_shards, len(flat))
        order = sorted(range(len(flat)), key=lambda i: (-nbytes[i], paths[i]))
        # greedy best-fit: each leaf goes to the currently lightest bin
        bins = [(0, s) for s in range(k)]  # (load, shard id) min-heap
        heapq.heapify(bins)
        assignment = [0] * len(flat)
        for i in order:
            load, s = heapq.heappop(bins)
            assignment[i] = s
            heapq.heappush(bins, (load + nbytes[i], s))
        return cls(n_shards=k, assignment=tuple(assignment),
                   leaf_nbytes=tuple(nbytes))

    # ------------------------------------------------------------- derived
    @property
    def n_leaves(self) -> int:
        return len(self.assignment)

    def shard_leaf_indices(self, shard: int) -> tuple[int, ...]:
        """Leaf positions (tree order) belonging to ``shard``."""
        self._check_shard(shard)
        return tuple(i for i, s in enumerate(self.assignment) if s == shard)

    def shard_nbytes(self) -> tuple[int, ...]:
        """Dense bytes per shard (the pull payload sizes)."""
        out = [0] * self.n_shards
        for s, nb in zip(self.assignment, self.leaf_nbytes):
            out[s] += nb
        return tuple(out)

    def _check_shard(self, shard: int) -> None:
        if not 0 <= shard < self.n_shards:
            raise IndexError(f"shard {shard} out of range [0, {self.n_shards})")

    def _check_tree(self, leaves: Sequence) -> None:
        if len(leaves) != self.n_leaves:
            raise ValueError(
                f"tree has {len(leaves)} leaves but the plan was built over "
                f"{self.n_leaves}; rebuild the ShardPlan for this tree"
            )

    # ------------------------------------------------------- slice / merge
    def slice(self, tree: Pytree, shard: int) -> list:
        """The sub-pytree of ``tree`` belonging to ``shard``: its leaves
        in tree order, as a list (lists are pytrees, so leaf-wise rules
        and codecs consume slices directly)."""
        self._check_shard(shard)
        leaves = jax.tree.leaves(tree)
        self._check_tree(leaves)
        return [leaves[i] for i in self.shard_leaf_indices(shard)]

    def merge(self, tree: Pytree, shard: int, new_leaves: Sequence) -> Pytree:
        """``tree`` with ``shard``'s leaves replaced by ``new_leaves``
        (tree order, as produced by ``slice``)."""
        self._check_shard(shard)
        leaves, treedef = jax.tree.flatten(tree)
        self._check_tree(leaves)
        idx = self.shard_leaf_indices(shard)
        if len(new_leaves) != len(idx):
            raise ValueError(
                f"shard {shard} holds {len(idx)} leaves, got {len(new_leaves)}"
            )
        for i, leaf in zip(idx, new_leaves):
            leaves[i] = leaf
        return jax.tree.unflatten(treedef, leaves)
