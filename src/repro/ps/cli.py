"""Shared argparse plumbing for rule selection — one definition of the
``--local-rule``/``--commit-rule``/``--rule-backend``/``--local-opt-lr``
flags for every entry point (``repro.launch.train``, examples), so new
rules or hyperparameters land everywhere at once. ``add_shard_args``
adds the PS-sharding knob (``--ps-shards``, DESIGN.md §11) the same way."""

from __future__ import annotations

import argparse

from .rules import UpdateRules

__all__ = ["add_rule_args", "rules_from_args", "add_shard_args"]


def add_rule_args(parser: argparse.ArgumentParser) -> None:
    parser.add_argument("--local-rule", default="sgd",
                        help="worker optimizer: sgd | sgd_momentum | adamw")
    parser.add_argument("--commit-rule", default="momentum_delta",
                        help="PS apply: momentum_delta | plain_average")
    parser.add_argument("--rule-backend", default=None,
                        help="reference | fused | auto (fused on TPU)")
    parser.add_argument("--local-opt-lr", type=float, default=None,
                        help="local-rule lr override (adamw defaults to 3e-4)")


def add_shard_args(parser: argparse.ArgumentParser) -> None:
    parser.add_argument("--ps-shards", type=int, default=1,
                        help="parameter-server shards K (1 = monolithic PS, "
                             "bit-identical to the unsharded stack; K>1 "
                             "pipelines per-shard push/pull)")


def rules_from_args(args: argparse.Namespace) -> UpdateRules:
    return UpdateRules(
        local=args.local_rule,
        commit=args.commit_rule,
        backend=args.rule_backend,
        local_hp={} if args.local_opt_lr is None else {"lr": args.local_opt_lr},
    )
