"""Commit configuration and the training state carried across commits.

``CommitConfig`` is the ADSP commit behaviour knob set.

``AdspState`` generalizes the seed's (params, prev_delta, step) triple:
optimizer state is *rule-owned* —

  * ``commit_state``: owned by the CommitRule. For the paper's
    momentum-delta rule (Eqn. 1) this is the previous global delta
    W_t − W_{t−1}; for plain averaging it is empty. This subsumes the
    ``optim.SGDState.prev_delta`` buffer the seed duplicated.
  * ``local_state``: owned by the LocalRule, one slot per ADSP worker
    (leading dim ``n_workers``, sharded over the worker axes by the
    train step so each worker's adaptive-optimizer moments survive
    across commit rounds). Stateless rules (plain sgd) carry ``()``.
  * ``transport_state``: owned by the transport Codec
    (``repro.transport``), one slot per worker like ``local_state`` —
    the error-feedback residual of lossy commit codecs. The identity
    codec (and ``codec=None``) carries ``()``.

``state.prev_delta`` is kept as a read-only alias of ``commit_state``
for the momentum-delta rule's users.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.control import theory

__all__ = ["CommitConfig", "AdspState", "effective_momentum"]

Pytree = Any


@dataclasses.dataclass(frozen=True)
class CommitConfig:
    """ADSP commit behaviour for the cluster runtime.

    tau: max local microsteps between commits (the fastest worker's τ).
    local_lr: η′ applied at each local microstep (sgd-family rules).
    global_lr: η applied by the PS-equivalent all-reduce commit.
    momentum: target total momentum; if correct_implicit_momentum, the
      explicit part is reduced by μ_implicit from Eqn. (3).
    gamma / c_target: check-period and commit-count target used to derive
      μ_implicit (and, in the trainer, per-worker τ_i).
    worker_axes: mesh axes enumerating workers (manual in shard_map).
    """

    tau: int = 4
    local_lr: float = 0.05
    global_lr: float = 1.0
    # PS sharding (DESIGN.md §11): the model pytree is partitioned into
    # n_shards size-balanced shards (repro.ps.sharding.ShardPlan) with
    # per-shard commit apply and per-shard version counters. 1 = the
    # monolithic PS, bit-identical to the pre-sharding stack.
    n_shards: int = 1
    # dtype of the commit all-reduce. f32 default: numerically safer for
    # accumulated updates, and XLA:CPU's AllReducePromotion pass crashes on
    # bf16 all-reduce (dry-run container). 'bfloat16' halves the collective
    # bytes — a measured hillclimb option for real TPU runs.
    commit_dtype: str = "float32"
    momentum: float = 0.9
    correct_implicit_momentum: bool = True
    gamma: float = 60.0
    c_target: int = 1
    worker_axes: tuple[str, ...] = ("data",)

    def __post_init__(self):
        if self.tau < 1:
            raise ValueError("tau must be >= 1")
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")


def effective_momentum(
    cfg: CommitConfig, speeds: Sequence[float], delta_c: Sequence[float]
) -> float:
    """Explicit momentum to apply at the PS so that explicit + implicit ≈
    cfg.momentum (Fig. 3: best total momentum ⇒ fastest convergence)."""
    if not cfg.correct_implicit_momentum:
        return cfg.momentum
    mu_imp = theory.mu_implicit(delta_c, speeds, cfg.gamma)
    return max(0.0, cfg.momentum - mu_imp)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdspState:
    """Training state carried across commits (see module docstring)."""

    params: Pytree
    commit_state: Pytree
    local_state: Pytree
    step: jax.Array  # global commit counter
    transport_state: Pytree = ()  # codec error-feedback residual per worker
    # per-shard PS version counters (int32[n_shards]); () when the PS is
    # monolithic (n_shards == 1) so unsharded state trees stay identical
    # to the pre-sharding stack (checkpoints, shardings, bit-parity).
    shard_versions: Pytree = ()

    @property
    def prev_delta(self) -> Pytree:
        """Legacy alias: the momentum-delta CommitRule's state is the
        previous global delta."""
        return self.commit_state

    @classmethod
    def create(cls, params: Pytree, rules=None, *, n_workers: int = 1,
               codec=None, n_shards: int = 1) -> "AdspState":
        """``rules`` is a resolved (LocalRule, CommitRule) pair (e.g.
        ``UpdateRules(...).resolve(ccfg)`` or ``make_train_step(...).rules``).
        None keeps the seed default: momentum-delta commit state (zeros)
        and a stateless local rule. ``codec`` is a resolved
        ``repro.transport.Codec`` (or None); its residual gets one slot
        per worker, like ``local_state``. ``n_shards`` > 1 adds the
        per-shard PS version counters (zeros)."""

        def per_worker(tree: Pytree) -> Pytree:
            return jax.tree.map(
                lambda x: jnp.repeat(x[None], n_workers, axis=0), tree
            )

        if rules is None:
            commit_state: Pytree = jax.tree.map(jnp.zeros_like, params)
            local_state: Pytree = ()
        else:
            local_rule, commit_rule = rules
            commit_state = commit_rule.init(params)
            local_state = per_worker(local_rule.init(params))
        transport_state: Pytree = () if codec is None else per_worker(codec.init(params))
        shard_versions: Pytree = (
            jnp.zeros((n_shards,), jnp.int32) if n_shards > 1 else ()
        )
        return cls(params=params, commit_state=commit_state,
                   local_state=local_state, step=jnp.zeros((), jnp.int32),
                   transport_state=transport_state,
                   shard_versions=shard_versions)
