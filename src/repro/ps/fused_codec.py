"""Codec-consuming CommitRules: the fused decode+apply commit path
(DESIGN.md §16).

The classic commit chain runs PS-side decode and commit apply as two
separate passes over every leaf (``codec.decode`` then
``CommitRule.apply``). For the elementwise codecs (int8, bf16) the
decode is itself elementwise, so the two passes fuse into one HBM trip:
these rules take the *encoded payload* straight from ``codec.encode``
and produce the committed params in a single pass per leaf
(``kernels.fused_codec_commit`` via ``kernels.ops``).

Registered under combined names ``<commit_rule>@<codec>`` — e.g.
``momentum_delta@int8`` — with the usual reference/fused backend pair:
the reference backend IS the unfused decode → apply chain (same jnp
expressions, same casts), which is the bit-for-bit contract the fused
kernels are parity-tested against per codec and shard count
(tests/test_update_rules.py, tests/test_sharding.py).

``make_train_step(fused_commit=True)`` resolves these by name when the
step's codec supports them; ``top_k`` (gather/scatter decode) and
``identity`` (nothing to fuse) fall back to the chain path.

Payload trees are not params-shaped (an int8 leaf is a ``{"q","scale"}``
dict), so each rule carries its ``is_payload`` predicate — how
``make_sharded_apply`` slices payloads leaf-aligned with the params.
The predicate is redefined here rather than imported from
``repro.transport`` (transport imports ``repro.ps.rules``; the package
layering is ps ← transport).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .rules import CommitRule, register_commit_rule

__all__ = ["FUSABLE_CODECS", "fused_commit_name"]

# codecs whose decode is elementwise and therefore fusable with the apply
FUSABLE_CODECS = ("int8", "bf16")


def fused_commit_name(commit_rule_name: str, codec_name: str) -> str:
    """The combined registry name of the fused decode+apply rule."""
    return f"{commit_rule_name}@{codec_name}"


def _is_int8_payload(x):
    return isinstance(x, dict) and set(x) == {"q", "scale"}


def _zip3(params, cstate, enc, is_payload):
    """(leaves, treedef) zip of params/commit-state/payload trees; the
    payload tree flattens under ``is_payload`` so its leaf order aligns
    with the params leaves."""
    p_leaves, treedef = jax.tree.flatten(params)
    c_leaves = jax.tree.leaves(cstate)
    e_leaves, _ = jax.tree_util.tree_flatten(enc, is_leaf=is_payload)
    return p_leaves, c_leaves, e_leaves, treedef


# ---------------------------------------------------------------------------
# momentum_delta @ codec  (Eqn. 1 PS with the decode folded in)
# ---------------------------------------------------------------------------

def _make_momentum_delta(name, backend, dec_apply, is_payload) -> CommitRule:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def apply(params, cstate, enc, momentum):
        p_leaves, c_leaves, e_leaves, treedef = _zip3(
            params, cstate, enc, is_payload)
        new_p, new_c = [], []
        for w, d, p in zip(p_leaves, c_leaves, e_leaves):
            nw, nd = dec_apply(w, d, p, momentum)
            new_p.append(nw)
            new_c.append(nd)
        return treedef.unflatten(new_p), treedef.unflatten(new_c)

    return CommitRule(name, backend, init, apply, is_payload=is_payload)


@register_commit_rule("momentum_delta@int8", "reference")
def _md_int8_reference(ccfg, *, interpret=None) -> CommitRule:
    def dec_apply(w, d, p, momentum):
        # the exact unfused chain: dequantize → cast like the params →
        # Eqn. 1 apply (δ ← μ·δ − η·u ; W ← W + δ), same casts throughout
        u = (p["q"].astype(jnp.float32) * p["scale"]).astype(w.dtype)
        delta = (momentum * d - ccfg.global_lr * u).astype(d.dtype)
        return w + delta, delta

    return _make_momentum_delta("momentum_delta@int8", "reference",
                                dec_apply, _is_int8_payload)


@register_commit_rule("momentum_delta@int8", "fused")
def _md_int8_fused(ccfg, *, interpret=None) -> CommitRule:
    def dec_apply(w, d, p, momentum):
        return ops.int8_decode_apply(w, d, p["q"], p["scale"],
                                     ccfg.global_lr, momentum,
                                     interpret=interpret)

    return _make_momentum_delta("momentum_delta@int8", "fused",
                                dec_apply, _is_int8_payload)


@register_commit_rule("momentum_delta@bf16", "reference")
def _md_bf16_reference(ccfg, *, interpret=None) -> CommitRule:
    def dec_apply(w, d, q, momentum):
        u = q.astype(jnp.float32).astype(w.dtype)
        delta = (momentum * d - ccfg.global_lr * u).astype(d.dtype)
        return w + delta, delta

    return _make_momentum_delta("momentum_delta@bf16", "reference",
                                dec_apply, None)


@register_commit_rule("momentum_delta@bf16", "fused")
def _md_bf16_fused(ccfg, *, interpret=None) -> CommitRule:
    def dec_apply(w, d, q, momentum):
        return ops.bf16_decode_apply(w, d, q, ccfg.global_lr, momentum,
                                     interpret=interpret)

    return _make_momentum_delta("momentum_delta@bf16", "fused",
                                dec_apply, None)


# ---------------------------------------------------------------------------
# plain_average @ codec  (stateless FedAvg-style pull with decode folded in)
# ---------------------------------------------------------------------------

def _make_plain_average(name, backend, dec_accum, is_payload) -> CommitRule:
    def init(params):
        return ()

    def apply(params, cstate, enc, momentum):
        del momentum  # stateless average has no PS momentum term
        p_leaves, _, e_leaves, treedef = _zip3(params, cstate, enc, is_payload)
        new_p = [dec_accum(w, p) for w, p in zip(p_leaves, e_leaves)]
        return treedef.unflatten(new_p), cstate

    return CommitRule(name, backend, init, apply, is_payload=is_payload)


@register_commit_rule("plain_average@int8", "reference")
def _pa_int8_reference(ccfg, *, interpret=None) -> CommitRule:
    def dec_accum(w, p):
        u = (p["q"].astype(jnp.float32) * p["scale"]).astype(w.dtype)
        return (w - ccfg.global_lr * u).astype(w.dtype)

    return _make_plain_average("plain_average@int8", "reference",
                               dec_accum, _is_int8_payload)


@register_commit_rule("plain_average@int8", "fused")
def _pa_int8_fused(ccfg, *, interpret=None) -> CommitRule:
    def dec_accum(w, p):
        return ops.int8_decode_accum(w, p["q"], p["scale"], ccfg.global_lr,
                                     interpret=interpret)

    return _make_plain_average("plain_average@int8", "fused",
                               dec_accum, _is_int8_payload)


@register_commit_rule("plain_average@bf16", "reference")
def _pa_bf16_reference(ccfg, *, interpret=None) -> CommitRule:
    def dec_accum(w, q):
        u = q.astype(jnp.float32).astype(w.dtype)
        return (w - ccfg.global_lr * u).astype(w.dtype)

    return _make_plain_average("plain_average@bf16", "reference",
                               dec_accum, None)


@register_commit_rule("plain_average@bf16", "fused")
def _pa_bf16_fused(ccfg, *, interpret=None) -> CommitRule:
    def dec_accum(w, q):
        return ops.bf16_decode_accum(w, q, ccfg.global_lr,
                                     interpret=interpret)

    return _make_plain_average("plain_average@bf16", "fused",
                               dec_accum, None)
