"""Typed update-rule API for the ADSP data plane (DESIGN.md §9).

The paper's PS commit (Alg. 2, Eqn. 1) is optimizer-agnostic — workers
ship an accumulated parameter update U, not gradients — so the data plane
factors into two independently pluggable pieces:

  * ``LocalRule``   — the per-microstep worker optimizer (what each live
    microstep does to the worker's local params and to U);
  * ``CommitRule``  — the PS apply over the worker axes (how the
    pmean-ed U becomes the next global params).

Each (rule, backend) pair is registered here; ``backend`` is either
``"reference"`` (pure-JAX, the correctness contract) or ``"fused"``
(single-HBM-pass Pallas kernels from ``repro.kernels``, with automatic
interpret fallback off-TPU — see ``kernels.ops.default_interpret`` and
the ``REPRO_PALLAS_INTERPRET`` env override). ``resolve_backend`` maps
the default ``"auto"`` to fused on TPU and reference elsewhere, and a
fused request for a rule with no fused implementation falls back to its
reference implementation.

Contracts (all pytree-preserving, jit/shard_map-safe, dtype-stable so
they can sit in a ``lax.scan`` carry):

  LocalRule.init(params) -> local_state            (no worker dim)
  LocalRule.update(params, u, grads, state, live)
      -> (new_params, new_u, new_state)
    ``live`` is a float32 scalar in {0.0, 1.0}; masked (live=0) steps
    must leave params, U, and state unchanged (the τ_i rate-rule mask).

  CommitRule.init(params) -> commit_state
  CommitRule.apply(params, commit_state, u, momentum)
      -> (new_params, new_commit_state)
    ``u`` is the worker-mean accumulated update (already pmean-ed and
    cast to ``commit_dtype`` by the train step); ``momentum`` is the
    explicit PS momentum (post implicit-momentum correction).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

__all__ = [
    "LocalRule",
    "CommitRule",
    "UpdateRules",
    "register_local_rule",
    "register_commit_rule",
    "get_local_rule",
    "get_commit_rule",
    "local_rule_names",
    "commit_rule_names",
    "rule_backends",
    "resolve_backend",
]

Pytree = Any


@dataclasses.dataclass(frozen=True)
class LocalRule:
    """Per-microstep worker optimizer (see module docstring for the
    ``init``/``update`` contracts)."""

    name: str
    backend: str
    init: Callable[[Pytree], Pytree]
    update: Callable[..., tuple]


@dataclasses.dataclass(frozen=True)
class CommitRule:
    """PS apply over the worker axes (see module docstring for the
    ``init``/``apply`` contracts).

    ``is_payload`` marks codec-consuming rules (the fused decode+apply
    path, DESIGN.md §16): when set, ``apply``'s ``u`` is an *encoded*
    payload tree whose per-leaf atoms this predicate identifies (e.g.
    the int8 ``{"q", "scale"}`` dict). ``make_sharded_apply`` uses it to
    slice payload trees leaf-aligned with the params; None means ``u``
    is a dense params-shaped tree (every classic rule)."""

    name: str
    backend: str
    init: Callable[[Pytree], Pytree]
    apply: Callable[..., tuple]
    is_payload: Callable[[Any], bool] | None = None


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

_LOCAL: dict[tuple[str, str], Callable] = {}
_COMMIT: dict[tuple[str, str], Callable] = {}


def register_local_rule(name: str, backend: str = "reference"):
    """Decorator: register ``factory(ccfg, *, interpret=None, **hp) ->
    LocalRule`` under (name, backend)."""

    def deco(factory):
        _LOCAL[(name, backend)] = factory
        return factory

    return deco


def register_commit_rule(name: str, backend: str = "reference"):
    def deco(factory):
        _COMMIT[(name, backend)] = factory
        return factory

    return deco


def local_rule_names() -> tuple[str, ...]:
    return tuple(sorted({n for n, _ in _LOCAL}))


def commit_rule_names() -> tuple[str, ...]:
    return tuple(sorted({n for n, _ in _COMMIT}))


def rule_backends(kind: str, name: str) -> tuple[str, ...]:
    table = _LOCAL if kind == "local" else _COMMIT
    return tuple(sorted(b for n, b in table if n == name))


def resolve_backend(requested: str | None = None) -> str:
    """``"auto"``/None → ``"fused"`` when a TPU backend is present (the
    kernels compile natively there), ``"reference"`` elsewhere — CPU
    interpret-mode Pallas is a validation path, not a fast path, so it is
    opt-in via an explicit ``backend="fused"``."""
    if requested in ("reference", "fused"):
        return requested
    if requested not in (None, "auto"):
        raise ValueError(
            f"unknown rule backend {requested!r} (want 'reference', 'fused', 'auto')"
        )
    return "fused" if jax.default_backend() == "tpu" else "reference"


def _lookup(table: dict, kind: str, name: str, backend: str | None) -> Callable:
    want = resolve_backend(backend)
    factory = table.get((name, want))
    if factory is None and want == "fused":
        factory = table.get((name, "reference"))  # no fused impl: fall back
    if factory is None:
        known = sorted({n for n, _ in table})
        raise KeyError(f"no {kind} rule {name!r}; registered: {known}")
    return factory


def get_local_rule(name, ccfg, *, backend: str | None = None,
                   interpret: bool | None = None, **hp) -> LocalRule:
    """Instantiate a registered local rule. ``name`` may already be a
    LocalRule (passed through). Hyperparameters default from ``ccfg``
    (e.g. sgd's lr is ``ccfg.local_lr``); ``hp`` overrides."""
    if isinstance(name, LocalRule):
        return name
    return _lookup(_LOCAL, "local", name, backend)(ccfg, interpret=interpret, **hp)


def get_commit_rule(name, ccfg, *, backend: str | None = None,
                    interpret: bool | None = None, **hp) -> CommitRule:
    if isinstance(name, CommitRule):
        return name
    return _lookup(_COMMIT, "commit", name, backend)(ccfg, interpret=interpret, **hp)


# --------------------------------------------------------------------------
# the bundle make_train_step consumes
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class UpdateRules:
    """Names (or instances) of the local/commit rules plus backend policy.

    backend: 'reference' | 'fused' | None/'auto' (fused on TPU only).
    interpret: Pallas interpret override for fused kernels; None defers
      to the auto probe + REPRO_PALLAS_INTERPRET (kernels.ops).
    local_hp / commit_hp: extra hyperparameters forwarded to the rule
      factories (e.g. {'lr': 1e-3} for adamw).
    """

    local: str | LocalRule = "sgd"
    commit: str | CommitRule = "momentum_delta"
    backend: str | None = None
    interpret: bool | None = None
    local_hp: dict = dataclasses.field(default_factory=dict)
    commit_hp: dict = dataclasses.field(default_factory=dict)

    def resolve(self, ccfg) -> tuple[LocalRule, CommitRule]:
        local = get_local_rule(self.local, ccfg, backend=self.backend,
                               interpret=self.interpret, **self.local_hp)
        commit = get_commit_rule(self.commit, ccfg, backend=self.backend,
                                 interpret=self.interpret, **self.commit_hp)
        return local, commit


def mask_tree(live, new: Pytree, old: Pytree) -> Pytree:
    """Select ``new`` where the microstep is live, else keep ``old``,
    leaf-wise and dtype-preserving (works for int leaves like step
    counters). ``live`` is the scan's float32 {0,1} scalar."""
    on = live > 0
    return jax.tree.map(lambda n, o: jax.numpy.where(on, n, o), new, old)
