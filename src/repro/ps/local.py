"""Registered LocalRules: the per-microstep worker optimizers.

Adapted from ``repro.optim`` (the (init, update) optimizers the seed
used only in examples): here each optimizer is wrapped into the
LocalRule contract — masked by ``live`` so the τ_i rate-rule mask keeps
the SPMD program uniform, and accumulating into U the *negated* local
parameter delta, which is exactly what the PS commit consumes
(U ← U − ΔW_local; for plain sgd this is the paper's U ← U + η′·g).

Reference backends are the bit-for-bit contract with the seed factories;
the fused sgd backend routes both HBM passes (param advance + U
accumulation) through the Pallas ``accumulate`` kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.optim.adamw import adamw as _optim_adamw

from .rules import LocalRule, mask_tree, register_local_rule

__all__ = []  # rules are reached through the registry


# --------------------------------------------------------------------------
# sgd — the paper's worker-side rule (stateless)
# --------------------------------------------------------------------------

@register_local_rule("sgd", "reference")
def _sgd_reference(ccfg, *, interpret=None, lr=None) -> LocalRule:
    eta = ccfg.local_lr if lr is None else lr

    def init(params):
        return ()

    def update(params, u, grads, state, live):
        # exact seed arithmetic: p −= η′·live·g ; U += η′·live·g
        new_p = jax.tree.map(
            lambda a, g: (a - eta * live * g).astype(a.dtype), params, grads
        )
        new_u = jax.tree.map(
            lambda a, g: (a + eta * live * g).astype(a.dtype), u, grads
        )
        return new_p, new_u, state

    return LocalRule("sgd", "reference", init, update)


@register_local_rule("sgd", "fused")
def _sgd_fused(ccfg, *, interpret=None, lr=None) -> LocalRule:
    eta = ccfg.local_lr if lr is None else lr

    def init(params):
        return ()

    def update(params, u, grads, state, live):
        lr_live = eta * live
        new_p = ops.accumulate_tree(params, grads, -lr_live, interpret=interpret)
        new_u = ops.accumulate_tree(u, grads, lr_live, interpret=interpret)
        return new_p, new_u, state

    return LocalRule("sgd", "fused", init, update)


# --------------------------------------------------------------------------
# sgd_momentum — Eqn. 1 applied at the worker (heavy-ball local steps)
# --------------------------------------------------------------------------

@register_local_rule("sgd_momentum", "reference")
def _sgd_momentum_reference(ccfg, *, interpret=None, lr=None, momentum=0.9) -> LocalRule:
    eta = ccfg.local_lr if lr is None else lr

    def init(params):
        return {"prev_delta": jax.tree.map(jnp.zeros_like, params)}

    def update(params, u, grads, state, live):
        delta = jax.tree.map(
            lambda d, g: (momentum * d - eta * g).astype(d.dtype),
            state["prev_delta"], grads,
        )
        new_p = jax.tree.map(
            lambda a, d: (a + live * d).astype(a.dtype), params, delta
        )
        new_u = jax.tree.map(
            lambda a, d: (a - live * d).astype(a.dtype), u, delta
        )
        prev = mask_tree(live, delta, state["prev_delta"])
        return new_p, new_u, {"prev_delta": prev}

    return LocalRule("sgd_momentum", "reference", init, update)


# --------------------------------------------------------------------------
# adamw — adaptive optimizer at the worker; the commit still ships ΔW
# --------------------------------------------------------------------------

@register_local_rule("adamw", "reference")
def _adamw_reference(ccfg, *, interpret=None, lr=3e-4, b1=0.9, b2=0.95,
                     eps=1e-8, weight_decay=0.01) -> LocalRule:
    # lr deliberately does NOT default from ccfg.local_lr: sgd-scale rates
    # (0.05) diverge under Adam preconditioning.
    opt_init, opt_update = _optim_adamw(
        lr=lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay
    )

    def init(params):
        return opt_init(params)

    def update(params, u, grads, state, live):
        cand_p, cand_s = opt_update(grads, state, params)
        on = live > 0
        new_p = jax.tree.map(lambda p, n: jnp.where(on, n, p), params, cand_p)
        new_u = jax.tree.map(
            lambda a, p, n: (a + jnp.where(on, (p - n).astype(a.dtype),
                                           jnp.zeros((), a.dtype))).astype(a.dtype),
            u, params, cand_p,
        )
        new_s = mask_tree(live, cand_s, state)
        return new_p, new_u, new_s

    return LocalRule("adamw", "reference", init, update)
