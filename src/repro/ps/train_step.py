"""One train-step factory for every ADSP granularity and rule backend.

``make_train_step`` replaces the seed's twice-written local-update/commit
math (the seed's ``make_adsp_step`` + ``make_accum_step`` factories,
both now thin shims over this): one τ-masked microstep scan feeds one
CommitRule apply, with the worker axes deciding whether a shard_map +
pmean wraps it.

Mapping (DESIGN.md §3): one ADSP *worker* = one index along the mesh's
worker axes — a model-parallel group holding a full replica of the
parameters (sharded over ``model`` by GSPMD). Workers run ``tau_i``
local microsteps on their own microbatches with no cross-worker
collective (the no-waiting property), then all commit at once: the
accumulated updates are ``pmean``-ed over the worker axes and applied by
the CommitRule — the PS of Alg. 2 realized as an all-reduce. Microsteps
beyond a worker's τ_i are masked (zero update, zero accumulation, frozen
local-optimizer state), keeping the SPMD program uniform.

Granularities (selected per arch, see DESIGN.md §3):

  * ``data`` / ``pod`` — worker axes exist: shard_map + pmean commit;
  * ``accum`` — no worker axis: the whole mesh is one worker doing
    τ-step accumulation; the commit is a plain state update. The
    ``commit_dtype`` cast only happens on the worker-axes path (it
    shapes the all-reduce; there is no collective to shape in accum).

Everything here is jit/shard_map-compatible pure JAX (the fused backends
lower to Pallas, interpret-mode off-TPU); no host callbacks.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import SCAN_IN_PARTIAL_AUTO_BROKEN, shard_map as _compat_shard_map

from .fused_codec import FUSABLE_CODECS, fused_commit_name
from .rules import LocalRule, UpdateRules, get_commit_rule
from .sharding import ShardPlan
from .state import AdspState, CommitConfig

__all__ = ["make_train_step", "make_local_update", "make_sharded_apply",
           "worker_axes_for"]

Pytree = object


def worker_axes_for(granularity: str, mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """ADSP worker axes for an arch's granularity on a given mesh.

    granularity 'data'  → every (pod×)data index is a worker.
    granularity 'pod'   → each pod is one worker (replica memory too large
                          for a 16-chip model group); on a single-pod mesh
                          this degenerates to 'accum' (no worker axis).
    granularity 'accum' → no worker axis: τ-step gradient accumulation.
    """
    has_pod = "pod" in mesh.axis_names
    if granularity == "data":
        return ("pod", "data") if has_pod else ("data",)
    if granularity == "pod":
        return ("pod",) if has_pod else ()
    if granularity == "accum":
        return ()
    raise ValueError(f"unknown adsp granularity {granularity!r}")


def _axes_spec(axes: tuple[str, ...]) -> P:
    """PartitionSpec sharding a leading dim over all worker axes."""
    return P(axes if len(axes) > 1 else axes[0])


def make_sharded_apply(commit_rule, n_shards: int) -> Callable:
    """The commit apply, shard-sliced per the deterministic ShardPlan
    (DESIGN.md §11): slice params/commit-state/update per shard, apply
    the CommitRule shard by shard, merge. Every built-in CommitRule is
    leaf-wise, so the K-sharded apply is bit-identical to the monolithic
    one — sharding reorganizes what the transport layer sees (per-shard
    payloads, versions), never the numerics. n_shards == 1 returns the
    rule's apply untouched (the monolithic fast path).

    Codec-consuming rules (``commit_rule.is_payload`` set, the fused
    decode+apply path of DESIGN.md §16) take an *encoded* ``u`` whose
    leaves are payload atoms, not params-shaped arrays — those trees are
    flattened under the rule's predicate so the per-shard slices stay
    leaf-aligned with the params."""
    if n_shards <= 1:
        return commit_rule.apply

    def apply(params, cstate, u, momentum):
        plan = ShardPlan.build(params, n_shards)
        p_struct = jax.tree.structure(params)
        # commit state is either params-shaped (momentum_delta: sliced
        # along with the params) or leafless (plain_average: passed
        # through whole); anything else cannot be shard-partitioned.
        c_sliceable = jax.tree.structure(cstate) == p_struct
        if not c_sliceable and jax.tree.leaves(cstate):
            raise ValueError(
                f"commit rule {commit_rule.name!r} carries state that is "
                "neither empty nor params-shaped; it cannot be sharded"
            )
        p_leaves, treedef = jax.tree.flatten(params)
        c_leaves = jax.tree.leaves(cstate) if c_sliceable else None
        u_leaves, _ = jax.tree_util.tree_flatten(
            u, is_leaf=commit_rule.is_payload)
        new_p = list(p_leaves)
        new_c = list(c_leaves) if c_sliceable else cstate
        for k in range(plan.n_shards):
            idx = plan.shard_leaf_indices(k)
            p_k = plan.slice(params, k)
            u_k = [u_leaves[i] for i in idx]
            c_k = [c_leaves[i] for i in idx] if c_sliceable else cstate
            np_k, nc_k = commit_rule.apply(p_k, c_k, u_k, momentum)
            for i, leaf in zip(idx, np_k):
                new_p[i] = leaf
            if c_sliceable:
                for i, leaf in zip(idx, nc_k):
                    new_c[i] = leaf
            else:
                new_c = nc_k
        out_p = jax.tree.unflatten(treedef, new_p)
        out_c = jax.tree.unflatten(treedef, new_c) if c_sliceable else new_c
        return out_p, out_c

    return apply


def make_local_update(
    loss_fn: Callable,
    ccfg: CommitConfig,
    local_rule: LocalRule,
    *,
    remat: bool = False,
    unroll=1,
) -> Callable:
    """The τ-microstep local-update scan: the per-worker inner loop.

    Returns ``run(params, local_state, microbatches, tau_i) ->
    (U, new_local_state, mean_loss)`` where microbatches is a pytree of
    arrays with leading dim ccfg.tau and tau_i is the worker's active
    step count (int32 scalar; steps ≥ tau_i are masked). U is the
    accumulated update the PS consumes (−Σ ΔW_local; for plain sgd the
    paper's Σ η′·g) and the *local* params advance rule-wise each live
    step (then are discarded — the commit applies U to the pre-scan
    params).
    """
    grad_fn = jax.value_and_grad(loss_fn)
    if remat:
        grad_fn = jax.remat(grad_fn)

    def run(params, local_state, microbatches, tau_i):
        zeros = jax.tree.map(jnp.zeros_like, params)

        def body(carry, xs):
            p, u, ls = carry
            mb, idx = xs
            live = (idx < tau_i).astype(jnp.float32)
            loss, g = grad_fn(p, mb)
            p, u, ls = local_rule.update(p, u, g, ls, live)
            return (p, u, ls), loss * live

        idxs = jnp.arange(ccfg.tau, dtype=jnp.int32)
        (_, u, ls), losses = jax.lax.scan(
            body, (params, zeros, local_state), (microbatches, idxs),
            unroll=unroll,
        )
        denom = jnp.maximum(tau_i.astype(jnp.float32), 1.0)
        return u, ls, jnp.sum(losses) / denom

    return run


def make_train_step(
    loss_fn: Callable,
    ccfg: CommitConfig,
    rules: UpdateRules | tuple | None = None,
    *,
    mesh: jax.sharding.Mesh | None = None,
    granularity: str | None = None,
    batch_spec=None,
    explicit_momentum: float = 0.0,
    remat: bool = False,
    codec=None,
    fused_commit: bool = False,
) -> Callable:
    """Build the full train step for any granularity and rule backend.

    train_step(state: AdspState, microbatches, tau_per_worker)
        -> (state, loss)

    * microbatches: pytree, arrays shaped (tau, global_batch, ...); on the
      worker-axes path the batch dim is sharded over the worker axes per
      ``batch_spec`` (default ``P(None, <worker axes>)``).
    * tau_per_worker: int32[num_workers] — ADSP rate rule output; worker w
      runs tau_per_worker[w] live microsteps (≤ ccfg.tau). The accum path
      also accepts a bare scalar.

    ``rules`` is an UpdateRules bundle (default: sgd + momentum_delta on
    the auto backend), a resolved (LocalRule, CommitRule) pair, or None.
    ``granularity`` + ``mesh`` derive the worker axes (overriding
    ``ccfg.worker_axes``); with granularity None the config's axes are
    used as-is. The worker-axes path is manual (shard_map) over exactly
    those axes; the ``model`` axis (and any other mesh axis) stays in
    GSPMD auto mode, so tensor-parallel sharding inside loss_fn keeps
    working untouched.

    ``codec`` is a ``repro.transport`` Codec (or registered name) that
    models the commit transport on the real path: each worker's
    accumulated update U is encoded (folding in the worker's
    error-feedback residual, carried in ``state.transport_state``) and
    decoded before the pmean — exactly what a PS shipping compressed
    payloads computes. None (default) and the identity codec leave the
    arithmetic bit-identical to the no-transport step.

    ``fused_commit=True`` asks for the single-pass decode+apply commit
    (DESIGN.md §16): the PS-side decode and the CommitRule apply run as
    one combined rule (``repro.ps.fused_codec``), skipping a full
    params-sized HBM round trip per commit. The fusion is taken only
    when it is bit-identical to the chain — a fusable elementwise codec
    (int8/bf16), one worker (per-worker int8 scales cannot be folded
    across the worker pmean), a registered ``<rule>@<codec>`` combined
    rule, and float32 ``commit_dtype`` (the chain's cast to commit_dtype
    would otherwise reorder the decode) — and falls back to the chain
    path silently otherwise; ``.fused_commit`` on the returned step
    reports whether the fusion is live.

    The returned callable carries ``.init(params) -> AdspState`` (state
    with rule-owned slots), ``.rules`` (the resolved pair), ``.codec``,
    ``.config`` (the effective CommitConfig), ``.n_workers``,
    ``.fused_commit``, and ``.donate_argnums`` (the state argument —
    what jit should donate on the hot path).
    """
    if isinstance(codec, str):
        from repro.transport import get_codec  # deferred: avoids ps↔transport cycle

        codec = get_codec(codec)
    if granularity is not None:
        if mesh is None and granularity != "accum":
            raise ValueError(
                f"make_train_step: granularity {granularity!r} needs a mesh "
                "to derive the worker axes (only 'accum' runs mesh-free)"
            )
        axes = worker_axes_for(granularity, mesh) if mesh is not None else ()
        ccfg = dataclasses.replace(ccfg, worker_axes=axes)
    axes = tuple(ccfg.worker_axes)
    if axes and mesh is None:
        raise ValueError("make_train_step: mesh is required when worker axes are set")

    if isinstance(rules, (tuple, list)):
        local_rule, commit_rule = rules
        _interpret = None
    else:
        bundle = rules if rules is not None else UpdateRules()
        local_rule, commit_rule = bundle.resolve(ccfg)
        _interpret = bundle.interpret

    if axes:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        n_workers = int(np.prod([sizes[a] for a in axes]))
    else:
        n_workers = 1

    # Fused decode+apply (§16): resolve the combined <rule>@<codec> rule
    # when the fusion preconditions hold (see docstring). The chain path
    # stays the default and the bit-for-bit contract.
    fused_rule = None
    if (fused_commit and codec is not None
            and codec.name in FUSABLE_CODECS
            and n_workers == 1
            and jnp.dtype(ccfg.commit_dtype) == jnp.dtype(jnp.float32)):
        try:
            fused_rule = get_commit_rule(
                fused_commit_name(commit_rule.name, codec.name), ccfg,
                backend=commit_rule.backend, interpret=_interpret)
        except KeyError:
            fused_rule = None  # no combined rule registered: chain path
    use_fused = fused_rule is not None

    # PS sharding (§11): the commit apply is shard-sliced per the
    # deterministic ShardPlan; 1 shard keeps the monolithic apply.
    commit_apply = make_sharded_apply(
        fused_rule if use_fused else commit_rule, ccfg.n_shards)

    def _validate_state(state: AdspState) -> None:
        # Catch a seed-era AdspState.create(params) (momentum-delta-shaped,
        # stateless local rule) paired with other rules early, instead of a
        # tree-structure error deep inside the scan. Runs at trace time.
        p_abs = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype), state.params
        )
        checks = [
            ("commit_state", commit_rule, state.commit_state),
            ("local_state", local_rule, state.local_state),
        ]
        if codec is not None:
            checks.append(("transport_state", codec, state.transport_state))
        # the effective shard count clamps to the leaf count (a 1-leaf
        # model runs monolithic no matter the requested K)
        eff = (ShardPlan.build(p_abs, ccfg.n_shards).n_shards
               if ccfg.n_shards > 1 else 1)
        if eff > 1 and not jax.tree.leaves(state.shard_versions):
            raise ValueError(
                f"AdspState.shard_versions is empty but the step runs "
                f"{eff} PS shards; build states with "
                "make_train_step(...).init(params)"
            )
        for label, rule, got in checks:
            want = jax.tree.structure(jax.eval_shape(rule.init, p_abs))
            if jax.tree.structure(got) != want:
                raise ValueError(
                    f"AdspState.{label} does not match the {rule.name!r} rule's "
                    "state; build states with make_train_step(...).init(params)"
                )

    def _next_versions(state: AdspState):
        # Synchronous commit: every shard is written every round, so all K
        # version counters advance together (the counters matter to
        # *asynchronous* consumers — the edgesim's partial pulls — and to
        # shard-granular checkpoint/serve layers reading this state).
        # Keyed off the state, not ccfg.n_shards: the effective count
        # clamps to the leaf count, which can degenerate to monolithic.
        if not jax.tree.leaves(state.shard_versions):
            return state.shard_versions
        return state.shard_versions + 1

    if axes:
        # On the 0.4.x series XLA aborts on a lax.scan inside a partially
        # manual shard_map; the scan is static-length, so unroll there.
        unroll = True if SCAN_IN_PARTIAL_AUTO_BROKEN else 1
        run = make_local_update(loss_fn, ccfg, local_rule, remat=remat, unroll=unroll)
        if batch_spec is None:
            batch_spec = P(None, axes if len(axes) > 1 else axes[0])

    def _through_codec(u, tstate):
        """Worker-side encode → PS-side decode of one worker's U, with the
        error-feedback residual threaded through the per-worker slot. A
        no-op (bit-identical u) for codec=None / identity."""
        if codec is None:
            return u, tstate
        ts0 = jax.tree.map(lambda x: x[0], tstate)
        enc, ts1 = codec.encode(u, ts0)
        u = codec.decode(enc, u)
        return u, jax.tree.map(lambda x: x[None], ts1)

    def _encode_codec(u, tstate):
        """Worker-side encode only — the fused commit path consumes the
        payload directly, so there is no PS-side decode pass (§16)."""
        ts0 = jax.tree.map(lambda x: x[0], tstate)
        enc, ts1 = codec.encode(u, ts0)
        return enc, jax.tree.map(lambda x: x[None], ts1)

    if axes:
        def _sharded_body(params, cstate, lstate, tstate, step,
                          microbatches, tau_per_worker):
            # tau_per_worker arrives sharded over the worker axes: this
            # shard holds exactly the one entry belonging to this worker.
            tau_i = tau_per_worker[0]
            ls0 = jax.tree.map(lambda x: x[0], lstate)
            u, ls1, loss = run(params, ls0, microbatches, tau_i)
            loss = jax.lax.pmean(loss, axes)
            if use_fused:
                # single worker: the payload IS the worker-mean update, so
                # the fused rule decodes+applies it in one pass (§16)
                enc, tstate_out = _encode_codec(u, tstate)
                new_p, new_c = commit_apply(params, cstate, enc,
                                            explicit_momentum)
            else:
                # ---- transport: what actually crosses the link ----
                u, tstate_out = _through_codec(u, tstate)
                # ---- the commit: PS apply as all-reduce over workers ----
                cd = jnp.dtype(ccfg.commit_dtype)
                u = jax.tree.map(lambda x: x.astype(cd), u)
                u = jax.lax.pmean(u, axes)
                new_p, new_c = commit_apply(params, cstate, u,
                                            explicit_momentum)
            lstate_out = jax.tree.map(lambda x: x[None], ls1)
            return new_p, new_c, lstate_out, tstate_out, step + 1, loss

        # params/commit-state replicated across worker axes (manual);
        # local/transport state sharded one slot per worker; model-axis
        # sharding is handled by auto GSPMD outside the manual set.
        rep = P()
        wspec = _axes_spec(axes)
        sharded = _compat_shard_map(
            _sharded_body,
            mesh,
            in_specs=(rep, rep, wspec, wspec, rep, batch_spec, wspec),
            out_specs=(rep, rep, wspec, wspec, rep, rep),
            axis_names=set(axes),
            check=False,
        )

        def train_step(state: AdspState, microbatches, tau_per_worker):
            _validate_state(state)
            p, c, l, t, s, loss = sharded(
                state.params, state.commit_state, state.local_state,
                state.transport_state, state.step, microbatches, tau_per_worker,
            )
            return AdspState(p, c, l, s, t, _next_versions(state)), loss

    else:
        run = make_local_update(loss_fn, ccfg, local_rule, remat=remat, unroll=1)

        def train_step(state: AdspState, microbatches, tau_per_worker):
            _validate_state(state)
            tau_i = jnp.reshape(jnp.asarray(tau_per_worker, jnp.int32), (-1,))[0]
            ls0 = jax.tree.map(lambda x: x[0], state.local_state)
            u, ls1, loss = run(state.params, ls0, microbatches, tau_i)
            if use_fused:
                enc, tstate_out = _encode_codec(u, state.transport_state)
                new_p, new_c = commit_apply(
                    state.params, state.commit_state, enc, explicit_momentum
                )
            else:
                u, tstate_out = _through_codec(u, state.transport_state)
                new_p, new_c = commit_apply(
                    state.params, state.commit_state, u, explicit_momentum
                )
            lstate_out = jax.tree.map(lambda x: x[None], ls1)
            return AdspState(new_p, new_c, lstate_out, state.step + 1,
                             tstate_out, _next_versions(state)), loss

    # version-vector length follows the plan's clamped shard count (a
    # tree with fewer leaves than requested shards gets one per leaf)
    train_step.init = lambda params: AdspState.create(
        params, rules=(local_rule, commit_rule), n_workers=n_workers,
        codec=codec,
        n_shards=(ShardPlan.build(params, ccfg.n_shards).n_shards
                  if ccfg.n_shards > 1 else 1),
    )
    train_step.rules = (local_rule, commit_rule)
    train_step.codec = codec
    train_step.config = ccfg
    train_step.n_workers = n_workers
    train_step.n_shards = ccfg.n_shards
    train_step.fused_commit = use_fused
    train_step.donate_argnums = (0,)  # the AdspState: safe to donate per round
    return train_step
