"""Registered CommitRules: the PS apply over the worker axes.

``momentum_delta`` is the paper's Eqn. 1 PS (explicit momentum over the
previous global delta); ``plain_average`` is the FedAvg-style variant
(W ← W − η·ū, no PS momentum state). Fused backends are single-HBM-pass
Pallas kernels (``kernels.fused_commit`` via ``kernels.ops``); reference
backends are the bit-for-bit contract with the seed factories.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .rules import CommitRule, register_commit_rule

__all__ = []  # rules are reached through the registry


@register_commit_rule("momentum_delta", "reference")
def _momentum_delta_reference(ccfg, *, interpret=None) -> CommitRule:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def apply(params, cstate, u, momentum):
        # exact seed arithmetic: δ ← μ·δ_prev − η·ū ; W ← W + δ
        delta = jax.tree.map(
            lambda d, uu: (momentum * d - ccfg.global_lr * uu).astype(d.dtype),
            cstate, u,
        )
        new_p = jax.tree.map(jnp.add, params, delta)
        return new_p, delta

    return CommitRule("momentum_delta", "reference", init, apply)


@register_commit_rule("momentum_delta", "fused")
def _momentum_delta_fused(ccfg, *, interpret=None) -> CommitRule:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def apply(params, cstate, u, momentum):
        return ops.ps_apply_tree(
            params, cstate, u, ccfg.global_lr, momentum, interpret=interpret
        )

    return CommitRule("momentum_delta", "fused", init, apply)


@register_commit_rule("plain_average", "reference")
def _plain_average_reference(ccfg, *, interpret=None) -> CommitRule:
    def init(params):
        return ()

    def apply(params, cstate, u, momentum):
        del momentum  # stateless average has no PS momentum term
        new_p = jax.tree.map(
            lambda p, uu: (p - ccfg.global_lr * uu).astype(p.dtype), params, u
        )
        return new_p, cstate

    return CommitRule("plain_average", "reference", init, apply)


@register_commit_rule("plain_average", "fused")
def _plain_average_fused(ccfg, *, interpret=None) -> CommitRule:
    def init(params):
        return ()

    def apply(params, cstate, u, momentum):
        del momentum
        # W ← W + (−η)·ū is exactly the fused accumulate pass
        new_p = ops.accumulate_tree(params, u, -ccfg.global_lr, interpret=interpret)
        return new_p, cstate

    return CommitRule("plain_average", "fused", init, apply)
