"""ClusterEngine: the single control-plane executor (DESIGN.md §2, §12).

The engine sits between exactly one policy and exactly one backend:

    backend occurrences ──► engine.dispatch(Event) ──► policy.handle()
    policy commands     ──► engine executes (timers, rates, parking,
                            batch fractions, Alg. 1 search) against the
                            shared worker bookkeeping + backend hooks

Both backends — the virtual-clock ``edgesim.Simulator`` and the real
mesh loop (``cluster.mesh_backend.MeshBackend``) — report through the
same entry points, so Alg. 1/Alg. 2 logic exists exactly once. The
engine also implements ``control.search.OnlineSystem`` (``commit_counts``
/ ``evaluate``), which is how a ``Search`` command turns into live probe
windows on whichever backend is attached.

A ``Search`` command opens an incremental ``control.SearchSession``: the
engine feeds it one probe window at a time, and because each window is
ordinary live execution (``backend.run_window``), churn and speed-shift
events dispatch *during* the search — the engine forwards them to the
active session, which restarts on the new fleet (or aborts past its
restart budget) instead of scoring a window that mixes two fleets.

Elastic churn: ``worker_joined`` / ``worker_left`` / ``speed_changed``
keep the policy's rate rule current while workers come and go. A joining
worker inherits the minimum cumulative commit count of its peers, so the
rate rule ΔC_i = C_target − c_i ramps it in at the shared pace instead of
forcing a catch-up burst.
"""

from __future__ import annotations

from repro.control.search import SearchSession

from .protocol import (
    ArmTimer,
    Block,
    Checkpoint,
    ClusterPolicy,
    ClusterStarted,
    Command,
    Commit,
    CommitApplied,
    EpochEnd,
    Event,
    Resume,
    Search,
    SetBatchFraction,
    SetRate,
    SpeedChanged,
    StepDone,
    WorkerJoined,
    WorkerLeft,
)

__all__ = ["ClusterEngine", "LegacyPolicyAdapter", "coerce_policy"]


class LegacyPolicyAdapter(ClusterPolicy):
    """Wraps a pre-engine strategy object (should_commit /
    may_start_next_step / on_* hooks) as a ClusterPolicy, for third-party
    policies not yet ported to the protocol. The old hooks mutate worker
    state directly, which still works: the engine's bookkeeping objects
    are the same ones the backend exposes."""

    def __init__(self, inner):
        super().__init__(name=getattr(inner, "name", "legacy"),
                         apply_mode=getattr(inner, "apply_mode", "immediate"),
                         gates=True, tunes_batches=True)
        self.inner = inner

    def wants_commit(self, view, w) -> bool:
        return self.inner.should_commit(view, w)

    def may_start(self, view, w) -> bool:
        return self.inner.may_start_next_step(view, w)

    def fraction_for(self, view, index: int) -> float:
        # Legacy batch_fraction takes a *positional* worker index; under
        # churn the stable id diverges from the position, so translate.
        # A dead/unknown id must raise KeyError like every other lookup,
        # not a bare StopIteration (which PEP 479 turns into a
        # RuntimeError when it crosses a generator frame).
        pos = next(
            (i for i, ws in enumerate(view.workers) if ws.index == index), None
        )
        if pos is None:
            raise KeyError(f"no alive worker with id {index}")
        return self.inner.batch_fraction(view, pos)

    def supports_retarget(self) -> bool:
        return hasattr(self.inner, "retarget")

    def retarget(self, view, c_target: int) -> list[Command]:
        # legacy retarget hooks mutate state directly and return nothing
        self.inner.retarget(view, c_target)
        return []

    def on_started(self, view) -> list[Command]:
        self.inner.on_sim_start(view)
        return self.batch_fractions(view)

    def on_commit_applied(self, view, w) -> list[Command]:
        self.inner.on_commit_applied(view, w)
        return self.gating(view)

    def on_checkpoint(self, view) -> list[Command]:
        self.inner.on_checkpoint(view)
        return []

    def on_epoch_end(self, view) -> list[Command]:
        self.inner.on_epoch(view)
        return []


def coerce_policy(policy) -> ClusterPolicy:
    if isinstance(policy, ClusterPolicy):
        return policy
    if hasattr(policy, "should_commit"):
        return LegacyPolicyAdapter(policy)
    raise TypeError(f"not a synchronization policy: {policy!r}")


class ClusterEngine:
    """See module docstring. The engine is also the ClusterView handed to
    policies and the OnlineSystem handed to Alg. 1."""

    def __init__(self, policy, backend, metrics=None):
        self.policy = coerce_policy(policy)
        self.backend = backend
        self.metrics = metrics  # repro.fleet.MetricsSink | None
        self.parked: set[int] = set()
        self._search: SearchSession | None = None
        backend.bind(self)

    def _record(self, rec) -> None:
        if self.metrics is not None:
            self.metrics.record(rec)

    # ------------------------------------------------------------ view
    @property
    def now(self) -> float:
        return self.backend.now

    @property
    def workers(self):
        return self.backend.workers

    @property
    def num_workers(self) -> int:
        return len(self.backend.workers)

    def worker(self, index: int):
        lookup = getattr(self.backend, "worker_by_id", None)
        if lookup is not None:  # O(1) when the backend keeps an id map
            return lookup(index)
        for w in self.backend.workers:
            if w.index == index:
                return w
        raise KeyError(f"no alive worker with id {index}")

    def recent_global_loss(self):
        return self.backend.recent_global_loss()

    def batch_fraction(self, w) -> float:
        f = getattr(w, "batch_fraction", None)
        return f if f is not None else 1.0 / max(self.num_workers, 1)

    def may_start(self, w) -> bool:
        return w.index not in self.parked

    # ------------------------------------------------- backend entry points
    def start(self) -> None:
        self.dispatch(ClusterStarted())

    def step_done(self, w) -> bool:
        """Report a finished step; returns True iff ``w`` must commit."""
        cmds = self.dispatch(StepDone(w.index))
        return any(isinstance(c, Commit) and c.worker == w.index for c in cmds)

    def commit_applied(self, w) -> None:
        self.dispatch(CommitApplied(w.index))

    def checkpoint(self) -> None:
        self.dispatch(Checkpoint(self.now))

    def epoch_end(self) -> None:
        self.dispatch(EpochEnd(self.now))

    # ------------------------------------------------------------ churn
    def worker_joined(self, w, discovered: bool = False) -> None:
        """``w`` is already present in backend.workers. ``discovered``
        marks a lease-layer rejoin (repro.fleet).

        The joiner inherits the minimum peer commit count so the rate rule
        ΔC_i = C_target − c_i ramps it in at the shared pace, and the
        minimum peer step count so step-gap policies (SSP) don't stall the
        veterans behind it. Both credits are recorded so reporting can
        subtract them (SimResult counts only real work)."""
        peers = [p for p in self.workers if p.index != w.index]
        if peers:
            w.commit_credit = min(p.commits for p in peers)
            w.commits = w.commit_credit
            w.step_credit = min(p.steps for p in peers)
            w.steps = w.step_credit
        self._notify_search_churn()
        if self.metrics is not None:
            from repro.fleet.metrics import ChurnRecord

            self._record(ChurnRecord(t=self.now, worker=w.index, event="join",
                                     discovered=discovered))
        self.dispatch(WorkerJoined(w.index, discovered=discovered))

    def worker_left(self, index: int, discovered: bool = False) -> None:
        """Called after the backend removed the worker. ``discovered``
        marks a lease-expiry failure (repro.fleet) rather than a scripted
        departure."""
        self.parked.discard(index)
        self._notify_search_churn()
        if self.metrics is not None:
            from repro.fleet.metrics import ChurnRecord

            self._record(ChurnRecord(t=self.now, worker=index, event="leave",
                                     discovered=discovered))
        self.dispatch(WorkerLeft(index, discovered=discovered))

    def speed_changed(self, w) -> None:
        self._notify_search_churn()
        self.dispatch(SpeedChanged(w.index, w.profile.v))

    def _notify_search_churn(self) -> None:
        if self._search is not None:
            self._search.notify_churn()

    # --------------------------------------------------------- dispatching
    def dispatch(self, event: Event) -> list[Command]:
        cmds = self.policy.handle(self, event)
        if (self.metrics is not None and not isinstance(event, EpochEnd)
                and any(isinstance(c, Search) for c in cmds)):
            # a Search outside the epoch clock is a drift/discovery trigger
            from repro.fleet.metrics import DriftRecord

            self._record(DriftRecord(t=self.now, cause=type(event).__name__))
        self.execute(cmds)
        return cmds

    def execute(self, cmds: list[Command]) -> None:
        for c in cmds:
            if isinstance(c, ArmTimer):
                self.worker(c.worker).next_commit_time = c.deadline
            elif isinstance(c, SetRate):
                self.worker(c.worker).delta_c_target = int(c.delta_c)
            elif isinstance(c, SetBatchFraction):
                self.worker(c.worker).batch_fraction = c.fraction
            elif isinstance(c, Block):
                self.parked.add(c.worker)
            elif isinstance(c, Resume):
                if c.worker in self.parked:
                    self.parked.discard(c.worker)
                    self.backend.wake(self.worker(c.worker))
            elif isinstance(c, Search):
                self._run_search(c)
            elif isinstance(c, Commit):
                pass  # interpreted by the backend caller (step_done)
            else:
                raise TypeError(f"unknown command {c!r}")

    # ------------------------------------------------ Alg. 1 (OnlineSystem)
    def commit_counts(self) -> list[int]:
        return [w.commits for w in self.workers]

    def _retarget_cmds(self, c_target: int) -> list[Command]:
        """``policy.retarget`` guarded: a policy without real retargeting
        support (the base no-op, or a legacy strategy object without a
        ``retarget`` hook) must fail loudly — a silent no-op would let the
        Alg. 1 search probe candidates that never take effect."""
        if not self.policy.supports_retarget():
            raise TypeError(
                f"policy {self.policy.name!r} ({type(self.policy).__name__}) "
                "does not support commit-rate retargeting; evaluate/"
                "set_c_target/Search need a policy that overrides "
                "ClusterPolicy.retarget (the ADSP family does)"
            )
        return self.policy.retarget(self, int(c_target))

    def evaluate(self, c_target: int, probe_seconds: float):
        """Probe a candidate C_target live for a window (Alg. 1 line 10)."""
        self.execute(self._retarget_cmds(c_target))
        return self.backend.run_window(probe_seconds)

    def run_window(self, seconds: float):
        return self.backend.run_window(seconds)

    def set_c_target(self, c_target: int) -> None:
        """Adopt a target outright (Scheduler / Fig. 3 sweep support)."""
        self.execute(self._retarget_cmds(c_target))

    @property
    def search_active(self) -> bool:
        """True while a SearchSession is consuming probe windows."""
        return self._search is not None and self._search.active

    def _run_search(self, cmd: Search) -> None:
        """Open a SearchSession and pump it one probe window at a time.

        Each window is live execution on the backend, so events (steps,
        commits, checkpoints, churn) dispatch normally *during* the
        search; churn invalidates the in-flight window and restarts the
        session on the new fleet. A ``Search`` arriving while a session
        is active (e.g. a drift trigger firing during one of the
        session's own probe windows) is dropped — the running session
        already is the re-search.
        """
        if self.search_active:
            return
        session = SearchSession(
            probe_seconds=cmd.probe_seconds,
            max_probes=cmd.max_probes,
            patience=cmd.patience,
            eps_tie=cmd.eps_tie,
            reward_model=cmd.reward_model,
        )
        self._search = session
        session.trace.t_start = self.now
        try:
            cand = session.begin(self.commit_counts())
            while cand is not None:
                self.execute(self._retarget_cmds(cand))
                ts, ls = self.backend.run_window(cmd.probe_seconds)
                if session.churned:
                    cand = session.restart(self.commit_counts())
                else:
                    cand = session.probe_window_complete(ts, ls)
        finally:
            self._search = None
        session.trace.t_end = self.now
        if self.metrics is not None:
            from repro.fleet.metrics import SearchRecord

            tr = session.trace
            self._record(SearchRecord(t=self.now, chosen=int(tr.chosen),
                                      windows=int(tr.probe_windows),
                                      restarts=int(tr.restarts),
                                      aborted=bool(tr.aborted)))
        self.execute(self._retarget_cmds(session.trace.chosen))
        self.execute(self.policy.on_search_done(self, session.trace))
