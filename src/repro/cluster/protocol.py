"""The event/command protocol between synchronization policies and the
cluster engine (DESIGN.md §1–§2).

ADSP's contribution is a *control plane*: Alg. 1's online commit-rate
search plus Alg. 2's per-worker commit timers. This module gives that
control plane one typed vocabulary so the logic exists exactly once and
runs unchanged over any backend:

  * **Events** flow backend → policy: a worker finished a mini-batch step,
    a commit was applied, a check period Γ elapsed, an epoch ended, a
    worker joined/left, a worker's measured speed changed.
  * **Commands** flow policy → engine: commit now, block/resume a worker,
    arm a commit timer (Alg. 2's TIMEOUT), set a commit rate ΔC_i
    (Alg. 2's rate rule), set a batch fraction (BatchTune), run the
    Alg. 1 search.

Policies are *pure control*: they own scheduler scalars (C_target, τ, …)
but never model state, so one policy object can steer the virtual-clock
simulator and the real mesh loop in the same process. Decision logic is
expressed as two pure predicates (``wants_commit`` / ``may_start``) plus
event handlers that return commands; the legacy strategy-object entry
points (``should_commit`` / ``may_start_next_step`` / ``batch_fraction``)
are kept as thin shims over those predicates.
"""

from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Protocol, Sequence, runtime_checkable

if TYPE_CHECKING:  # pragma: no cover
    from .engine import ClusterEngine

__all__ = [
    # events
    "Event", "ClusterStarted", "StepDone", "CommitApplied", "Checkpoint",
    "EpochEnd", "WorkerJoined", "WorkerLeft", "SpeedChanged",
    # commands
    "Command", "Commit", "Block", "Resume", "ArmTimer", "SetRate",
    "SetBatchFraction", "Search",
    # state / interfaces
    "WorkerView", "ClusterView", "ClusterBackend", "ClusterPolicy",
]


# ---------------------------------------------------------------------------
# Events (backend → policy)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Event:
    """Base class; all events are immutable records."""


@dataclasses.dataclass(frozen=True)
class ClusterStarted(Event):
    """Emitted once before any worker steps; policies set initial rates."""


@dataclasses.dataclass(frozen=True)
class StepDone(Event):
    """Worker finished one mini-batch step (update already accumulated)."""

    worker: int


@dataclasses.dataclass(frozen=True)
class CommitApplied(Event):
    """Worker's commit was applied by the PS and the pull completed."""

    worker: int


@dataclasses.dataclass(frozen=True)
class Checkpoint(Event):
    """Check-period boundary (every Γ): Alg. 2 re-derives commit rates."""

    now: float


@dataclasses.dataclass(frozen=True)
class EpochEnd(Event):
    """Epoch boundary: Alg. 1 may search for a new C_target."""

    now: float


@dataclasses.dataclass(frozen=True)
class WorkerJoined(Event):
    """A worker was added to the cluster (elastic scale-out).
    ``discovered`` marks a lease-layer rejoin (repro.fleet) rather than a
    scripted/administrative join."""

    worker: int
    discovered: bool = False


@dataclasses.dataclass(frozen=True)
class WorkerLeft(Event):
    """A worker left the cluster; ``worker`` is its (now dead) id.
    ``discovered`` marks a failure found by lease expiry (repro.fleet)
    rather than a scripted/administrative departure."""

    worker: int
    discovered: bool = False


@dataclasses.dataclass(frozen=True)
class SpeedChanged(Event):
    """A worker's measured speed v_i changed (throttling, contention)."""

    worker: int
    v: float


# ---------------------------------------------------------------------------
# Commands (policy → engine)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Command:
    """Base class; all commands are immutable records."""


@dataclasses.dataclass(frozen=True)
class Commit(Command):
    """Push the worker's accumulated update U_i to the PS now. Only valid
    for the worker whose StepDone is being handled (commits happen at step
    boundaries); the engine returns it to the backend caller."""

    worker: int


@dataclasses.dataclass(frozen=True)
class Block(Command):
    """Park the worker: it must not start its next step (SSP bound)."""

    worker: int


@dataclasses.dataclass(frozen=True)
class Resume(Command):
    """Unpark the worker; a no-op if it is not parked."""

    worker: int


@dataclasses.dataclass(frozen=True)
class ArmTimer(Command):
    """Set the worker's commit deadline (Alg. 2 TIMEOUT restart)."""

    worker: int
    deadline: float


@dataclasses.dataclass(frozen=True)
class SetRate(Command):
    """Assign the worker's commit rate ΔC_i = C_target − c_i."""

    worker: int
    delta_c: int


@dataclasses.dataclass(frozen=True)
class SetBatchFraction(Command):
    """Assign the worker's share of the global batch (BatchTune)."""

    worker: int
    fraction: float


@dataclasses.dataclass(frozen=True)
class Search(Command):
    """Run Alg. 1 (DECIDECOMMITRATE): the engine opens an incremental
    ``control.SearchSession``, probes candidates one live window at a
    time (churn restarts the session), and calls back into
    ``policy.retarget`` with the winner. ``patience``/``eps_tie`` are the
    ε-tie patience guard and ``reward_model`` names a registered
    ``control.RewardModel`` (see repro.control)."""

    probe_seconds: float
    max_probes: int
    patience: int = 0
    eps_tie: float = 0.0
    reward_model: str = "log_slope"


# ---------------------------------------------------------------------------
# Worker / cluster views
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class WorkerView:
    """Per-worker control-plane bookkeeping the engine maintains.

    Backends may substitute their own richer state object (the edge
    simulator's WorkerState duck-types this — it adds params/update);
    the engine only relies on the fields below. ``index`` is a *stable
    id*: it never shifts when other workers leave.
    """

    index: int
    profile: object  # control.theory.WorkerProfile (v, o)
    steps: int = 0
    steps_since_commit: int = 0
    commits: int = 0
    delta_c_target: int = 1
    next_commit_time: float = math.inf
    batch_fraction: float | None = None  # None → equal split 1/M
    # ramp-in credit granted to elastic joiners (engine.worker_joined);
    # included in steps/commits for control-plane math, subtracted for
    # reporting real work.
    step_credit: int = 0
    commit_credit: int = 0


@runtime_checkable
class ClusterView(Protocol):
    """What a policy may read when deciding. The engine implements this;
    so does the edge simulator (for the legacy entry points)."""

    now: float
    workers: Sequence[WorkerView]
    num_workers: int

    def recent_global_loss(self) -> float | None: ...


class ClusterBackend(Protocol):
    """What the engine drives. A backend owns training state and a clock;
    it reports occurrences to the engine (``engine.step_done`` etc.) and
    obeys the resulting bookkeeping.

    Required surface::

        now: float                     # current (virtual) time
        workers: list[WorkerView]      # alive workers, stable ids
        bind(engine)                   # engine attaches itself
        wake(worker)                   # a parked worker was resumed
        run_window(seconds) -> (times, losses)   # Alg. 1 probe window
    """

    now: float
    workers: list

    def bind(self, engine: "ClusterEngine") -> None: ...

    def wake(self, worker) -> None: ...

    def run_window(self, seconds: float): ...

    def recent_global_loss(self) -> float | None: ...


# ---------------------------------------------------------------------------
# Policy base class
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ClusterPolicy:
    """Base event-driven synchronization policy.

    Subclasses implement the pure predicates ``wants_commit`` /
    ``may_start`` (and ``fraction_for`` for BatchTune) and override the
    ``on_*`` handlers they care about; ``handle`` is the single protocol
    entry point the engine calls. Set ``gates = True`` on policies whose
    ``may_start`` can return False so the engine receives Block/Resume
    commands after every step.
    """

    name: str = "base"
    apply_mode: str = "immediate"  # or "barrier" (PS collects whole round)
    gates: bool = False  # True → emit Block/Resume from may_start
    tunes_batches: bool = False  # True → emit SetBatchFraction on churn

    # -- pure decision predicates -------------------------------------------
    def wants_commit(self, view: ClusterView, w) -> bool:
        raise NotImplementedError

    def may_start(self, view: ClusterView, w) -> bool:
        return True

    def fraction_for(self, view: ClusterView, index: int) -> float:
        return 1.0 / max(view.num_workers, 1)

    # -- protocol entry point ------------------------------------------------
    def handle(self, view: ClusterView, event: Event) -> list[Command]:
        if isinstance(event, StepDone):
            return self.on_step_done(view, _worker(view, event.worker))
        if isinstance(event, CommitApplied):
            return self.on_commit_applied(view, _worker(view, event.worker))
        if isinstance(event, Checkpoint):
            return self.on_checkpoint(view)
        if isinstance(event, EpochEnd):
            return self.on_epoch_end(view)
        if isinstance(event, ClusterStarted):
            return self.on_started(view)
        if isinstance(event, WorkerJoined):
            cmds = self.on_worker_joined(view, _worker(view, event.worker))
            if event.discovered:
                cmds = cmds + self.on_worker_rejoined(
                    view, _worker(view, event.worker))
            return cmds
        if isinstance(event, WorkerLeft):
            cmds = self.on_worker_left(view, event.worker)
            if event.discovered:
                cmds = cmds + self.on_worker_lost(view, event.worker)
            return cmds
        if isinstance(event, SpeedChanged):
            return self.on_speed_changed(view, _worker(view, event.worker))
        raise TypeError(f"unknown event {event!r}")

    # -- default handlers ----------------------------------------------------
    def on_started(self, view) -> list[Command]:
        return self.batch_fractions(view)

    def on_step_done(self, view, w) -> list[Command]:
        cmds: list[Command] = []
        if self.wants_commit(view, w):
            cmds.append(Commit(w.index))
        return cmds + self.gating(view)

    def on_commit_applied(self, view, w) -> list[Command]:
        return self.gating(view)

    def on_checkpoint(self, view) -> list[Command]:
        return []

    def on_epoch_end(self, view) -> list[Command]:
        return []

    def on_worker_joined(self, view, w) -> list[Command]:
        return self.batch_fractions(view) + self.gating(view)

    def on_worker_left(self, view, index: int) -> list[Command]:
        return self.batch_fractions(view) + self.gating(view)

    # Discovered-churn hooks: fired *in addition to* on_worker_joined /
    # on_worker_left when the membership change came from the lease layer
    # (repro.fleet) instead of a script — a discovered failure is stronger
    # evidence the fleet moved than an administrative change of the same
    # size. Base: no extra commands.
    def on_worker_rejoined(self, view, w) -> list[Command]:
        return []

    def on_worker_lost(self, view, index: int) -> list[Command]:
        return []

    def on_speed_changed(self, view, w) -> list[Command]:
        return self.batch_fractions(view)

    def retarget(self, view, c_target: int) -> list[Command]:
        """Alg. 1 support: adopt a (candidate) C_target. Base: no-op."""
        return []

    def supports_retarget(self) -> bool:
        """True iff ``retarget`` actually does something. The engine
        refuses to run a search / set_c_target against a policy whose
        retarget is the base no-op (a silent non-retarget would probe
        candidates that never take effect)."""
        return type(self).retarget is not ClusterPolicy.retarget

    def on_search_done(self, view, trace) -> list[Command]:
        """A SearchSession finished (the engine already retargeted to
        ``trace.chosen``). Base: record the trace on policies that keep a
        ``traces`` log."""
        traces = getattr(self, "traces", None)
        if traces is not None:
            traces.append(trace)
        return []

    # -- helpers -------------------------------------------------------------
    def gating(self, view) -> list[Command]:
        if not self.gates:
            return []
        return [
            Block(w.index) if not self.may_start(view, w) else Resume(w.index)
            for w in view.workers
        ]

    def batch_fractions(self, view) -> list[Command]:
        if not self.tunes_batches:
            return []
        return [
            SetBatchFraction(w.index, self.fraction_for(view, w.index))
            for w in view.workers
        ]

    # -- legacy entry points (pre-engine strategy-object API) ----------------
    def should_commit(self, sim, w) -> bool:
        """Thin shim: old decision point #1 answers from wants_commit."""
        return self.wants_commit(sim, w)

    def may_start_next_step(self, sim, w) -> bool:
        """Thin shim: old decision point #2 answers from may_start."""
        return self.may_start(sim, w)

    def batch_fraction(self, sim, worker_index: int) -> float:
        """Thin shim: old decision point #3 answers from fraction_for."""
        return self.fraction_for(sim, worker_index)


def _worker(view: ClusterView, index: int):
    get = getattr(view, "worker", None)
    if get is not None:  # the engine resolves ids in O(1)
        return get(index)
    for w in view.workers:
        if w.index == index:
            return w
    raise KeyError(f"no alive worker with id {index}")
