"""Elastic-churn schedules: scripted worker joins/leaves/speed shifts.

The paper (§6) argues ADSP adapts to changing worker populations and
speeds; a ChurnSchedule makes that testable: it is a time-sorted list of
actions a backend applies at the given (virtual) times, each of which
lands in the engine as a WorkerJoined / WorkerLeft / SpeedChanged event
so the policy re-derives commit rates on the spot.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.control.theory import WorkerProfile

__all__ = ["ChurnAction", "ChurnSchedule", "join", "leave", "speed",
           "stall", "recover"]


@dataclasses.dataclass(frozen=True)
class ChurnAction:
    """``join``/``leave``/``speed`` are *administrative*: the engine hears
    about them immediately. ``stall``/``recover`` are *silent*: the worker
    freezes (or resumes) without any notice — only a lease layer
    (``repro.fleet``) can discover the failure, which is exactly what
    ``benchmarks/bench_fleet.py`` measures."""

    at: float  # virtual time
    kind: str  # "join" | "leave" | "speed" | "stall" | "recover"
    profile: WorkerProfile | None = None  # join
    worker: int | None = None  # leave / speed / stall / recover (stable id)
    v: float | None = None  # speed

    def __post_init__(self):
        if self.kind not in ("join", "leave", "speed", "stall", "recover"):
            raise ValueError(f"unknown churn kind {self.kind!r}")
        if self.kind == "join" and self.profile is None:
            raise ValueError("join requires a profile")
        if self.kind in ("leave", "speed", "stall", "recover") and self.worker is None:
            raise ValueError(f"{self.kind} requires a worker id")
        if self.kind == "speed" and (self.v is None or self.v <= 0):
            raise ValueError("speed requires a positive v")


def join(at: float, profile: WorkerProfile) -> ChurnAction:
    return ChurnAction(at=at, kind="join", profile=profile)


def leave(at: float, worker: int) -> ChurnAction:
    return ChurnAction(at=at, kind="leave", worker=worker)


def speed(at: float, worker: int, v: float) -> ChurnAction:
    return ChurnAction(at=at, kind="speed", worker=worker, v=v)


def stall(at: float, worker: int) -> ChurnAction:
    """Silent failure: the worker freezes mid-run with no departure
    notice (heartbeats stop; only lease expiry can discover it)."""
    return ChurnAction(at=at, kind="stall", worker=worker)


def recover(at: float, worker: int) -> ChurnAction:
    """A stalled worker resumes. Before its lease expired this is
    invisible to the control plane; after, it is a discovered rejoin."""
    return ChurnAction(at=at, kind="recover", worker=worker)


@dataclasses.dataclass
class ChurnSchedule:
    """Time-sorted actions; backends pop them as the clock passes ``at``."""

    actions: Sequence[ChurnAction] = ()

    def __post_init__(self):
        self.actions = sorted(self.actions, key=lambda a: a.at)
        self._i = 0

    def due(self, now: float) -> list[ChurnAction]:
        """Actions with at ≤ now that have not been handed out yet."""
        out = []
        while self._i < len(self.actions) and self.actions[self._i].at <= now:
            out.append(self.actions[self._i])
            self._i += 1
        return out

    def next_time(self) -> float | None:
        """Time of the next pending action (None when exhausted)."""
        if self._i < len(self.actions):
            return self.actions[self._i].at
        return None
