"""Unified event-driven cluster runtime (DESIGN.md).

One control plane — Alg. 1's commit-rate search + Alg. 2's per-worker
timers, expressed as typed events and commands — executed by a single
ClusterEngine over pluggable backends: the virtual-clock edge simulator
(``repro.edgesim.Simulator``) and the real-hardware mesh loop
(``repro.cluster.mesh_backend.MeshBackend``, used by
``repro.launch.train``).
"""

from .churn import ChurnAction, ChurnSchedule, join, leave, recover, speed, stall
from .engine import ClusterEngine, LegacyPolicyAdapter, coerce_policy
from .policies import (
    ADSP,
    ADSPPlus,
    AdaComm,
    BatchTuneBSP,
    BatchTuneFixedAdaComm,
    BSP,
    FixedAdaComm,
    SSP,
    SyncPolicy,
    TAP,
    make_policy,
)
from .protocol import (
    ArmTimer,
    Block,
    Checkpoint,
    ClusterPolicy,
    ClusterStarted,
    Command,
    Commit,
    CommitApplied,
    EpochEnd,
    Event,
    Resume,
    Search,
    SetBatchFraction,
    SetRate,
    SpeedChanged,
    StepDone,
    WorkerJoined,
    WorkerLeft,
    WorkerView,
)

__all__ = [
    # engine
    "ClusterEngine", "LegacyPolicyAdapter", "coerce_policy",
    # policies
    "ClusterPolicy", "BSP", "SSP", "TAP", "FixedAdaComm", "AdaComm",
    "ADSP", "ADSPPlus", "BatchTuneBSP", "BatchTuneFixedAdaComm",
    "SyncPolicy", "make_policy",
    # protocol
    "Event", "ClusterStarted", "StepDone", "CommitApplied", "Checkpoint",
    "EpochEnd", "WorkerJoined", "WorkerLeft", "SpeedChanged",
    "Command", "Commit", "Block", "Resume", "ArmTimer", "SetRate",
    "SetBatchFraction", "Search", "WorkerView",
    # churn
    "ChurnAction", "ChurnSchedule", "join", "leave", "speed", "stall",
    "recover",
]
