"""Real-hardware backend of the ClusterEngine: the ADSP commit step on a
JAX mesh (DESIGN.md §3–§4).

One *commit round* = every worker runs its τ_i local microsteps (fused,
no cross-worker collective — the no-waiting property) and then all commit
at once via the ``repro.ps.make_train_step`` all-reduce. The update rules
are pluggable (``rules=UpdateRules(...)``): any registered LocalRule
(sgd / sgd_momentum / adamw) at the worker, any CommitRule
(momentum_delta / plain_average) at the PS, reference or Pallas-fused
backend. Heterogeneity is realized through the τ_i vector: the engine's
SetRate commands carry ΔC_i from the policy's rate rule, and the backend
converts them to local step counts τ_i = v_i·(Γ/ΔC_i − O_i), bounded to
[1, cfg.tau] (the compiled step bound).

Clock: ``now`` advances ``round_seconds`` per commit round, so the same
policy object (same Γ, same probe windows) drives this backend and the
virtual-clock simulator. Checkpoint/epoch cadence is driven by
``train(..., check_period=, epoch_rounds=)``.

Churn: mid-run SpeedChanged is fully supported (speeds only shape τ_i).
WorkerJoined/WorkerLeft are rejected — the worker set is baked into the
compiled SPMD program; elastic membership needs a recompile, which the
virtual-clock backend models instead.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.control import theory
from repro.control.theory import WorkerProfile
from repro.fleet import CommitRecord, EvalRecord, FleetConfig, FleetMonitor
from repro.ps import (
    AdspState,
    CommitConfig,
    ShardPlan,
    UpdateRules,
    make_local_update,
    make_train_step,
)
from repro.transport import Codec, dense_nbytes, get_codec

from .engine import ClusterEngine
from .protocol import WorkerView

__all__ = ["MeshTask", "MeshBackend"]

Pytree = object


@dataclasses.dataclass
class MeshTask:
    """The learning problem for the mesh backend, as pure callables.

    loss_fn(params, microbatch) -> scalar loss
    make_microbatches(round_idx, tau, n_workers) -> pytree whose arrays
        have leading dims (tau, global_batch, ...); the batch dim is
        sharded over the worker axes by the compiled step.
    """

    init_params: Pytree
    loss_fn: Callable
    make_microbatches: Callable
    name: str = "mesh_task"


class MeshBackend:
    """See module docstring. Drive with ``train()`` (or ``run_round``)
    after wrapping in a ClusterEngine — the backend dispatches
    ClusterStarted itself on the first round, so do not call
    ``engine.start()`` directly::

        backend = MeshBackend(task, mesh, tau=4)
        engine = ClusterEngine(policy, backend)
        backend.train(rounds=50, check_period=policy.gamma)
    """

    def __init__(
        self,
        task: MeshTask,
        mesh: jax.sharding.Mesh,
        *,
        worker_axes: tuple[str, ...] = ("data",),
        tau: int = 4,
        local_lr: float = 0.05,
        global_lr: float = 1.0,
        commit_dtype: str = "float32",
        profiles: Sequence[WorkerProfile] | None = None,
        round_seconds: float = 1.0,
        batch_spec: P | None = None,
        rules: UpdateRules | None = None,
        explicit_momentum: float = 0.0,
        codec: str | Codec | None = None,
        n_shards: int = 1,
        fused_commit: bool = False,
        overlap_shards: bool = False,
        fleet: FleetConfig | None = None,
        metrics=None,
    ):
        self.task = task
        self.mesh = mesh
        self.tau = tau
        self.round_seconds = round_seconds
        # fleet layer (DESIGN.md §13): *observational* on the mesh — the
        # worker set is baked into the compiled SPMD program, so leases
        # can't evict anybody, but capability reports and the structured
        # metrics stream flow into the same sink the simulator uses.
        self.metrics = metrics
        self.fleet = FleetMonitor(fleet, metrics=metrics) if fleet is not None else None
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        n_workers = int(np.prod([sizes[a] for a in worker_axes])) if worker_axes else 1
        if profiles is None:
            profiles = [WorkerProfile(v=1.0, o=0.0)] * n_workers
        if len(profiles) != n_workers:
            raise ValueError(f"{len(profiles)} profiles for {n_workers} workers")
        self.workers = [WorkerView(index=i, profile=p) for i, p in enumerate(profiles)]
        self.now = 0.0
        self.losses: list[tuple[float, float]] = []
        self.engine: ClusterEngine | None = None
        self._round = 0
        self._started = False

        ccfg = CommitConfig(
            tau=tau, local_lr=local_lr, global_lr=global_lr,
            worker_axes=worker_axes, commit_dtype=commit_dtype,
            n_shards=n_shards,
        )
        codec = get_codec(codec) if isinstance(codec, str) else codec
        step = make_train_step(
            task.loss_fn, ccfg, rules,
            mesh=mesh if worker_axes else None,
            batch_spec=batch_spec,
            explicit_momentum=explicit_momentum,
            codec=codec,
            fused_commit=fused_commit,
        )
        self.rules = step.rules
        self.codec = step.codec
        self.fused_commit = step.fused_commit
        # the round's state is dead the moment the new one lands: donate
        # it so params/commit/transport buffers are updated in place.
        # Donated buffers are consumed — init from a private copy of the
        # params so the caller's init_params tree stays valid.
        self.step_fn = jax.jit(step, donate_argnums=step.donate_argnums)
        self.state = step.init(jax.tree.map(jnp.array, task.init_params))
        # effective shard count: the plan clamps to the leaf count, and
        # the state's version vector is the ground truth for what ran
        versions = jax.tree.leaves(self.state.shard_versions)
        self.n_shards = int(versions[0].shape[0]) if versions else 1
        # Overlapped per-shard commit (DESIGN.md §16): split the round
        # into one push phase (local scan + encode) and K per-shard
        # decode+apply dispatches issued back-to-back with NO host sync
        # between them — shard k+1's transfer is in flight while shard
        # k's apply runs, exactly the simulator's FIFO pull pipeline.
        # Bit-identical to the monolithic step (the per-shard applies
        # are the same leaf-wise ops make_sharded_apply runs in one jit);
        # only valid where the fused commit is (single worker — with one
        # worker the axes-path shard_map degenerates to the plain jit
        # the push phase uses, so the split round stays exact).
        self.overlap_shards = bool(
            overlap_shards and step.fused_commit and self.n_shards > 1
            and n_workers == 1
        )
        if self.overlap_shards:
            self._init_overlap(step, ccfg, explicit_momentum)
        # Wire accounting: bytes each commit round moves worker→PS (every
        # worker ships one encoded update per round). Measured from the
        # codec's static payload size; the identity/no-codec round ships
        # the dense update.
        per_worker = (
            self.codec.encoded_nbytes(task.init_params)
            if self.codec is not None else dense_nbytes(task.init_params)
        )
        self._per_worker_nbytes = per_worker
        self.bytes_per_round = per_worker * n_workers
        self.bytes_to_ps = 0
        if self.fleet is not None:
            for w in self.workers:
                self.fleet.join(w.index, 0.0, w.profile)

    # ------------------------------------------------------- overlapped commit
    def _init_overlap(self, step, ccfg, explicit_momentum: float) -> None:
        from repro.ps import get_commit_rule
        from repro.ps.fused_codec import fused_commit_name

        local_rule, commit_rule = step.rules
        codec = step.codec
        fused_rule = get_commit_rule(
            fused_commit_name(commit_rule.name, codec.name), ccfg,
            backend=commit_rule.backend,
        )

        run = make_local_update(self.task.loss_fn, ccfg, local_rule)

        def push(params, lstate, tstate, microbatches, tau_i):
            ls0 = jax.tree.map(lambda x: x[0], lstate)
            u, ls1, loss = run(params, ls0, microbatches, tau_i)
            ts0 = jax.tree.map(lambda x: x[0], tstate)
            enc, ts1 = codec.encode(u, ts0)
            return (enc, jax.tree.map(lambda x: x[None], ls1),
                    jax.tree.map(lambda x: x[None], ts1), loss)

        def pull(p_k, c_k, e_k):
            return fused_rule.apply(p_k, c_k, e_k, explicit_momentum)

        # local/transport slots die with the round: donate them; params
        # feed the per-shard pulls so they are donated there instead
        # (each leaf belongs to exactly one shard). One compiled pull
        # variant per shard shape; K stays small.
        self._push_fn = jax.jit(push, donate_argnums=(1, 2))
        self._pull_fn = jax.jit(pull, donate_argnums=(0, 1))
        self._plan = ShardPlan.build(self.state.params, self.n_shards)
        self._is_payload = fused_rule.is_payload

    def _commit_overlapped(self, mbs, tau_arr):
        """One commit round as push + K per-shard pulls, dispatched with
        no host sync in between: shard k+1's payload transfer is issued
        while shard k's fused decode+apply runs (the device queue
        pipelines them), mirroring the edgesim's FIFO pull pipeline.
        ``run_round`` syncs once at the round boundary via the loss."""
        st = self.state
        tau_i = jnp.asarray(int(tau_arr[0]), jnp.int32)
        enc, lstate, tstate, loss = self._push_fn(
            st.params, st.local_state, st.transport_state, mbs, tau_i)
        p_leaves, treedef = jax.tree.flatten(st.params)
        c_leaves = jax.tree.leaves(st.commit_state)
        e_leaves, _ = jax.tree_util.tree_flatten(enc, is_leaf=self._is_payload)
        new_p = list(p_leaves)
        new_c = list(c_leaves)
        for k in range(self._plan.n_shards):
            idx = self._plan.shard_leaf_indices(k)
            np_k, nc_k = self._pull_fn(
                [p_leaves[i] for i in idx],
                [c_leaves[i] for i in idx] if c_leaves else (),
                [e_leaves[i] for i in idx],
            )
            for i, leaf in zip(idx, np_k):
                new_p[i] = leaf
            if c_leaves:
                for i, leaf in zip(idx, nc_k):
                    new_c[i] = leaf
        params = jax.tree.unflatten(treedef, new_p)
        cstate = (jax.tree.unflatten(treedef, new_c) if c_leaves
                  else st.commit_state)
        versions = st.shard_versions
        if jax.tree.leaves(versions):
            versions = versions + 1
        self.state = AdspState(params, cstate, lstate, st.step + 1,
                               tstate, versions)
        return loss

    # ------------------------------------------------------------ backend API
    def bind(self, engine: ClusterEngine) -> None:
        self.engine = engine
        if self.fleet is not None:
            # initial scheduler pass over the join-time capability reports
            # (later passes ride each heartbeat-delivered set_speed report)
            engine.execute(self.fleet.assignments(self.now))

    def wake(self, w) -> None:  # rounds are synchronous; nothing is parked
        pass

    def recent_global_loss(self) -> float | None:
        if not self.losses:
            return None
        return float(np.mean([l for _, l in self.losses[-3:]]))

    def run_window(self, seconds: float) -> tuple[list[float], list[float]]:
        """Alg. 1 probe: run live for ``seconds`` of round time."""
        start = self.now
        rounds = max(int(math.ceil(seconds / self.round_seconds)), 2)
        for _ in range(rounds):
            self.run_round()
        from repro.control.search import pad_probe_samples

        ts = [t for t, _ in self.losses if t >= start]
        ls = [l for t, l in self.losses if t >= start]
        return pad_probe_samples(ts, ls)

    # ---------------------------------------------------------------- rounds
    def tau_per_worker(self) -> np.ndarray:
        """Rate rule → local step counts: τ_i = v_i·(Γ/ΔC_i − O_i), bounded
        to [1, tau]. Γ here is the policy's check period in round time; with
        no check period yet (before the first SetRate) every worker runs the
        full tau."""
        out = np.empty(len(self.workers), np.int64)
        gamma = getattr(self.engine.policy, "gamma", None) if self.engine else None
        for i, w in enumerate(self.workers):
            if gamma is None:
                out[i] = self.tau
                continue
            t = theory.local_steps_between_commits(
                w.profile, gamma, max(w.delta_c_target, 1)
            )
            out[i] = min(max(t, 1), self.tau)
        return out

    def _ensure_started(self) -> None:
        if not self._started:
            self._started = True
            self.engine.start()

    def run_round(self) -> float:
        """One fused commit round; dispatches CommitApplied per worker."""
        self._ensure_started()
        tau_arr = self.tau_per_worker()
        mbs = self.task.make_microbatches(self._round, self.tau, len(self.workers))
        if self.overlap_shards:
            loss = self._commit_overlapped(mbs, tau_arr)
        else:
            self.state, loss = self.step_fn(
                self.state, mbs, jnp.asarray(tau_arr, jnp.int32))
        self._round += 1
        self.now = self._round * self.round_seconds
        self.bytes_to_ps += self.bytes_per_round
        loss = float(loss)
        self.losses.append((self.now, loss))
        if self.metrics is not None:
            self.metrics.record(EvalRecord(t=self.now, loss=loss))
        for w, t in zip(self.workers, tau_arr):
            w.steps += int(t)
            w.steps_since_commit = 0
            w.commits += 1
            if self.metrics is not None:
                # one fused all-reduce round: latency is the round wall
                # time; the pull is folded into the collective (0 bytes)
                self.metrics.record(CommitRecord(
                    t=self.now, worker=w.index, latency=self.round_seconds,
                    push_bytes=float(self._per_worker_nbytes),
                    pull_bytes=0.0, stale_shards=0, n_shards=self.n_shards,
                ))
            self.engine.commit_applied(w)
        return loss

    # ----------------------------------------------------------------- churn
    def set_speed(self, index: int, v: float) -> None:
        """Mid-run speed shift: re-derives τ_i through the policy."""
        w = self.engine.worker(index)
        w.profile = dataclasses.replace(w.profile, v=v)
        self.engine.speed_changed(w)
        if self.fleet is not None:
            # rounds are synchronous: the capability report lands with the
            # next round's commit rather than on a modelled link
            self.fleet.report(index, self.now, v)
            self.engine.execute(self.fleet.assignments(self.now))

    # ----------------------------------------------------------------- drive
    def train(
        self,
        rounds: int,
        *,
        check_period: float | None = None,
        epoch_rounds: int = 0,
        on_round: Callable[[int, float], None] | None = None,
    ) -> list[tuple[float, float]]:
        """Run ``rounds`` commit rounds with checkpoint/epoch cadence.

        check_period: Γ in round time (fire engine.checkpoint each Γ);
        epoch_rounds: fire engine.epoch_end every N rounds (0 = never —
        note Alg. 1's search consumes probe rounds beyond ``rounds``).
        on_round receives the count of *scheduled* rounds completed
        (1-based, probe rounds excluded) and the round's loss.
        """
        self._ensure_started()
        next_check = check_period if check_period else math.inf
        done = 0
        while done < rounds:
            if epoch_rounds and done and done % epoch_rounds == 0:
                self.engine.epoch_end()
            loss = self.run_round()
            done += 1
            if on_round is not None:
                on_round(done, loss)
            if self.now >= next_check:
                self.engine.checkpoint()
                next_check += check_period
        return self.losses
