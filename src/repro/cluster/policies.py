"""The nine synchronization policies (§2.2, §4, §5 baselines), ported to
the event/command protocol.

Each policy is pure control logic: typed events in, typed commands out
(see protocol.py). Training state lives in the backend; scheduler scalars
(C_target, τ, loss smoothing) live here, so policies stay trivially
serializable and unit-testable, and the same object can drive the edge
simulator or the real mesh loop.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.control import theory
from repro.control.drift import DriftDetector, speed_fractions
from repro.control.reward import get_reward_model
from repro.control.search import SearchTrace  # noqa: F401  (re-export for callers)

from .protocol import (
    ArmTimer,
    ClusterPolicy,
    Command,
    Search,
    SetRate,
)

__all__ = [
    "BSP",
    "SSP",
    "TAP",
    "FixedAdaComm",
    "AdaComm",
    "ADSP",
    "ADSPPlus",
    "BatchTuneBSP",
    "BatchTuneFixedAdaComm",
    "SyncPolicy",
    "make_policy",
]


# ---------------------------------------------------------------------------
# Classic baselines
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BSP(ClusterPolicy):
    """Bulk Synchronous Parallel: commit every step, strict barrier."""

    name: str = "bsp"
    apply_mode: str = "barrier"

    def wants_commit(self, view, w) -> bool:
        return True


@dataclasses.dataclass
class SSP(ClusterPolicy):
    """Stale Synchronous Parallel with slack ``s``: commit every step, a
    worker may run ahead of the slowest by at most ``s`` steps."""

    name: str = "ssp"
    apply_mode: str = "immediate"
    gates: bool = True
    s: int = 8

    def wants_commit(self, view, w) -> bool:
        return True

    def may_start(self, view, w) -> bool:
        slowest = min(ws.steps for ws in view.workers)
        return w.steps - slowest < self.s


@dataclasses.dataclass
class TAP(ClusterPolicy):
    """Totally Asynchronous Parallel: commit every step, never block.
    No convergence guarantee (Hsieh et al. 2017) — kept for completeness."""

    name: str = "tap"
    apply_mode: str = "immediate"

    def wants_commit(self, view, w) -> bool:
        return True


@dataclasses.dataclass
class FixedAdaComm(ClusterPolicy):
    """Wang & Joshi (2018), fixed-τ variant: every worker accumulates τ
    local updates, then synchronizes with a BSP-style barrier."""

    name: str = "fixed_adacomm"
    apply_mode: str = "barrier"
    tau: int = 8

    def wants_commit(self, view, w) -> bool:
        return w.steps_since_commit >= self.tau


@dataclasses.dataclass
class AdaComm(FixedAdaComm):
    """ADACOMM with the paper-described periodic τ tuning: re-evaluated at
    every checkpoint; if the smoothed global loss failed to decrease since
    the previous checkpoint, multiply τ by ``tau_decay`` (<1 ⇒ commit more
    often). Follows AdaComm's τ(t) = ceil(τ0 · sqrt(loss_t/loss_0)) schedule
    as the base, which the paper criticizes for its rapidly-declining rate."""

    name: str = "adacomm"
    tau0: int = 16
    tau_decay: float = 0.5
    _loss0: float = dataclasses.field(default=math.nan, init=False)
    _last_loss: float = dataclasses.field(default=math.nan, init=False)

    def on_started(self, view) -> list[Command]:
        self.tau = self.tau0
        # a restarted policy must not reuse the previous run's loss
        # baseline — the τ ∝ sqrt(loss/loss0) schedule would be anchored
        # to stale (often lower) losses and over-commit from step one
        self._loss0 = math.nan
        self._last_loss = math.nan
        return super().on_started(view)

    def on_checkpoint(self, view) -> list[Command]:
        loss = view.recent_global_loss()
        if loss is None:
            return []
        if math.isnan(self._loss0):
            self._loss0, self._last_loss = loss, loss
            return []
        # AdaComm schedule: τ ∝ sqrt(current/initial loss).
        self.tau = max(1, math.ceil(self.tau0 * math.sqrt(max(loss, 1e-9) / self._loss0)))
        if loss >= self._last_loss:  # stagnation → commit more often
            self.tau = max(1, int(self.tau * self.tau_decay))
        self._last_loss = loss
        return []


# ---------------------------------------------------------------------------
# ADSP (the paper's contribution)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ADSP(ClusterPolicy):
    """ADaptive Synchronous Parallel (Alg. 1 + Alg. 2), event-driven.

    * no-waiting: workers always train; commits triggered by per-worker
      timers with timeout Γ/ΔC_i − O_i (Alg. 2 → ArmTimer commands);
    * at every Checkpoint (period Γ) commit rates are re-derived as
      ΔC_i = C_target − c_i, equalizing cumulative commit counts
      (→ SetRate commands);
    * the online search (Alg. 1 / ``control.SearchSession``) fires as a
      Search command the engine executes incrementally, calling
      ``retarget`` with the winner. When it fires is ``search_mode``:
      ``"epoch"`` (paper: every EpochEnd), ``"drift"`` (a
      ``control.DriftDetector`` watches the per-worker speed fractions
      and the loss trajectory and re-searches mid-epoch when the fleet
      the current C_target was chosen for no longer exists), or
      ``"both"``.

    ``search=False`` freezes C_target (used by unit tests and by the
    Fig. 3 commit-rate sweep where ΔC is set exogenously). Elastic churn:
    WorkerJoined/WorkerLeft/SpeedChanged all re-derive rates, so a joining
    worker is folded into the rate rule immediately — and in drift mode
    may additionally trigger an immediate re-search.
    """

    name: str = "adsp"
    apply_mode: str = "immediate"
    gamma: float = 60.0  # check period Γ (virtual seconds); paper: 60 s
    initial_c_target: int = 1
    search: bool = True
    probe_seconds: float = 60.0
    max_probes: int = 8
    # Alg. 1 knobs: ε-tie patience (0 = paper's break-on-first-miss climb)
    # and the reward model scoring probe windows (control.reward registry).
    search_patience: int = 0
    eps_tie: float = 0.0
    reward_model: str = "log_slope"
    # When to re-search: "epoch" (paper), "drift", or "both".
    search_mode: str = "epoch"
    drift_threshold: float = 0.25  # speed-fraction TV distance triggering re-search
    drift_cooldown: float = 120.0  # min virtual seconds between drift triggers
    # Fixed commit-rate mode (Fig. 3 sweep): with search=False the target
    # advances by `delta_per_period` each check period, pinning every
    # worker's ΔC_target ≈ delta_per_period.
    delta_per_period: int = 1
    c_target: int = dataclasses.field(default=0, init=False)
    traces: list = dataclasses.field(default_factory=list, init=False)
    drift: DriftDetector | None = dataclasses.field(default=None, init=False)

    def __post_init__(self):
        if self.search_mode not in ("epoch", "drift", "both"):
            raise ValueError(
                f"search_mode must be epoch|drift|both, got {self.search_mode!r}"
            )
        # fail at construction, not when the first search fires mid-run
        get_reward_model(self.reward_model)

    def wants_commit(self, view, w) -> bool:
        return view.now >= w.next_commit_time

    def on_started(self, view) -> list[Command]:
        self.c_target = max(self.initial_c_target, 1)
        if self.search and self.search_mode in ("drift", "both"):
            self.drift = DriftDetector(
                threshold=self.drift_threshold, cooldown=self.drift_cooldown
            )
            self.drift.rebaseline(speed_fractions(view), view.now)
        else:
            self.drift = None
        return super().on_started(view) + self.rate_commands(view)

    def on_commit_applied(self, view, w) -> list[Command]:
        # Alg. 2 TIMEOUT: restart the timer.
        dc = max(w.delta_c_target, 1)
        deadline = view.now + theory.commit_interval_seconds(
            self.gamma, dc, w.profile.o
        )
        return [ArmTimer(w.index, deadline)]

    def on_checkpoint(self, view) -> list[Command]:
        # New check period: move the target forward so every worker is
        # expected to add ≥ delta_per_period commits, then re-derive rates.
        counts = [ws.commits for ws in view.workers]
        self.c_target = max(self.c_target, max(counts) + self.delta_per_period)
        if self.drift is not None:
            self.drift.observe_loss(view.recent_global_loss())
        return self.rate_commands(view) + self._drift_commands(view)

    def on_epoch_end(self, view) -> list[Command]:
        if not self.search or self.search_mode == "drift":
            return []
        return [self.search_command()]

    def on_worker_joined(self, view, w) -> list[Command]:
        return (super().on_worker_joined(view, w) + self.rate_commands(view)
                + self._drift_commands(view))

    def on_worker_left(self, view, index: int) -> list[Command]:
        return (super().on_worker_left(view, index) + self.rate_commands(view)
                + self._drift_commands(view))

    def on_speed_changed(self, view, w) -> list[Command]:
        return (super().on_speed_changed(view, w) + self.rate_commands(view)
                + self._drift_commands(view))

    def on_worker_lost(self, view, index: int) -> list[Command]:
        """A lease expiry (repro.fleet) discovered this death — the PS was
        never told. Feed the drift baseline: discovery bypasses the
        TV-distance threshold, so even a small worker's silent failure
        re-searches once the cooldown allows (on_worker_left already ran
        the threshold-gated check; at most one Search survives because
        the trigger stamps the cooldown)."""
        if self.drift is None:
            return []
        self.drift.note_discovered_failure(view.now)
        return self._drift_commands(view)

    def retarget(self, view, c_target: int) -> list[Command]:
        self.c_target = int(c_target)
        return self.rate_commands(view)

    def on_search_done(self, view, trace) -> list[Command]:
        cmds = super().on_search_done(view, trace)  # records the trace
        if self.drift is not None and not trace.aborted:
            # the chosen C_target belongs to *this* fleet: drift measures
            # from here on. An ABORTED search keeps the old baseline —
            # its choice was never scored against this fleet, so the
            # drift signal must stay armed to retry after the cooldown
            # (in pure drift mode there is no epoch clock to fall back on).
            self.drift.rebaseline(speed_fractions(view), view.now)
        return cmds

    def search_command(self) -> Search:
        return Search(self.probe_seconds, self.max_probes,
                      patience=self.search_patience, eps_tie=self.eps_tie,
                      reward_model=self.reward_model)

    def _drift_commands(self, view) -> list[Command]:
        """Mid-epoch re-search trigger (search_mode drift/both)."""
        if self.drift is None:
            return []
        if self.drift.should_search(speed_fractions(view), view.now):
            return [self.search_command()]
        return []

    def rate_commands(self, view) -> list[Command]:
        """Alg. 2 rate rule: ΔC_i = C_target − c_i, timers re-armed.

        A timer already armed *earlier* than the new interval is kept (do
        not extend); shrink if the new rate demands faster commits.
        """
        counts = [ws.commits for ws in view.workers]
        rates = theory.commit_rates_from_target(self.c_target, counts)
        cmds: list[Command] = []
        for ws, dc in zip(view.workers, rates):
            interval = theory.commit_interval_seconds(
                self.gamma, int(dc), ws.profile.o
            )
            deadline = min(ws.next_commit_time, view.now + interval)
            cmds.append(SetRate(ws.index, int(dc)))
            cmds.append(ArmTimer(ws.index, deadline))
        return cmds

    def mu_implicit(self, view) -> float:
        """Current implicit momentum per Eqn. (3)."""
        dc = [max(ws.delta_c_target, 1) for ws in view.workers]
        v = [ws.profile.v for ws in view.workers]
        return theory.mu_implicit(dc, v, self.gamma)


@dataclasses.dataclass
class ADSPPlus(ADSP):
    """ADSP⁺ (Appendix D): offline oracle that, for a fixed C_target, grid
    searches per-worker local-step counts τ_i ≤ no-waiting τ_i. Used to
    verify that ADSP's no-waiting choice is near-optimal; the benchmark
    driver performs the outer offline grid, this policy simply enforces a
    τ cap per worker."""

    name: str = "adsp_plus"
    search: bool = False
    tau_cap: tuple = ()  # per-worker max local steps between commits

    def wants_commit(self, view, w) -> bool:
        # tau_cap is indexed by stable worker id, which is only dense for
        # the initial fleet — an elastically joined worker (id ≥ len) has
        # no offline-grid entry, so it runs uncapped (plain ADSP timers)
        if self.tau_cap and w.index < len(self.tau_cap):
            if w.steps_since_commit >= self.tau_cap[w.index]:
                return True
        return view.now >= w.next_commit_time


# ---------------------------------------------------------------------------
# BatchTune baselines (Appendix D, R²SP-style)
# ---------------------------------------------------------------------------


def _speed_fraction(view, index: int) -> float:
    """Batch share ∝ v_i over the currently alive fleet."""
    total = float(np.sum([ws.profile.v for ws in view.workers]))
    for ws in view.workers:
        if ws.index == index:
            return float(ws.profile.v) / total
    # a bare next(...) here would raise StopIteration, which silently
    # terminates any generator the caller runs inside (same bug class as
    # LegacyPolicyAdapter.fraction_for)
    raise KeyError(f"no alive worker with id {index}")


@dataclasses.dataclass
class BatchTuneBSP(BSP):
    """BSP with per-worker batch sizes ∝ v_i (global batch fixed), so step
    times equalize and the barrier costs ~nothing."""

    name: str = "batchtune_bsp"
    tunes_batches: bool = True

    def fraction_for(self, view, index: int) -> float:
        return _speed_fraction(view, index)


@dataclasses.dataclass
class BatchTuneFixedAdaComm(FixedAdaComm):
    name: str = "batchtune_fixed_adacomm"
    tunes_batches: bool = True

    def fraction_for(self, view, index: int) -> float:
        return _speed_fraction(view, index)


# ---------------------------------------------------------------------------
# Legacy strategy-object base (pre-engine API)
# ---------------------------------------------------------------------------


class SyncPolicy:
    """Legacy strategy-object base (the pre-engine API, kept from the
    retired legacy package).

    Third-party subclasses implementing ``should_commit`` /
    ``may_start_next_step`` / ``on_*`` hooks still run everywhere a
    policy is accepted: the engine adapts them with
    ``repro.cluster.LegacyPolicyAdapter``. New policies should subclass
    ``repro.cluster.ClusterPolicy`` instead.
    """

    name: str = "base"
    apply_mode: str = "immediate"  # or "barrier"

    def should_commit(self, sim, w) -> bool:
        raise NotImplementedError

    def may_start_next_step(self, sim, w) -> bool:
        return True

    def on_sim_start(self, sim) -> None:
        pass

    def on_commit_applied(self, sim, w) -> None:
        pass

    def on_checkpoint(self, sim) -> None:
        pass

    def on_epoch(self, sim) -> None:
        pass

    def batch_fraction(self, sim, worker_index: int) -> float:
        return 1.0 / sim.num_workers


_POLICIES = {
    "bsp": BSP,
    "ssp": SSP,
    "tap": TAP,
    "adacomm": AdaComm,
    "fixed_adacomm": FixedAdaComm,
    "adsp": ADSP,
    "adsp_plus": ADSPPlus,
    "batchtune_bsp": BatchTuneBSP,
    "batchtune_fixed_adacomm": BatchTuneFixedAdaComm,
}


def make_policy(name: str, **kwargs) -> ClusterPolicy:
    try:
        cls = _POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown sync policy {name!r}; known: {sorted(_POLICIES)}")
    return cls(**kwargs)
