"""SGD + momentum in the paper's form (Eqn. 1):

    W_{t+1} = W_t − η ∇ℓ(W_t) + μ (W_t − W_{t−1})

State carries the previous delta (W_t − W_{t−1}) — the same buffer the
ADSP PS uses (the momentum_delta CommitRule's commit_state in
``repro.ps``), so the commit layer and this optimizer share semantics.
Plus the paper's exponentially-decaying local learning rate schedule.
The worker-side LocalRule adaptations of these optimizers live in
``repro.ps.local``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["SGDState", "sgd_momentum", "exp_decay"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SGDState:
    prev_delta: object
    step: jax.Array

    @classmethod
    def create(cls, params):
        return cls(jax.tree.map(jnp.zeros_like, params), jnp.zeros((), jnp.int32))


def sgd_momentum(lr: float | Callable = 0.1, momentum: float = 0.0):
    """Returns (init, update): update(grads, state, params) -> (new_params, state)."""

    def init(params):
        return SGDState.create(params)

    def update(grads, state: SGDState, params):
        eta = lr(state.step) if callable(lr) else lr
        delta = jax.tree.map(
            lambda d, g: momentum * d - eta * g, state.prev_delta, grads
        )
        new_params = jax.tree.map(jnp.add, params, delta)
        return new_params, SGDState(delta, state.step + 1)

    return init, update


def exp_decay(initial: float, decay: float, period_steps: int) -> Callable:
    """η(t) = initial · decay^(t / period) — the paper's local-lr schedule."""

    def fn(step):
        return initial * decay ** (step.astype(jnp.float32) / period_steps)

    return fn
