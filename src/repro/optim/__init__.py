from .sgd import sgd_momentum, SGDState, exp_decay
from .adamw import adamw, AdamWState

__all__ = ["sgd_momentum", "SGDState", "exp_decay", "adamw", "AdamWState"]
