"""AdamW for the LM training examples (the paper's PS uses plain SGD; the
e2e 100M-parameter example trains with AdamW at the worker level and
commits accumulated parameter deltas, showing ADSP composes with modern
optimizers — the commit is optimizer-agnostic: it ships ΔW, not grads)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    mu: object
    nu: object
    step: jax.Array

    @classmethod
    def create(cls, params):
        z = lambda: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
        return cls(z(), z(), jnp.zeros((), jnp.int32))


def adamw(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.01):
    def init(params):
        return AdamWState.create(params)

    def update(grads, state: AdamWState, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        eta = lr(step) if callable(lr) else lr
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads)
        bc1 = 1 - b1**t
        bc2 = 1 - b2**t

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            return (p - eta * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32))).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(mu, nu, step)

    return init, update
