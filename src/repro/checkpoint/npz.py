"""Checkpointing: pytrees → .npz with path-keyed arrays + JSON metadata.

Arrays are gathered to host (fully addressable) before writing; sharding
specs are stored as metadata so a restore onto a mesh can re-place leaves
(`shardings` arg). Atomic via temp-file rename. This is deliberately
simple (single-host writes) — a production deployment would swap in
tensorstore/orbax behind the same 4-function API.
"""

from __future__ import annotations

import json
import os
import pathlib
import tempfile

import jax
import numpy as np

__all__ = ["save_pytree", "load_pytree", "save_train_state", "load_train_state"]

_SEP = "|"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    dtypes = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # numpy .npz cannot store ml_dtypes (bfloat16 etc.) — widen to
            # f32 and record the original dtype for restore.
            dtypes[key] = str(arr.dtype)
            arr = np.asarray(leaf, dtype=np.float32)
        out[key] = arr
    return out, treedef, dtypes


def save_pytree(path: str | pathlib.Path, tree, metadata: dict | None = None) -> None:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    arrays, _, dtypes = _flatten(tree)
    # Suffix ends in ".npz" so np.savez writes INTO the mkstemp file
    # instead of appending ".npz" to it — with the old ".tmp" suffix the
    # data landed in a second file and the original empty temp file was
    # an extra artifact to clean up (and survived a crash mid-save).
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp.npz")
    os.close(fd)
    meta = {"__dtypes__": dtypes, **(metadata or {})}
    try:
        np.savez(tmp, __metadata__=json.dumps(meta), **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def load_pytree(path: str | pathlib.Path, like=None, shardings=None):
    """Restore. If ``like`` is given, reconstruct its tree structure; else
    return a flat {path: array} dict. ``shardings`` (same structure as
    ``like``) places leaves onto devices."""
    with np.load(path, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files if k != "__metadata__"}
        meta = json.loads(str(z["__metadata__"])) if "__metadata__" in z.files else {}
    dtypes = meta.pop("__dtypes__", {})
    for key, dt in dtypes.items():
        if key in arrays:
            import ml_dtypes  # ships with jax

            arrays[key] = arrays[key].astype(ml_dtypes.bfloat16 if dt == "bfloat16" else dt)
    if like is None:
        return arrays, meta
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_k, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_k)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(arrays[key])
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree, meta


def save_train_state(path, state, step: int, extra: dict | None = None):
    save_pytree(path, state, metadata={"step": int(step), **(extra or {})})


def load_train_state(path, like, shardings=None):
    return load_pytree(path, like=like, shardings=shardings)
