"""Open-loop request workload generation (DESIGN.md §14).

A serving trace is the *input* to the engine: a deterministic, seeded
list of requests with arrival times fixed in advance — open-loop, so the
load does not slow down when the server falls behind (the regime where
batching policy actually matters; closed-loop clients self-throttle and
hide queueing collapse). Modeled on the Clockwork request simulation
(SNIPPETS.md snippet 3): every request carries its own SLO deadline and
the engine reports per-request sat/unsat.

Generators follow the repo's registry idiom (``repro.ps`` rules,
``repro.transport`` codecs): registered by name, pure functions of
``TraceConfig`` (every field seeded through ``np.random.default_rng``),
so the same config always yields the same trace on any host.

  * ``poisson`` — memoryless arrivals at ``rate`` req/s.
  * ``bursty``  — a modulated Poisson process: each ``burst_period``
    opens with a ``burst_duty`` fraction at ``burst_factor``× the base
    rate (thinning construction), the remainder at the compensating low
    rate — same mean load, spiky queues.

Prompt *content* is not part of the trace: the engine derives each
request's tokens deterministically from (trace seed, request id), so a
trace file stays a few hundred bytes no matter the prompt lengths.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

__all__ = [
    "Request", "TraceConfig",
    "register_trace", "get_trace", "trace_names", "make_trace",
]


@dataclasses.dataclass(frozen=True)
class Request:
    """One inference request of an open-loop trace.

    slo is in *seconds*; the deadline is ``arrival + slo``. ``max_new``
    counts every generated token including the prefill argmax."""

    rid: int
    arrival: float
    prompt_len: int
    max_new: int
    slo: float

    @property
    def deadline(self) -> float:
        return self.arrival + self.slo


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Knobs shared by all trace generators (burst_* used by ``bursty``).

    ``slo_scale`` draws each request's SLO as ``slo_ms/1000 × factor``
    with the factor sampled uniformly from the tuple — heterogeneous
    deadlines are what separates EDF from FCFS.

    ``prompt_weights`` (same length as ``prompt_lens``, auto-normalised)
    skews the prompt-length draw — a heavy tail like
    ``prompt_lens=(8, 16, 96), prompt_weights=(8, 8, 1)`` makes the
    occasional long prompt a straggler among short ones, the workload
    where chunked prefill earns its keep."""

    n_requests: int = 32
    rate: float = 8.0  # mean arrivals per (virtual) second
    prompt_lens: tuple[int, ...] = (8, 16)
    prompt_weights: tuple[float, ...] | None = None
    max_new: tuple[int, int] = (4, 12)  # inclusive range
    slo_ms: float = 1500.0
    slo_scale: tuple[float, ...] = (1.0,)
    seed: int = 0
    # bursty modulation
    burst_factor: float = 4.0
    burst_duty: float = 0.25
    burst_period: float = 4.0

    def __post_init__(self):
        if self.n_requests < 1:
            raise ValueError("n_requests must be >= 1")
        if self.rate <= 0:
            raise ValueError("rate must be > 0")
        if self.max_new[0] < 1 or self.max_new[1] < self.max_new[0]:
            raise ValueError(f"bad max_new range {self.max_new}")
        if not 0.0 < self.burst_duty < 1.0:
            raise ValueError("burst_duty must be in (0, 1)")
        if (self.prompt_weights is not None
                and len(self.prompt_weights) != len(self.prompt_lens)):
            raise ValueError(
                f"prompt_weights {self.prompt_weights} must match "
                f"prompt_lens {self.prompt_lens}"
            )


_TRACES: dict[str, Callable[[TraceConfig], list[Request]]] = {}


def register_trace(name: str):
    def deco(fn):
        _TRACES[name] = fn
        return fn
    return deco


def get_trace(name: str) -> Callable[[TraceConfig], list[Request]]:
    try:
        return _TRACES[name]
    except KeyError:
        raise KeyError(f"unknown trace {name!r}; known: {trace_names()}")


def trace_names() -> list[str]:
    return sorted(_TRACES)


def make_trace(name: str, cfg: TraceConfig) -> list[Request]:
    return get_trace(name)(cfg)


def _fill(cfg: TraceConfig, arrivals: np.ndarray) -> list[Request]:
    """Attach per-request shape/SLO draws to a sorted arrival sequence."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 0xD5]))
    p = None
    if cfg.prompt_weights is not None:
        w = np.asarray(cfg.prompt_weights, np.float64)
        p = w / w.sum()
    lens = rng.choice(np.asarray(cfg.prompt_lens), size=len(arrivals), p=p)
    lo, hi = cfg.max_new
    news = rng.integers(lo, hi + 1, size=len(arrivals))
    scales = rng.choice(np.asarray(cfg.slo_scale, np.float64), size=len(arrivals))
    return [
        Request(rid=i, arrival=float(t), prompt_len=int(lens[i]),
                max_new=int(news[i]), slo=float(cfg.slo_ms / 1e3 * scales[i]))
        for i, t in enumerate(arrivals)
    ]


@register_trace("poisson")
def poisson_trace(cfg: TraceConfig) -> list[Request]:
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 0xA1]))
    gaps = rng.exponential(1.0 / cfg.rate, size=cfg.n_requests)
    return _fill(cfg, np.cumsum(gaps))


@register_trace("bursty")
def bursty_trace(cfg: TraceConfig) -> list[Request]:
    """Thinning: draw candidates at the peak rate, keep each with
    probability rate(t)/peak. rate(t) alternates hi (duty window) / lo
    with the same long-run mean as ``cfg.rate``."""
    hi = cfg.rate * cfg.burst_factor
    lo = max(cfg.rate * (1.0 - cfg.burst_duty * cfg.burst_factor)
             / (1.0 - cfg.burst_duty), 0.0)
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 0xB2]))
    arrivals: list[float] = []
    t = 0.0
    while len(arrivals) < cfg.n_requests:
        t += float(rng.exponential(1.0 / hi))
        phase = (t % cfg.burst_period) / cfg.burst_period
        r = hi if phase < cfg.burst_duty else lo
        if rng.uniform() < r / hi:
            arrivals.append(t)
    return _fill(cfg, np.asarray(arrivals))
