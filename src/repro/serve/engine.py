"""Continuous-batching serving engine (DESIGN.md §14, §17).

The engine turns the one-shot prefill+decode demo into a request-level
server: an open-loop trace (``serve.trace``) feeds an admission queue, a
bounded pool of decode slots (``serve.cache``) runs **one compiled
decode step over the whole pool per tick**, and slots are evicted the
step their request finishes (EOS or max-tokens) and immediately
backfilled from the queue — prefill interleaves with decode, so a free
slot never waits for the rest of the batch. The contrast baseline,
static rebatching (``mode="static"``), admits a full batch only when the
pool is empty and holds every slot until the whole batch drains — same
hardware, same cost model, same per-request token streams.

Two clocks, deliberately separate:

  * tokens come from the *real* model (``lm_prefill_chunk``/
    ``lm_decode_step`` on the actual params) — a request served from a
    pool slot is token-identical to the same request decoded alone
    (enforced per model family by tests/test_serve_parity.py);
  * *time* is virtual, from a deterministic ``CostModel`` (prefill cost
    affine in prompt length, decode cost affine in pool width), so
    latency distributions, SLO attainment, and scheduler comparisons are
    reproducible on any host and "equal hardware" between policies means
    exactly equal step costs.

Prefill runs in two regimes (§17):

  * **monolithic** (``prefill_chunk=0``): one dispatch consumes the
    whole prompt before anything else happens — the engine loop stalls
    for the full prefill cost, exactly the straggler-blocks-the-barrier
    shape ADSP §3 removes from training. Dispatches are jit-cached by
    the prompt length rounded up to a power of two (padding masked by
    ``n_valid``), so realistic traces compile O(log max_len) prefill
    fns, not one per distinct length.
  * **chunked** (``prefill_chunk=C``, continuous mode): prompts are
    admitted to up to ``prefill_batch`` *lanes* (a second ``CachePool``)
    and advanced C tokens at a time — all lanes in **one dispatch** per
    chunk, ragged rows masked — with the chunk *riding the decode step*
    whenever the pool is busy: one combined step costs
    ``decode(slots) + per_token×chunk`` (``CostModel.piggyback``), so a
    2k-token prompt never stalls the decode pool and pays no per-chunk
    dispatch base. Only a standalone chunk (empty pool) pays a base,
    once per dispatch however many lanes share it — batched prefill
    admission amortizes exactly that.

Admission order is a registered scheduler: ``fcfs`` (arrival order) or
``deadline`` (earliest deadline first — EDF spends slack where it
exists). Between decode steps the engine can poll a ``ReplicaSync``
(``serve.sync``) so the served model tracks a live training PS via
version-stale shard pulls.

The run loop is a stepping API (``submit``/``run_until``) so a
``serve.balance.LoadBalancer`` can drive N engines on one virtual clock;
``run()`` is the single-replica convenience that feeds the engine's own
trace through it.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import lm_tokens
from repro.fleet.metrics import PullRecord, ServeRecord
from repro.models import lm

from .cache import CachePool
from .sync import ReplicaSync
from .trace import Request

__all__ = [
    "CostModel", "ServeConfig", "ServeReport", "ServeEngine", "serve_trace",
    "solo_decode",
    "register_scheduler", "get_scheduler", "scheduler_names",
]

Pytree = Any

_EPS = 1e-12


# ---------------------------------------------------------------------------
# virtual step costs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Virtual seconds per engine operation. Affine models: prefill in
    prompt tokens, decode in pool width (every slot is computed whether
    occupied or not — that is precisely static batching's waste).

    Chunked prefill is priced at the *step* level, the way continuous
    batching actually schedules it: when a decode step is running
    anyway, the chunk's tokens ride that step — ``piggyback`` charges
    only their per-token work, the dispatch base is already paid by the
    decode step. Only a *standalone* chunk dispatch (empty decode pool)
    pays a base (``chunk``): the base is paid once per dispatch however
    many lanes advance, which is what batched prefill admission buys."""

    prefill_base: float = 2e-3
    prefill_per_token: float = 2.5e-4
    decode_base: float = 4e-3
    decode_per_slot: float = 1e-3
    chunk_base: float | None = None  # standalone-chunk base (None: prefill_base)

    def prefill(self, prompt_len: int) -> float:
        return self.prefill_base + self.prefill_per_token * prompt_len

    def decode(self, n_slots: int) -> float:
        return self.decode_base + self.decode_per_slot * n_slots

    def chunk(self, tokens: int) -> float:
        base = self.prefill_base if self.chunk_base is None else self.chunk_base
        return base + self.prefill_per_token * tokens

    def piggyback(self, tokens: int) -> float:
        """Marginal cost of chunk tokens sharing a decode step."""
        return self.prefill_per_token * tokens


# ---------------------------------------------------------------------------
# admission schedulers (registry idiom, as repro.ps / repro.transport)
# ---------------------------------------------------------------------------

_SCHEDULERS: dict[str, Callable[[], "AdmissionScheduler"]] = {}


def register_scheduler(name: str):
    def deco(cls):
        _SCHEDULERS[name] = cls
        return cls
    return deco


def get_scheduler(name: str) -> "AdmissionScheduler":
    try:
        return _SCHEDULERS[name]()
    except KeyError:
        raise KeyError(f"unknown scheduler {name!r}; known: {scheduler_names()}")


def scheduler_names() -> list[str]:
    return sorted(_SCHEDULERS)


class AdmissionScheduler:
    """Picks which queued request gets the next free slot."""

    def pick(self, queue: list[Request], t: float) -> int:
        raise NotImplementedError


@register_scheduler("fcfs")
class FCFSScheduler(AdmissionScheduler):
    def pick(self, queue: list[Request], t: float) -> int:
        return min(range(len(queue)),
                   key=lambda i: (queue[i].arrival, queue[i].rid))


@register_scheduler("deadline")
class DeadlineScheduler(AdmissionScheduler):
    """Earliest deadline first (ties to arrival, then rid)."""

    def pick(self, queue: list[Request], t: float) -> int:
        return min(range(len(queue)),
                   key=lambda i: (queue[i].deadline, queue[i].arrival, queue[i].rid))


# ---------------------------------------------------------------------------
# engine config / report
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """slots: decode-slot pool width. mode: 'continuous' (per-step
    evict + backfill) or 'static' (rebatch only when the pool drains).
    sync_every: decode steps between PS polls (0 = never). capacity:
    attention cache length per slot; 0 derives the minimum from the
    trace (max prompt + max new tokens). prefill_chunk: tokens per
    chunked-prefill dispatch (0 = monolithic prefill); prefill_batch:
    concurrent prefill lanes sharing each chunk dispatch."""

    slots: int = 4
    scheduler: str = "fcfs"
    mode: str = "continuous"
    eos_id: int | None = None
    sync_every: int = 0
    capacity: int = 0
    seed: int = 0
    prefill_chunk: int = 0
    prefill_batch: int = 1
    cost: CostModel = dataclasses.field(default_factory=CostModel)

    def __post_init__(self):
        if self.slots < 1:
            raise ValueError("slots must be >= 1")
        if self.mode not in ("continuous", "static"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.prefill_chunk < 0:
            raise ValueError("prefill_chunk must be >= 0")
        if self.prefill_batch < 1:
            raise ValueError("prefill_batch must be >= 1")
        if self.prefill_chunk and self.mode != "continuous":
            raise ValueError(
                "chunked prefill interleaves with decode; static mode "
                "rebatches whole pools and cannot use it"
            )


@dataclasses.dataclass
class ServeReport:
    """Everything a run produced: the per-request records (also streamed
    to the metrics sink as they happen) plus aggregates."""

    records: list[ServeRecord]
    t_end: float
    decode_steps: int
    tokens_by_rid: dict[int, list[int]]
    inserts: int
    evictions: int
    sync_polls: int = 0
    sync_pulls: int = 0
    pull_bytes: int = 0
    full_pull_bytes: int = 0  # dense re-pull at the same pull points
    chunk_dispatches: int = 0  # chunked-prefill dispatches (0 = monolithic)

    # ------------------------------------------------------------ derived
    def _vals(self, field: str) -> list[float]:
        return [getattr(r, field) for r in self.records]

    @staticmethod
    def _pct(xs: list[float], q: float) -> float:
        if not xs:
            return 0.0
        xs = sorted(xs)
        return xs[min(len(xs) - 1, int(round(q * (len(xs) - 1))))]

    def percentile(self, field: str, q: float) -> float:
        return self._pct(self._vals(field), q)

    @property
    def total_tokens(self) -> int:
        return sum(r.tokens for r in self.records)

    @property
    def slo_attainment(self) -> float:
        if not self.records:
            return 0.0
        return sum(r.slo_ok for r in self.records) / len(self.records)

    @property
    def goodput(self) -> float:
        """SLO-attained requests per virtual second."""
        if self.t_end <= 0:
            return 0.0
        return sum(r.slo_ok for r in self.records) / self.t_end

    @property
    def tokens_per_s(self) -> float:
        return self.total_tokens / self.t_end if self.t_end > 0 else 0.0


@dataclasses.dataclass
class _Active:
    req: Request
    t_admit: float
    prefill_s: float
    gen: int
    tokens: list[int]


@dataclasses.dataclass
class _Lane:
    """One chunked-prefill lane: a request whose prompt is being consumed
    ``prefill_chunk`` tokens per shared dispatch. ``first`` is the
    prefill argmax once the prompt is fully consumed (the lane then
    waits for a decode slot); ``t_first`` stamps that dispatch."""

    req: Request
    t_admit: float
    consumed: int = 0
    first: int | None = None
    t_first: float = 0.0


def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length() if n > 1 else 1


def _prev_pow2(n: int) -> int:
    return 1 << (n.bit_length() - 1)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


class ServeEngine:
    """One serving replica: model + slot pool + admission queue.

    ``sync`` (a ``ReplicaSync``) makes the replica track a live training
    PS; ``tick`` is called as ``tick(engine, t)`` once per decode step
    *before* the sync poll — benchmarks use it to advance a co-running
    trainer to the serving clock and to probe serving-side loss.
    ``replica`` stamps this engine's records when several engines share
    one metrics stream under a ``serve.balance.LoadBalancer``.
    """

    def __init__(self, cfg, params: Pytree, serve_cfg: ServeConfig,
                 trace: list[Request], *, metrics=None,
                 sync: ReplicaSync | None = None,
                 tick: Callable[["ServeEngine", float], None] | None = None,
                 replica: int = 0):
        if cfg.frontend or cfg.encoder is not None:
            raise ValueError(
                "the serve engine drives token-only decoders; "
                f"{cfg.name} needs a modality frontend at prefill"
            )
        if serve_cfg.sync_every and sync is None:
            raise ValueError("sync_every > 0 needs a ReplicaSync")
        self.cfg = cfg
        self.params = params
        self.serve_cfg = serve_cfg
        self.trace = sorted(trace, key=lambda r: (r.arrival, r.rid))
        self.metrics = metrics
        self.sync = sync
        self.tick = tick
        self.replica = replica
        need = max((r.prompt_len + r.max_new for r in self.trace), default=2)
        cap = serve_cfg.capacity or need
        if cap < need:
            raise ValueError(f"capacity {cap} < trace requirement {need}")
        self.pool = CachePool(cfg, serve_cfg.slots, cap)
        self.scheduler = get_scheduler(serve_cfg.scheduler)
        # chunks larger than the smallest ring cache would overwrite keys
        # the chunk's own early queries still need (models.lm.max_chunk_len)
        self._ring_limit = lm.max_chunk_len(cfg, cap)
        if serve_cfg.prefill_chunk and self._ring_limit is not None and \
                serve_cfg.prefill_chunk > self._ring_limit:
            raise ValueError(
                f"prefill_chunk {serve_cfg.prefill_chunk} exceeds the "
                f"smallest ring cache capacity {self._ring_limit} of {cfg.name}"
            )
        self.lanes: CachePool | None = None
        if serve_cfg.prefill_chunk:
            self.lanes = CachePool(cfg, serve_cfg.prefill_batch, cap)
            self._chunk_fn = jax.jit(self._chunk_step)
        self._decode = jax.jit(self._decode_fn)
        # monolithic prefill dispatches, jit-cached by pow2-padded length
        self._prefill_fns: dict[int, Callable] = {}
        self._last_tok = np.zeros((serve_cfg.slots,), np.int32)
        self._slots: dict[int, _Active] = {}
        self._begin()

    # ---------------------------------------------------------- jitted fns
    def _decode_fn(self, params, toks, caches):
        """One pool-wide decode step; the argmax stays on device so the
        loop ships (slots,) token ids, not (slots, vocab) logits."""
        logits, caches = lm.lm_decode_step(self.cfg, params, {"tokens": toks}, caches)
        return jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32), caches

    def _chunk_step(self, params, toks, caches, start, nv):
        """Advance every prefill lane by one (ragged) chunk."""
        logits, caches = lm.lm_prefill_chunk(
            self.cfg, params, {"tokens": toks}, caches, start, n_valid=nv
        )
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    def _build_prefill_fn(self, padded: int) -> Callable:
        """Monolithic prefill at bucket length ``padded`` (pow2): fresh
        caches + the chunk path over the whole (masked) prompt, split
        into ring-safe sub-blocks when a sliding window caps the chunk."""
        cap = self.pool.capacity
        step = padded if self._ring_limit is None else \
            min(padded, _prev_pow2(self._ring_limit))
        nblk = (padded + step - 1) // step

        def fn(params, toks, nv):
            caches = lm.init_decode_caches(self.cfg, 1, cap)
            lgs = []
            for j in range(nblk):
                off = j * step
                lg, caches = lm.lm_prefill_chunk(
                    self.cfg, params, {"tokens": toks[:, off:off + step]},
                    caches, jnp.full((1,), off, jnp.int32),
                    n_valid=jnp.clip(nv - off, 0, step),
                )
                lgs.append(lg)
            # the last *valid* block holds the first-token logits
            jstar = jnp.clip((nv[0] - 1) // step, 0, nblk - 1)
            lg = jnp.stack(lgs)[jstar]  # (1, V)
            return jnp.argmax(lg, axis=-1).astype(jnp.int32), caches

        return jax.jit(fn)

    # ------------------------------------------------------------ helpers
    def prompt_tokens(self, req: Request) -> np.ndarray:
        """Deterministic (1, prompt_len) prompt for a request: a pure
        function of (engine seed, rid) — test harnesses rebuild it to
        replay a request solo."""
        toks = lm_tokens(self.serve_cfg.seed, req.rid, 1,
                         req.prompt_len, self.cfg.vocab_size)
        return toks[:, : req.prompt_len]

    def _prefill(self, req: Request):
        padded = _next_pow2(req.prompt_len)
        fn = self._prefill_fns.get(padded)
        if fn is None:
            fn = self._build_prefill_fn(padded)
            self._prefill_fns[padded] = fn
        toks = np.zeros((1, padded), np.int32)
        toks[:, : req.prompt_len] = self.prompt_tokens(req)
        tok, caches = fn(self.params, jnp.asarray(toks),
                         jnp.asarray([req.prompt_len], jnp.int32))
        return int(tok[0]), caches

    def _version(self) -> int:
        return self.sync.version if self.sync is not None else 0

    def _complete(self, st: _Active, t: float, *, prefill_only: bool = False):
        r = st.req
        t_first = st.t_admit + st.prefill_s
        rec = ServeRecord(
            t=t, req=r.rid,
            queue=st.t_admit - r.arrival,
            prefill=st.prefill_s,
            decode=0.0 if prefill_only else t - t_first,
            total=t - r.arrival,
            tokens=st.gen, slo=r.slo,
            slo_ok=bool(t <= r.deadline + _EPS),
            version=self._version(),
            replica=self.replica,
        )
        self._done.append(rec)
        self._tokens_by_rid[r.rid] = st.tokens
        if self.metrics is not None:
            self.metrics.record(rec)

    # ---------------------------------------------------------- stepping API
    #
    # The balancer drives N engines on one virtual clock through these
    # three calls; run() is the single-replica composition. One _step()
    # performs exactly one *timed* action (a prefill, a chunk dispatch,
    # or a decode step) plus any zero-cost bookkeeping before it, so the
    # clock only ever advances inside _step().

    def _begin(self):
        self.t = 0.0
        self._queue: list[Request] = []
        self._done: list[ServeRecord] = []
        self._tokens_by_rid: dict[int, list[int]] = {}
        self._decode_steps = 0
        self._chunk_dispatches = 0
        self._filling = False  # static mode: batch-formation phase
        self._lanes: dict[int, _Lane] = {}
        self._prompt_np: dict[int, np.ndarray] = {}
        self._chunk_tok = None  # last chunk dispatch's device-side argmaxes

    def submit(self, req: Request) -> None:
        """Hand a request to the admission queue (arrival bookkeeping is
        the caller's: submit when the clock reaches ``req.arrival``)."""
        self._queue.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self._queue or self._slots or self._lanes)

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    @property
    def n_active(self) -> int:
        """Requests holding a decode slot or a prefill lane."""
        return len(self._slots) + len(self._lanes)

    def backlog_seconds(self) -> float:
        """Deterministic service-time estimate for everything queued or
        in flight — the ``deadline_slack`` router's load signal."""
        cost = self.serve_cfg.cost
        per_tok = cost.decode(self.serve_cfg.slots)
        s = 0.0
        for st in self._slots.values():
            s += max(st.req.max_new - st.gen, 0) * per_tok
        for lane in self._lanes.values():
            rem = lane.req.prompt_len - lane.consumed
            if rem > 0:
                s += cost.prefill(rem)
            s += lane.req.max_new * per_tok
        for q in self._queue:
            s += cost.prefill(q.prompt_len) + q.max_new * per_tok
        return s

    def run_until(self, t: float) -> None:
        """Process work while the clock is before ``t`` (an action that
        *starts* before ``t`` may finish past it — the caller submits
        arrivals that landed mid-action before the next one). Idle
        engines jump their clock straight to ``t``."""
        while self.has_work and self.t < t - _EPS:
            if not self._step():
                break
        if math.isfinite(t) and not self.has_work and self.t < t:
            self.t = t

    def finish(self) -> ServeReport:
        report = ServeReport(
            records=self._done, t_end=self.t, decode_steps=self._decode_steps,
            tokens_by_rid=self._tokens_by_rid,
            inserts=self.pool.inserts, evictions=self.pool.evictions,
            chunk_dispatches=self._chunk_dispatches,
        )
        if self.sync is not None:
            report.sync_polls = self.sync.polls
            report.sync_pulls = self.sync.pulls
            report.pull_bytes = self.sync.bytes_pulled
            report.full_pull_bytes = self.sync.full_bytes_equiv
        return report

    # -------------------------------------------------------------- steps
    def _step(self) -> bool:
        """One timed action; False when nothing can run (idle)."""
        if self.serve_cfg.prefill_chunk:
            return self._step_chunked()
        return self._step_monolithic()

    def _step_monolithic(self) -> bool:
        cfg = self.serve_cfg
        if cfg.mode == "static" and not self._slots and self._queue:
            self._filling = True
        can_admit = (self.pool.n_free > 0 and
                     (cfg.mode == "continuous" or self._filling))

        if self._queue and can_admit:
            req = self._queue.pop(self.scheduler.pick(self._queue, self.t))
            t_admit = self.t
            first, caches = self._prefill(req)
            pf = cfg.cost.prefill(req.prompt_len)
            self.t += pf
            st = _Active(req=req, t_admit=t_admit, prefill_s=pf,
                         gen=1, tokens=[first])
            done_now = (req.max_new <= 1 or
                        (cfg.eos_id is not None and first == cfg.eos_id))
            if done_now:
                self._complete(st, self.t, prefill_only=True)
            else:
                slot = self.pool.insert(req.rid, caches)
                self._last_tok[slot] = first
                self._slots[slot] = st
            return True
        self._filling = False

        if not self._slots:
            return False
        self._decode_step()
        return True

    def _step_chunked(self) -> bool:
        # lane admission is zero-cost bookkeeping: the scheduler hands
        # queued requests to free lanes, then finished lanes drain into
        # free decode slots, then exactly one timed step runs. When both
        # kinds of work exist, the chunk rides the decode step (one
        # combined step: decode cost + the chunk's per-token work); a
        # standalone chunk (empty pool) pays its own dispatch base.
        while self._queue and self.lanes.n_free > 0:
            req = self._queue.pop(self.scheduler.pick(self._queue, self.t))
            slot = self.lanes.admit(req.rid)
            self._lanes[slot] = _Lane(req=req, t_admit=self.t)
            self._prompt_np[req.rid] = self.prompt_tokens(req)
        self._drain_ready()

        chunk_work = any(l.first is None for l in self._lanes.values())
        decode_work = bool(self._slots)
        if chunk_work and decode_work:
            pend = self._chunk_issue()
            self._decode_step(piggyback_tokens=pend[2])
            self._chunk_finalize(pend)
            self._drain_ready()
            return True
        if chunk_work:
            pend = self._chunk_issue()
            self.t += self.serve_cfg.cost.chunk(pend[2])
            self._chunk_finalize(pend)
            self._drain_ready()
            return True
        if decode_work:
            self._decode_step()
            return True
        return False

    def _chunk_issue(self):
        """Dispatch one (ragged) chunk over every mid-prompt lane.
        Device work only — the clock and lane bookkeeping advance in
        ``_chunk_finalize`` once the step this dispatch rides is priced.
        Returns (active lane slots, per-lane valid counts, total)."""
        n_lanes, chunk = self.lanes.n_slots, self.serve_cfg.prefill_chunk
        blk = np.zeros((n_lanes, chunk), np.int32)
        nv = np.zeros((n_lanes,), np.int32)
        start = np.zeros((n_lanes,), np.int32)
        active = []
        for slot in sorted(self._lanes):
            lane = self._lanes[slot]
            if lane.first is not None:
                continue  # prefilled, waiting for a decode slot
            n = min(chunk, lane.req.prompt_len - lane.consumed)
            nv[slot], start[slot] = n, lane.consumed
            prompt = self._prompt_np[lane.req.rid]
            blk[slot, :n] = prompt[0, lane.consumed:lane.consumed + n]
            active.append(slot)
        tok, self.lanes.caches = self._chunk_fn(
            self.params, jnp.asarray(blk), self.lanes.caches,
            jnp.asarray(start), jnp.asarray(nv),
        )
        self._chunk_dispatches += 1
        self._chunk_tok = tok  # device array; fetched in finalize
        return active, nv, int(nv.sum())

    def _chunk_finalize(self, pend) -> None:
        cfg = self.serve_cfg
        active, nv, _ = pend
        tok_host = np.asarray(self._chunk_tok)
        for slot in active:
            lane = self._lanes[slot]
            lane.consumed += int(nv[slot])
            if lane.consumed >= lane.req.prompt_len:
                lane.first = int(tok_host[slot])
                lane.t_first = self.t
                done_now = (lane.req.max_new <= 1 or
                            (cfg.eos_id is not None and
                             lane.first == cfg.eos_id))
                if done_now:
                    st = _Active(req=lane.req, t_admit=lane.t_admit,
                                 prefill_s=lane.t_first - lane.t_admit,
                                 gen=1, tokens=[lane.first])
                    self._complete(st, self.t, prefill_only=True)
                    self._free_lane(slot)

    def _free_lane(self, slot: int) -> None:
        lane = self._lanes.pop(slot)
        self.lanes.evict(lane.req.rid)
        del self._prompt_np[lane.req.rid]

    def _drain_ready(self) -> None:
        """Move prefilled lanes into free decode slots (lane order)."""
        for slot in sorted(self._lanes):
            lane = self._lanes[slot]
            if lane.first is None:
                continue
            if self.pool.n_free == 0:
                break
            src = self.lanes.extract(lane.req.rid)
            dslot = self.pool.insert(lane.req.rid, src)
            self._last_tok[dslot] = lane.first
            self._slots[dslot] = _Active(
                req=lane.req, t_admit=lane.t_admit,
                prefill_s=lane.t_first - lane.t_admit,
                gen=1, tokens=[lane.first],
            )
            self._free_lane(slot)

    def _decode_step(self, piggyback_tokens: int = 0) -> None:
        cfg = self.serve_cfg
        toks = jnp.asarray(self._last_tok[:, None])
        tok_ids, self.pool.caches = self._decode(
            self.params, toks, self.pool.caches
        )
        self.t += cfg.cost.decode(cfg.slots)
        if piggyback_tokens:
            self.t += cfg.cost.piggyback(piggyback_tokens)
        self._decode_steps += 1

        if self.tick is not None:
            self.tick(self, self.t)
        if (self.sync is not None and cfg.sync_every
                and self._decode_steps % cfg.sync_every == 0):
            self.params, n_stale, nbytes, secs = self.sync.poll(self.params)
            self.t += secs
            if n_stale and self.metrics is not None:
                self.metrics.record(PullRecord(
                    t=self.t, stale_shards=n_stale,
                    n_shards=self.sync.plan.n_shards, nbytes=float(nbytes),
                    replica=self.replica,
                ))

        next_tok = np.asarray(tok_ids)
        for slot in sorted(self._slots):
            st = self._slots[slot]
            tok = int(next_tok[slot])
            st.tokens.append(tok)
            st.gen += 1
            self._last_tok[slot] = tok
            if (st.gen >= st.req.max_new or
                    (cfg.eos_id is not None and tok == cfg.eos_id)):
                self._complete(st, self.t)
                self.pool.evict(st.req.rid)
                del self._slots[slot]
        if self.serve_cfg.prefill_chunk:
            self._drain_ready()

    # -------------------------------------------------------------- run
    def run(self) -> ServeReport:
        self._begin()
        for req in self.trace:
            self.run_until(req.arrival)
            self.submit(req)
        self.run_until(math.inf)
        return self.finish()


def serve_trace(cfg, params: Pytree, serve_cfg: ServeConfig,
                trace: list[Request], **kw) -> ServeReport:
    """Convenience: build an engine and run the trace to completion."""
    return ServeEngine(cfg, params, serve_cfg, trace, **kw).run()


def solo_decode(cfg, params: Pytree, prompt: np.ndarray, max_new: int,
                capacity: int, *, eos_id: int | None = None) -> list[int]:
    """Reference decode of one request alone (batch 1) at the same cache
    capacity a pool would give it — the token-identity oracle for
    tests/test_serve_parity.py and the degenerate one-shot path."""
    plen = prompt.shape[1]
    logits, caches = lm.lm_prefill(
        cfg, params, {"tokens": jnp.asarray(prompt, jnp.int32)},
        reserve=capacity - plen,
    )
    tok = int(jnp.argmax(logits[0]))
    out = [tok]
    while len(out) < max_new and not (eos_id is not None and tok == eos_id):
        lg, caches = lm.lm_decode_step(
            cfg, params, {"tokens": jnp.asarray([[tok]], jnp.int32)}, caches
        )
        tok = int(jnp.argmax(lg[0, 0]))
        out.append(tok)
    return out
