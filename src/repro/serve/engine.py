"""Continuous-batching serving engine (DESIGN.md §14).

The engine turns the one-shot prefill+decode demo into a request-level
server: an open-loop trace (``serve.trace``) feeds an admission queue, a
bounded pool of decode slots (``serve.cache``) runs **one compiled
decode step over the whole pool per tick**, and slots are evicted the
step their request finishes (EOS or max-tokens) and immediately
backfilled from the queue — prefill interleaves with decode, so a free
slot never waits for the rest of the batch. The contrast baseline,
static rebatching (``mode="static"``), admits a full batch only when the
pool is empty and holds every slot until the whole batch drains — same
hardware, same cost model, same per-request token streams.

Two clocks, deliberately separate:

  * tokens come from the *real* model (``lm_prefill``/``lm_decode_step``
    on the actual params) — a request served from a pool slot is
    bit-identical to the same request decoded alone (enforced per model
    family by tests/test_serve_parity.py);
  * *time* is virtual, from a deterministic ``CostModel`` (prefill cost
    affine in prompt length, decode cost affine in pool width), so
    latency distributions, SLO attainment, and scheduler comparisons are
    reproducible on any host and "equal hardware" between policies means
    exactly equal step costs.

Admission order is a registered scheduler: ``fcfs`` (arrival order) or
``deadline`` (earliest deadline first — EDF spends slack where it
exists). Between decode steps the engine can poll a ``ReplicaSync``
(``serve.sync``) so the served model tracks a live training PS via
version-stale shard pulls.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import lm_tokens
from repro.fleet.metrics import PullRecord, ServeRecord
from repro.models import lm

from .cache import CachePool
from .sync import ReplicaSync
from .trace import Request

__all__ = [
    "CostModel", "ServeConfig", "ServeReport", "ServeEngine", "serve_trace",
    "solo_decode",
    "register_scheduler", "get_scheduler", "scheduler_names",
]

Pytree = Any


# ---------------------------------------------------------------------------
# virtual step costs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Virtual seconds per engine operation. Affine models: prefill in
    prompt tokens, decode in pool width (every slot is computed whether
    occupied or not — that is precisely static batching's waste)."""

    prefill_base: float = 2e-3
    prefill_per_token: float = 2.5e-4
    decode_base: float = 4e-3
    decode_per_slot: float = 1e-3

    def prefill(self, prompt_len: int) -> float:
        return self.prefill_base + self.prefill_per_token * prompt_len

    def decode(self, n_slots: int) -> float:
        return self.decode_base + self.decode_per_slot * n_slots


# ---------------------------------------------------------------------------
# admission schedulers (registry idiom, as repro.ps / repro.transport)
# ---------------------------------------------------------------------------

_SCHEDULERS: dict[str, Callable[[], "AdmissionScheduler"]] = {}


def register_scheduler(name: str):
    def deco(cls):
        _SCHEDULERS[name] = cls
        return cls
    return deco


def get_scheduler(name: str) -> "AdmissionScheduler":
    try:
        return _SCHEDULERS[name]()
    except KeyError:
        raise KeyError(f"unknown scheduler {name!r}; known: {scheduler_names()}")


def scheduler_names() -> list[str]:
    return sorted(_SCHEDULERS)


class AdmissionScheduler:
    """Picks which queued request gets the next free slot."""

    def pick(self, queue: list[Request], t: float) -> int:
        raise NotImplementedError


@register_scheduler("fcfs")
class FCFSScheduler(AdmissionScheduler):
    def pick(self, queue: list[Request], t: float) -> int:
        return min(range(len(queue)),
                   key=lambda i: (queue[i].arrival, queue[i].rid))


@register_scheduler("deadline")
class DeadlineScheduler(AdmissionScheduler):
    """Earliest deadline first (ties to arrival, then rid)."""

    def pick(self, queue: list[Request], t: float) -> int:
        return min(range(len(queue)),
                   key=lambda i: (queue[i].deadline, queue[i].arrival, queue[i].rid))


# ---------------------------------------------------------------------------
# engine config / report
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """slots: decode-slot pool width. mode: 'continuous' (per-step
    evict + backfill) or 'static' (rebatch only when the pool drains).
    sync_every: decode steps between PS polls (0 = never). capacity:
    attention cache length per slot; 0 derives the minimum from the
    trace (max prompt + max new tokens)."""

    slots: int = 4
    scheduler: str = "fcfs"
    mode: str = "continuous"
    eos_id: int | None = None
    sync_every: int = 0
    capacity: int = 0
    seed: int = 0
    cost: CostModel = dataclasses.field(default_factory=CostModel)

    def __post_init__(self):
        if self.slots < 1:
            raise ValueError("slots must be >= 1")
        if self.mode not in ("continuous", "static"):
            raise ValueError(f"unknown mode {self.mode!r}")


@dataclasses.dataclass
class ServeReport:
    """Everything a run produced: the per-request records (also streamed
    to the metrics sink as they happen) plus aggregates."""

    records: list[ServeRecord]
    t_end: float
    decode_steps: int
    tokens_by_rid: dict[int, list[int]]
    inserts: int
    evictions: int
    sync_polls: int = 0
    sync_pulls: int = 0
    pull_bytes: int = 0
    full_pull_bytes: int = 0  # dense re-pull at the same pull points

    # ------------------------------------------------------------ derived
    def _vals(self, field: str) -> list[float]:
        return [getattr(r, field) for r in self.records]

    @staticmethod
    def _pct(xs: list[float], q: float) -> float:
        if not xs:
            return 0.0
        xs = sorted(xs)
        return xs[min(len(xs) - 1, int(round(q * (len(xs) - 1))))]

    def percentile(self, field: str, q: float) -> float:
        return self._pct(self._vals(field), q)

    @property
    def total_tokens(self) -> int:
        return sum(r.tokens for r in self.records)

    @property
    def slo_attainment(self) -> float:
        if not self.records:
            return 0.0
        return sum(r.slo_ok for r in self.records) / len(self.records)

    @property
    def goodput(self) -> float:
        """SLO-attained requests per virtual second."""
        if self.t_end <= 0:
            return 0.0
        return sum(r.slo_ok for r in self.records) / self.t_end

    @property
    def tokens_per_s(self) -> float:
        return self.total_tokens / self.t_end if self.t_end > 0 else 0.0


@dataclasses.dataclass
class _Active:
    req: Request
    t_admit: float
    prefill_s: float
    gen: int
    tokens: list[int]


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


class ServeEngine:
    """One serving replica: model + slot pool + admission queue.

    ``sync`` (a ``ReplicaSync``) makes the replica track a live training
    PS; ``tick`` is called as ``tick(engine, t)`` once per decode step
    *before* the sync poll — benchmarks use it to advance a co-running
    trainer to the serving clock and to probe serving-side loss.
    """

    def __init__(self, cfg, params: Pytree, serve_cfg: ServeConfig,
                 trace: list[Request], *, metrics=None,
                 sync: ReplicaSync | None = None,
                 tick: Callable[["ServeEngine", float], None] | None = None):
        if cfg.frontend or cfg.encoder is not None:
            raise ValueError(
                "the serve engine drives token-only decoders; "
                f"{cfg.name} needs a modality frontend at prefill"
            )
        if serve_cfg.sync_every and sync is None:
            raise ValueError("sync_every > 0 needs a ReplicaSync")
        self.cfg = cfg
        self.params = params
        self.serve_cfg = serve_cfg
        self.trace = sorted(trace, key=lambda r: (r.arrival, r.rid))
        self.metrics = metrics
        self.sync = sync
        self.tick = tick
        need = max((r.prompt_len + r.max_new for r in self.trace), default=2)
        cap = serve_cfg.capacity or need
        if cap < need:
            raise ValueError(f"capacity {cap} < trace requirement {need}")
        self.pool = CachePool(cfg, serve_cfg.slots, cap)
        self.scheduler = get_scheduler(serve_cfg.scheduler)
        self._decode = jax.jit(
            lambda p, toks, c: lm.lm_decode_step(cfg, p, {"tokens": toks}, c)
        )
        self._prefill_fns: dict[int, Callable] = {}
        self._last_tok = np.zeros((serve_cfg.slots,), np.int32)
        self._slots: dict[int, _Active] = {}

    # ------------------------------------------------------------ helpers
    def prompt_tokens(self, req: Request) -> np.ndarray:
        """Deterministic (1, prompt_len) prompt for a request: a pure
        function of (engine seed, rid) — test harnesses rebuild it to
        replay a request solo."""
        toks = lm_tokens(self.serve_cfg.seed, req.rid, 1,
                         req.prompt_len, self.cfg.vocab_size)
        return toks[:, : req.prompt_len]

    def _prefill(self, req: Request):
        reserve = self.pool.capacity - req.prompt_len
        fn = self._prefill_fns.get(req.prompt_len)
        if fn is None:
            fn = jax.jit(
                lambda p, b, _r=reserve: lm.lm_prefill(self.cfg, p, b, reserve=_r)
            )
            self._prefill_fns[req.prompt_len] = fn
        batch = {"tokens": jnp.asarray(self.prompt_tokens(req), jnp.int32)}
        logits, caches = fn(self.params, batch)
        first = int(np.argmax(np.asarray(logits[0])))
        return first, caches

    def _version(self) -> int:
        return self.sync.version if self.sync is not None else 0

    def _complete(self, st: _Active, t: float, *, prefill_only: bool = False):
        r = st.req
        t_first = st.t_admit + st.prefill_s
        rec = ServeRecord(
            t=t, req=r.rid,
            queue=st.t_admit - r.arrival,
            prefill=st.prefill_s,
            decode=0.0 if prefill_only else t - t_first,
            total=t - r.arrival,
            tokens=st.gen, slo=r.slo,
            slo_ok=bool(t <= r.deadline + 1e-12),
            version=self._version(),
        )
        self._done.append(rec)
        self._tokens_by_rid[r.rid] = st.tokens
        if self.metrics is not None:
            self.metrics.record(rec)

    # -------------------------------------------------------------- run
    def run(self) -> ServeReport:
        cfg = self.serve_cfg
        cost = cfg.cost
        self._done: list[ServeRecord] = []
        self._tokens_by_rid: dict[int, list[int]] = {}
        queue: list[Request] = []
        t, i, n = 0.0, 0, len(self.trace)
        decode_steps = 0
        filling = False  # static mode: batch-formation phase

        while i < n or queue or self._slots:
            # open-loop admission: everything that has arrived by now
            while i < n and self.trace[i].arrival <= t + 1e-12:
                queue.append(self.trace[i])
                i += 1

            if cfg.mode == "static" and not self._slots and queue:
                filling = True
            can_admit = (self.pool.n_free > 0 and
                         (cfg.mode == "continuous" or filling))

            if queue and can_admit:
                req = queue.pop(self.scheduler.pick(queue, t))
                t_admit = t
                first, caches = self._prefill(req)
                pf = cost.prefill(req.prompt_len)
                t += pf
                st = _Active(req=req, t_admit=t_admit, prefill_s=pf,
                             gen=1, tokens=[first])
                done_now = (req.max_new <= 1 or
                            (cfg.eos_id is not None and first == cfg.eos_id))
                if done_now:
                    self._complete(st, t, prefill_only=True)
                else:
                    slot = self.pool.insert(req.rid, caches)
                    self._last_tok[slot] = first
                    self._slots[slot] = st
                continue  # re-admit arrivals that landed during prefill
            filling = False

            if not self._slots:
                if i < n:  # idle: jump to the next arrival
                    t = max(t, self.trace[i].arrival)
                    continue
                break  # queue empty, nothing active, trace exhausted

            # one decode step over the whole pool
            toks = jnp.asarray(self._last_tok[:, None])
            logits, self.pool.caches = self._decode(
                self.params, toks, self.pool.caches
            )
            t += cost.decode(cfg.slots)
            decode_steps += 1

            if self.tick is not None:
                self.tick(self, t)
            if (self.sync is not None and cfg.sync_every
                    and decode_steps % cfg.sync_every == 0):
                self.params, n_stale, nbytes, secs = self.sync.poll(self.params)
                t += secs
                if n_stale and self.metrics is not None:
                    self.metrics.record(PullRecord(
                        t=t, stale_shards=n_stale,
                        n_shards=self.sync.plan.n_shards, nbytes=float(nbytes),
                    ))

            next_tok = np.argmax(np.asarray(logits[:, 0]), axis=-1)
            for slot in sorted(self._slots):
                st = self._slots[slot]
                tok = int(next_tok[slot])
                st.tokens.append(tok)
                st.gen += 1
                self._last_tok[slot] = tok
                if (st.gen >= st.req.max_new or
                        (cfg.eos_id is not None and tok == cfg.eos_id)):
                    self._complete(st, t)
                    self.pool.evict(st.req.rid)
                    del self._slots[slot]

        report = ServeReport(
            records=self._done, t_end=t, decode_steps=decode_steps,
            tokens_by_rid=self._tokens_by_rid,
            inserts=self.pool.inserts, evictions=self.pool.evictions,
        )
        if self.sync is not None:
            report.sync_polls = self.sync.polls
            report.sync_pulls = self.sync.pulls
            report.pull_bytes = self.sync.bytes_pulled
            report.full_pull_bytes = self.sync.full_bytes_equiv
        return report


def serve_trace(cfg, params: Pytree, serve_cfg: ServeConfig,
                trace: list[Request], **kw) -> ServeReport:
    """Convenience: build an engine and run the trace to completion."""
    return ServeEngine(cfg, params, serve_cfg, trace, **kw).run()


def solo_decode(cfg, params: Pytree, prompt: np.ndarray, max_new: int,
                capacity: int, *, eos_id: int | None = None) -> list[int]:
    """Reference decode of one request alone (batch 1) at the same cache
    capacity a pool would give it — the bit-identity oracle for
    tests/test_serve_parity.py and the degenerate one-shot path."""
    plen = prompt.shape[1]
    logits, caches = lm.lm_prefill(
        cfg, params, {"tokens": jnp.asarray(prompt, jnp.int32)},
        reserve=capacity - plen,
    )
    tok = int(np.argmax(np.asarray(logits[0])))
    out = [tok]
    while len(out) < max_new and not (eos_id is not None and tok == eos_id):
        lg, caches = lm.lm_decode_step(
            cfg, params, {"tokens": jnp.asarray([[tok]], jnp.int32)}, caches
        )
        tok = int(np.argmax(np.asarray(lg[0, 0])))
        out.append(tok)
    return out
