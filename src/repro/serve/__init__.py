"""SLO-aware serving subsystem (DESIGN.md §14).

The fourth registry-idiom subsystem (after ``repro.ps``,
``repro.transport``, ``repro.fleet``): serve the models the system
trains, from the same parameter-server state it trains them in.

  * ``trace`` — open-loop request arrivals (poisson, bursty) with
    per-request SLO deadlines, seeded and deterministic;
  * ``cache`` — per-family decode-slot pools: O(capacity) ring-buffer
    K/V for attention kinds, O(1) recurrent state for rwkv6/rglru;
  * ``engine`` — continuous batching over a bounded slot pool
    (per-step eviction + immediate backfill, prefill/decode
    interleaving) under ``fcfs`` or ``deadline``/EDF admission, with a
    deterministic virtual-clock cost model; chunked prefill splits long
    prompts into fixed-size dispatches interleaved 1:1 with decode, and
    batches queued prefills into shared lane dispatches (§17);
  * ``balance`` — N engine replicas on one virtual clock behind a
    registered routing policy (``round_robin`` | ``least_queue`` |
    ``deadline_slack``), per-replica caches and PS sync (§17);
  * ``sync`` — version-stale shard pulls from a live training PS
    (``repro.ps.AdspState`` + ``ShardPlan``) between decode steps.

Per-request records flow through ``repro.fleet.metrics``
(``ServeRecord``/``PullRecord``) into the same JSONL stream
``tools/fleet_report.py`` summarizes.
"""

from .balance import (
    BalanceReport,
    LoadBalancer,
    get_router,
    register_router,
    router_names,
)
from .cache import CachePool, family_of
from .engine import (
    CostModel,
    ServeConfig,
    ServeEngine,
    ServeReport,
    get_scheduler,
    register_scheduler,
    scheduler_names,
    serve_trace,
    solo_decode,
)
from .sync import ReplicaSync, ShardedTrainer, pull_stale, shard_versions_of
from .trace import (
    Request,
    TraceConfig,
    get_trace,
    make_trace,
    register_trace,
    trace_names,
)

__all__ = [
    # trace
    "Request", "TraceConfig", "make_trace", "get_trace",
    "register_trace", "trace_names",
    # cache
    "CachePool", "family_of",
    # engine
    "ServeEngine", "ServeConfig", "ServeReport", "CostModel",
    "serve_trace", "solo_decode",
    "register_scheduler", "get_scheduler", "scheduler_names",
    # balance
    "LoadBalancer", "BalanceReport",
    "register_router", "get_router", "router_names",
    # sync
    "ReplicaSync", "ShardedTrainer", "pull_stale", "shard_versions_of",
]
