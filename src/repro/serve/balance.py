"""Multi-replica serving behind a load balancer (DESIGN.md §17).

N ``ServeEngine`` replicas — each owning its slot pool, prefill lanes,
and (optionally) its own ``ReplicaSync`` against the training PS — are
driven on **one virtual clock** by a ``LoadBalancer``: arrivals from a
single trace are routed to a replica by a registered policy, then every
replica runs until the next arrival. Replica clocks advance
independently between arrivals (a busy replica may still be working at
t=5 while an idle one has jumped ahead), which is exactly the
heterogeneous-participant shape ADSP builds for: routing decisions see
the *divergent* replica states, never a barrier-synchronised fiction.

Routing policies (registry idiom, as ``serve.engine`` schedulers):

  * ``round_robin`` — arrival index mod N, the no-information baseline;
  * ``least_queue`` — fewest requests queued or in flight, ties to the
    lowest replica index;
  * ``deadline_slack`` — pick the replica maximising the request's slack
    at estimated completion: deadline − (replica clock at arrival +
    backlog + this request's own service estimate). Backlog is
    ``ServeEngine.backlog_seconds()``, a deterministic cost-model sum
    over the replica's slots, lanes, and queue — the router prices the
    *work*, not the request count, so one 2k-token prompt counts for
    what it costs.

Determinism: the trace is seeded, the cost model is virtual, replica
state evolves only through ``run_until``/``submit``, and every policy
breaks ties by replica index — same trace + same seed ⇒ identical
per-request records, which tests/test_serve.py asserts.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import math

from .engine import ServeConfig, ServeEngine, ServeReport
from .sync import ReplicaSync
from .trace import Request

__all__ = [
    "RouterPolicy", "register_router", "get_router", "router_names",
    "LoadBalancer", "BalanceReport",
]

Pytree = Any

_ROUTERS: dict[str, Callable[[], "RouterPolicy"]] = {}


def register_router(name: str):
    def deco(cls):
        _ROUTERS[name] = cls
        return cls
    return deco


def get_router(name: str) -> "RouterPolicy":
    try:
        return _ROUTERS[name]()
    except KeyError:
        raise KeyError(f"unknown router {name!r}; known: {router_names()}")


def router_names() -> list[str]:
    return sorted(_ROUTERS)


class RouterPolicy:
    """Picks the replica for one arriving request. Engines have been
    run up to the request's arrival when ``pick`` is called."""

    def pick(self, req: Request, engines: list[ServeEngine]) -> int:
        raise NotImplementedError


@register_router("round_robin")
class RoundRobinRouter(RouterPolicy):
    def __init__(self):
        self._i = 0

    def pick(self, req: Request, engines: list[ServeEngine]) -> int:
        i = self._i % len(engines)
        self._i += 1
        return i


@register_router("least_queue")
class LeastQueueRouter(RouterPolicy):
    """Fewest requests on the replica (queued + slots + lanes)."""

    def pick(self, req: Request, engines: list[ServeEngine]) -> int:
        return min(range(len(engines)),
                   key=lambda i: (engines[i].n_queued + engines[i].n_active, i))


@register_router("deadline_slack")
class DeadlineSlackRouter(RouterPolicy):
    """Maximise the request's slack at its estimated completion time.

    Estimated completion on replica i = max(replica clock, arrival)
    + backlog_seconds() + the request's own service estimate (prefill
    of the full prompt + max_new decode steps). Slack = deadline − that.
    The replica clock matters: a replica mid-way through a long prefill
    has a *later* effective start than an idle one even at equal
    backlog."""

    def pick(self, req: Request, engines: list[ServeEngine]) -> int:
        def slack(i: int) -> float:
            e = engines[i]
            cost = e.serve_cfg.cost
            est = (cost.prefill(req.prompt_len)
                   + req.max_new * cost.decode(e.serve_cfg.slots))
            t0 = max(e.t, req.arrival)
            return req.deadline - (t0 + e.backlog_seconds() + est)

        # max slack; ties to the lowest index (min over negated slack)
        return min(range(len(engines)), key=lambda i: (-slack(i), i))


@dataclasses.dataclass
class BalanceReport:
    """Merged view over all replicas plus the per-replica reports.
    ``merged`` carries every request record (each stamped with its
    replica) and the fleet clock ``t_end = max`` over replicas, so
    goodput/percentiles aggregate exactly as a single engine's would."""

    merged: ServeReport
    replicas: list[ServeReport]
    router: str

    @property
    def per_replica_requests(self) -> list[int]:
        return [len(r.records) for r in self.replicas]


class LoadBalancer:
    """N replicas of one model behind a routing policy.

    ``make_sync(i)`` (optional) builds replica i's ``ReplicaSync`` — each
    replica tracks the training PS independently, so pull traffic and
    version staleness stay per-replica stories. ``tick`` is shared; the
    serve-side trainer advances monotonically, so out-of-order ticks
    from replicas with divergent clocks are safe no-ops backwards.
    """

    def __init__(self, cfg, params: Pytree, serve_cfg: ServeConfig,
                 trace: list[Request], *, n_replicas: int = 2,
                 router: str = "least_queue", metrics=None,
                 make_sync: Callable[[int], ReplicaSync] | None = None,
                 tick=None):
        if n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        if serve_cfg.sync_every and make_sync is None:
            raise ValueError("sync_every > 0 needs a make_sync factory")
        self.trace = sorted(trace, key=lambda r: (r.arrival, r.rid))
        self.router_name = router
        self.router = get_router(router)
        # capacity must come from the *global* trace: any replica can be
        # routed any request, and per-replica traces are empty at build
        need = max((r.prompt_len + r.max_new for r in self.trace), default=2)
        cap = serve_cfg.capacity or need
        serve_cfg = dataclasses.replace(serve_cfg, capacity=cap)
        self.engines = [
            ServeEngine(cfg, params, serve_cfg, [], metrics=metrics,
                        sync=make_sync(i) if make_sync else None,
                        tick=tick, replica=i)
            for i in range(n_replicas)
        ]

    def run(self) -> BalanceReport:
        for req in self.trace:
            for e in self.engines:
                e.run_until(req.arrival)
            self.engines[self.router.pick(req, self.engines)].submit(req)
        for e in self.engines:
            e.run_until(math.inf)
        reports = [e.finish() for e in self.engines]
        records = sorted((r for rep in reports for r in rep.records),
                         key=lambda r: (r.t, r.req))
        tokens: dict[int, list[int]] = {}
        for rep in reports:
            tokens.update(rep.tokens_by_rid)
        merged = ServeReport(
            records=records,
            t_end=max((rep.t_end for rep in reports), default=0.0),
            decode_steps=sum(rep.decode_steps for rep in reports),
            tokens_by_rid=tokens,
            inserts=sum(rep.inserts for rep in reports),
            evictions=sum(rep.evictions for rep in reports),
            sync_polls=sum(rep.sync_polls for rep in reports),
            sync_pulls=sum(rep.sync_pulls for rep in reports),
            pull_bytes=sum(rep.pull_bytes for rep in reports),
            full_pull_bytes=sum(rep.full_pull_bytes for rep in reports),
            chunk_dispatches=sum(rep.chunk_dispatches for rep in reports),
        )
        return BalanceReport(merged=merged, replicas=reports,
                             router=self.router_name)
