"""Per-family decode-cache slot pools (DESIGN.md §14).

A ``CachePool`` owns the stacked decode caches for a fixed number of
serving slots and the host-side occupancy bookkeeping. The state layout
is the model's own (``models.lm.init_decode_caches``) — every leaf
carries the slot dim at axis 1 — so slot insert/evict are the tree-map
hooks ``models.lm.cache_slot_insert``/``cache_slot_clear`` and the decode
step stays one compiled call over the whole pool.

What differs per model family is the *cost* of a slot, not the
mechanics:

  * attention kinds (global/local/dense/moe) cache K/V per token —
    O(capacity) bytes per slot, ring-buffered when the window is finite
    (capacity = window), dense otherwise;
  * rwkv6 / rglru carry O(1) recurrent state (wkv matrices, LRU
    hidden + conv tail) — slot reuse is a constant-size state swap
    regardless of how long the previous occupant ran, never a
    re-prefill.

``slot_nbytes()`` reports that split so reports/benchmarks can show the
per-family serving memory story.
"""

from __future__ import annotations

import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.lm import ATTN_KINDS

__all__ = ["CachePool", "family_of"]

# leaves that scale with cache capacity (per-token attention state)
_KV_LEAVES = ("k", "v", "cross_k", "cross_v")


def family_of(cfg: ModelConfig) -> str:
    """Cache family: 'attention' | 'rwkv6' | 'rglru' | 'hybrid'."""
    kinds = set()
    for pat, _ in cfg.layer_groups:
        kinds.update(pat)
    has_attn = bool(kinds & set(ATTN_KINDS))
    has_rec = "recurrent" in kinds
    has_rwkv = "rwkv" in kinds
    if sum((has_attn, has_rec, has_rwkv)) > 1:
        return "hybrid"
    if has_rwkv:
        return "rwkv6"
    if has_rec:
        return "rglru"
    return "attention"


class CachePool:
    """Fixed-size pool of decode slots for one model.

    ``capacity`` is the attention cache length (prompt + generated
    tokens a slot must hold); recurrent families ignore it beyond
    allocation. Occupancy is host-side: ``slot_of``/``request_of`` map
    request-id ↔ slot, ``free`` is the LIFO free list (deterministic
    slot choice ⇒ reproducible runs).
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, capacity: int):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        self.cfg = cfg
        self.n_slots = n_slots
        self.capacity = capacity
        self.family = family_of(cfg)
        self.caches = lm.init_decode_caches(cfg, n_slots, capacity)
        self.free: list[int] = list(range(n_slots - 1, -1, -1))  # pop() -> slot 0 first
        self.request_of: dict[int, int] = {}  # slot -> rid
        self.slot_of: dict[int, int] = {}  # rid -> slot
        self.inserts = 0
        self.evictions = 0

    # ----------------------------------------------------------- occupancy
    @property
    def n_active(self) -> int:
        return len(self.request_of)

    @property
    def n_free(self) -> int:
        return len(self.free)

    def active_slots(self) -> list[int]:
        return sorted(self.request_of)

    # ------------------------------------------------------- insert / evict
    def insert(self, rid: int, src_caches) -> int:
        """Claim a free slot for ``rid`` and splice in its prefilled
        state (batch-1 tree from ``lm_prefill`` at matching capacity).
        Returns the slot index."""
        if not self.free:
            raise RuntimeError("no free slot; evict before inserting")
        if rid in self.slot_of:
            raise ValueError(f"request {rid} already holds slot {self.slot_of[rid]}")
        slot = self.free.pop()
        self.caches = lm.cache_slot_insert(self.caches, slot, src_caches)
        self.request_of[slot] = rid
        self.slot_of[rid] = slot
        self.inserts += 1
        return slot

    def admit(self, rid: int) -> int:
        """Claim a free slot for ``rid`` with *zeroed* state. Chunked
        prefill lanes start from empty caches and are advanced in place
        by ``lm_prefill_chunk`` — unlike ``insert`` there is no source
        tree, so the previous occupant's state must be cleared (the
        chunk path accumulates into whatever it finds)."""
        if not self.free:
            raise RuntimeError("no free slot; evict before admitting")
        if rid in self.slot_of:
            raise ValueError(f"request {rid} already holds slot {self.slot_of[rid]}")
        slot = self.free.pop()
        self.caches = lm.cache_slot_clear(self.caches, slot)
        self.request_of[slot] = rid
        self.slot_of[rid] = slot
        self.inserts += 1
        return slot

    def extract(self, rid: int):
        """``rid``'s slot state as a batch-1 cache tree (insertable into
        another pool of the same cfg/capacity — the lane → decode-pool
        handoff when a chunked prefill completes)."""
        return lm.cache_slot_extract(self.caches, self.slot_of[rid])

    def evict(self, rid: int) -> int:
        """Release ``rid``'s slot. The state is left in place — the next
        insert overwrites every leaf, so no clear pass is needed."""
        slot = self.slot_of.pop(rid)
        del self.request_of[slot]
        self.free.append(slot)
        self.evictions += 1
        return slot

    # ------------------------------------------------------------- metrics
    def slot_nbytes(self) -> dict[str, int]:
        """Bytes of cache state per slot, split into capacity-scaling
        attention K/V ('kv') and constant-size recurrent state
        ('recurrent')."""
        kv = rec = 0
        for group in self.caches:
            for block in group.values():
                for name, leaf in block.items():
                    per_slot = int(np.prod(leaf.shape) // leaf.shape[1]
                                   * np.dtype(leaf.dtype).itemsize)
                    if name in _KV_LEAVES:
                        kv += per_slot
                    else:
                        rec += per_slot
        return {"kv": kv, "recurrent": rec}
