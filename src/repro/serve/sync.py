"""Serving ↔ training synchronization (DESIGN.md §14).

ADSP's premise is a global model that improves *continuously* as
heterogeneous workers commit. A serving replica therefore has a choice:
freeze a checkpoint (stale forever), re-pull the dense model on a timer
(bytes scale with model size × poll rate), or track the PS the way PR 4
taught training workers to — compare per-shard version counters and pull
**only the stale shards**. This module implements the third option
against a live ``repro.ps.AdspState``:

  * ``shard_versions_of`` normalizes the two PS shapes: a sharded state
    exposes ``shard_versions`` (int32[K]); the monolithic K=1 state
    carries ``()`` and its global ``step`` acts as the single version.
  * ``pull_stale`` is the pure pull: slice the PS params for every shard
    whose version advanced past the replica's, merge them into the
    serving params (``ShardPlan.slice``/``merge``, bit-exact — transport
    reorganization, never numerics), and account the dense bytes moved.
  * ``ReplicaSync`` wraps that into the engine-facing poller with byte /
    pull counters and an optional link bandwidth so pull time can show
    up in the serving clock.
  * ``ShardedTrainer`` is a minimal co-running training simulator for
    demos and benchmarks: AdamW on the LM loss, commits applied to the
    PS *per shard* on a staggered schedule (PR 4's pipelined applies),
    so at most instants only part of the model is newer — exactly the
    regime where stale-shard pulls beat dense re-pulls.

The engine polls between decode steps, never mid-step: a decode step
always runs against one consistent params snapshot.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import numpy as np

from repro.ps.sharding import ShardPlan

__all__ = ["shard_versions_of", "pull_stale", "ReplicaSync", "ShardedTrainer"]

Pytree = Any


def shard_versions_of(state, n_shards: int) -> np.ndarray:
    """PS-side version vector (int64[n_shards]) of an ``AdspState``-like
    object (anything with ``.params``/``.shard_versions``/``.step``)."""
    sv = getattr(state, "shard_versions", ())
    if sv is None or (isinstance(sv, tuple) and sv == ()):
        if n_shards != 1:
            raise ValueError(
                f"PS state is monolithic but the replica expects {n_shards} shards"
            )
        return np.asarray([int(state.step)], np.int64)
    sv = np.asarray(sv, np.int64)
    if sv.shape != (n_shards,):
        raise ValueError(f"shard_versions has shape {sv.shape}, expected ({n_shards},)")
    return sv


def pull_stale(params: Pytree, state, plan: ShardPlan,
               versions: np.ndarray) -> tuple[Pytree, list[int], int]:
    """Refresh ``params`` from ``state`` for every version-stale shard.

    Returns (new params, stale shard ids, dense bytes pulled).
    ``versions`` is updated in place to the PS versions of the pulled
    shards (untouched shards keep their counter)."""
    ps_versions = shard_versions_of(state, plan.n_shards)
    stale = [s for s in range(plan.n_shards) if ps_versions[s] > versions[s]]
    if not stale:
        return params, [], 0
    nbytes = plan.shard_nbytes()
    pulled = 0
    for s in stale:
        params = plan.merge(params, s, plan.slice(state.params, s))
        versions[s] = ps_versions[s]
        pulled += nbytes[s]
    return params, stale, pulled


class ReplicaSync:
    """Engine-side poller: versioned partial pulls from a live PS.

    ``source`` returns the current PS state (in-process: the trainer's
    ``AdspState``; a real deployment would RPC the version vector first —
    the byte accounting here already excludes the metadata probe).
    ``bandwidth`` (bytes/s) converts pulled bytes into virtual seconds on
    the serving clock; ``inf`` (default) makes pulls free in time but
    still counted in bytes."""

    def __init__(self, params: Pytree, source: Callable[[], Any], *,
                 n_shards: int = 1, bandwidth: float = math.inf):
        self.plan = ShardPlan.build(params, n_shards)
        self.source = source
        self.bandwidth = bandwidth
        self.versions = np.zeros(self.plan.n_shards, np.int64)
        self.total_nbytes = sum(self.plan.shard_nbytes())
        self.polls = 0
        self.pulls = 0
        self.bytes_pulled = 0
        self.full_bytes_equiv = 0  # dense re-pull at the same poll points

    @property
    def version(self) -> int:
        """Monotone scalar 'model version served': total shard commits
        reflected by the replica."""
        return int(self.versions.sum())

    def poll(self, params: Pytree) -> tuple[Pytree, int, int, float]:
        """One sync point. Returns (params, n_stale, bytes, seconds)."""
        self.polls += 1
        params, stale, nbytes = pull_stale(
            params, self.source(), self.plan, self.versions
        )
        if stale:
            self.pulls += 1
            self.bytes_pulled += nbytes
            # a version-oblivious replica would re-ship the dense model
            # whenever anything changed — the honest baseline
            self.full_bytes_equiv += self.total_nbytes
        seconds = nbytes / self.bandwidth if math.isfinite(self.bandwidth) else 0.0
        return params, len(stale), nbytes, seconds


@dataclasses.dataclass
class ShardedTrainer:
    """Minimal co-running LM trainer with pipelined per-shard PS applies.

    Every ``commit_every`` virtual seconds the trainer takes
    ``steps_per_commit`` AdamW steps on deterministic ``lm_tokens``
    batches, then applies the resulting params to its ``AdspState``
    shard-by-shard, staggered across the commit interval, bumping that
    shard's version counter as PR 4's pipelined push path does. Drive it
    with ``advance(t)`` from the serving engine's tick hook; the engine's
    ``ReplicaSync`` sees a PS whose shards go stale at different times.
    """

    cfg: Any
    params: Pytree
    n_shards: int = 4
    commit_every: float = 0.5
    steps_per_commit: int = 1
    lr: float = 1e-2
    batch: int = 8
    seq: int = 32
    seed: int = 0

    def __post_init__(self):
        from repro.data.synthetic import lm_tokens
        from repro.models import lm
        from repro.optim.adamw import adamw
        from repro.ps.state import AdspState

        self.state = AdspState.create(self.params, n_shards=self.n_shards)
        self.plan = ShardPlan.build(self.params, self.n_shards)
        init, update = adamw(lr=self.lr, weight_decay=0.0)
        self._opt_state = init(self.params)
        self._grad = jax.jit(
            lambda p, b: jax.grad(lambda q: lm.lm_loss(self.cfg, q, b))(p)
        )
        self._update = jax.jit(update)
        self._loss = jax.jit(lambda p, b: lm.lm_loss(self.cfg, p, b))
        self._lm_tokens = lm_tokens
        self._train_params = self.params  # trainer-side latest full model
        self._pending: list[tuple[float, int]] = []  # (t_apply, shard)
        self._pending_params: Pytree | None = None
        self._next_commit = self.commit_every
        self._step_idx = 0
        self.commits = 0
        self.shard_applies = 0

    # ------------------------------------------------------------ training
    def _train_batch(self):
        import jax.numpy as jnp

        toks = self._lm_tokens(self.seed, 1000 + self._step_idx, self.batch,
                               self.seq, self.cfg.vocab_size)[:, :-1]
        self._step_idx += 1
        return {"tokens": jnp.asarray(toks, jnp.int32)}

    def _commit(self):
        for _ in range(self.steps_per_commit):
            grads = self._grad(self._train_params, self._train_batch())
            self._train_params, self._opt_state = self._update(
                grads, self._opt_state, self._train_params
            )
        self.commits += 1
        self._pending_params = self._train_params
        # stagger the K shard applies across the commit interval so the
        # PS shards go stale one at a time (pipelined applies, PR 4)
        dt = self.commit_every / (self.plan.n_shards + 1)
        t0 = self._next_commit
        self._pending = [(t0 + (j + 1) * dt, j) for j in range(self.plan.n_shards)]
        self._next_commit = t0 + self.commit_every

    def _apply_shard(self, shard: int):
        self.state.params = self.plan.merge(
            self.state.params, shard, self.plan.slice(self._pending_params, shard)
        )
        sv = self.state.shard_versions
        if isinstance(sv, tuple) and sv == ():
            self.state.step = self.state.step + 1
        else:
            self.state.shard_versions = sv.at[shard].add(1)
        self.shard_applies += 1

    def advance(self, t: float) -> None:
        """Fire every commit / shard-apply due at or before virtual ``t``."""
        while True:
            next_apply = self._pending[0][0] if self._pending else math.inf
            nxt = min(self._next_commit, next_apply)
            if nxt > t:
                return
            if next_apply <= self._next_commit:
                _, shard = self._pending.pop(0)
                self._apply_shard(shard)
            else:
                self._commit()

    # ------------------------------------------------------------- evals
    def eval_loss(self, params: Pytree) -> float:
        """LM loss of (serving) ``params`` on a fixed held-out batch."""
        import jax.numpy as jnp

        toks = self._lm_tokens(self.seed, 999_999, self.batch, self.seq,
                               self.cfg.vocab_size)[:, :-1]
        return float(self._loss(params, {"tokens": jnp.asarray(toks, jnp.int32)}))
