"""Alg. 1 — Commit-Rate Adjustment at the Scheduler (online search).

The scheduler is substrate-agnostic: it talks to the running system through
the ``OnlineSystem`` protocol, which both the edge simulator
(``repro.edgesim``) and the cluster runtime (``repro.launch.train``)
implement. ``evaluate`` runs the system *live* (no state reset — this is
the paper's online search) for a probe window under a given C_target and
returns the (time, loss) samples observed.

DECIDECOMMITRATE starts from C_target = max_i c_i + 1 (the smallest value
letting every worker commit ≥ once per period), compares the rewards of
C_target and C_target+1, and climbs while the reward improves. §4.2 argues
the optimum is to the right of the start point, so a one-directional climb
suffices; we also add a patience/max-probe guard so a noisy plateau cannot
climb forever.
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, Sequence

from .reward import log_slope_reward, reward

__all__ = ["OnlineSystem", "SearchTrace", "decide_commit_rate", "Scheduler",
           "pad_probe_samples"]


def pad_probe_samples(ts: list, ls: list) -> tuple[list, list]:
    """Ensure a probe window yields ≥3 (time, loss) samples — the minimum
    the reward curve fit needs — by inserting a midpoint. Shared by every
    backend's ``run_window`` so the sampling contract lives in one place.

    Degenerate windows (shorter than the eval interval, or cut off by
    convergence) can arrive with 0 or 1 samples, or with all samples at
    one instant; those yield a synthetic flat window (zero reward slope)
    instead of an IndexError / duplicate time points that break the
    curve fit's slope normalization.
    """
    ts, ls = list(ts), list(ls)
    if not ts:
        return ts, ls
    if len(ts) == 1 or ts[-1] <= ts[0]:
        # A single observed instant carries no decay-rate information:
        # expand to a flat 1-second window so the fit sees slope 0.
        t0, l0 = ts[-1], ls[-1]
        return [t0, t0 + 0.5, t0 + 1.0], [l0, l0, l0]
    if len(ts) < 3:
        ts.insert(1, (ts[0] + ts[-1]) / 2)
        ls.insert(1, (ls[0] + ls[-1]) / 2)
    return ts, ls


class OnlineSystem(Protocol):
    """What Alg. 1 needs from the system under control."""

    def commit_counts(self) -> Sequence[int]:
        """Current cumulative commit count c_i per worker."""
        ...

    def evaluate(self, c_target: int, probe_seconds: float) -> tuple[Sequence[float], Sequence[float]]:
        """Run live with commit rates ΔC_i = C_target − c_i for
        ``probe_seconds`` (virtual) seconds; return (times, losses) sampled
        during the window (≥3 samples: start / middle / end)."""
        ...


@dataclasses.dataclass
class SearchTrace:
    """Record of one epoch's search, for EXPERIMENTS.md and tests."""

    candidates: list[int] = dataclasses.field(default_factory=list)
    rewards: list[float] = dataclasses.field(default_factory=list)
    chosen: int = -1


def decide_commit_rate(
    system: OnlineSystem,
    probe_seconds: float = 60.0,
    max_probes: int = 16,
) -> tuple[int, SearchTrace]:
    """DECIDECOMMITRATE (Alg. 1 lines 8–16), iterative form.

    Returns the chosen C_target and the search trace. The paper probes each
    candidate for ~1 minute; probe_seconds is virtual time in the simulator.
    """
    trace = SearchTrace()
    c_target = int(max(system.commit_counts())) + 1

    t1, l1 = system.evaluate(c_target, probe_seconds)
    all_losses = list(l1)
    trace.candidates.append(c_target)

    probes = 1
    while probes < max_probes:
        t2, l2 = system.evaluate(c_target + 1, probe_seconds)
        all_losses += list(l2)
        probes += 1
        # Normalized (drift-free) decay-rate reward; see
        # core.reward.log_slope_reward for why this replaces the paper's
        # absolute-time formula in sequential probing.
        r1 = log_slope_reward(t1, l1)
        r2 = log_slope_reward(t2, l2)
        if not trace.rewards:
            trace.rewards.append(r1)
        trace.candidates.append(c_target + 1)
        trace.rewards.append(r2)
        if r2 > r1:
            c_target, t1, l1 = c_target + 1, t2, l2
        else:
            break
    trace.chosen = c_target
    if not trace.rewards:  # max_probes == 1
        trace.rewards.append(log_slope_reward(t1, l1))
    return c_target, trace


@dataclasses.dataclass
class Scheduler:
    """MAINFUNCTION (Alg. 1 lines 1–7): per-epoch commit-rate control.

    Drives an OnlineSystem that additionally exposes ``run(seconds)`` and
    ``set_c_target(c)``; the edgesim simulator satisfies this.
    """

    epoch_seconds: float = 1200.0  # paper default: 20-minute epochs
    probe_seconds: float = 60.0
    max_probes: int = 16
    traces: list[SearchTrace] = dataclasses.field(default_factory=list)

    def run_epoch(self, system) -> int:
        c_target, trace = decide_commit_rate(
            system, self.probe_seconds, self.max_probes
        )
        self.traces.append(trace)
        spent = self.probe_seconds * len(trace.candidates)
        remaining = max(self.epoch_seconds - spent, 0.0)
        system.set_c_target(c_target)
        if remaining > 0:
            system.run(remaining)
        return c_target
