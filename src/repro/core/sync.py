"""Parameter-synchronization policies — compatibility facade.

The nine policies (§2.2, §4, §5 baselines) now live in
``repro.cluster.policies`` as event-driven ``ClusterPolicy`` objects:
pure functions from typed events (StepDone, CommitApplied, Checkpoint,
EpochEnd, WorkerJoined, WorkerLeft, SpeedChanged) to typed commands
(Commit, Block, ArmTimer, SetRate, SetBatchFraction, …), executed by the
single ``repro.cluster.ClusterEngine`` over either backend (edge
simulator or real mesh loop). See DESIGN.md.

This module re-exports them under their historical names and keeps the
old strategy-object entry points working:

  * ``make_policy(name, **kw)`` — unchanged registry constructor;
  * ``policy.should_commit(sim, w)`` / ``policy.may_start_next_step(sim,
    w)`` / ``policy.batch_fraction(sim, i)`` — thin shims on
    ``ClusterPolicy`` answering from the same pure predicates the event
    handlers use;
  * ``SyncPolicy`` — the legacy abstract base, retained so third-party
    strategy objects keep type-checking; the engine wraps instances via
    ``repro.cluster.LegacyPolicyAdapter``.
"""

from __future__ import annotations

from repro.cluster.policies import (
    ADSP,
    ADSPPlus,
    AdaComm,
    BatchTuneBSP,
    BatchTuneFixedAdaComm,
    BSP,
    FixedAdaComm,
    SSP,
    TAP,
    make_policy,
)
from repro.cluster.protocol import ClusterPolicy

__all__ = [
    "SyncPolicy",
    "ClusterPolicy",
    "BSP",
    "SSP",
    "TAP",
    "FixedAdaComm",
    "AdaComm",
    "ADSP",
    "ADSPPlus",
    "BatchTuneBSP",
    "BatchTuneFixedAdaComm",
    "make_policy",
]


class SyncPolicy:
    """Legacy strategy-object base (pre-engine API).

    Third-party subclasses implementing ``should_commit`` /
    ``may_start_next_step`` / ``on_*`` hooks still run everywhere a
    policy is accepted: the engine adapts them with
    ``repro.cluster.LegacyPolicyAdapter``. New policies should subclass
    ``repro.cluster.ClusterPolicy`` instead.
    """

    name: str = "base"
    apply_mode: str = "immediate"  # or "barrier"

    def should_commit(self, sim, w) -> bool:
        raise NotImplementedError

    def may_start_next_step(self, sim, w) -> bool:
        return True

    def on_sim_start(self, sim) -> None:
        pass

    def on_commit_applied(self, sim, w) -> None:
        pass

    def on_checkpoint(self, sim) -> None:
        pass

    def on_epoch(self, sim) -> None:
        pass

    def batch_fraction(self, sim, worker_index: int) -> float:
        return 1.0 / sim.num_workers
