"""Parameter-synchronization models (§2.2, §4, §5 baselines).

Each policy is a small strategy object consulted by the edge simulator
(``repro.edgesim.simulator.Simulator``) at three decision points:

  * ``should_commit(sim, w)``   — worker ``w`` just finished a mini-batch
    step: must it push its accumulated update to the PS now?
  * ``may_start_next_step(sim, w)`` — may ``w`` begin another mini-batch,
    or is it blocked (barrier / staleness bound)?
  * ``apply_mode``              — ``"immediate"`` (PS applies every commit
    on arrival: TAP/SSP/ADSP) or ``"barrier"`` (PS waits for the whole
    round: BSP/ADACOMM).

plus periodic hooks ``on_checkpoint`` (every check period Γ) and
``on_epoch`` (ADSP's Alg. 1 search; ADACOMM's τ tuning).

Policies hold *no* model state — all training state lives in the
simulator — so they are trivially serializable and unit-testable.
"""

from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING

import numpy as np

from . import theory
from .search import decide_commit_rate

if TYPE_CHECKING:  # pragma: no cover
    from repro.edgesim.simulator import Simulator, WorkerState

__all__ = [
    "SyncPolicy",
    "BSP",
    "SSP",
    "TAP",
    "FixedAdaComm",
    "AdaComm",
    "ADSP",
    "ADSPPlus",
    "BatchTuneBSP",
    "BatchTuneFixedAdaComm",
    "make_policy",
]


@dataclasses.dataclass
class SyncPolicy:
    name: str = "base"
    apply_mode: str = "immediate"  # or "barrier"

    # -- decision points -----------------------------------------------------
    def should_commit(self, sim: "Simulator", w: "WorkerState") -> bool:
        raise NotImplementedError

    def may_start_next_step(self, sim: "Simulator", w: "WorkerState") -> bool:
        return True

    # -- hooks ----------------------------------------------------------------
    def on_sim_start(self, sim: "Simulator") -> None:
        pass

    def on_commit_applied(self, sim: "Simulator", w: "WorkerState") -> None:
        pass

    def on_checkpoint(self, sim: "Simulator") -> None:
        pass

    def on_epoch(self, sim: "Simulator") -> None:
        pass

    # BatchTune policies override this to give fast workers bigger batches.
    def batch_fraction(self, sim: "Simulator", worker_index: int) -> float:
        return 1.0 / sim.num_workers


# ---------------------------------------------------------------------------
# Classic baselines
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BSP(SyncPolicy):
    """Bulk Synchronous Parallel: commit every step, strict barrier."""

    name: str = "bsp"
    apply_mode: str = "barrier"

    def should_commit(self, sim, w) -> bool:
        return True


@dataclasses.dataclass
class SSP(SyncPolicy):
    """Stale Synchronous Parallel with slack ``s``: commit every step, a
    worker may run ahead of the slowest by at most ``s`` steps."""

    name: str = "ssp"
    apply_mode: str = "immediate"
    s: int = 8

    def should_commit(self, sim, w) -> bool:
        return True

    def may_start_next_step(self, sim, w) -> bool:
        slowest = min(ws.steps for ws in sim.workers)
        return w.steps - slowest < self.s


@dataclasses.dataclass
class TAP(SyncPolicy):
    """Totally Asynchronous Parallel: commit every step, never block.
    No convergence guarantee (Hsieh et al. 2017) — kept for completeness."""

    name: str = "tap"
    apply_mode: str = "immediate"

    def should_commit(self, sim, w) -> bool:
        return True


@dataclasses.dataclass
class FixedAdaComm(SyncPolicy):
    """Wang & Joshi (2018), fixed-τ variant: every worker accumulates τ
    local updates, then synchronizes with a BSP-style barrier."""

    name: str = "fixed_adacomm"
    apply_mode: str = "barrier"
    tau: int = 8

    def should_commit(self, sim, w) -> bool:
        return w.steps_since_commit >= self.tau


@dataclasses.dataclass
class AdaComm(FixedAdaComm):
    """ADACOMM with the paper-described periodic τ tuning: re-evaluated at
    every checkpoint; if the smoothed global loss failed to decrease since
    the previous checkpoint, multiply τ by ``tau_decay`` (<1 ⇒ commit more
    often). Follows AdaComm's τ(t) = ceil(τ0 · sqrt(loss_t/loss_0)) schedule
    as the base, which the paper criticizes for its rapidly-declining rate."""

    name: str = "adacomm"
    tau0: int = 16
    tau_decay: float = 0.5
    _loss0: float = dataclasses.field(default=math.nan, init=False)
    _last_loss: float = dataclasses.field(default=math.nan, init=False)

    def on_sim_start(self, sim) -> None:
        self.tau = self.tau0

    def on_checkpoint(self, sim) -> None:
        loss = sim.recent_global_loss()
        if loss is None:
            return
        if math.isnan(self._loss0):
            self._loss0, self._last_loss = loss, loss
            return
        # AdaComm schedule: τ ∝ sqrt(current/initial loss).
        self.tau = max(1, math.ceil(self.tau0 * math.sqrt(max(loss, 1e-9) / self._loss0)))
        if loss >= self._last_loss:  # stagnation → commit more often
            self.tau = max(1, int(self.tau * self.tau_decay))
        self._last_loss = loss


# ---------------------------------------------------------------------------
# ADSP (the paper's contribution)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ADSP(SyncPolicy):
    """ADaptive Synchronous Parallel (Alg. 1 + Alg. 2).

    * no-waiting: workers always train; commits triggered by per-worker
      timers with timeout Γ/ΔC_i − O_i (Alg. 2);
    * at every checkpoint (period Γ) commit rates are re-derived as
      ΔC_i = C_target − c_i, equalizing cumulative commit counts;
    * at every epoch the scheduler runs the online search (Alg. 1 /
      core.search.decide_commit_rate) to pick C_target.

    ``search=False`` freezes C_target (used by unit tests and by the
    Fig. 3 commit-rate sweep where ΔC is set exogenously).
    """

    name: str = "adsp"
    apply_mode: str = "immediate"
    gamma: float = 60.0  # check period Γ (virtual seconds); paper: 60 s
    initial_c_target: int = 1
    search: bool = True
    probe_seconds: float = 60.0
    max_probes: int = 8
    # Fixed commit-rate mode (Fig. 3 sweep): with search=False the target
    # advances by `delta_per_period` each check period, pinning every
    # worker's ΔC_target ≈ delta_per_period.
    delta_per_period: int = 1
    c_target: int = dataclasses.field(default=0, init=False)
    traces: list = dataclasses.field(default_factory=list, init=False)

    def on_sim_start(self, sim) -> None:
        self.c_target = max(self.initial_c_target, 1)
        self._assign_rates(sim)

    def should_commit(self, sim, w) -> bool:
        return sim.now >= w.next_commit_time

    def on_commit_applied(self, sim, w) -> None:
        # Alg. 2 TIMEOUT: restart the timer.
        dc = max(w.delta_c_target, 1)
        w.next_commit_time = sim.now + theory.commit_interval_seconds(
            self.gamma, dc, w.profile.o
        )

    def on_checkpoint(self, sim) -> None:
        # New check period: move the target forward so every worker is
        # expected to add ≥ delta_per_period commits, then re-derive rates.
        counts = [ws.commits for ws in sim.workers]
        self.c_target = max(self.c_target, max(counts) + self.delta_per_period)
        self._assign_rates(sim)

    def on_epoch(self, sim) -> None:
        if not self.search:
            return
        chosen, trace = decide_commit_rate(
            _ADSPSearchAdapter(sim, self), self.probe_seconds, self.max_probes
        )
        self.traces.append(trace)
        self.c_target = chosen
        self._assign_rates(sim)

    def _assign_rates(self, sim) -> None:
        counts = [ws.commits for ws in sim.workers]
        rates = theory.commit_rates_from_target(self.c_target, counts)
        for ws, dc in zip(sim.workers, rates):
            ws.delta_c_target = int(dc)
            interval = theory.commit_interval_seconds(
                self.gamma, int(dc), ws.profile.o
            )
            # Do not extend an already-armed earlier timer; shrink if the
            # new rate demands faster commits.
            ws.next_commit_time = min(
                getattr(ws, "next_commit_time", np.inf), sim.now + interval
            )

    def mu_implicit(self, sim) -> float:
        """Current implicit momentum per Eqn. (3)."""
        dc = [max(ws.delta_c_target, 1) for ws in sim.workers]
        v = [ws.profile.v for ws in sim.workers]
        return theory.mu_implicit(dc, v, self.gamma)


class _ADSPSearchAdapter:
    """Adapts a live Simulator to core.search.OnlineSystem."""

    def __init__(self, sim, policy: ADSP):
        self._sim = sim
        self._policy = policy

    def commit_counts(self):
        return [ws.commits for ws in self._sim.workers]

    def evaluate(self, c_target: int, probe_seconds: float):
        self._policy.c_target = int(c_target)
        self._policy._assign_rates(self._sim)
        return self._sim.run_window(probe_seconds)


@dataclasses.dataclass
class ADSPPlus(ADSP):
    """ADSP⁺ (Appendix D): offline oracle that, for a fixed C_target, grid
    searches per-worker local-step counts τ_i ≤ no-waiting τ_i. Used to
    verify that ADSP's no-waiting choice is near-optimal; the simulator's
    driver (benchmarks/appendix_adsp_plus.py) performs the outer offline
    grid, this policy simply enforces a τ cap per worker."""

    name: str = "adsp_plus"
    search: bool = False
    tau_cap: tuple = ()  # per-worker max local steps between commits

    def should_commit(self, sim, w) -> bool:
        if self.tau_cap:
            cap = self.tau_cap[w.index]
            if w.steps_since_commit >= cap:
                return True
        return sim.now >= w.next_commit_time


# ---------------------------------------------------------------------------
# BatchTune baselines (Appendix D, R²SP-style)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BatchTuneBSP(BSP):
    """BSP with per-worker batch sizes ∝ v_i (global batch fixed), so step
    times equalize and the barrier costs ~nothing."""

    name: str = "batchtune_bsp"

    def batch_fraction(self, sim, worker_index: int) -> float:
        v = np.array([ws.profile.v for ws in sim.workers], dtype=np.float64)
        return float(v[worker_index] / v.sum())


@dataclasses.dataclass
class BatchTuneFixedAdaComm(FixedAdaComm):
    name: str = "batchtune_fixed_adacomm"

    def batch_fraction(self, sim, worker_index: int) -> float:
        v = np.array([ws.profile.v for ws in sim.workers], dtype=np.float64)
        return float(v[worker_index] / v.sum())


_POLICIES = {
    "bsp": BSP,
    "ssp": SSP,
    "tap": TAP,
    "adacomm": AdaComm,
    "fixed_adacomm": FixedAdaComm,
    "adsp": ADSP,
    "adsp_plus": ADSPPlus,
    "batchtune_bsp": BatchTuneBSP,
    "batchtune_fixed_adacomm": BatchTuneFixedAdaComm,
}


def make_policy(name: str, **kwargs) -> SyncPolicy:
    try:
        cls = _POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown sync policy {name!r}; known: {sorted(_POLICIES)}")
    return cls(**kwargs)
