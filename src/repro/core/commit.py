"""Cluster-side ADSP commit layer (the paper's technique on a TPU mesh).

Mapping (see DESIGN.md §3): one *worker* = one index along the mesh's
worker axes (``("data",)`` single-pod, ``("pod", "data")`` multi-pod) — a
model-parallel group that holds a full replica of the parameters (sharded
over ``model`` by GSPMD). Workers run ``tau`` local SGD microsteps on
their own microbatches *without any cross-worker collective* (the
no-waiting property: a worker's local steps are independent), then all
commit at once: the accumulated updates are ``pmean``-ed over the worker
axes and applied with the global learning rate — the PS of Alg. 2
realized as an all-reduce.

Heterogeneity: workers may be assigned different local-step counts
``tau_i ≤ tau`` (the ADSP rate rule τ_i = v_i·(Γ/ΔC_i − O_i) normalizes
commit *counts*, letting fast workers do more local work). Microsteps
beyond a worker's τ_i are masked (zero update, zero accumulation), which
keeps the SPMD program uniform; on a real heterogeneous deployment the
masked steps are where the fast workers' extra capacity goes.

Implicit momentum (Theorem 1): accumulation-induced staleness acts as
extra momentum μ_implicit = 1 − p. ``effective_momentum`` lets the caller
keep total momentum at a target by subtracting μ_implicit from the
explicit PS momentum — the Fig. 3(c) tuning knob, exposed as a
first-class config.

Everything here is jit/shard_map-compatible pure JAX; no host callbacks.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import theory
from .jaxcompat import SCAN_IN_PARTIAL_AUTO_BROKEN, shard_map as _compat_shard_map

__all__ = [
    "CommitConfig",
    "effective_momentum",
    "make_local_update_fn",
    "make_adsp_step",
    "AdspState",
]

Pytree = object


@dataclasses.dataclass(frozen=True)
class CommitConfig:
    """ADSP commit behaviour for the cluster runtime.

    tau: max local microsteps between commits (the fastest worker's τ).
    local_lr: η′ applied at each local microstep.
    global_lr: η applied by the PS-equivalent all-reduce commit.
    momentum: target total momentum; if correct_implicit_momentum, the
      explicit part is reduced by μ_implicit from Eqn. (3).
    gamma / c_target: check-period and commit-count target used to derive
      μ_implicit (and, in the trainer, per-worker τ_i).
    worker_axes: mesh axes enumerating workers (manual in shard_map).
    """

    tau: int = 4
    local_lr: float = 0.05
    global_lr: float = 1.0
    # dtype of the commit all-reduce. f32 default: numerically safer for
    # accumulated updates, and XLA:CPU's AllReducePromotion pass crashes on
    # bf16 all-reduce (dry-run container). 'bfloat16' halves the collective
    # bytes — a measured hillclimb option for real TPU runs.
    commit_dtype: str = "float32"
    momentum: float = 0.9
    correct_implicit_momentum: bool = True
    gamma: float = 60.0
    c_target: int = 1
    worker_axes: tuple[str, ...] = ("data",)

    def __post_init__(self):
        if self.tau < 1:
            raise ValueError("tau must be >= 1")


def effective_momentum(
    cfg: CommitConfig, speeds: Sequence[float], delta_c: Sequence[float]
) -> float:
    """Explicit momentum to apply at the PS so that explicit + implicit ≈
    cfg.momentum (Fig. 3: best total momentum ⇒ fastest convergence)."""
    if not cfg.correct_implicit_momentum:
        return cfg.momentum
    mu_imp = theory.mu_implicit(delta_c, speeds, cfg.gamma)
    return max(0.0, cfg.momentum - mu_imp)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdspState:
    """Training state carried across commits."""

    params: Pytree
    prev_delta: Pytree  # W_t − W_{t−1} for the PS momentum term
    step: jax.Array  # global commit counter

    @classmethod
    def create(cls, params: Pytree) -> "AdspState":
        zeros = jax.tree.map(jnp.zeros_like, params)
        return cls(params=params, prev_delta=zeros, step=jnp.zeros((), jnp.int32))


def make_local_update_fn(
    loss_fn: Callable[[Pytree, Pytree], jax.Array],
    cfg: CommitConfig,
    remat: bool = False,
) -> Callable:
    """Build the τ-microstep local-update scan: the per-worker inner loop.

    Returns ``local_update(params, microbatches, tau_i) ->
    (accumulated_update U, mean_loss)`` where microbatches is a pytree of
    arrays with leading dim cfg.tau and tau_i is the worker's active step
    count (int32 scalar; steps ≥ tau_i are masked).

    Note U accumulates η′·g (the paper's accumulative update) and the
    *local* params advance by the same quantity each live step.
    """
    grad_fn = jax.value_and_grad(loss_fn)
    if remat:
        grad_fn = jax.remat(grad_fn)

    def local_update(params, microbatches, tau_i):
        zeros = jax.tree.map(jnp.zeros_like, params)

        def body(carry, xs):
            p, u = carry
            mb, idx = xs
            live = (idx < tau_i).astype(jnp.float32)
            loss, g = grad_fn(p, mb)
            # masked local SGD step + accumulation (η′·g)
            p = jax.tree.map(
                lambda a, b: (a - cfg.local_lr * live * b).astype(a.dtype), p, g
            )
            u = jax.tree.map(
                lambda a, b: (a + cfg.local_lr * live * b).astype(a.dtype), u, g
            )
            return (p, u), loss * live

        idxs = jnp.arange(cfg.tau, dtype=jnp.int32)
        (_, u), losses = jax.lax.scan(
            body, (params, zeros), (microbatches, idxs),
            unroll=True if SCAN_IN_PARTIAL_AUTO_BROKEN else 1,
        )
        denom = jnp.maximum(tau_i.astype(jnp.float32), 1.0)
        return u, jnp.sum(losses) / denom

    return local_update


def make_adsp_step(
    loss_fn: Callable,
    cfg: CommitConfig,
    mesh: jax.sharding.Mesh,
    batch_spec: P = P(("data",)),
    explicit_momentum: float = 0.0,
    remat: bool = False,
) -> Callable:
    """The full ADSP training step on a mesh.

    adsp_step(state: AdspState, microbatches, tau_per_worker) -> (state, loss)

    * microbatches: pytree, arrays shaped (tau, global_batch, ...) with the
      batch dim sharded over the worker axes per ``batch_spec``.
    * tau_per_worker: int32[num_workers] — ADSP rate rule output; worker w
      runs tau_per_worker[w] live microsteps (≤ cfg.tau).

    Manual over cfg.worker_axes; the ``model`` axis (and any other mesh
    axis) stays in GSPMD auto mode, so tensor-parallel sharding inside
    loss_fn keeps working untouched.
    """
    local_update = make_local_update_fn(loss_fn, cfg, remat=remat)
    axes = cfg.worker_axes

    def _sharded_body(params, prev_delta, step, microbatches, tau_per_worker):
        # tau_per_worker arrives sharded over the worker axes: this shard
        # holds exactly the one entry belonging to this worker (no
        # axis_index/partition-id computation, which XLA:CPU SPMD rejects).
        tau_i = tau_per_worker[0]
        u, loss = local_update(params, microbatches, tau_i)
        # ---- the commit: PS apply as all-reduce over workers ----
        cd = jnp.dtype(cfg.commit_dtype)
        u = jax.tree.map(lambda x: x.astype(cd), u)
        u = jax.lax.pmean(u, axes)
        loss = jax.lax.pmean(loss, axes)
        delta = jax.tree.map(
            lambda d, uu: (explicit_momentum * d - cfg.global_lr * uu).astype(d.dtype),
            prev_delta,
            u,
        )
        params = jax.tree.map(jnp.add, params, delta)
        return params, delta, step + 1, loss

    # params/opt-state replicated across worker axes (manual) — model-axis
    # sharding handled by auto GSPMD outside the manual set.
    rep = P()
    tau_spec = P(axes if len(axes) > 1 else axes[0])
    sharded = _compat_shard_map(
        _sharded_body,
        mesh,
        in_specs=(rep, rep, rep, batch_spec, tau_spec),
        out_specs=(rep, rep, rep, rep),
        axis_names=set(axes),
        check=False,
    )

    def adsp_step(state: AdspState, microbatches, tau_per_worker):
        params, delta, step, loss = sharded(
            state.params, state.prev_delta, state.step, microbatches, tau_per_worker
        )
        return AdspState(params, delta, step), loss

    return adsp_step
