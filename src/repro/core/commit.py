"""DEPRECATED shim — the cluster-side ADSP commit layer moved to
``repro.ps`` (the pluggable update-rule API, DESIGN.md §9).

``make_adsp_step``/``make_local_update_fn`` survive here as thin
deprecation shims over ``repro.ps.make_train_step`` with the seed's
exact rules (sgd local updates + Eqn. 1 momentum-delta commit, reference
backend) so existing callers keep bit-identical behaviour.
``CommitConfig``, ``AdspState`` and ``effective_momentum`` are
re-exported from their new home.
"""

from __future__ import annotations

import warnings
from typing import Callable

from jax.sharding import PartitionSpec as P

from repro.ps import (
    AdspState,
    CommitConfig,
    UpdateRules,
    effective_momentum,
    get_local_rule,
    make_local_update,
    make_train_step,
)
from .jaxcompat import SCAN_IN_PARTIAL_AUTO_BROKEN

__all__ = [
    "CommitConfig",
    "effective_momentum",
    "make_local_update_fn",
    "make_adsp_step",
    "AdspState",
]


def _deprecated(old: str) -> None:
    warnings.warn(
        f"repro.core.commit.{old} is deprecated; use repro.ps.make_train_step "
        "(one factory for every granularity and rule backend)",
        DeprecationWarning,
        stacklevel=3,
    )


def make_local_update_fn(loss_fn: Callable, cfg: CommitConfig, remat: bool = False):
    """Deprecated: the τ-microstep scan with the seed's sgd local rule.

    Returns ``local_update(params, microbatches, tau_i) -> (U, mean_loss)``.
    """
    _deprecated("make_local_update_fn")
    rule = get_local_rule("sgd", cfg, backend="reference")
    run = make_local_update(
        loss_fn, cfg, rule, remat=remat,
        unroll=True if SCAN_IN_PARTIAL_AUTO_BROKEN else 1,
    )

    def local_update(params, microbatches, tau_i):
        u, _, loss = run(params, (), microbatches, tau_i)
        return u, loss

    return local_update


def make_adsp_step(
    loss_fn: Callable,
    cfg: CommitConfig,
    mesh,
    batch_spec: P = P(("data",)),
    explicit_momentum: float = 0.0,
    remat: bool = False,
) -> Callable:
    """Deprecated: the worker-axes ADSP step with the seed's rules."""
    _deprecated("make_adsp_step")
    return make_train_step(
        loss_fn,
        cfg,
        UpdateRules(local="sgd", commit="momentum_delta", backend="reference"),
        mesh=mesh,
        batch_spec=batch_spec,
        explicit_momentum=explicit_momentum,
        remat=remat,
    )
