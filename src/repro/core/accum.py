"""DEPRECATED shim — the 'accum' granularity (τ-step gradient
accumulation with no worker axis) is now the no-worker-axes path of
``repro.ps.make_train_step``; see that module for the semantics.

``make_accum_step`` survives as a thin deprecation shim with the seed's
exact rules (sgd + momentum-delta, reference backend). The returned step
accepts the legacy scalar ``tau_active`` as well as the unified
``tau_per_worker`` int32[1] vector.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable

from repro.ps import CommitConfig, UpdateRules, make_train_step

__all__ = ["make_accum_step"]


def make_accum_step(loss_fn: Callable, cfg: CommitConfig, explicit_momentum: float = 0.0,
                    remat: bool = False) -> Callable:
    warnings.warn(
        "repro.core.accum.make_accum_step is deprecated; use "
        "repro.ps.make_train_step(..., granularity='accum')",
        DeprecationWarning,
        stacklevel=2,
    )
    cfg = dataclasses.replace(cfg, worker_axes=())
    return make_train_step(
        loss_fn,
        cfg,
        UpdateRules(local="sgd", commit="momentum_delta", backend="reference"),
        explicit_momentum=explicit_momentum,
        remat=remat,
    )
