"""ADSP 'accum' granularity: τ-microstep gradient accumulation without a
manual worker axis (single-pod runs of replica-heavy archs).

The whole mesh acts as ONE ADSP worker: weights are fully sharded
(FSDP × TP via GSPMD auto mode), each microstep computes a full-batch
gradient (collectives inside), and the τ-step accumulation plays the role
of the worker's local-update buffer — the commit is the state update at
the end. Cross-step collective *frequency* is unchanged within the pod
(the pod is internally homogeneous — there is no waiting to eliminate);
ADSP's cross-worker saving appears only once a worker axis exists
(granularity 'data'/'pod', core.commit).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .commit import AdspState, CommitConfig

__all__ = ["make_accum_step"]


def make_accum_step(loss_fn: Callable, cfg: CommitConfig, explicit_momentum: float = 0.0,
                    remat: bool = False) -> Callable:
    grad_fn = jax.value_and_grad(loss_fn)
    if remat:
        grad_fn = jax.remat(grad_fn)

    def accum_step(state: AdspState, microbatches, tau_active):
        zeros = jax.tree.map(jnp.zeros_like, state.params)

        def body(carry, xs):
            p, u = carry
            mb, idx = xs
            live = (idx < tau_active).astype(jnp.float32)
            loss, g = grad_fn(p, mb)
            p = jax.tree.map(
                lambda a, b: (a - cfg.local_lr * live * b).astype(a.dtype), p, g
            )
            u = jax.tree.map(
                lambda a, b: (a + cfg.local_lr * live * b).astype(a.dtype), u, g
            )
            return (p, u), loss * live

        idxs = jnp.arange(cfg.tau, dtype=jnp.int32)
        (_, u), losses = jax.lax.scan(body, (state.params, zeros), (microbatches, idxs))
        loss = jnp.sum(losses) / jnp.maximum(tau_active.astype(jnp.float32), 1.0)
        delta = jax.tree.map(
            lambda d, uu: (explicit_momentum * d - cfg.global_lr * uu).astype(d.dtype),
            state.prev_delta, u,
        )
        params = jax.tree.map(jnp.add, state.params, delta)
        return AdspState(params, delta, state.step + 1), loss

    return accum_step
