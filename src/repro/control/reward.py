"""Online-search reward models from §4.2 of the ADSP paper.

The scheduler compares configurations that do NOT start from the same
system state, so raw final loss is not comparable. The paper fits the
O(1/t) SGD loss-curve model

    ℓ(t) = 1 / (a1² t + a2) + a3

to (time, loss) pairs observed while a configuration is live, then defines
the reward as the *loss-decrease speed*: fix a reference loss level ℓ_ref
and report the reciprocal of the time the fitted curve needs to reach it,

    r = a1² / (1/(ℓ_ref − a3) − a2).

Larger r ⇒ the fitted curve reaches ℓ_ref sooner ⇒ faster convergence.

The fit is a tiny nonlinear least squares; we implement a Gauss-Newton /
grid-seeded curve fit in numpy (no scipy in the container) with safeguards
for the degenerate windows that occur early in training (flat or rising
loss), where we fall back to a slope-based reward.

Reward models are pluggable (mirroring the ``repro.ps``/``repro.transport``
registries): a ``RewardModel`` maps one probe window's (times, losses) to a
scalar, larger = faster convergence, and must be a *pure deterministic*
function of the window — the search compares model outputs across windows.
Built-ins:

  * ``curve_fit`` — the paper-exact absolute-time reward (``reward``);
  * ``log_slope`` — the drift-free normalized decay rate
    (``log_slope_reward``), the default used by Alg. 1 here.
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, Sequence, runtime_checkable

import numpy as np

__all__ = [
    "LossCurveFit",
    "fit_loss_curve",
    "reward_from_fit",
    "reward",
    "log_slope_reward",
    "RewardModel",
    "register_reward_model",
    "get_reward_model",
    "reward_model_names",
]


@dataclasses.dataclass(frozen=True)
class LossCurveFit:
    a1_sq: float  # a1² ≥ 0
    a2: float
    a3: float
    rss: float  # residual sum of squares
    ok: bool  # whether the nonlinear fit succeeded / is well-conditioned

    def predict(self, t: np.ndarray) -> np.ndarray:
        return 1.0 / (self.a1_sq * np.asarray(t, dtype=np.float64) + self.a2) + self.a3


_FAILED_FIT = LossCurveFit(np.nan, np.nan, np.nan, np.inf, ok=False)


def _fit_given_a3(t: np.ndarray, loss: np.ndarray, a3: float) -> tuple[float, float, float]:
    """With a3 fixed, 1/(ℓ−a3) = a1² t + a2 is linear — solve by least squares.

    Returns (a1_sq, a2, rss in the original loss space).
    """
    y = loss - a3
    if np.any(y <= 1e-9):
        return np.nan, np.nan, np.inf
    z = 1.0 / y
    A = np.stack([t, np.ones_like(t)], axis=1)
    coef, *_ = np.linalg.lstsq(A, z, rcond=None)
    a1_sq, a2 = float(coef[0]), float(coef[1])
    # a1² must be strictly positive: a1² = 0 is a flat curve (no decay
    # information), a1² < 0 a rising one — neither is a valid 1/t fit.
    if a1_sq <= 0:
        return np.nan, np.nan, np.inf
    denom = a1_sq * t + a2
    if np.any(denom <= 1e-12):
        return np.nan, np.nan, np.inf
    pred = 1.0 / denom + a3
    rss = float(np.sum((pred - loss) ** 2))
    return a1_sq, a2, rss


def fit_loss_curve(times: Sequence[float], losses: Sequence[float]) -> LossCurveFit:
    """Fit ℓ = 1/(a1² t + a2) + a3 by profiling a3 over a grid.

    a3 is the asymptotic loss: it must lie strictly below min(losses).
    We grid-search a3 and solve the conditionally-linear subproblem exactly.

    Never raises: degenerate windows (fewer than 3 samples, mismatched or
    non-1-D inputs, non-finite values, flat or rising loss) return a fit
    with ``ok=False`` — callers branch on ``fit.ok``, not on exceptions.
    """
    t = np.asarray(times, dtype=np.float64)
    l = np.asarray(losses, dtype=np.float64)
    if t.shape != l.shape or t.ndim != 1 or t.size < 3:
        return _FAILED_FIT
    if not (np.all(np.isfinite(t)) and np.all(np.isfinite(l))):
        return _FAILED_FIT
    t = t - t[0]  # shift origin; reward only depends on curve shape

    lmin, lmax = float(np.min(l)), float(np.max(l))
    if lmax <= lmin:
        # perfectly flat window: no decay information — lstsq would fit
        # a1² within rounding error of zero and bless a meaningless curve
        return _FAILED_FIT
    span = max(lmax - lmin, 1e-6)
    best = _FAILED_FIT
    best_frac = 0.5

    def try_frac(frac):
        nonlocal best, best_frac
        a3 = lmin - frac * span
        a1_sq, a2, rss = _fit_given_a3(t, l, a3)
        if rss < best.rss:
            best = LossCurveFit(a1_sq, a2, a3, rss, ok=True)
            best_frac = frac

    for frac in np.linspace(0.005, 3.0, 80):
        try_frac(frac)
    # refine around the coarse winner (the profile is smooth in a3)
    lo, hi = max(best_frac - 0.08, 1e-4), best_frac + 0.08
    for frac in np.linspace(lo, hi, 40):
        try_frac(frac)
    return best


def reward_from_fit(fit: LossCurveFit, ell_ref: float) -> float:
    """r = a1² / (1/(ℓ_ref − a3) − a2). Requires ℓ_ref > a3."""
    if not fit.ok:
        return -np.inf
    gap = ell_ref - fit.a3
    if gap <= 1e-12:
        return -np.inf
    denom = 1.0 / gap - fit.a2
    if denom <= 1e-12:
        # The fitted curve is already below ℓ_ref at t=0 — infinitely fast.
        return np.inf
    return fit.a1_sq / denom


def reward(
    times: Sequence[float],
    losses: Sequence[float],
    ell_ref: float | None = None,
) -> float:
    """End-to-end reward of one online-evaluation window (§4.2).

    ell_ref defaults to 90% of the window's loss drop below the first
    observation — a loss level the run is heading towards; any fixed
    reference consistent across the two compared windows works, and the
    scheduler passes a shared reference when comparing C_target vs
    C_target+1.

    Falls back to the negative least-squares slope (loss decrease per
    second) when the 1/t fit is degenerate, so early noisy windows still
    produce a usable ordering.
    """
    t = np.asarray(times, dtype=np.float64)
    l = np.asarray(losses, dtype=np.float64)
    if t.size == 0 or t.shape != l.shape or t.ndim != 1:
        return 0.0  # no observations ⇒ no ordering information
    if ell_ref is None:
        ell_ref = float(l[0] - 0.9 * max(l[0] - np.min(l), 1e-6))
    r = reward_from_fit(fit_loss_curve(t, l), ell_ref)
    if np.isfinite(r) and r >= 0:
        return float(r)
    # Slope fallback: reward = −dℓ/dt.
    tt = t - t[0]
    A = np.stack([tt, np.ones_like(tt)], axis=1)
    coef, *_ = np.linalg.lstsq(A, l, rcond=None)
    return float(-coef[0])


def log_slope_reward(times, losses) -> float:
    """Drift-free reward: the relative loss-decay rate −d ln(ℓ̂)/dt, with
    ℓ̂ = ℓ − a3 from the 1/t fit (falls back to raw ℓ when the fit is
    degenerate).

    Rationale: the paper's absolute-time reward r = a1²/(1/(ℓ_ref−a3)−a2)
    compares windows against one fixed loss level; when probe windows are
    sampled sequentially on a decaying curve, later windows start closer
    to ℓ_ref and win regardless of their decay *rate* (drift bias). The
    normalized rate is invariant to the window's starting level — and to
    a constant time shift of the whole window — so consecutive candidates
    compare fairly. Used by Alg. 1's implementation here; the paper-exact
    reward stays available as ``reward`` / the ``curve_fit`` model.
    """
    t = np.asarray(times, dtype=np.float64)
    l = np.asarray(losses, dtype=np.float64)
    if t.size < 2 or t.shape != l.shape or t.ndim != 1 or t[-1] <= t[0]:
        return 0.0  # no time span observed ⇒ no decay-rate information
    a3 = 0.0
    fit = fit_loss_curve(t, l)
    if fit.ok and np.isfinite(fit.a3):
        a3 = min(fit.a3, float(l.min()) - 1e-9)
    y = np.log(np.maximum(l - a3, 1e-12))
    tt = t - t[0]
    A = np.stack([tt, np.ones_like(tt)], axis=1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    return float(-coef[0])


# ---------------------------------------------------------------------------
# Reward-model registry (mirrors repro.ps / repro.transport)
# ---------------------------------------------------------------------------


@runtime_checkable
class RewardModel(Protocol):
    """Scores one probe window: larger = faster convergence. Must be a
    pure deterministic function of the (times, losses) window."""

    def __call__(self, times: Sequence[float], losses: Sequence[float]) -> float: ...


_REWARD_MODELS: dict[str, RewardModel] = {}


def register_reward_model(name: str, model: RewardModel) -> RewardModel:
    """Register ``model`` under ``name`` (last registration wins)."""
    _REWARD_MODELS[name] = model
    return model


def get_reward_model(name: str | RewardModel | None) -> RewardModel:
    """Resolve a reward model by registry name; callables pass through and
    ``None`` yields the default (``log_slope``)."""
    if name is None:
        return _REWARD_MODELS["log_slope"]
    if callable(name):
        return name
    try:
        return _REWARD_MODELS[name]
    except KeyError:
        raise KeyError(
            f"unknown reward model {name!r}; known: {sorted(_REWARD_MODELS)}"
        ) from None


def reward_model_names() -> tuple[str, ...]:
    return tuple(sorted(_REWARD_MODELS))


# NOTE: a RewardModel sees one window at a time, so the registered
# ``curve_fit`` scores each window against its *own* default ℓ_ref (90%
# of that window's drop) rather than one reference shared across the
# candidates being compared — on sequentially-sampled probes that
# carries the drift bias described in ``log_slope_reward``. It is kept
# for paper-fidelity experiments; ``log_slope`` (reference-free by
# construction) is the search default. Callers who need the paper's
# shared-reference comparison can register a closure capturing ℓ_ref:
# ``register_reward_model("curve_fit@ref", lambda t, l: reward(t, l, REF))``.
register_reward_model("curve_fit", reward)
register_reward_model("log_slope", log_slope_reward)
