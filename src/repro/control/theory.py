"""Analytical results from the ADSP paper (Hu, Wang, Wu — AAAI 2020).

Implements:
  * Eqn. (3): the geometric-staleness parameter ``p`` and the implicit
    momentum ``mu_implicit = 1 - p`` induced by accumulated local updates
    (Theorem 1).
  * Appendix C: closed-form average training speeds (steps/sec) of BSP,
    SSP, Fixed ADACOMM and ADSP over a heterogeneous worker set, used both
    by benchmarks and by the cluster scheduler's napkin math.
  * The commit-interval / local-step-count transforms used by Alg. 2
    (timer timeout Γ/ΔC_i − O_i) and by the reference sequence in the
    convergence proof (D_i = Γ/(ΔC_i · v_i) — note the paper's Appendix B
    writes this as a time quantity; the *step count* between commits is
    τ_i = v_i · (Γ/ΔC_i − O_i), which is what a discrete simulator and the
    TPU runtime use).

Everything here is plain float math on Python/numpy scalars and arrays —
no jax — so the scheduler can run on a CPU host thread without touching
device state.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

__all__ = [
    "WorkerProfile",
    "staleness_p",
    "mu_implicit",
    "commit_interval_seconds",
    "local_steps_between_commits",
    "commit_rates_from_target",
    "effective_step_time",
    "heterogeneity_degree",
    "speed_bsp",
    "speed_ssp",
    "speed_fixed_adacomm",
    "speed_adsp",
]


@dataclasses.dataclass(frozen=True)
class WorkerProfile:
    """Static capability profile of one edge worker.

    Attributes:
      v: training speed, mini-batch steps per (virtual) second.
      o: communication overhead per commit (push U_i + pull W), seconds.
         This is the payload-independent part (connection setup, PS queue,
         protocol overhead); payload transfer time comes from the link.
      bandwidth: link throughput in bytes per (virtual) second. The default
         ``inf`` makes every transfer free, reducing the commit cost to the
         fixed ``o`` — exactly the pre-link-model behaviour.
      latency: fixed one-way link latency per transfer, seconds.
    """

    v: float
    o: float = 0.0
    bandwidth: float = math.inf
    latency: float = 0.0

    def __post_init__(self) -> None:
        if self.v <= 0:
            raise ValueError(f"worker speed must be positive, got {self.v}")
        if self.o < 0:
            raise ValueError(f"comm overhead must be >= 0, got {self.o}")
        if self.bandwidth <= 0:
            raise ValueError(f"link bandwidth must be positive, got {self.bandwidth}")
        if self.latency < 0:
            raise ValueError(f"link latency must be >= 0, got {self.latency}")

    def transfer_seconds(self, nbytes: float) -> float:
        """One-way time to move ``nbytes`` over this worker's link (the
        payload-dependent half of a commit; the fixed ``o`` is charged
        separately by the caller)."""
        return self.latency + nbytes / self.bandwidth


# ---------------------------------------------------------------------------
# Theorem 1 / Eqn. (3): implicit momentum
# ---------------------------------------------------------------------------

def staleness_p(
    delta_c: Sequence[float],
    v: Sequence[float],
    gamma: float,
) -> float:
    """Eqn. (3): p = 1 / (1 + (1 - 1/m) * sum_i Γ / (ΔC_i · v_i)).

    Args:
      delta_c: per-worker commit rates ΔC_target^i (commits per check period).
      v: per-worker speeds (steps/sec).
      gamma: check-period length Γ (seconds).
    Returns:
      p ∈ (0, 1]; the staleness of commits is Geom(p).
    """
    delta_c = np.asarray(delta_c, dtype=np.float64)
    v = np.asarray(v, dtype=np.float64)
    if delta_c.shape != v.shape or delta_c.ndim != 1:
        raise ValueError("delta_c and v must be equal-length 1-D sequences")
    if np.any(delta_c <= 0) or np.any(v <= 0) or gamma <= 0:
        raise ValueError("delta_c, v, gamma must be positive")
    m = delta_c.shape[0]
    s = float(np.sum(gamma / (delta_c * v)))
    return 1.0 / (1.0 + (1.0 - 1.0 / m) * s)


def mu_implicit(
    delta_c: Sequence[float],
    v: Sequence[float],
    gamma: float,
) -> float:
    """Implicit momentum μ_implicit = 1 − p (Theorem 1).

    Monotonically decreasing in each ΔC_i: more frequent commits → less
    staleness → less implicit momentum.
    """
    return 1.0 - staleness_p(delta_c, v, gamma)


# ---------------------------------------------------------------------------
# Alg. 2 transforms
# ---------------------------------------------------------------------------

def commit_interval_seconds(gamma: float, delta_c_i: float, o_i: float) -> float:
    """Timer timeout used by worker i: Γ/ΔC_i − O_i  (Alg. 2 line 4).

    Clamped at a small positive floor — if the worker's communication
    overhead already exceeds its commit budget, it commits back-to-back.
    """
    if delta_c_i <= 0:
        raise ValueError("commit rate must be positive")
    return max(gamma / delta_c_i - o_i, 1e-9)


def local_steps_between_commits(
    profile: WorkerProfile, gamma: float, delta_c_i: float
) -> int:
    """τ_i: number of mini-batch steps worker i trains between two commits.

    τ_i = v_i · (Γ/ΔC_i − O_i), floored at 1 (a worker always trains at
    least one step per commit — committing an empty update is useless).
    """
    t = commit_interval_seconds(gamma, delta_c_i, profile.o)
    return max(1, int(math.floor(profile.v * t)))


def commit_rates_from_target(
    c_target: int, commit_counts: Sequence[int]
) -> np.ndarray:
    """ΔC_target^i = C_target − c_i (floored at 1: every worker must commit
    at least once per check period, per §4.2)."""
    c = np.asarray(commit_counts, dtype=np.int64)
    return np.maximum(c_target - c, 1)


# ---------------------------------------------------------------------------
# Appendix C: average-speed model
# ---------------------------------------------------------------------------

def effective_step_time(profile: WorkerProfile, tau_i: float) -> float:
    """t′_i = t_i + O_i/τ_i — per-step time amortizing commit overhead
    over τ_i local steps (Appendix C 'Conclusion'). For BSP τ_i = 1."""
    if tau_i <= 0:
        raise ValueError("tau must be positive")
    return 1.0 / profile.v + profile.o / tau_i


def heterogeneity_degree(v: Sequence[float]) -> float:
    """H = mean(v) / min(v) (§5.2)."""
    v = np.asarray(v, dtype=np.float64)
    if np.any(v <= 0):
        raise ValueError("speeds must be positive")
    return float(np.mean(v) / np.min(v))


def speed_bsp(profiles: Sequence[WorkerProfile]) -> float:
    """V_BSP = 1 / max_i (t_i + O_i)  [steps/sec, per-worker synchronous]."""
    return 1.0 / max(effective_step_time(p, 1.0) for p in profiles)


def speed_fixed_adacomm(profiles: Sequence[WorkerProfile], tau: int) -> float:
    """V_Fixed = 1 / max_i (t_i + O_i/τ).

    Note the paper's Appendix C writes 1/(max_i τ(t_i + O_i/τ)) in units of
    *rounds*; per-step speed divides the round time by the τ steps trained,
    giving 1/max_i(t_i + O_i/τ).
    """
    return 1.0 / max(effective_step_time(p, float(tau)) for p in profiles)


def speed_ssp(profiles: Sequence[WorkerProfile], s: int, tau: int = 1) -> float:
    """SSP sits between BSP and Fixed ADACOMM (Appendix C):
    V_BSP ≤ V_SSP ≤ V_Fixed, equal to BSP at s=1 (well, s=0 barrier) and to
    Fixed at homogeneity. We model it as a linear interpolation in the
    slack s (bounded by τ): a coarse but monotone surrogate used only for
    napkin math — the edgesim measures SSP speed exactly by simulation.
    """
    lo, hi = speed_bsp(profiles), speed_fixed_adacomm(profiles, max(tau, 1))
    frac = min(max(s, 0), tau) / max(tau, 1)
    return lo + (hi - lo) * frac


def speed_adsp(
    profiles: Sequence[WorkerProfile],
    gamma: float,
    delta_c: Sequence[float],
) -> float:
    """V_ADSP = (1/m) Σ_i 1/(t_i + O_i/τ_i), with τ_i from the rate rule
    t_i τ_i + O_i = Γ/ΔC_i. Every worker contributes its own full speed —
    the no-waiting property."""
    total = 0.0
    for p, dc in zip(profiles, delta_c, strict=True):
        tau_i = max((gamma / dc - p.o) * p.v, 1.0)
        total += 1.0 / effective_step_time(p, tau_i)
    return total / len(profiles)
