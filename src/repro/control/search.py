"""Alg. 1 — Commit-Rate Adjustment at the Scheduler (online search).

The scheduler is substrate-agnostic: it talks to the running system through
the ``OnlineSystem`` protocol, which both the edge simulator
(``repro.edgesim``) and the cluster runtime (``repro.launch.train``)
implement. ``evaluate`` runs the system *live* (no state reset — this is
the paper's online search) for a probe window under a given C_target and
returns the (time, loss) samples observed.

DECIDECOMMITRATE starts from C_target = max_i c_i + 1 (the smallest value
letting every worker commit ≥ once per period), compares the rewards of
C_target and C_target+1, and climbs while the reward improves. §4.2 argues
the optimum is to the right of the start point, so a one-directional climb
suffices. Two guards bound the climb: ``max_probes`` caps total probe
windows, and the ε-tie **patience** guard lets up to ``patience``
consecutive near-tie probes (reward within ``eps_tie`` of the best, in
relative terms) extend the climb instead of ending it — one noisy plateau
probe cannot terminate the search, and a noisy plateau cannot climb
forever either. With the defaults (patience=0) the climb is exactly the
paper's: break on the first non-improving probe.

The climb itself is the :class:`SearchSession` state machine: one
``probe_window_complete`` transition per probe window, so the engine can
interleave probes with normal event dispatch — and churn or speed-shift
events arriving *mid-probe* invalidate the window and restart (or, past
``max_restarts``, abort) the session instead of being invisible to it.
``decide_commit_rate`` is the blocking convenience wrapper that drives a
session to completion against an ``OnlineSystem``.
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, Sequence

from .reward import RewardModel, get_reward_model

__all__ = [
    "OnlineSystem",
    "SearchTrace",
    "SearchSession",
    "decide_commit_rate",
    "Scheduler",
    "pad_probe_samples",
]


def pad_probe_samples(ts: list, ls: list) -> tuple[list, list]:
    """Ensure a probe window yields ≥3 (time, loss) samples — the minimum
    the reward curve fit needs — by inserting a midpoint. Shared by every
    backend's ``run_window`` so the sampling contract lives in one place.

    Degenerate windows (shorter than the eval interval, or cut off by
    convergence) can arrive with 0 or 1 samples, or with all samples at
    one instant; those yield a synthetic flat window (zero reward slope)
    instead of an IndexError / duplicate time points that break the
    curve fit's slope normalization.
    """
    ts, ls = list(ts), list(ls)
    if not ts:
        return ts, ls
    if len(ts) == 1 or ts[-1] <= ts[0]:
        # A single observed instant carries no decay-rate information:
        # expand to a flat 1-second window so the fit sees slope 0.
        t0, l0 = ts[-1], ls[-1]
        return [t0, t0 + 0.5, t0 + 1.0], [l0, l0, l0]
    if len(ts) < 3:
        ts.insert(1, (ts[0] + ts[-1]) / 2)
        ls.insert(1, (ls[0] + ls[-1]) / 2)
    return ts, ls


class OnlineSystem(Protocol):
    """What Alg. 1 needs from the system under control."""

    def commit_counts(self) -> Sequence[int]:
        """Current cumulative commit count c_i per worker."""
        ...

    def evaluate(self, c_target: int, probe_seconds: float) -> tuple[Sequence[float], Sequence[float]]:
        """Run live with commit rates ΔC_i = C_target − c_i for
        ``probe_seconds`` (virtual) seconds; return (times, losses) sampled
        during the window (≥3 samples: start / middle / end)."""
        ...


@dataclasses.dataclass
class SearchTrace:
    """Record of one search, for EXPERIMENTS.md and tests."""

    candidates: list[int] = dataclasses.field(default_factory=list)
    rewards: list[float] = dataclasses.field(default_factory=list)
    chosen: int = -1
    restarts: int = 0  # churn-forced restarts absorbed by the session
    aborted: bool = False  # True if churn exhausted max_restarts
    # every window the backend actually ran for this search: scored ones
    # (including those of climbs later abandoned by a restart) plus the
    # churn-invalidated window behind each restart
    windows: int = 0
    # (virtual) time span of the search, stamped by the engine; -1 when
    # the driver keeps no clock (e.g. decide_commit_rate on a bare system)
    t_start: float = -1.0
    t_end: float = -1.0

    @property
    def probe_windows(self) -> int:
        """Probe windows consumed, counting both the discarded window
        behind every churn restart and the scored windows of abandoned
        climbs. Falls back to the final climb's length for traces built
        without a session (e.g. hand-made oracles)."""
        return self.windows if self.windows else len(self.candidates) + self.restarts


@dataclasses.dataclass
class SearchSession:
    """Incremental DECIDECOMMITRATE (Alg. 1 lines 8–16): one probe window
    per transition, driven by probe-window-complete events.

    Lifecycle::

        s = SearchSession(...)
        cand = s.begin(commit_counts)        # -> first candidate to probe
        while cand is not None:
            ...run the system live at C_target=cand for probe_seconds...
            # churn mid-window? -> s.notify_churn(); then either
            #   s.restart(commit_counts)  (window invalid, start over), or
            #   the session aborts itself past max_restarts
            cand = s.probe_window_complete(times, losses)
        s.trace.chosen                        # the winner (engine retargets)

    States: ``idle`` → ``probing`` → ``done`` | ``aborted``. The climb
    keeps the best candidate seen; a probe improving on it advances the
    climb, a probe within ``eps_tie`` (relative) of it spends one unit of
    ``patience`` and keeps climbing, anything worse — or patience/probes
    exhausted — ends the search at the best candidate. Defaults
    (patience=0, eps_tie=0) reproduce the paper's break-on-first-miss
    climb bit for bit.
    """

    probe_seconds: float = 60.0
    max_probes: int = 16
    patience: int = 0
    eps_tie: float = 0.0
    reward_model: str | RewardModel | None = "log_slope"
    max_restarts: int = 2
    # -- state ---------------------------------------------------------------
    state: str = dataclasses.field(default="idle", init=False)
    trace: SearchTrace = dataclasses.field(default_factory=SearchTrace, init=False)
    candidate: int = dataclasses.field(default=-1, init=False)
    _reward: RewardModel = dataclasses.field(default=None, init=False, repr=False)
    _best_c: int = dataclasses.field(default=-1, init=False)
    _best_r: float = dataclasses.field(default=0.0, init=False)
    _have_best: bool = dataclasses.field(default=False, init=False)
    _misses: int = dataclasses.field(default=0, init=False)
    _probes: int = dataclasses.field(default=0, init=False)
    _churned: bool = dataclasses.field(default=False, init=False)

    @property
    def active(self) -> bool:
        return self.state == "probing"

    @property
    def churned(self) -> bool:
        """True if churn arrived since the current probe window started."""
        return self._churned

    # ------------------------------------------------------------ lifecycle
    def begin(self, commit_counts: Sequence[int]) -> int:
        """Start (or restart) the climb from C_target = max_i c_i + 1.
        Returns the first candidate to probe."""
        self._reward = get_reward_model(self.reward_model)
        self.candidate = int(max(commit_counts)) + 1
        self.trace.candidates = [self.candidate]
        self.trace.rewards = []
        self.trace.chosen = -1
        self._have_best = False
        self._best_c, self._best_r = -1, 0.0
        self._misses = 0
        self._probes = 0
        self._churned = False
        self.state = "probing"
        return self.candidate

    def notify_churn(self) -> None:
        """A worker joined/left or changed speed mid-probe: the window in
        flight mixes two fleets and must not be scored."""
        if self.state == "probing":
            self._churned = True

    def restart(self, commit_counts: Sequence[int]) -> int | None:
        """Throw away the climb and start over on the new fleet (commit
        counts changed under us). Past ``max_restarts`` the session aborts
        — the epoch/drift trigger will search again later — and the best
        candidate probed so far (if any) is kept as the choice.

        Returns the next candidate to probe, or None if the session ended.
        """
        if self.state != "probing":
            return None
        self.trace.windows += 1  # the churn-invalidated window still ran
        if self.trace.restarts >= self.max_restarts:
            self.trace.aborted = True
            self._finish(aborted=True)
            return None
        self.trace.restarts += 1
        self.begin(commit_counts)  # does not reset trace.restarts/windows
        return self.candidate

    def probe_window_complete(self, times, losses) -> int | None:
        """Consume the probe window observed for ``self.candidate``.
        Returns the next candidate to probe, or None when the search is
        done (``trace.chosen`` holds the winner)."""
        if self.state != "probing":
            raise RuntimeError(f"probe_window_complete in state {self.state!r}")
        if self._churned:
            raise RuntimeError(
                "probe window invalidated by churn; call restart() first"
            )
        self._probes += 1
        self.trace.windows += 1
        r = float(self._reward(times, losses))
        if not self._have_best:
            # First probe: its reward enters the trace lazily, at the first
            # comparison (or at _finish if max_probes == 1).
            self._have_best = True
            self._best_c, self._best_r = self.candidate, r
        else:
            if not self.trace.rewards:
                self.trace.rewards.append(self._best_r)
            self.trace.rewards.append(r)
            if r > self._best_r:
                self._best_c, self._best_r = self.candidate, r
                self._misses = 0
            else:
                drop = self._best_r - r
                near_tie = drop <= self.eps_tie * max(abs(self._best_r), 1e-12)
                if near_tie and self._misses < self.patience:
                    self._misses += 1  # noisy plateau: spend patience, climb on
                else:
                    self._finish()
                    return None
        if self._probes >= self.max_probes:
            self._finish()
            return None
        self.candidate += 1
        self.trace.candidates.append(self.candidate)
        return self.candidate

    def _finish(self, aborted: bool = False) -> None:
        self.state = "aborted" if aborted else "done"
        if self._have_best:
            self.trace.chosen = self._best_c
        else:
            # aborted before any window completed: keep the start candidate
            self.trace.chosen = self.candidate
        if not self.trace.rewards and self._have_best:
            self.trace.rewards.append(self._best_r)
        # drop the candidate left un-probed when the climb ended early
        n = self._probes if self._probes else 1
        del self.trace.candidates[n:]


def decide_commit_rate(
    system: OnlineSystem,
    probe_seconds: float = 60.0,
    max_probes: int = 16,
    patience: int = 0,
    eps_tie: float = 0.0,
    reward_model: str | RewardModel | None = "log_slope",
) -> tuple[int, SearchTrace]:
    """DECIDECOMMITRATE (Alg. 1 lines 8–16), blocking form: drives a
    :class:`SearchSession` to completion against an ``OnlineSystem``.

    Returns the chosen C_target and the search trace. The paper probes each
    candidate for ~1 minute; probe_seconds is virtual time in the simulator.
    """
    session = SearchSession(
        probe_seconds=probe_seconds,
        max_probes=max_probes,
        patience=patience,
        eps_tie=eps_tie,
        reward_model=reward_model,
    )
    cand = session.begin(system.commit_counts())
    while cand is not None:
        ts, ls = system.evaluate(cand, probe_seconds)
        cand = session.probe_window_complete(ts, ls)
    return session.trace.chosen, session.trace


@dataclasses.dataclass
class Scheduler:
    """MAINFUNCTION (Alg. 1 lines 1–7): per-epoch commit-rate control.

    Drives an OnlineSystem that additionally exposes ``run(seconds)`` and
    ``set_c_target(c)``; the edgesim simulator satisfies this.
    """

    epoch_seconds: float = 1200.0  # paper default: 20-minute epochs
    probe_seconds: float = 60.0
    max_probes: int = 16
    patience: int = 0
    eps_tie: float = 0.0
    reward_model: str | RewardModel | None = "log_slope"
    traces: list[SearchTrace] = dataclasses.field(default_factory=list)

    def run_epoch(self, system) -> int:
        c_target, trace = decide_commit_rate(
            system, self.probe_seconds, self.max_probes,
            patience=self.patience, eps_tie=self.eps_tie,
            reward_model=self.reward_model,
        )
        self.traces.append(trace)
        spent = self.probe_seconds * len(trace.candidates)
        remaining = max(self.epoch_seconds - spent, 0.0)
        system.set_c_target(c_target)
        if remaining > 0:
            system.run(remaining)
        return c_target
