"""Drift detection for mid-epoch re-search (DESIGN.md §12).

The paper's scheduler re-runs Alg. 1 on a fixed epoch clock (20 minutes).
Adaptive-control related work (Wang et al., *Adaptive Federated Learning
in Resource-Constrained Edge Computing Systems*; Basani et al., *When
Less is More*) re-tunes the synchronization knob when conditions *drift*
instead: the chosen C_target is only optimal for the fleet it was
searched on, so a mid-epoch speed shift, join, or leave strands the
system on a stale target until the next epoch boundary.

``DriftDetector`` watches two signals between searches:

  * **speed fractions** — the normalized per-worker speed vector
    f_i = v_i / Σv. Its total-variation distance from the baseline
    recorded at the last (re-)search measures how much the heterogeneity
    pattern moved; membership changes (join/leave) register as mass
    appearing/disappearing at a worker id.
  * **loss trajectory** — the smoothed global loss observed at
    checkpoints. A loss *regressing* above its best-since-baseline by
    more than ``loss_rise_tol`` (relative) means the current commit rate
    stopped working even though no profile changed (e.g. gradient noise
    from a batch rebalance).

When either signal exceeds its threshold — and the ``cooldown`` since the
last trigger has elapsed — ``should_search`` fires once; the policy turns
that into a ``Search`` command and the detector re-baselines when the
search completes (``rebaseline``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping

__all__ = ["DriftDetector", "speed_fractions"]


def speed_fractions(view) -> dict[int, float]:
    """Normalized speed share per stable worker id over the alive fleet."""
    total = sum(w.profile.v for w in view.workers)
    if total <= 0:
        return {}
    return {w.index: w.profile.v / total for w in view.workers}


@dataclasses.dataclass
class DriftDetector:
    """See module docstring. All state is plain floats/dicts — the
    detector lives inside a policy and must stay trivially serializable.
    """

    threshold: float = 0.25  # total-variation distance of speed fractions
    loss_rise_tol: float = 0.1  # relative loss regression vs best-since-baseline
    cooldown: float = 120.0  # min (virtual) seconds between triggers
    _baseline: dict[int, float] = dataclasses.field(default_factory=dict, init=False)
    _best_loss: float = dataclasses.field(default=math.inf, init=False)
    _last_loss: float = dataclasses.field(default=math.nan, init=False)
    _last_trigger: float = dataclasses.field(default=-math.inf, init=False)
    _pending_discovery: bool = dataclasses.field(default=False, init=False)

    # ------------------------------------------------------------ baseline
    def rebaseline(self, fractions: Mapping[int, float], now: float) -> None:
        """Record the fleet the current C_target was chosen for. Called
        when a search completes (and once at start)."""
        self._baseline = dict(fractions)
        self._best_loss = math.inf
        self._last_loss = math.nan
        self._last_trigger = max(self._last_trigger, now - self.cooldown)
        self._pending_discovery = False

    # ------------------------------------------------------------- signals
    def fleet_drift(self, fractions: Mapping[int, float]) -> float:
        """Total-variation distance ½·Σ|f_now − f_base| over the union of
        worker ids (a departed/joined worker contributes its full share)."""
        ids = set(self._baseline) | set(fractions)
        return 0.5 * sum(
            abs(fractions.get(i, 0.0) - self._baseline.get(i, 0.0)) for i in ids
        )

    def observe_loss(self, loss: float | None) -> None:
        """Feed the smoothed global loss at a checkpoint."""
        if loss is None or not math.isfinite(loss):
            return
        self._last_loss = loss
        self._best_loss = min(self._best_loss, loss)

    def loss_regressed(self) -> bool:
        if not math.isfinite(self._best_loss) or math.isnan(self._last_loss):
            return False
        return self._last_loss > self._best_loss * (1.0 + self.loss_rise_tol)

    def note_discovered_failure(self, now: float) -> None:
        """A lease expiry (repro.fleet) removed a worker the PS was never
        told about. Discovery is categorical evidence the baseline fleet
        no longer exists, so the next ``should_search`` bypasses the
        TV-distance threshold — a small worker's silent death still
        re-searches — while the cooldown still rate-limits failure
        cascades. The flag is consumed by the trigger and cleared by
        ``rebaseline``."""
        self._pending_discovery = True

    # ------------------------------------------------------------- trigger
    def should_search(self, fractions: Mapping[int, float], now: float) -> bool:
        """True exactly when a re-search should fire now; stamps the
        cooldown so a burst of churn events triggers once."""
        if not self._baseline:
            # never baselined: adopt this fleet silently, don't trigger
            self.rebaseline(fractions, now)
            return False
        if now - self._last_trigger < self.cooldown:
            return False
        if (self._pending_discovery
                or self.fleet_drift(fractions) > self.threshold
                or self.loss_regressed()):
            self._pending_discovery = False
            self._last_trigger = now
            return True
        return False
