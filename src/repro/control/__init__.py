"""The ADSP control plane (DESIGN.md §12).

Everything the scheduler *decides with* lives here, mirroring the
``repro.ps``/``repro.transport`` registry pattern:

  * ``control.search`` — Alg. 1: the incremental :class:`SearchSession`
    state machine (one probe window per transition, churn-aware), the
    blocking ``decide_commit_rate`` wrapper, and the per-epoch
    ``Scheduler``;
  * ``control.reward`` — §4.2 reward models behind the pluggable
    ``RewardModel`` registry (``curve_fit`` paper-exact, ``log_slope``
    drift-free default);
  * ``control.drift`` — :class:`DriftDetector`: mid-epoch re-search
    triggers from speed-fraction / loss-trajectory drift;
  * ``control.theory`` — the paper's analytical results (Eqn. 3 implicit
    momentum, Alg. 2 transforms, Appendix C speed models).

The executor side — events, commands, policies, the engine — stays in
``repro.cluster``; this package is pure decision logic on plain
Python/numpy scalars, importable without jax device state.
"""

from .drift import DriftDetector, speed_fractions
from .reward import (
    LossCurveFit,
    RewardModel,
    fit_loss_curve,
    get_reward_model,
    log_slope_reward,
    register_reward_model,
    reward,
    reward_from_fit,
    reward_model_names,
)
from .search import (
    OnlineSystem,
    Scheduler,
    SearchSession,
    SearchTrace,
    decide_commit_rate,
    pad_probe_samples,
)
from .theory import WorkerProfile

__all__ = [
    # search (Alg. 1)
    "OnlineSystem", "Scheduler", "SearchSession", "SearchTrace",
    "decide_commit_rate", "pad_probe_samples",
    # reward models
    "LossCurveFit", "RewardModel", "fit_loss_curve", "get_reward_model",
    "log_slope_reward", "register_reward_model", "reward", "reward_from_fit",
    "reward_model_names",
    # drift
    "DriftDetector", "speed_fractions",
    # theory
    "WorkerProfile",
]
