"""Registered transport codecs: identity, int8, bf16, top-k.

Every lossy codec follows the error-feedback discipline of the Codec
contract (``codec.py``): encode compresses e = update + residual and
carries e − decode(encode(e)) forward, so quantization/sparsification
error is re-injected instead of lost. Reference backends are pure JAX
(the correctness contract); fused backends route the elementwise
encode/decode passes through the Pallas codec kernels
(``kernels.codec`` via ``kernels.ops``). ``top_k`` has no fused
implementation (gather/scatter-dominated, not an elementwise pass) —
a fused request falls back to its reference implementation.

Payload formats (per dense leaf):

  identity  the leaf itself                          (bytes: dense)
  int8      {"q": int8[shape], "scale": f32 scalar}  (bytes: n + 4)
  bf16      bf16[shape]                              (bytes: 2n)
  top_k     {"idx": int32[k], "vals": f32[k]}        (bytes: 8k)
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .codec import Codec, register_codec

__all__ = []  # codecs are reached through the registry


def _zip_encode(fn, u, state):
    """Apply a per-leaf ``fn(u_leaf, r_leaf) -> (payload, residual)`` and
    unzip into (payload_tree, state_tree)."""
    pairs = jax.tree.map(fn, u, state)
    is_pair = lambda x: isinstance(x, tuple)
    enc = jax.tree.map(lambda p: p[0], pairs, is_leaf=is_pair)
    new_state = jax.tree.map(lambda p: p[1], pairs, is_leaf=is_pair)
    return enc, new_state


def _residual_init(params):
    return jax.tree.map(lambda x: jnp.zeros(jnp.shape(x), jnp.float32), params)


def _cast_like(dense, like):
    if like is None:
        return dense
    return jax.tree.map(lambda d, l: d.astype(l.dtype), dense, like)


# ---------------------------------------------------------------------------
# identity — the exact passthrough (today's uncompressed commit)
# ---------------------------------------------------------------------------

@register_codec("identity", "reference")
def _identity(*, interpret=None) -> Codec:
    def init(params):
        return ()

    def encode(u, state):
        return u, state  # exact passthrough: bit-parity with no transport

    def decode(enc, like=None):
        return enc

    return Codec("identity", "reference", init, encode, decode)


# ---------------------------------------------------------------------------
# int8 — symmetric per-leaf quantization (4× over f32)
# ---------------------------------------------------------------------------

def _int8_scale(e):
    amax = jnp.max(jnp.abs(e))
    return jnp.where(amax > 0, amax / 127.0, jnp.float32(1.0))


def _is_int8_payload(x):
    return isinstance(x, dict) and set(x) == {"q", "scale"}


def _int8_decode_leaf(p):
    return p["q"].astype(jnp.float32) * p["scale"]


def _make_int8(enc_leaf, backend) -> Codec:
    def encode(u, state):
        return _zip_encode(enc_leaf, u, state)

    def decode(enc, like=None):
        dense = jax.tree.map(_int8_decode_leaf, enc, is_leaf=_is_int8_payload)
        return _cast_like(dense, like)

    return Codec("int8", backend, _residual_init, encode, decode)


@register_codec("int8", "reference")
def _int8_reference(*, interpret=None) -> Codec:
    def enc_leaf(ul, rl):
        e = ul.astype(jnp.float32) + rl
        scale = _int8_scale(e)
        q = jnp.clip(jnp.round(e / scale), -127.0, 127.0).astype(jnp.int8)
        res = e - q.astype(jnp.float32) * scale
        return {"q": q, "scale": scale}, res

    return _make_int8(enc_leaf, "reference")


@register_codec("int8", "fused")
def _int8_fused(*, interpret=None) -> Codec:
    def enc_leaf(ul, rl):
        # the scale is a jnp reduction over e = u + r (XLA fuses it into
        # the read); the error-feedback add + quantize + residual run as
        # ONE Pallas pass — e is never materialized (quantize_int8_ef)
        scale = _int8_scale(ul.astype(jnp.float32) + rl)
        q, res = ops.quantize_int8_ef(ul, rl, scale, interpret=interpret)
        return {"q": q, "scale": scale}, res

    def encode(u, state):
        return _zip_encode(enc_leaf, u, state)

    def decode(enc, like=None):
        dense = jax.tree.map(
            lambda p: ops.dequantize_int8(p["q"], p["scale"], interpret=interpret),
            enc, is_leaf=_is_int8_payload,
        )
        return _cast_like(dense, like)

    return Codec("int8", "fused", _residual_init, encode, decode)


# ---------------------------------------------------------------------------
# bf16 — mantissa truncation (2× over f32)
# ---------------------------------------------------------------------------

def _make_bf16(enc_leaf, backend) -> Codec:
    def encode(u, state):
        return _zip_encode(enc_leaf, u, state)

    def decode(enc, like=None):
        dense = jax.tree.map(lambda q: q.astype(jnp.float32), enc)
        return _cast_like(dense, like)

    return Codec("bf16", backend, _residual_init, encode, decode)


@register_codec("bf16", "reference")
def _bf16_reference(*, interpret=None) -> Codec:
    def enc_leaf(ul, rl):
        e = ul.astype(jnp.float32) + rl
        q = e.astype(jnp.bfloat16)
        return q, e - q.astype(jnp.float32)

    return _make_bf16(enc_leaf, "reference")


@register_codec("bf16", "fused")
def _bf16_fused(*, interpret=None) -> Codec:
    def enc_leaf(ul, rl):
        # error-feedback add folded into the cast pass (encode_bf16_ef)
        q, res = ops.encode_bf16_ef(ul, rl, interpret=interpret)
        return q, res

    return _make_bf16(enc_leaf, "fused")


# ---------------------------------------------------------------------------
# top_k — magnitude sparsification (keep a fraction of the coordinates)
# ---------------------------------------------------------------------------

def _topk_k(n: int, frac: float) -> int:
    return max(1, min(n, int(round(frac * n))))


def _is_topk_payload(x):
    return isinstance(x, dict) and set(x) == {"idx", "vals"}


@register_codec("top_k", "reference")
def _topk_reference(*, interpret=None, frac: float = 0.05) -> Codec:
    if not 0.0 < frac <= 1.0:
        raise ValueError(f"top_k frac must be in (0, 1], got {frac}")

    def encode(u, state):
        def enc_leaf(ul, rl):
            n = int(np.prod(jnp.shape(ul)))
            k = _topk_k(n, frac)
            e = ul.astype(jnp.float32).reshape(-1) + rl.reshape(-1)
            _, idx = jax.lax.top_k(jnp.abs(e), k)
            idx = idx.astype(jnp.int32)
            vals = e[idx]
            res = e.at[idx].set(0.0).reshape(jnp.shape(ul))
            return {"idx": idx, "vals": vals}, res

        return _zip_encode(enc_leaf, u, state)

    def decode(enc, like):
        if like is None:
            raise ValueError("top_k decode needs `like` for the dense shapes")

        def dec_leaf(p, l):
            n = int(np.prod(l.shape))
            dense = jnp.zeros((n,), jnp.float32).at[p["idx"]].set(p["vals"])
            return dense.reshape(l.shape).astype(l.dtype)

        return jax.tree.map(dec_leaf, enc, like, is_leaf=_is_topk_payload)

    return Codec("top_k", "reference", _residual_init, encode, decode)
