"""The commit-transport Codec contract and registry (DESIGN.md §10).

ADSP ships one payload per commit: the worker's accumulated update U up
to the PS and fresh params back down. The transport layer makes that
payload first-class: a ``Codec`` turns a dense update pytree into a wire
payload (and back), carrying an **error-feedback residual** in per-worker
state so lossy codecs (quantization, sparsification) stay unbiased over
time — the compression error of commit t is re-injected at commit t+1
(Karimireddy et al. 2019; the "when less is more" result that volume,
not frequency, dominates edge convergence).

Contracts (pytree-preserving, jit/shard_map-safe, shape/dtype-static so
encoded size is known without running the encoder):

  Codec.init(params_like) -> state        # the residual, no worker dim
  Codec.encode(update, state) -> (encoded, new_state)
      e = update + state; encoded ≈ e; new_state = e − decode(encoded)
  Codec.decode(encoded, like) -> dense update
      ``like`` supplies dense shapes/dtypes (needed by sparse codecs and
      for casting back to the update dtype); pass the update (or params)
      pytree, abstract ShapeDtypeStructs work too.

Registration mirrors ``repro.ps`` rules: each (name, backend) pair with
``backend ∈ {reference, fused}``; reference is pure JAX (the correctness
contract), fused routes the elementwise passes through the Pallas codec
kernels (``kernels.codec`` via ``kernels.ops``, interpret fallback
off-TPU). ``backend="auto"`` resolves fused on TPU / reference
elsewhere; a fused request for a codec with no fused implementation
falls back to its reference implementation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np

from repro.ps.rules import resolve_backend

__all__ = [
    "Codec",
    "register_codec",
    "get_codec",
    "codec_names",
    "codec_backends",
    "dense_nbytes",
]

Pytree = Any


def dense_nbytes(like: Pytree) -> int:
    """Bytes of a dense (uncompressed) pytree on the wire — what the PS
    pull ships down, and the identity codec's upload cost."""
    return int(sum(
        int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        for leaf in jax.tree.leaves(like)
    ))


@dataclasses.dataclass(frozen=True)
class Codec:
    """One registered transport codec (see module docstring for the
    ``init``/``encode``/``decode`` contracts)."""

    name: str
    backend: str
    init: Callable[[Pytree], Pytree]
    encode: Callable[[Pytree, Pytree], tuple]
    decode: Callable[[Pytree, Pytree], Pytree]

    def encoded_nbytes(self, like: Pytree) -> int:
        """Wire bytes of one encoded update for a dense tree shaped like
        ``like``. Static — derived from the encoder's abstract output
        shapes via ``eval_shape``, never from payload values — so link
        timing can be computed once per model, not once per commit."""

        def run(u):
            enc, _ = self.encode(u, self.init(u))
            return enc

        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(getattr(x, "shape"), getattr(x, "dtype")),
            like,
        )
        return dense_nbytes(jax.tree.leaves(jax.eval_shape(run, abstract)))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_CODECS: dict[tuple[str, str], Callable] = {}


def register_codec(name: str, backend: str = "reference"):
    """Decorator: register ``factory(*, interpret=None, **hp) -> Codec``
    under (name, backend)."""

    def deco(factory):
        _CODECS[(name, backend)] = factory
        return factory

    return deco


def codec_names() -> tuple[str, ...]:
    return tuple(sorted({n for n, _ in _CODECS}))


def codec_backends(name: str) -> tuple[str, ...]:
    return tuple(sorted(b for n, b in _CODECS if n == name))


def get_codec(name, *, backend: str | None = None,
              interpret: bool | None = None, **hp) -> Codec:
    """Instantiate a registered codec. ``name`` may already be a Codec
    (passed through); ``backend`` follows the rule-registry semantics
    (auto → fused on TPU, fused falls back when unimplemented)."""
    if isinstance(name, Codec):
        return name
    want = resolve_backend(backend)
    factory = _CODECS.get((name, want))
    if factory is None and want == "fused":
        factory = _CODECS.get((name, "reference"))  # no fused impl: fall back
    if factory is None:
        raise KeyError(f"no codec {name!r}; registered: {list(codec_names())}")
    return factory(interpret=interpret, **hp)
