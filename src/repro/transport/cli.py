"""Shared argparse plumbing for codec selection — one definition of the
``--codec``/``--codec-backend``/``--topk-frac`` flags for every entry
point (``repro.launch.train``, examples, benchmarks), mirroring
``repro.ps.cli``."""

from __future__ import annotations

import argparse

from .codec import Codec, get_codec

__all__ = ["add_codec_args", "codec_from_args"]


def add_codec_args(parser: argparse.ArgumentParser) -> None:
    parser.add_argument("--codec", default="identity",
                        help="commit payload codec: identity | int8 | bf16 | top_k")
    parser.add_argument("--codec-backend", default=None,
                        help="reference | fused | auto (fused on TPU)")
    parser.add_argument("--topk-frac", type=float, default=0.05,
                        help="fraction of coordinates the top_k codec keeps")


def codec_from_args(args: argparse.Namespace) -> Codec:
    hp = {"frac": args.topk_frac} if args.codec == "top_k" else {}
    return get_codec(args.codec, backend=args.codec_backend, **hp)
