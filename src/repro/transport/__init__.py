"""Commit-transport layer: payload codecs + link accounting (DESIGN.md §10).

Public surface:

  * ``Codec`` — the typed encode/decode/error-feedback contract;
  * ``get_codec`` / ``register_codec`` / ``codec_names`` /
    ``codec_backends`` — the (name, backend) registry, mirroring
    ``repro.ps`` rules (reference = pure JAX, fused = Pallas kernels);
  * ``dense_nbytes`` — wire size of an uncompressed pytree (what the PS
    pull ships down);
  * ``add_codec_args`` / ``codec_from_args`` — shared argparse plumbing.

Built-ins: ``identity`` (exact passthrough), ``int8`` (symmetric
per-leaf quantization, 4×), ``bf16`` (2×), ``top_k`` (magnitude
sparsification, ``frac`` hyperparameter).
"""

from .cli import add_codec_args, codec_from_args
from .codec import (
    Codec,
    codec_backends,
    codec_names,
    dense_nbytes,
    get_codec,
    register_codec,
)

# importing this registers the built-in codecs
from . import codecs as _codecs  # noqa: F401

__all__ = [
    "Codec",
    "add_codec_args",
    "codec_backends",
    "codec_from_args",
    "codec_names",
    "dense_nbytes",
    "get_codec",
    "register_codec",
]
