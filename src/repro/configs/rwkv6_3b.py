"""RWKV-6 "Finch" 3B [arXiv:2404.05892] — attention-free SSM.

32 layers, d_model 2560 (40 heads × 64), channel-mix d_ff 8960, vocab
65536. Data-dependent decay (ddlerp + decay LoRA). O(1) recurrent state ⇒
long_500k decode is native.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    arch_type="ssm",
    source="arXiv:2404.05892",
    num_layers=32,
    d_model=2560,
    num_heads=40,        # wkv heads = d_model / rwkv_head_dim
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65_536,
    layer_pattern=("rwkv",),
    rwkv_head_dim=64,
    pos_variant="none",
    adsp_granularity="data",
)
