"""Qwen2.5-32B [hf:Qwen/Qwen2.5-0.5B family] — dense GQA with QKV bias.

64 layers, d_model 5120, 40 heads / 8 KV, d_ff 27648, vocab 152064.
ADSP granularity 'pod' (replica ×3 state at 64 GB params is too large for
a 16-chip model group). long_500k via sliding-window variant only.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    arch_type="dense",
    source="hf:Qwen/Qwen2.5-0.5B",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=27648,
    vocab_size=152_064,
    qkv_bias=True,
    layer_pattern=("global",),
    mlp_variant="swiglu",
    rope_theta=1_000_000.0,
    adsp_granularity="pod",
)
