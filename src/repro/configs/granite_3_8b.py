"""Granite-3 8B [hf:ibm-granite/granite-3.0-2b-base family] — dense GQA.

40 layers, d_model 4096, 32 heads / 8 KV heads, d_ff 12800, vocab 49155
(padded to 49408 for model-axis sharding). long_500k runs only with the
beyond-paper sliding-window variant (window 4096), flagged in the dry-run.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    arch_type="dense",
    source="hf:ibm-granite/granite-3.0-2b-base",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12800,
    vocab_size=49_155,
    layer_pattern=("global",),
    mlp_variant="swiglu",
    rope_theta=10_000.0,
    adsp_granularity="data",
)
