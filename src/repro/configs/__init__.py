"""Architecture registry: one module per assigned architecture (exact
assigned hyper-parameters, source cited) + the paper's own small models.

``get_config(name)`` returns the full ModelConfig; ``get_smoke(name)``
returns the reduced same-family variant used by CPU smoke tests.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, smoke_variant

ARCH_IDS = [
    "recurrentgemma_9b",
    "whisper_small",
    "granite_3_8b",
    "llama4_maverick_400b_a17b",
    "rwkv6_3b",
    "qwen2_5_32b",
    "internlm2_20b",
    "phi_3_vision_4_2b",
    "starcoder2_7b",
    "qwen2_moe_a2_7b",
]

_ALIASES = {
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-small": "whisper_small",
    "granite-3-8b": "granite_3_8b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "rwkv6-3b": "rwkv6_3b",
    "qwen2.5-32b": "qwen2_5_32b",
    "internlm2-20b": "internlm2_20b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "starcoder2-7b": "starcoder2_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke(name: str) -> ModelConfig:
    return smoke_variant(get_config(name))


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
