"""StarCoder2-7B [arXiv:2402.19173] — dense GQA with native sliding window.

32 layers, d_model 4608, 36 heads / 4 KV, d_ff 18432, vocab 49152, RoPE,
sliding-window attention 4096 (paper-native) ⇒ long_500k is valid without
a variant flag.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    arch_type="dense",
    source="arXiv:2402.19173",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49_152,
    sliding_window=4096,
    layer_pattern=("global",),
    mlp_variant="gelu",
    norm_variant="layernorm",
    rope_theta=100_000.0,
    adsp_granularity="data",
)
