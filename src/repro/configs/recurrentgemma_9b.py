"""RecurrentGemma-9B [arXiv:2402.19427] — hybrid RG-LRU + local attention.

38 layers in the Griffin 2:1 pattern (recurrent, recurrent, local attn);
38 = 12×(rec,rec,local) + (rec,rec) remainder. GQA for the local-attention
blocks with a single KV head (kv=1 per assignment), local window 2048.
Attention-free recurrence ⇒ long_500k decode is native (O(1) state).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    source="arXiv:2402.19427",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256_000,
    head_dim=256,
    layer_pattern=("recurrent", "recurrent", "local"),
    local_window=2048,
    lru_width=4096,
    conv1d_width=4,
    mlp_variant="swiglu",
    rope_theta=10_000.0,
    adsp_granularity="data",
)
