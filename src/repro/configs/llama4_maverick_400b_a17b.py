"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family].

48 layers, d_model 5120, 40 heads / 8 KV, vocab 202048. MoE with 128
routed experts, top-1, d_expert 8192, one shared expert, interleaved every
other layer (dense, moe) — Llama-4 style early-fusion decoder. At ~400B
total / ~17B active this is the memory-critical arch: ADSP granularity is
'pod' (one full replica per pod; within a pod weights shard over
data×model — see DESIGN.md §5 on ADSP's replica-memory constraint).
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    layer_pattern=("dense", "moe"),
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        d_expert=8192,
        num_shared_experts=1,
        d_shared=8192,
        capacity_factor=1.25,
    ),
    mlp_variant="swiglu",
    rope_theta=500_000.0,
    adsp_granularity="pod",
)
