"""InternLM2-20B [arXiv:2403.17297] — dense GQA.

48 layers, d_model 6144, 48 heads / 8 KV, d_ff 16384, vocab 92544.
long_500k via sliding-window variant only.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    arch_type="dense",
    source="arXiv:2403.17297",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92_544,
    layer_pattern=("global",),
    mlp_variant="swiglu",
    rope_theta=1_000_000.0,
    adsp_granularity="data",
)
