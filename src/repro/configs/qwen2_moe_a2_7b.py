"""Qwen2-MoE A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — fine-grained MoE.

24 layers, d_model 2048, 16 heads (kv=16, MHA), 60 routed experts top-4
with d_expert 1408, plus 4 shared experts (fused 4×1408=5632 hidden),
vocab 151936. Every layer is MoE.
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151_936,
    layer_pattern=("moe",),
    moe=MoEConfig(
        num_experts=60,
        top_k=4,
        d_expert=1408,
        num_shared_experts=4,
        d_shared=1408,  # fused shared MLP hidden = 4 x 1408 = 5632
        capacity_factor=1.5,
    ),
    mlp_variant="swiglu",
    rope_theta=1_000_000.0,
    qkv_bias=True,
    adsp_granularity="data",
)
