"""Whisper-small [arXiv:2212.04356] — encoder-decoder audio model.

12+12 layers, d_model 768, MHA 12 heads (kv=12), GELU MLP, LayerNorm,
learned positions. The mel-spectrogram + conv frontend is a STUB: the
model consumes precomputed frame embeddings (B, 1500, 768) per the
assignment carve-out. Decoder = causal self-attn + cross-attn.
long_500k is SKIPPED (enc-dec audio decoder, full self-attention,
1500-frame encoder context — out of family; see DESIGN.md).
"""

from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    arch_type="audio",
    source="arXiv:2212.04356",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    layer_pattern=("global",),
    mlp_variant="gelu",
    norm_variant="layernorm",
    pos_variant="learned",
    frontend="audio",
    encoder=EncoderConfig(
        num_layers=12, num_frames=1500, d_model=768, num_heads=12, d_ff=3072
    ),
    max_seq_len=32_768,  # structural stand-in: real whisper decodes <=448 tokens;
    # the assignment exercises the backbone at 32k (see DESIGN.md)
    adsp_granularity="data",
)
