"""Phi-3-vision 4.2B [hf:microsoft/Phi-3-vision-128k-instruct] — VLM.

phi3-mini backbone: 32 layers, d_model 3072, MHA 32 heads (kv=32 per
assignment), d_ff 8192, vocab 32064. The CLIP vision tower + projector is
a STUB: precomputed patch embeddings (B, 576, 3072) are prepended to the
token sequence; loss is masked to text positions. long_500k via the
sliding-window variant only.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    source="hf:microsoft/Phi-3-vision-128k-instruct",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32_064,
    layer_pattern=("global",),
    mlp_variant="swiglu",
    rope_theta=10_000.0,
    frontend="vision",
    num_prefix_embeddings=576,
    adsp_granularity="data",
)
