"""Hypothesis property tests for the reward models (repro.control.reward).

Contracts under test:
  * ``log_slope_reward`` is invariant to a constant time shift of the
    whole probe window (the drift-free property the search relies on
    when comparing sequentially-sampled windows);
  * ``fit_loss_curve`` NEVER raises — flat, rising, and degenerate
    (short / mismatched / non-finite) windows return ``ok=False``;
  * a valid decaying 1/t window still fits (``ok=True``) so the
    never-raise hardening did not break the happy path.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev extra; pip install -e .[dev]")
from hypothesis import given, settings, strategies as st

from repro.control.reward import fit_loss_curve, log_slope_reward, reward


def _curve(a1_sq, a2, a3, t):
    return 1.0 / (a1_sq * t + a2) + a3


# ---------------------------------------------------------------------------
# log_slope_reward: time-shift invariance
# ---------------------------------------------------------------------------


@given(
    st.floats(0.005, 0.2),     # a1_sq (decay rate)
    st.floats(0.1, 1.0),       # a2
    st.floats(0.0, 2.0),       # a3 (asymptote)
    st.floats(-1e4, 1e4),      # constant time shift
    st.integers(4, 16),        # samples in the window
)
@settings(max_examples=80, deadline=None)
def test_log_slope_reward_time_shift_invariant(a1_sq, a2, a3, shift, n):
    """r(t + Δ, ℓ) == r(t, ℓ): the model normalizes the window to its own
    start, so sequential probes compare fairly no matter when they were
    sampled. (Equality up to the rounding of t + Δ itself — the shifted
    time stamps are not exactly representable.)"""
    t = np.linspace(0.0, 60.0, n)
    loss = _curve(a1_sq, a2, a3, t)
    r0 = log_slope_reward(t, loss)
    assert log_slope_reward(t + shift, loss) == pytest.approx(r0, rel=1e-9, abs=1e-12)


@given(st.floats(0.01, 0.2), st.floats(0.05, 0.5), st.floats(-1e3, 1e3))
@settings(max_examples=50, deadline=None)
def test_log_slope_reward_orders_decay_speed_any_origin(a1_slow, extra, shift):
    """Faster decay ⇒ larger reward, regardless of the window's origin."""
    t = np.linspace(0.0, 60.0, 10) + shift
    slow = _curve(a1_slow, 0.5, 0.2, np.linspace(0.0, 60.0, 10))
    fast = _curve(a1_slow + extra, 0.5, 0.2, np.linspace(0.0, 60.0, 10))
    assert log_slope_reward(t, fast) > log_slope_reward(t, slow)


# ---------------------------------------------------------------------------
# fit_loss_curve: ok=False (never an exception) on bad windows
# ---------------------------------------------------------------------------


@given(st.floats(0.01, 100.0), st.integers(3, 12))
@settings(max_examples=50, deadline=None)
def test_fit_flat_window_returns_not_ok(level, n):
    t = np.linspace(0.0, 60.0, n)
    fit = fit_loss_curve(t, np.full(n, level))
    assert not fit.ok


@given(st.floats(1e-4, 1.0), st.floats(0.01, 10.0), st.integers(3, 12))
@settings(max_examples=50, deadline=None)
def test_fit_rising_window_returns_not_ok(slope, start, n):
    t = np.linspace(0.0, 60.0, n)
    fit = fit_loss_curve(t, start + slope * t)
    assert not fit.ok


@given(st.lists(st.floats(-1e6, 1e6), max_size=2),
       st.lists(st.floats(-1e6, 1e6), max_size=2))
@settings(max_examples=50, deadline=None)
def test_fit_too_short_or_mismatched_returns_not_ok(ts, ls):
    assert not fit_loss_curve(ts, ls).ok


@given(st.integers(3, 8), st.sampled_from([np.nan, np.inf, -np.inf]))
@settings(max_examples=30, deadline=None)
def test_fit_non_finite_values_return_not_ok(n, bad):
    t = np.linspace(0.0, 10.0, n)
    l = np.linspace(3.0, 1.0, n)
    l_bad = l.copy()
    l_bad[n // 2] = bad
    assert not fit_loss_curve(t, l_bad).ok
    t_bad = t.copy()
    t_bad[n // 2] = bad
    assert not fit_loss_curve(t_bad, l).ok


@given(st.floats(0.005, 0.3), st.floats(0.1, 1.0), st.floats(0.0, 2.0))
@settings(max_examples=50, deadline=None)
def test_fit_valid_decaying_window_still_ok(a1_sq, a2, a3):
    """The hardening must not reject real decaying windows."""
    t = np.linspace(0.0, 60.0, 12)
    fit = fit_loss_curve(t, _curve(a1_sq, a2, a3, t))
    assert fit.ok
    assert fit.a1_sq > 0


@given(st.lists(st.floats(0.0, 1e4), min_size=0, max_size=8),
       st.lists(st.floats(-10.0, 1e4), min_size=0, max_size=8))
@settings(max_examples=80, deadline=None)
def test_reward_pipeline_never_raises(ts, ls):
    """End to end: arbitrary windows through either reward model produce
    a float, never an exception (degenerate ⇒ 0 / slope fallback)."""
    r1 = log_slope_reward(ts, ls)
    r2 = reward(ts, ls)
    assert isinstance(r1, float) and isinstance(r2, float)
    assert not np.isnan(r1)
