"""MoE layer invariants: gating, capacity, shared experts, gradients."""


import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, MoEConfig
from repro.models.moe import moe_apply, moe_init


def _cfg(experts=4, top_k=2, shared=0, cf=2.0):
    return ModelConfig(
        name="t", arch_type="moe", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=128,
        moe=MoEConfig(num_experts=experts, top_k=top_k, d_expert=16,
                      num_shared_experts=shared, d_shared=16,
                      capacity_factor=cf),
        dtype="float32",
    )


def test_moe_output_shape_and_aux():
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out, aux = moe_apply(cfg, p, x, {})
    assert out.shape == x.shape
    assert jnp.isfinite(out).all()
    # Switch aux loss ≈ 1 at uniform routing, ≥1-ish generally
    assert 0.5 < float(aux) < float(cfg.moe.num_experts)


def test_moe_top1_selects_argmax_expert():
    """With capacity ≥ tokens, top-1 output = gate · expert_argmax(x)."""
    cfg = _cfg(experts=3, top_k=1, cf=100.0)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    out, _ = moe_apply(cfg, p, x, {})
    xt = x.reshape(-1, 32)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    eid = jnp.argmax(probs, axis=-1)
    expect = []
    for t in range(8):
        e = int(eid[t])
        h = jax.nn.silu(xt[t] @ p["wi"][e]) * (xt[t] @ p["wg"][e])
        expect.append((h @ p["wo"][e]))  # top-1 normalized gate = 1
    expect = jnp.stack(expect).reshape(1, 8, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)


def test_moe_capacity_drops_tokens():
    """All tokens routed to one expert + tiny capacity ⇒ most get dropped
    (output ≈ 0 for dropped tokens, shared experts off)."""
    cfg = _cfg(experts=4, top_k=1, cf=0.001)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    # identical tokens ⇒ same expert
    x = jnp.ones((1, 64, 32)) * 0.3
    out, _ = moe_apply(cfg, p, x, {})
    norms = jnp.linalg.norm(out[0], axis=-1)
    # capacity = max(ceil(64/4*0.001), min(64,8)) = 8 tokens survive
    assert int((norms > 1e-6).sum()) == 8


def test_moe_shared_expert_contributes():
    cfg_ns = _cfg(shared=0)
    cfg_s = _cfg(shared=2)
    p = moe_init(jax.random.PRNGKey(0), cfg_s)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    out_s, _ = moe_apply(cfg_s, p, x, {})
    p_ns = {k: v for k, v in p.items() if not k.startswith("shared")}
    out_ns, _ = moe_apply(cfg_ns, p_ns, x, {})
    assert float(jnp.max(jnp.abs(out_s - out_ns))) > 1e-4


def test_moe_gradients_flow_to_router_and_experts():
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))

    def loss(p):
        out, aux = moe_apply(cfg, p, x, {})
        return jnp.mean(out**2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["wi"]).max()) > 0
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))
