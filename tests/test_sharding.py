"""The sharded parameter server (repro.ps.sharding + the wiring through
the train step, simulator, and mesh backend).

Key invariants:
  * ShardPlan is deterministic (abstract == concrete builds) and
    size-balanced, and slice/merge round-trips any tree;
  * K=1 is bit-identical to the unsharded train step per granularity,
    and — because every built-in CommitRule is leaf-wise — K>1 matches
    K=1 bit for bit too (sharding reorganizes transport, not numerics);
  * the simulator's partial pulls: a worker with no interleaving writers
    pulls zero bytes, pull bytes are version-gated, push bytes are
    invariant in K, and n_shards=1 runs the exact monolithic code path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_array_equal

from repro.compat import use_mesh
from repro.control.theory import WorkerProfile
from repro.cluster import make_policy
from repro.edgesim import SimConfig, Simulator
from repro.edgesim.profiles import ratio_profiles, with_links
from repro.edgesim.tasks import svm_task
from repro.ps import AdspState, CommitConfig, ShardPlan, UpdateRules, make_train_step
from repro.transport import dense_nbytes


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------

@pytest.fixture()
def tree():
    return {
        "emb": jnp.zeros((100, 8), jnp.float32),
        "blocks": {"w1": jnp.zeros((64, 4), jnp.float32),
                   "w2": jnp.zeros((32, 4), jnp.float32),
                   "b": jnp.zeros((7,), jnp.float32)},
        "head": jnp.zeros((60,), jnp.bfloat16),
    }


def test_plan_deterministic_and_abstract(tree):
    p1 = ShardPlan.build(tree, 3)
    p2 = ShardPlan.build(tree, 3)
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )
    p3 = ShardPlan.build(abstract, 3)
    assert p1 == p2 == p3


def test_plan_k1_is_monolithic(tree):
    p = ShardPlan.build(tree, 1)
    assert p.n_shards == 1
    assert set(p.assignment) == {0}
    assert sum(p.shard_nbytes()) == dense_nbytes(tree)


def test_plan_clamps_to_leaf_count(tree):
    p = ShardPlan.build(tree, 64)
    assert p.n_shards == 5  # one shard per leaf
    assert sorted(p.assignment) == list(range(5))


def test_plan_partitions_every_leaf_once(tree):
    p = ShardPlan.build(tree, 3)
    seen = []
    for k in range(p.n_shards):
        seen.extend(p.shard_leaf_indices(k))
    assert sorted(seen) == list(range(p.n_leaves))
    assert sum(p.shard_nbytes()) == dense_nbytes(tree)


def test_plan_balance(tree):
    p = ShardPlan.build(tree, 2)
    total = sum(p.leaf_nbytes)
    # greedy best-fit bound: no shard exceeds an even split by more
    # than the largest single leaf
    assert max(p.shard_nbytes()) <= total / 2 + max(p.leaf_nbytes)


def test_plan_slice_merge_roundtrip(tree):
    p = ShardPlan.build(tree, 3)
    rebuilt = tree
    for k in range(p.n_shards):
        rebuilt = p.merge(rebuilt, k, p.slice(tree, k))
    for a, b in zip(jax.tree.leaves(rebuilt), jax.tree.leaves(tree)):
        assert a is b  # merge of unchanged slices keeps identities
    # a merge of modified leaves lands exactly on that shard's positions
    bumped = p.merge(tree, 1, [x + 1 for x in p.slice(tree, 1)])
    idx = set(p.shard_leaf_indices(1))
    for i, (a, b) in enumerate(zip(jax.tree.leaves(bumped), jax.tree.leaves(tree))):
        if i in idx:
            assert_array_equal(np.asarray(a), np.asarray(b) + 1)
        else:
            assert a is b


def test_plan_validation(tree):
    with pytest.raises(ValueError):
        ShardPlan.build(tree, 0)
    p = ShardPlan.build(tree, 2)
    with pytest.raises(IndexError):
        p.slice(tree, 2)
    with pytest.raises(ValueError):
        p.slice({"only": jnp.zeros((3,))}, 0)
    with pytest.raises(ValueError):
        p.merge(tree, 0, [])


# ---------------------------------------------------------------------------
# the train step
# ---------------------------------------------------------------------------

def quad_loss(params, batch):
    x, y = batch
    return jnp.mean((x @ params["w"] + params["b"] - y) ** 2)


@pytest.fixture()
def problem():
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(4, 1)).astype(np.float32)
    x = rng.normal(size=(64, 4)).astype(np.float32)
    y = x @ w_true
    params = {"w": jnp.zeros((4, 1), jnp.float32),
              "b": jnp.zeros((1,), jnp.float32)}
    return params, (jnp.asarray(x), jnp.asarray(y))


def _run(problem, granularity, n_shards, rounds=4, commit="momentum_delta"):
    """n_shards=None omits the field entirely (the pre-sharding call)."""
    params, batch = problem
    mesh = jax.make_mesh((1,), ("data",))
    shard_kw = {} if n_shards is None else {"n_shards": n_shards}
    cfg = CommitConfig(tau=2, local_lr=0.1, global_lr=0.7, **shard_kw)
    mbs = (jnp.stack([batch[0]] * 2), jnp.stack([batch[1]] * 2))
    step = make_train_step(
        quad_loss, cfg, UpdateRules(commit=commit, backend="reference"),
        mesh=mesh, granularity=granularity, explicit_momentum=0.3,
    )
    with use_mesh(mesh):
        state = step.init(params)
        for _ in range(rounds):
            state, loss = jax.jit(step)(state, mbs, jnp.asarray([2], jnp.int32))
    return state, float(loss)


@pytest.mark.parametrize("granularity", ["data", "accum"])
@pytest.mark.parametrize("commit", ["momentum_delta", "plain_average"])
def test_k1_bit_identical_to_unsharded(problem, granularity, commit):
    s1, l1 = _run(problem, granularity, n_shards=1, commit=commit)
    s0, l0 = _run(problem, granularity, n_shards=None, commit=commit)
    assert l0 == l1
    # and K=1 state carries no version vector — the unsharded tree shape
    assert s1.shard_versions == ()
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s0.params)):
        assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("granularity", ["data", "accum"])
@pytest.mark.parametrize("commit", ["momentum_delta", "plain_average"])
def test_sharded_apply_matches_monolithic(problem, granularity, commit):
    """Leaf-wise commit rules ⇒ the K-sharded apply is the monolithic
    apply, bit for bit, at every K."""
    base, l_base = _run(problem, granularity, n_shards=1, commit=commit)
    for k in (2, 4):
        sk, lk = _run(problem, granularity, n_shards=k, commit=commit)
        assert lk == l_base
        for a, b in zip(jax.tree.leaves(sk.params), jax.tree.leaves(base.params)):
            assert_array_equal(np.asarray(a), np.asarray(b))
        assert_array_equal(
            np.asarray(sk.shard_versions), np.full((min(k, 2),), 4, np.int32)
        )


def test_single_leaf_model_clamps_to_monolithic():
    """A 1-leaf pytree with n_shards>1 degenerates to the monolithic PS:
    init produces no version vector and the step must accept it (the
    validator/version bump key off the clamped effective count)."""
    def loss(params, batch):
        x, y = batch
        return jnp.mean((x @ params["w"] - y) ** 2)

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 4)), np.float32)
    y = jnp.asarray(rng.normal(size=(8, 1)), np.float32)
    params = {"w": jnp.zeros((4, 1), jnp.float32)}
    mesh = jax.make_mesh((1,), ("data",))
    cfg = CommitConfig(tau=1, local_lr=0.1, n_shards=4)
    step = make_train_step(loss, cfg, UpdateRules(backend="reference"),
                           mesh=mesh, granularity="data")
    with use_mesh(mesh):
        state = step.init(params)
        assert state.shard_versions == ()
        state, _ = jax.jit(step)(state, (jnp.stack([x]), jnp.stack([y])),
                                 jnp.ones((1,), jnp.int32))
    assert state.shard_versions == ()


def test_stale_state_without_versions_raises(problem):
    params, batch = problem
    mesh = jax.make_mesh((1,), ("data",))
    cfg = CommitConfig(tau=1, local_lr=0.1, n_shards=2)
    mbs = (jnp.stack([batch[0]]), jnp.stack([batch[1]]))
    step = make_train_step(quad_loss, cfg, UpdateRules(backend="reference"),
                           mesh=mesh, granularity="data")
    with use_mesh(mesh):
        with pytest.raises(ValueError, match="shard_versions"):
            step(AdspState.create(params), mbs, jnp.ones((1,), jnp.int32))


def test_commit_config_rejects_bad_shards():
    with pytest.raises(ValueError):
        CommitConfig(n_shards=0)


# ---------------------------------------------------------------------------
# the simulator: pipelined pushes, partial pulls
# ---------------------------------------------------------------------------

def _sim(n_shards, m=3, codec="identity", seconds=240.0, policy=None,
         bandwidth_div=1.0, **cfg_kw):
    task = svm_task(m)
    profiles = with_links(
        ratio_profiles(((1, 1, 3)[:m]), base_v=1.0, o=0.2),
        bandwidth=dense_nbytes(task.init_params) / bandwidth_div, latency=0.02,
    )
    cfg = SimConfig(max_seconds=seconds, base_batch=32, gamma=20.0,
                    epoch_seconds=80.0, **cfg_kw)
    policy = policy or make_policy("adsp", search=False, gamma=20.0)
    sim = Simulator(task, profiles, policy, cfg, codec=codec,
                    n_shards=n_shards)
    return sim, sim.train(seconds)


def test_k1_matches_default_exactly():
    """n_shards=1 runs the monolithic code path: every observable of a
    default run, reproduced bit for bit."""
    _, r0 = _sim(1)
    sim1 = Simulator(
        svm_task(3),
        with_links(ratio_profiles((1, 1, 3), base_v=1.0, o=0.2),
                   bandwidth=dense_nbytes(svm_task(3).init_params), latency=0.02),
        make_policy("adsp", search=False, gamma=20.0),
        SimConfig(max_seconds=240.0, base_batch=32, gamma=20.0,
                  epoch_seconds=80.0),
        codec="identity",
    )
    r1 = sim1.train(240.0)
    assert r0.bytes_to_ps == r1.bytes_to_ps
    assert r0.bytes_from_ps == r1.bytes_from_ps
    assert r0.convergence_time == r1.convergence_time
    assert r0.total_steps == r1.total_steps
    assert r0.total_commits == r1.total_commits
    assert_array_equal(r0.losses, r1.losses)


def test_k1_pull_bytes_are_dense_per_commit():
    sim, res = _sim(1, seconds=120.0)
    assert res.total_commits > 0
    assert res.bytes_from_ps == res.total_commits * sim._pull_nbytes


def test_single_worker_pulls_nothing():
    """With no interleaving writers every shard is self-tracked: the
    worker's own commits never stale its copy, so partial pulls ship
    zero bytes (the monolithic PS re-ships the dense model each time)."""
    sim, res = _sim(2, m=1, seconds=120.0)
    assert res.total_commits > 0
    assert res.bytes_from_ps == 0.0
    assert res.bytes_to_ps == res.total_commits * sim._enc_nbytes
    assert sim._ps_version == [res.total_commits] * sim.n_shards


def test_sharded_push_bytes_invariant_and_pulls_partial():
    """Per-leaf codecs partition exactly: the K per-shard encodes sum to
    the lumped payload, and multi-writer pulls move at most the dense
    bytes per commit — strictly less once any shard is self-tracked."""
    sim, res = _sim(4, seconds=240.0, bandwidth_div=8.0)
    assert sim.n_shards == 2  # svm task has two leaves
    assert sum(sim._shard_enc_nbytes) == sim._enc_nbytes
    assert sum(sim._shard_pull_nbytes) == sim._pull_nbytes
    assert res.total_commits > 0
    # push bytes: every applied shard booked (+ a possible in-flight tail)
    assert res.bytes_to_ps >= res.total_commits * sim._enc_nbytes
    assert res.bytes_from_ps < res.total_commits * sim._pull_nbytes


def test_sharded_barrier_policy_runs():
    """Barrier policies buffer complete sharded commits and release whole
    rounds; byte accounting stays consistent."""
    sim, res = _sim(2, policy=make_policy("fixed_adacomm", tau=4),
                    seconds=120.0)
    assert res.total_commits > 0
    assert res.bytes_to_ps == res.total_commits * sim._enc_nbytes
    assert res.bytes_from_ps <= res.total_commits * sim._pull_nbytes


def test_sharded_churn_join_leave():
    """Elastic churn under a sharded PS: a joiner starts current (knows
    the versions it copied), a leaver's in-flight shards are dropped."""
    from repro.cluster import ChurnSchedule, join, leave

    task = svm_task(3)
    profiles = with_links(ratio_profiles((1, 1, 3), base_v=1.0, o=0.2),
                          bandwidth=dense_nbytes(task.init_params), latency=0.02)
    churn = ChurnSchedule([
        leave(30.0, worker=2),
        join(50.0, WorkerProfile(v=1.0, o=0.2)),
    ])
    sim = Simulator(task, profiles, make_policy("adsp", search=False, gamma=20.0),
                    SimConfig(max_seconds=150.0, base_batch=32, gamma=20.0,
                              epoch_seconds=80.0),
                    churn=churn, codec="identity", n_shards=2)
    res = sim.train(150.0)
    assert res.total_commits > 0
    assert len(sim.workers) == 3
    joiner = sim.workers[-1]
    assert len(joiner.shard_known) == sim.n_shards


def test_simulator_rejects_bad_shards():
    with pytest.raises(ValueError):
        _sim(0, seconds=1.0)


# ---------------------------------------------------------------------------
# the mesh backend
# ---------------------------------------------------------------------------

def test_mesh_backend_sharded_state():
    from repro.cluster import ADSP, ClusterEngine
    from repro.cluster.mesh_backend import MeshBackend, MeshTask

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(8, 1)), jnp.float32)
    task = MeshTask(
        init_params={"w": jnp.zeros((4, 1), jnp.float32),
                     "b": jnp.zeros((1,), jnp.float32)},
        loss_fn=quad_loss,
        make_microbatches=lambda r, tau, n: (jnp.stack([x] * tau),
                                             jnp.stack([y] * tau)),
    )
    mesh = jax.make_mesh((1,), ("data",))
    outs = {}
    for k in (1, 2):
        backend = MeshBackend(task, mesh, tau=2, n_shards=k)
        ClusterEngine(ADSP(search=False, gamma=4.0), backend)
        with use_mesh(mesh):
            backend.train(rounds=3)
        outs[k] = backend
    assert outs[2].n_shards == 2
    assert_array_equal(np.asarray(outs[2].state.shard_versions),
                       np.asarray([3, 3], np.int32))
    assert outs[1].state.shard_versions == ()
    for a, b in zip(jax.tree.leaves(outs[1].state.params),
                    jax.tree.leaves(outs[2].state.params)):
        assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# fused decode+apply across shard counts (§16)
# ---------------------------------------------------------------------------

def _run_fused(problem, n_shards, codec, fused, rounds=4):
    params, batch = problem
    mesh = jax.make_mesh((1,), ("data",))
    cfg = CommitConfig(tau=2, local_lr=0.1, global_lr=0.7, n_shards=n_shards)
    mbs = (jnp.stack([batch[0]] * 2), jnp.stack([batch[1]] * 2))
    step = make_train_step(
        quad_loss, cfg, UpdateRules(backend="reference"),
        mesh=mesh, granularity="data", explicit_momentum=0.3,
        codec=codec, fused_commit=fused,
    )
    with use_mesh(mesh):
        state = step.init(params)
        for _ in range(rounds):
            state, loss = jax.jit(step)(state, mbs, jnp.asarray([2], jnp.int32))
    return step, state, float(loss)


@pytest.mark.parametrize("commit", ["momentum_delta", "plain_average"])
@pytest.mark.parametrize("codec_name", ["int8", "bf16"])
@pytest.mark.parametrize("k", [1, 2, 8])
def test_fused_sharded_apply_bit_identical_to_chain(codec_name, commit, k):
    """The §16 contract: given the same encoded payload, the fused
    decode+apply under the ShardPlan — int8 payloads flatten as
    {"q","scale"} units — is bit-identical to decode → apply at every K.
    (K=8 clamps to the leaf count like any plan.)"""
    from repro.ps import get_commit_rule, make_sharded_apply
    from repro.ps.fused_codec import fused_commit_name
    from repro.transport import get_codec

    rng = np.random.default_rng(11)
    params = {
        "w": jnp.asarray(rng.normal(size=(37, 5)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(1,)), jnp.float32),
        "h": {"v": jnp.asarray(rng.normal(size=(260,)), jnp.float32)},
    }
    u = jax.tree.map(lambda x: (x * 0.07 + 0.01).astype(jnp.float32), params)
    cfg = CommitConfig(tau=1, global_lr=0.7, worker_axes=(), n_shards=k)
    chain_rule = get_commit_rule(commit, cfg, backend="reference")
    fused_rule = get_commit_rule(fused_commit_name(commit, codec_name), cfg,
                                 backend="reference")
    codec = get_codec(codec_name, backend="reference")
    enc, _ = jax.jit(codec.encode)(u, jax.tree.map(jnp.zeros_like, u))
    dec = jax.jit(lambda e: codec.decode(e, params))(enc)
    cstate = chain_rule.init(params)
    fstate = fused_rule.init(params)

    out_c = jax.jit(make_sharded_apply(chain_rule, k))(params, cstate, dec, 0.3)
    out_f = jax.jit(make_sharded_apply(fused_rule, k))(params, fstate, enc, 0.3)
    for a, b in zip(jax.tree.leaves(out_c), jax.tree.leaves(out_f)):
        assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("codec", ["int8", "bf16"])
@pytest.mark.parametrize("k", [1, 2, 8])
def test_fused_commit_sharded_train_step_matches_chain(problem, codec, k):
    """End-to-end sharded train step, fused vs chain. bf16 is bit-exact
    (its EF residual is a bare subtract). int8's residual e − q·s is
    mul+sub: LLVM FMA-contracts it in the encode-only fused graph but not
    in the chain graph (where the product is CSEd with the decode), so
    across 4 rounds the trajectories agree only to ~1e-7 — the per-commit
    numerics are pinned exactly by the same-payload apply test above."""
    step_c, sc, lc = _run_fused(problem, k, codec, fused=False)
    step_f, sf, lf = _run_fused(problem, k, codec, fused=True)
    assert not step_c.fused_commit and step_f.fused_commit
    if codec == "bf16":
        assert lc == lf
        for a, b in zip(jax.tree.leaves(sc), jax.tree.leaves(sf)):
            assert_array_equal(np.asarray(a, np.float32),
                               np.asarray(b, np.float32))
    else:
        assert lc == pytest.approx(lf, rel=1e-6)
        for a, b in zip(jax.tree.leaves(sc), jax.tree.leaves(sf)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=1e-7)


def test_mesh_backend_overlapped_shards_bit_identical():
    """The overlapped per-shard commit (push once, K pull dispatches with
    no host sync between them): params, commit state, and losses match
    the monolithic fused step and the plain chain bit for bit. The
    transport residual alone is compiler-sensitive (the push graph
    compiles the local scan without the apply epilogue, shifting one
    fusion decision) and is pinned to one f32 ulp instead. Donation must
    leave the caller's init params untouched."""
    from repro.cluster import ADSP, ClusterEngine
    from repro.cluster.mesh_backend import MeshBackend, MeshTask

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(8, 1)), jnp.float32)
    init = {"w": jnp.zeros((4, 1), jnp.float32),
            "b": jnp.zeros((1,), jnp.float32)}
    init_copy = jax.tree.map(np.asarray, init)
    task = MeshTask(
        init_params=init,
        loss_fn=quad_loss,
        make_microbatches=lambda r, tau, n: (jnp.stack([x] * tau),
                                             jnp.stack([y] * tau)),
    )
    mesh = jax.make_mesh((1,), ("data",))
    variants = {
        "chain": dict(),
        "fused": dict(fused_commit=True),
        "overlap": dict(fused_commit=True, overlap_shards=True),
    }
    outs = {}
    for name, kw in variants.items():
        backend = MeshBackend(task, mesh, tau=2, codec="bf16", n_shards=2, **kw)
        ClusterEngine(ADSP(search=False, gamma=4.0), backend)
        with use_mesh(mesh):
            losses = [backend.run_round() for _ in range(3)]
        outs[name] = (backend, losses)
    assert not outs["chain"][0].fused_commit
    assert outs["fused"][0].fused_commit and not outs["fused"][0].overlap_shards
    assert outs["overlap"][0].overlap_shards
    assert outs["chain"][1] == outs["fused"][1] == outs["overlap"][1]
    ref_state = outs["chain"][0].state
    for a, b in zip(jax.tree.leaves(ref_state),
                    jax.tree.leaves(outs["fused"][0].state)):
        assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    ov = outs["overlap"][0].state
    for tree in ("params", "commit_state", "shard_versions"):
        for a, b in zip(jax.tree.leaves(getattr(ref_state, tree)),
                        jax.tree.leaves(getattr(ov, tree))):
            assert_array_equal(np.asarray(a, np.float32),
                               np.asarray(b, np.float32))
    for a, b in zip(jax.tree.leaves(ref_state.transport_state),
                    jax.tree.leaves(ov.transport_state)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-8)
    # donated round buffers never alias the caller's tree
    for a, b in zip(jax.tree.leaves(init), jax.tree.leaves(init_copy)):
        assert_array_equal(np.asarray(a), b)
