"""benchmarks.compare: snapshot diffing rules.

Pins the sign-safe relative check: a HIGHER_BETTER key whose baseline
is negative (a speedup that was already a slowdown) must not flag an
equal — or improved — current value as a regression. The pre-fix form
``cv < bv * (1 - threshold)`` fired on exact equality when ``bv < 0``
(-0.3 < -0.24), which is how BENCH_9 -> BENCH_10 first tripped it.
"""

import json

from benchmarks.compare import GATE_KEYS, compare


def _snap(path, rows):
    path.write_text(json.dumps({"rows": rows}))
    return path


def _row(name, **derived):
    return {"name": name, "derived": derived}


def test_negative_speedup_equal_is_not_a_regression(tmp_path):
    base = _snap(tmp_path / "a.json",
                 [_row("fig/speedup", adsp_vs_fixed_speedup=-0.3)])
    for cv in (-0.3, -0.2, 0.5):  # equal or better
        cur = _snap(tmp_path / "b.json",
                    [_row("fig/speedup", adsp_vs_fixed_speedup=cv)])
        regressions, _ = compare(base, cur)
        assert regressions == [], (cv, regressions)


def test_speedup_drop_still_flags(tmp_path):
    base = _snap(tmp_path / "a.json", [_row("fig/speedup", sched_speedup=2.0)])
    cur = _snap(tmp_path / "b.json", [_row("fig/speedup", sched_speedup=1.0)])
    regressions, _ = compare(base, cur)
    assert len(regressions) == 1 and "fell" in regressions[0]


def test_lower_better_rise_flags_and_negative_base_tolerated(tmp_path):
    base = _snap(tmp_path / "a.json", [_row("fig/conv", t_conv=100.0)])
    cur = _snap(tmp_path / "b.json", [_row("fig/conv", t_conv=150.0)])
    regressions, _ = compare(base, cur)
    assert len(regressions) == 1 and "rose" in regressions[0]


def test_serve_gates_registered():
    assert {"chunked_beats_unchunked_p99", "balancer_beats_rr"} <= GATE_KEYS


def test_gate_drop_flags(tmp_path):
    base = _snap(tmp_path / "a.json",
                 [_row("serve/chunked_p99", chunked_beats_unchunked_p99=1)])
    cur = _snap(tmp_path / "b.json",
                [_row("serve/chunked_p99", chunked_beats_unchunked_p99=0)])
    regressions, _ = compare(base, cur)
    assert len(regressions) == 1 and "gate" in regressions[0]
