"""Required per-architecture smoke tests: a REDUCED variant of each family
(≤2 pattern-cycles of layers, d_model ≤ 512, ≤ 4 experts) runs one
forward + train step on CPU; asserts output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import lm

B, S = 2, 32


def _batch(cfg, rng, s=S):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s)), jnp.int32)}
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_prefix_embeddings, cfg.d_model)) * 0.02,
            jnp.float32,
        )
    if cfg.encoder is not None:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder.num_frames, cfg.encoder.d_model)) * 0.02,
            jnp.float32,
        )
    return batch


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_reduced_variant_limits(arch):
    cfg = get_smoke(arch)
    assert cfg.d_model <= 512
    assert cfg.num_layers <= max(2, len(get_config(arch).layer_pattern))
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_smoke(arch)
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng)

    logits, aux = lm.lm_logits(cfg, params, batch, remat=False)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    loss, grads = jax.value_and_grad(lambda p: lm.lm_loss(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    # near-uniform init loss
    assert float(loss) == pytest.approx(np.log(cfg.padded_vocab), rel=0.25)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))

    # one SGD step decreases loss on the same batch
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    loss2 = lm.lm_loss(cfg, params2, batch)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_consistency(arch, rng):
    """prefill + 1 decode step ≡ full forward at the same positions."""
    cfg = get_smoke(arch)
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    s = 17
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s + 1)), jnp.int32)
    full = dict(_batch(cfg, rng, s + 1), tokens=toks)
    part = dict(full, tokens=toks[:, :s])

    ref_logits, _ = lm.lm_logits(cfg, params, full, remat=False)
    last, caches = lm.lm_prefill(cfg, params, part)
    np.testing.assert_allclose(
        np.asarray(last, np.float32), np.asarray(ref_logits[:, s - 1], np.float32),
        atol=2e-5, rtol=2e-5,
    )
    dec, caches = lm.lm_decode_step(cfg, params, {"tokens": toks[:, s : s + 1]}, caches)
    np.testing.assert_allclose(
        np.asarray(dec[:, 0], np.float32), np.asarray(ref_logits[:, s], np.float32),
        atol=5e-5, rtol=5e-5,
    )


def test_resolve_attn_impl_mapping(monkeypatch):
    """'ref'/'flash' user names map onto scan/pallas; auto defaults to
    flash only for the granite family on TPU."""
    from repro.models import attention

    assert attention.resolve_attn_impl("ref") == "scan"
    assert attention.resolve_attn_impl("flash") == "pallas"
    assert attention.resolve_attn_impl("naive") == "naive"
    with pytest.raises(ValueError):
        attention.resolve_attn_impl("magic")
    # off-TPU everything resolves to the pure-JAX scan
    assert attention.resolve_attn_impl(None, "granite-3-8b") == "scan"
    assert attention.resolve_attn_impl("auto", "granite-3-8b") == "scan"
    monkeypatch.setattr(attention.jax, "default_backend", lambda: "tpu")
    assert attention.resolve_attn_impl(None, "granite-3-8b") == "pallas"
    assert attention.resolve_attn_impl(None, "qwen2-5-32b") == "scan"
    assert attention.resolve_attn_impl("ref", "granite-3-8b") == "scan"


def test_flash_attention_is_differentiable():
    """The Pallas forward carries a custom_vjp that recomputes through
    the reference attention, so --attn-impl flash works under grad (the
    raw pallas_call has no autodiff rule). Gradients must match the
    reference's own."""
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 48, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 48, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 48, 2, 16)), jnp.float32)

    def loss(fa, q, k, v):
        return jnp.sum(fa(q, k, v, causal=True, window=8) ** 2)

    g_ops = jax.grad(lambda *a: loss(ops.flash_attention, *a), (0, 1, 2))(q, k, v)
    g_ref = jax.grad(lambda *a: loss(ref.flash_attention, *a), (0, 1, 2))(q, k, v)
    for a, b in zip(g_ops, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)
