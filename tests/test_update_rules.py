"""The pluggable update-rule API (repro.ps): legacy parity, fused-vs-
reference backend agreement, rule semantics, and AdamW-at-worker e2e.

Parity contract: ``make_train_step`` with the sgd rule must match the
seed factories bit-for-bit — checked against an inline re-statement of
the seed's arithmetic (embedded verbatim below), per granularity.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose, assert_array_equal

from repro.compat import use_mesh
from repro.ps import (
    AdspState,
    CommitConfig,
    UpdateRules,
    commit_rule_names,
    get_commit_rule,
    get_local_rule,
    local_rule_names,
    make_train_step,
    resolve_backend,
    rule_backends,
    worker_axes_for,
)


def quad_loss(params, batch):
    x, y = batch
    pred = x @ params["w"]
    return jnp.mean((pred - y) ** 2)


@pytest.fixture()
def problem():
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(4, 1)).astype(np.float32)
    x = rng.normal(size=(64, 4)).astype(np.float32)
    y = x @ w_true
    params = {"w": jnp.zeros((4, 1), jnp.float32)}
    return params, (jnp.asarray(x), jnp.asarray(y))


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def _null_ctx():
    import contextlib
    return contextlib.nullcontext()


def _stack(batch, tau):
    x, y = batch
    return jnp.stack([x] * tau), jnp.stack([y] * tau)


def _seed_local_update_fn(loss_fn, cfg, unroll):
    """Verbatim seed implementation (core.commit.make_local_update_fn at
    PR 1) — the bit-for-bit oracle for the sgd LocalRule."""
    grad_fn = jax.value_and_grad(loss_fn)

    def local_update(params, microbatches, tau_i):
        zeros = jax.tree.map(jnp.zeros_like, params)

        def body(carry, xs):
            p, u = carry
            mb, idx = xs
            live = (idx < tau_i).astype(jnp.float32)
            loss, g = grad_fn(p, mb)
            p = jax.tree.map(
                lambda a, b: (a - cfg.local_lr * live * b).astype(a.dtype), p, g
            )
            u = jax.tree.map(
                lambda a, b: (a + cfg.local_lr * live * b).astype(a.dtype), u, g
            )
            return (p, u), loss * live

        idxs = jnp.arange(cfg.tau, dtype=jnp.int32)
        (_, u), losses = jax.lax.scan(
            body, (params, zeros), (microbatches, idxs), unroll=unroll
        )
        denom = jnp.maximum(tau_i.astype(jnp.float32), 1.0)
        return u, jnp.sum(losses) / denom

    return local_update


def _seed_adsp_step(loss_fn, cfg, mesh, batch_spec, explicit_momentum=0.0):
    """Verbatim seed implementation (core.commit.make_adsp_step at PR 1)."""
    from repro.compat import SCAN_IN_PARTIAL_AUTO_BROKEN
    from repro.compat import shard_map as compat_shard_map

    local_update = _seed_local_update_fn(
        loss_fn, cfg, unroll=True if SCAN_IN_PARTIAL_AUTO_BROKEN else 1
    )
    axes = cfg.worker_axes

    def _sharded_body(params, prev_delta, step, microbatches, tau_per_worker):
        tau_i = tau_per_worker[0]
        u, loss = local_update(params, microbatches, tau_i)
        cd = jnp.dtype(cfg.commit_dtype)
        u = jax.tree.map(lambda x: x.astype(cd), u)
        u = jax.lax.pmean(u, axes)
        loss = jax.lax.pmean(loss, axes)
        delta = jax.tree.map(
            lambda d, uu: (explicit_momentum * d - cfg.global_lr * uu).astype(d.dtype),
            prev_delta, u,
        )
        params = jax.tree.map(jnp.add, params, delta)
        return params, delta, step + 1, loss

    rep = jax.sharding.PartitionSpec()
    tau_spec = jax.sharding.PartitionSpec(axes if len(axes) > 1 else axes[0])
    sharded = compat_shard_map(
        _sharded_body, mesh,
        in_specs=(rep, rep, rep, batch_spec, tau_spec),
        out_specs=(rep, rep, rep, rep),
        axis_names=set(axes), check=False,
    )

    def adsp_step(params, prev_delta, step, microbatches, tau_per_worker):
        return sharded(params, prev_delta, step, microbatches, tau_per_worker)

    return adsp_step


def _seed_accum_step(loss_fn, cfg, explicit_momentum=0.0):
    """Verbatim seed implementation (core.accum.make_accum_step at PR 1)."""
    grad_fn = jax.value_and_grad(loss_fn)

    def accum_step(params, prev_delta, step, microbatches, tau_active):
        zeros = jax.tree.map(jnp.zeros_like, params)

        def body(carry, xs):
            p, u = carry
            mb, idx = xs
            live = (idx < tau_active).astype(jnp.float32)
            loss, g = grad_fn(p, mb)
            p = jax.tree.map(
                lambda a, b: (a - cfg.local_lr * live * b).astype(a.dtype), p, g
            )
            u = jax.tree.map(
                lambda a, b: (a + cfg.local_lr * live * b).astype(a.dtype), u, g
            )
            return (p, u), loss * live

        idxs = jnp.arange(cfg.tau, dtype=jnp.int32)
        (_, u), losses = jax.lax.scan(body, (params, zeros), (microbatches, idxs))
        loss = jnp.sum(losses) / jnp.maximum(tau_active.astype(jnp.float32), 1.0)
        delta = jax.tree.map(
            lambda d, uu: (explicit_momentum * d - cfg.global_lr * uu).astype(d.dtype),
            prev_delta, u,
        )
        params = jax.tree.map(jnp.add, params, delta)
        return params, delta, step + 1, loss

    return accum_step


# ---------------------------------------------------------------------------
# legacy parity (the SGD rule must reproduce the seed factories exactly)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("granularity", ["data", "accum", "pod"])
def test_train_step_matches_seed_arithmetic(problem, granularity):
    """Bit-for-bit against the seed factories (their PR 1 implementations,
    embedded verbatim above). 'pod' on a pod-less mesh degenerates to
    accum (DESIGN.md §3)."""
    params, batch = problem
    tau, tau_i = 3, 2
    cfg = CommitConfig(tau=tau, local_lr=0.1, global_lr=0.7, worker_axes=("data",))
    mesh = _mesh1()
    mbs = _stack(batch, tau)
    mu = 0.25
    step = make_train_step(
        quad_loss, cfg, UpdateRules(backend="reference"),
        mesh=mesh, granularity=granularity, explicit_momentum=mu,
    )
    worker_path = granularity == "data"
    if worker_path:
        seed = jax.jit(_seed_adsp_step(
            quad_loss, cfg, mesh,
            batch_spec=jax.sharding.PartitionSpec(None, "data"),
            explicit_momentum=mu,
        ))
        tau_seed = jnp.asarray([tau_i], jnp.int32)
    else:
        import dataclasses as _dc
        seed = jax.jit(_seed_accum_step(
            quad_loss, _dc.replace(cfg, worker_axes=()), explicit_momentum=mu
        ))
        tau_seed = jnp.asarray(tau_i, jnp.int32)
    with use_mesh(mesh):
        state = step.init(params)
        p, d, s = params, jax.tree.map(jnp.zeros_like, params), jnp.zeros((), jnp.int32)
        for _ in range(3):
            state, loss = jax.jit(step)(state, mbs, jnp.asarray([tau_i], jnp.int32))
            p, d, s, ref_loss = seed(p, d, s, mbs, tau_seed)
    assert_array_equal(np.asarray(state.params["w"]), np.asarray(p["w"]))
    assert_array_equal(np.asarray(state.commit_state["w"]), np.asarray(d["w"]))
    assert_array_equal(np.asarray(loss), np.asarray(ref_loss))
    assert int(state.step) == int(s) == 3


def test_legacy_state_and_scalar_tau_still_accepted(problem):
    """Seed-era entry conventions survive the shim retirement: a bare
    ``AdspState.create(params)`` (no rule-owned state) and the legacy
    scalar ``tau_active`` both work against the unified factory."""
    params, batch = problem
    cfg = CommitConfig(tau=2, local_lr=0.05, global_lr=1.0, worker_axes=("data",))
    mesh = _mesh1()
    mbs = _stack(batch, 2)
    tau = jnp.asarray([2], jnp.int32)
    direct = make_train_step(quad_loss, cfg, UpdateRules(backend="reference"),
                             mesh=mesh, batch_spec=jax.sharding.PartitionSpec(None, "data"))
    accum = make_train_step(quad_loss,
                            CommitConfig(tau=2, local_lr=0.05, global_lr=1.0,
                                         worker_axes=()),
                            UpdateRules(backend="reference"))
    with use_mesh(mesh):
        s_direct, l_direct = direct(direct.init(params), mbs, tau)
        s_legacy, l_legacy = direct(AdspState.create(params), mbs, tau)
        # legacy scalar tau_active still accepted by the accum path
        s_accum, _ = accum(AdspState.create(params), mbs, jnp.asarray(2, jnp.int32))
    assert_array_equal(np.asarray(s_direct.params["w"]), np.asarray(s_legacy.params["w"]))
    assert_array_equal(np.asarray(l_direct), np.asarray(l_legacy))
    assert np.asarray(s_accum.params["w"]).shape == (4, 1)


# ---------------------------------------------------------------------------
# fused backend: exercised from a real train step, parity vs reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("granularity", ["data", "accum"])
def test_fused_backend_matches_reference_from_train_step(problem, granularity):
    """The Pallas-fused commit path (accumulate + ps_apply kernels) runs
    inside the actual train step and agrees with the reference rules."""
    params, batch = problem
    cfg = CommitConfig(tau=2, local_lr=0.05, global_lr=1.0, worker_axes=("data",))
    mesh = _mesh1()
    mbs = _stack(batch, 2)
    tau = jnp.asarray([2], jnp.int32)
    outs = {}
    for backend in ("reference", "fused"):
        step = make_train_step(quad_loss, cfg, UpdateRules(backend=backend),
                               mesh=mesh, granularity=granularity,
                               explicit_momentum=0.5)
        assert step.rules[1].backend == backend
        with use_mesh(mesh):
            state = step.init(params)
            for _ in range(3):
                state, loss = jax.jit(step)(state, mbs, tau)
        outs[backend] = (np.asarray(state.params["w"]), float(loss))
    assert_allclose(outs["fused"][0], outs["reference"][0], atol=1e-6, rtol=1e-6)
    assert outs["fused"][1] == pytest.approx(outs["reference"][1], rel=1e-6)


@pytest.mark.parametrize("dtype,momentum", [
    (jnp.float32, 0.9),
    (jnp.bfloat16, 0.9),
    (jnp.float32, 0.0),
])
def test_ps_apply_backends_agree_fixed(dtype, momentum):
    """Fixed ragged/dtype cases of the fused-vs-reference commit parity
    (the hypothesis sweep lives in test_rule_backends_property.py)."""
    rng = np.random.default_rng(7)
    cfg = CommitConfig(tau=1, global_lr=0.3, worker_axes=())
    w = {
        "a": jnp.asarray(rng.normal(size=(10_007,)), dtype),
        "b": {"c": jnp.asarray(rng.normal(size=(3, 5)), dtype)},
    }
    d = jax.tree.map(lambda t: (t * 0.1).astype(t.dtype), w)
    u = jax.tree.map(lambda t: (t * 0.2 + 0.3).astype(jnp.float32), w)
    ref = get_commit_rule("momentum_delta", cfg, backend="reference")
    fus = get_commit_rule("momentum_delta", cfg, backend="fused")
    rw, rd = ref.apply(w, d, u, momentum)
    fw, fd = fus.apply(w, d, u, momentum)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    for a, b in zip(jax.tree.leaves((rw, rd)), jax.tree.leaves((fw, fd))):
        assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                        atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# rule semantics
# ---------------------------------------------------------------------------

def test_registry_contents():
    assert set(local_rule_names()) >= {"sgd", "sgd_momentum", "adamw"}
    assert set(commit_rule_names()) >= {"momentum_delta", "plain_average"}
    assert rule_backends("local", "sgd") == ("fused", "reference")
    assert rule_backends("commit", "momentum_delta") == ("fused", "reference")
    # auto resolves off-TPU to reference; explicit names pass through
    assert resolve_backend(None) in ("reference", "fused")
    assert resolve_backend("fused") == "fused"
    with pytest.raises(ValueError):
        resolve_backend("magic")
    # fused request for a rule with no fused impl falls back to reference
    cfg = CommitConfig(tau=1, worker_axes=())
    assert get_local_rule("adamw", cfg, backend="fused").backend == "reference"


def test_plain_average_is_worker_mean(problem):
    """One round of plain_average equals W − η·mean-over-workers(U)."""
    params, batch = problem
    cfg = CommitConfig(tau=1, local_lr=0.1, global_lr=1.0, worker_axes=("data",))
    mesh = _mesh1()
    mbs = _stack(batch, 1)
    step = make_train_step(
        quad_loss, cfg,
        UpdateRules(commit="plain_average", backend="reference"), mesh=mesh,
    )
    with use_mesh(mesh):
        state, _ = jax.jit(step)(step.init(params), mbs, jnp.ones((1,), jnp.int32))
    _, g = jax.value_and_grad(quad_loss)(params, batch)
    expect = params["w"] - 0.1 * g["w"]
    assert_allclose(np.asarray(state.params["w"]), np.asarray(expect), rtol=1e-6)
    assert state.commit_state == ()


def test_adamw_state_masking(problem):
    """Masked microsteps must freeze the local optimizer state: with
    cfg.tau=3 and τ_i=1 the adam step counter advances by exactly 1."""
    params, batch = problem
    cfg = CommitConfig(tau=3, local_lr=0.05, worker_axes=("data",))
    mesh = _mesh1()
    mbs = _stack(batch, 3)
    step = make_train_step(quad_loss, cfg,
                           UpdateRules(local="adamw", backend="reference"),
                           mesh=mesh)
    with use_mesh(mesh):
        state = step.init(params)
        state, _ = jax.jit(step)(state, mbs, jnp.asarray([1], jnp.int32))
        assert int(state.local_state.step[0]) == 1
        state, _ = jax.jit(step)(state, mbs, jnp.asarray([3], jnp.int32))
    # local adam moments persist across commit rounds (1 + 3 live steps)
    assert int(state.local_state.step[0]) == 4


def test_adamw_at_worker_converges(problem):
    params, batch = problem
    cfg = CommitConfig(tau=2, local_lr=0.05, worker_axes=("data",))
    mesh = _mesh1()
    mbs = _stack(batch, 2)
    step = make_train_step(
        quad_loss, cfg,
        UpdateRules(local="adamw", backend="reference", local_hp={"lr": 0.05}),
        mesh=mesh,
    )
    with use_mesh(mesh):
        state = step.init(params)
        losses = []
        for _ in range(30):
            state, loss = jax.jit(step)(state, mbs, jnp.asarray([2], jnp.int32))
            losses.append(float(loss))
    assert losses[-1] < 0.02 * losses[0]


def test_sgd_momentum_local_rule_converges(problem):
    params, batch = problem
    cfg = CommitConfig(tau=2, local_lr=0.02, worker_axes=("data",))
    mesh = _mesh1()
    mbs = _stack(batch, 2)
    step = make_train_step(
        quad_loss, cfg,
        UpdateRules(local="sgd_momentum", backend="reference",
                    local_hp={"momentum": 0.8}),
        mesh=mesh,
    )
    with use_mesh(mesh):
        state = step.init(params)
        losses = []
        for _ in range(30):
            state, loss = jax.jit(step)(state, mbs, jnp.asarray([2], jnp.int32))
            losses.append(float(loss))
    assert losses[-1] < 0.05 * losses[0]


def test_default_interpret_cached_and_env_override(monkeypatch):
    """kernels.ops probes the backend once (cached) and honours the
    REPRO_PALLAS_INTERPRET override."""
    from repro.kernels import ops

    try:
        monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
        ops.default_interpret.cache_clear()
        auto = ops.default_interpret()
        assert auto == (jax.default_backend() != "tpu")
        assert ops._interp(None) is auto and ops._interp(True) is True

        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
        # cache still serves the old value until cleared...
        assert ops.default_interpret() is auto
        ops.default_interpret.cache_clear()
        assert ops.default_interpret() is False
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "true")
        ops.default_interpret.cache_clear()
        assert ops.default_interpret() is True
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "sideways")
        ops.default_interpret.cache_clear()
        with pytest.raises(ValueError):
            ops.default_interpret()
    finally:
        ops.default_interpret.cache_clear()


def test_worker_axes_for_mapping():
    mesh = _mesh1()
    assert worker_axes_for("data", mesh) == ("data",)
    assert worker_axes_for("pod", mesh) == ()
    assert worker_axes_for("accum", mesh) == ()
    with pytest.raises(ValueError):
        worker_axes_for("bogus", mesh)


def test_worker_granularity_without_mesh_raises():
    """granularity='data' with no mesh must fail loudly, not silently
    degrade to single-worker accumulation."""
    cfg = CommitConfig(tau=1, worker_axes=("data",))
    with pytest.raises(ValueError, match="needs a mesh"):
        make_train_step(quad_loss, cfg, UpdateRules(backend="reference"),
                        granularity="data")
    # accum is the one mesh-free granularity
    step = make_train_step(quad_loss, cfg, UpdateRules(backend="reference"),
                           granularity="accum")
    assert step.n_workers == 1


def test_mismatched_state_raises_clearly(problem):
    """Seed-era AdspState.create(params) paired with a stateful local rule
    must raise a pointed error, not a tree-structure failure mid-scan."""
    params, batch = problem
    cfg = CommitConfig(tau=1, local_lr=0.05, worker_axes=("data",))
    mesh = _mesh1()
    mbs = _stack(batch, 1)
    step = make_train_step(quad_loss, cfg,
                           UpdateRules(local="adamw", backend="reference"),
                           mesh=mesh)
    with use_mesh(mesh):
        with pytest.raises(ValueError, match="local_state does not match"):
            step(AdspState.create(params), mbs, jnp.ones((1,), jnp.int32))


# ---------------------------------------------------------------------------
# integration: AdamW-at-worker through the launcher (smoke example)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_launch_train_smoke_adamw(tmp_path, capsys):
    """`python -m repro.launch.train --smoke --local-rule adamw` trains
    end-to-end: the full control plane over the unified train step."""
    from repro.launch import train as launch_train

    ckpt = tmp_path / "adamw.npz"
    launch_train.main([
        "--arch", "granite-3-8b", "--smoke", "--steps", "3",
        "--seq", "16", "--batch", "2", "--tau", "2",
        "--local-rule", "adamw", "--local-opt-lr", "1e-3",
        "--checkpoint", str(ckpt),
    ])
    out = capsys.readouterr().out
    assert "rules=adamw+momentum_delta" in out
    assert ckpt.exists()


# ---------------------------------------------------------------------------
# fused decode+apply commit path (DESIGN.md §16)
# ---------------------------------------------------------------------------

def test_fused_codec_rule_registry():
    """The combined decode+apply rules register under "<rule>@<codec>" in
    both backends: "momentum_delta@int8", "momentum_delta@bf16",
    "plain_average@int8", "plain_average@bf16"."""
    combined = {
        "momentum_delta@int8", "momentum_delta@bf16",
        "plain_average@int8", "plain_average@bf16",
    }
    assert combined <= set(commit_rule_names())
    cfg = CommitConfig(tau=1, worker_axes=())
    for name in combined:
        assert rule_backends("commit", name) == ("fused", "reference")
        rule = get_commit_rule(name, cfg, backend="reference")
        if name.endswith("@int8"):
            # int8 payloads are {"q","scale"} dicts the tree flattener
            # must treat as leaves
            assert rule.is_payload({"q": 1, "scale": 2})
            assert not rule.is_payload({"q": 1})
            assert not rule.is_payload(jnp.zeros(3))
        else:
            assert rule.is_payload is None


@pytest.mark.parametrize("granularity", ["data", "accum"])
@pytest.mark.parametrize("commit", ["momentum_delta", "plain_average"])
@pytest.mark.parametrize("codec", ["identity", "int8", "bf16", "top_k"])
def test_fused_commit_bit_identical_to_chain(problem, codec, commit,
                                             granularity):
    """fused_commit=True must be bit-for-bit the encode → decode → apply
    chain for every codec: fusable codecs take the single-pass rule,
    the rest silently fall back to the chain itself."""
    params, batch = problem
    cfg = CommitConfig(tau=2, local_lr=0.05, global_lr=1.0,
                       worker_axes=("data",) if granularity == "data" else ())
    mesh = _mesh1() if granularity == "data" else None
    mbs = _stack(batch, 2)
    tau = jnp.asarray([2], jnp.int32)
    rules = UpdateRules(commit=commit, backend="reference")
    outs = {}
    for fused in (False, True):
        step = make_train_step(quad_loss, cfg, rules, mesh=mesh,
                               granularity=granularity, codec=codec,
                               explicit_momentum=0.5, fused_commit=fused)
        assert step.fused_commit is (fused and codec in ("int8", "bf16"))
        with use_mesh(mesh) if mesh is not None else _null_ctx():
            state = step.init(params)
            for _ in range(3):
                state, loss = jax.jit(step)(state, mbs, tau)
        outs[fused] = (state, float(loss))
    sa, sb = outs[False][0], outs[True][0]
    for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    assert outs[False][1] == outs[True][1]


def test_fused_commit_kernel_backend_matches_reference(problem):
    """backend="fused" routes the combined rule through the Pallas
    single-pass kernels (interpret on CPU) — same bits as the reference
    combined rule."""
    params, batch = problem
    cfg = CommitConfig(tau=2, local_lr=0.05, global_lr=1.0, worker_axes=())
    mbs = _stack(batch, 2)
    tau = jnp.asarray([2], jnp.int32)
    outs = {}
    for backend in ("reference", "fused"):
        step = make_train_step(
            quad_loss, cfg, UpdateRules(backend=backend), granularity="accum",
            codec="int8", explicit_momentum=0.5, fused_commit=True,
        )
        assert step.fused_commit
        state = step.init(params)
        for _ in range(3):
            state, loss = jax.jit(step)(state, mbs, tau)
        outs[backend] = (np.asarray(state.params["w"]), float(loss))
    assert_array_equal(outs["fused"][0], outs["reference"][0])
    assert outs["fused"][1] == outs["reference"][1]


def test_fused_commit_gate_falls_back():
    """Fusion preconditions: codec present + fusable, one worker, f32
    commit dtype — anything else silently uses the chain path."""
    cfg = CommitConfig(tau=1, worker_axes=())
    mk = lambda **kw: make_train_step(quad_loss, kw.pop("cfg", cfg),
                                      UpdateRules(backend="reference"),
                                      granularity="accum", **kw)
    assert mk(codec="int8", fused_commit=True).fused_commit
    assert not mk(codec="int8", fused_commit=False).fused_commit
    assert not mk(codec=None, fused_commit=True).fused_commit
    assert not mk(codec="top_k", fused_commit=True).fused_commit
    cfg16 = CommitConfig(tau=1, worker_axes=(), commit_dtype="bfloat16")
    assert not mk(cfg=cfg16, codec="int8", fused_commit=True).fused_commit


def test_train_step_exposes_donate_argnums():
    """The state argument is safe to donate: callers jit with
    step.donate_argnums and reuse buffers round over round."""
    cfg = CommitConfig(tau=1, worker_axes=())
    step = make_train_step(quad_loss, cfg, UpdateRules(backend="reference"),
                           granularity="accum")
    assert step.donate_argnums == (0,)
