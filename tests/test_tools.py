"""tools/make_experiments.py: first-run skeleton + graceful no-results
exit, and table splicing once dry-run artifacts exist."""

import json
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
TOOL = ROOT / "tools" / "make_experiments.py"


def _run(cwd):
    return subprocess.run([sys.executable, str(TOOL)], cwd=cwd,
                          capture_output=True, text=True, timeout=60)


def test_first_run_creates_skeleton_and_exits_cleanly(tmp_path):
    r = _run(tmp_path)
    assert r.returncode == 0, r.stderr
    assert "created static skeleton" in r.stdout
    assert "no dry-run results" in r.stdout
    exp = (tmp_path / "EXPERIMENTS.md").read_text()
    assert "<!-- AUTOGEN:DRYRUN -->" in exp and "<!-- AUTOGEN:ROOFLINE -->" in exp
    # second run is idempotent: skeleton kept, still a clean exit
    r2 = _run(tmp_path)
    assert r2.returncode == 0
    assert "created static skeleton" not in r2.stdout


def test_splices_tables_when_results_present(tmp_path):
    outdir = tmp_path / "results" / "dryrun"
    outdir.mkdir(parents=True)
    (outdir / "granite.json").write_text(json.dumps({
        "arch": "granite_3_8b", "shape": "train_4k", "mesh": "single",
        "status": "ok", "compile_s": 1.2,
        "analytic_param_bytes_per_chip": 1e9,
        "memory_analysis": {"temp_bytes": 2e9},
        "hlo_collective_lines": 3, "variant_note": "",
        "roofline": {"compute_s": 0.5, "memory_s": 0.2, "collective_s": 0.1,
                     "bottleneck": "compute", "useful_flops_ratio": 0.8},
    }))
    r = _run(tmp_path)
    assert r.returncode == 0, r.stderr
    exp = (tmp_path / "EXPERIMENTS.md").read_text()
    assert "| granite_3_8b | train_4k | single | ok |" in exp
    assert "**compute**" in exp
