"""Sliding-window attention + ring-buffer decode caches — the machinery
behind long_500k for dense archs and local attention in hybrids."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import lm
from repro.launch import specs as S


def test_window_equals_full_when_window_covers_seq():
    """window ≥ S ⇒ identical to full causal attention."""
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 48, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 48, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 48, 2, 16)), jnp.float32)
    full = ref.flash_attention(q, k, v, causal=True, window=0)
    win = ops.flash_attention(q, k, v, causal=True, window=48, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(win), np.asarray(full), atol=2e-5)


def test_window_restricts_receptive_field():
    """A key outside the window must not influence the output."""
    from repro.kernels import ref

    rng = np.random.default_rng(1)
    s, w = 32, 8
    q = jnp.asarray(rng.normal(size=(1, s, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, s, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, s, 2, 8)), jnp.float32)
    out1 = ref.flash_attention(q, k, v, window=w)
    # perturb an early key/value: positions ≥ w later must be unchanged
    k2 = k.at[:, 0].set(k[:, 0] + 10.0)
    v2 = v.at[:, 0].set(v[:, 0] - 5.0)
    out2 = ref.flash_attention(q, k2, v2, window=w)
    np.testing.assert_allclose(
        np.asarray(out1[:, w:]), np.asarray(out2[:, w:]), atol=1e-5
    )
    assert float(jnp.max(jnp.abs(out1[:, 0] - out2[:, 0]))) > 1e-3


def test_ring_cache_decode_matches_full_forward_beyond_window():
    """Decode with a ring cache of size `window` must agree with the full
    forward even after the prompt exceeds the window (starcoder2 family)."""
    cfg = get_smoke("starcoder2_7b")
    assert cfg.sliding_window == 64
    cfg = dataclasses.replace(cfg, sliding_window=16)  # small ring
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    B, s = 1, 40  # prompt 2.5× the window
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s + 2)), jnp.int32)

    ref_logits, _ = lm.lm_logits(cfg, params, {"tokens": toks}, remat=False)
    last, caches = lm.lm_prefill(cfg, params, {"tokens": toks[:, :s]}, reserve=2)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(ref_logits[:, s - 1]), atol=3e-5, rtol=3e-5
    )
    dec, caches = lm.lm_decode_step(cfg, params, {"tokens": toks[:, s:s+1]}, caches)
    np.testing.assert_allclose(
        np.asarray(dec[:, 0]), np.asarray(ref_logits[:, s]), atol=5e-5, rtol=5e-5
    )
    dec2, _ = lm.lm_decode_step(cfg, params, {"tokens": toks[:, s+1:s+2]}, caches)
    np.testing.assert_allclose(
        np.asarray(dec2[:, 0]), np.asarray(ref_logits[:, s + 1]), atol=5e-5, rtol=5e-5
    )


def test_long_500k_variant_config():
    """effective_config applies the sliding-window variant exactly for the
    dense full-attention archs and leaves native/sub-quadratic archs alone."""
    from repro.configs import get_config

    g = S.effective_config(get_config("granite_3_8b"), "long_500k")
    assert g.sliding_window == 4096
    g2 = S.effective_config(get_config("granite_3_8b"), "decode_32k")
    assert g2.sliding_window == 0
    r = S.effective_config(get_config("rwkv6_3b"), "long_500k")
    assert r.sliding_window == 0
    sc = S.effective_config(get_config("starcoder2_7b"), "long_500k")
    assert sc.sliding_window == 4096  # paper-native, unchanged


def test_decode_cache_sizes():
    """long_500k decode caches must be O(window)/O(1), never O(seq)."""
    from repro.configs import get_config

    cfg = S.effective_config(get_config("granite_3_8b"), "long_500k")
    tokens, caches = S.abstract_decode_state(cfg, S.SHAPES["long_500k"])
    leaves = jax.tree.leaves(caches)
    total = sum(l.size * l.dtype.itemsize for l in leaves)
    assert total < 3e9  # ring buffers only — not the 85 GB dense cache
    cfg2 = S.effective_config(get_config("rwkv6_3b"), "long_500k")
    _, caches2 = S.abstract_decode_state(cfg2, S.SHAPES["long_500k"])
    total2 = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(caches2))
    assert total2 < 1e9  # O(1) recurrent state


def test_rwkv_chunked_matches_ref():
    from repro.kernels import ref
    from repro.models.rwkv_chunked import wkv_chunked

    rng = np.random.default_rng(3)
    b, s, h, n = 2, 100, 2, 8
    r = jnp.asarray(rng.normal(size=(b, s, h, n)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, n)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, n)) * 0.5, jnp.float32)
    w = jnp.asarray(rng.uniform(0.4, 0.999, size=(b, s, h, n)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, n)) * 0.1, jnp.float32)
    out, st = wkv_chunked(r, k, v, w, u, chunk=32)
    oute, ste = ref.rwkv6_scan(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oute), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st), np.asarray(ste), atol=1e-5)
