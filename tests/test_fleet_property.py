"""Property tests for the fleet metrics registry: every record kind must
round-trip losslessly through ``to_dict`` → JSON → ``from_dict`` (the
persistence contract ``MetricsLog.to_jsonl``/``load_jsonl`` rely on),
and the schedulers must produce a valid assignment for any capability
table. Skipped (not failed) in bare containers without hypothesis."""

import json
import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.fleet import (
    AssignRecord,
    CapabilityRecord,
    ChurnRecord,
    CommitRecord,
    DriftRecord,
    EvalRecord,
    LeaseRecord,
    SearchRecord,
    from_dict,
    get_scheduler,
    scheduler_names,
    to_dict,
)

ts = st.floats(min_value=0.0, max_value=1e12, allow_nan=False)
wid = st.integers(min_value=0, max_value=10**9)
nbytes = st.floats(min_value=0.0, max_value=1e15, allow_nan=False)
loss = st.floats(allow_nan=False, allow_infinity=False)
frac = st.floats(min_value=0.0, max_value=1.0, allow_nan=False)

records = st.one_of(
    st.builds(CommitRecord, t=ts, worker=wid, latency=ts, push_bytes=nbytes,
              pull_bytes=nbytes, stale_shards=st.integers(0, 4096),
              n_shards=st.integers(1, 4096)),
    st.builds(EvalRecord, t=ts, loss=loss),
    st.builds(SearchRecord, t=ts, chosen=st.integers(1, 10**6),
              windows=st.integers(0, 100), restarts=st.integers(0, 100),
              aborted=st.booleans()),
    st.builds(DriftRecord, t=ts, cause=st.text(max_size=40)),
    st.builds(LeaseRecord, t=ts, worker=wid,
              event=st.sampled_from(["granted", "stalled", "expired",
                                     "rejoined"])),
    st.builds(ChurnRecord, t=ts, worker=wid,
              event=st.sampled_from(["join", "leave"]),
              discovered=st.booleans()),
    st.builds(CapabilityRecord, t=ts, worker=wid,
              v=st.floats(min_value=0.0, max_value=1e9, allow_nan=False)),
    st.builds(AssignRecord, t=ts, worker=wid, fraction=frac,
              data_share=frac),
)


@given(rec=records)
@settings(max_examples=200, deadline=None)
def test_any_record_roundtrips_through_json(rec):
    wire = json.dumps(to_dict(rec))
    back = from_dict(json.loads(wire))
    assert back == rec
    assert back.kind == rec.kind
    assert type(back) is type(rec)


@given(stream=st.lists(records, max_size=50))
@settings(max_examples=50, deadline=None)
def test_any_stream_roundtrips_through_jsonl_lines(stream):
    """Line-oriented framing (what JsonlSink/MetricsLog.to_jsonl write):
    order and content survive, record by record."""
    lines = [json.dumps(to_dict(r)) for r in stream]
    assert [from_dict(json.loads(line)) for line in lines] == stream


@given(table=st.dictionaries(
    wid, st.floats(min_value=0.0, max_value=1e9, allow_nan=False),
    min_size=1, max_size=64),
    name=st.sampled_from(scheduler_names()))
@settings(max_examples=100, deadline=None)
def test_any_capability_table_yields_a_valid_assignment(table, name):
    asg = get_scheduler(name).assign(table)
    assert set(asg.fractions) == set(table)
    assert all(math.isfinite(f) and f >= 0.0 for f in asg.fractions.values())
    assert sum(asg.fractions.values()) == pytest.approx(1.0)
    assert sum(asg.data_shares.values()) == pytest.approx(1.0)
