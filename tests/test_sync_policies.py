"""Simulator-level invariants of every synchronization policy."""

import numpy as np
import pytest

from repro.cluster import make_policy
from repro.edgesim import SimConfig, Simulator
from repro.edgesim.profiles import ratio_profiles
from repro.edgesim.tasks import svm_task

PROFILES = ratio_profiles((1, 1, 3), base_v=1.0, o=0.2)


def run(policy, seconds=240.0, profiles=PROFILES, **cfg_kw):
    cfg = SimConfig(max_seconds=seconds, base_batch=32, gamma=20.0,
                    epoch_seconds=80.0, **cfg_kw)
    task = svm_task(len(profiles))
    sim = Simulator(task, profiles, policy, cfg)
    res = sim.train(seconds)
    return sim, res


def test_bsp_equal_steps_and_commits():
    sim, res = run(make_policy("bsp"))
    assert len(set(res.commit_counts)) == 1  # barrier ⇒ identical counts
    steps = [w.steps for w in sim.workers]
    assert max(steps) - min(steps) <= 1


def test_ssp_staleness_bound():
    s = 4
    sim, res = run(make_policy("ssp", s=s))
    steps = [w.steps for w in sim.workers]
    assert max(steps) - min(steps) <= s


def test_tap_never_blocks():
    sim, res = run(make_policy("tap"))
    assert all(w.status != "blocked" for w in sim.workers)
    # fast workers commit ~3x as often as the slow one
    assert res.commit_counts[0] > 2 * res.commit_counts[2] * 0.8


def test_fixed_adacomm_commits_every_tau_steps():
    tau = 8
    sim, res = run(make_policy("fixed_adacomm", tau=tau))
    for w in sim.workers:
        assert w.steps_since_commit <= tau
        # every completed commit corresponds to τ local steps
        assert w.steps >= w.commits * tau


def test_adsp_commit_counts_roughly_equal():
    """Theorem 2 precondition: |c_i − c_j| ≤ ε at checkpoints."""
    sim, res = run(make_policy("adsp", search=False, gamma=20.0), seconds=400)
    cc = res.commit_counts
    assert max(cc) - min(cc) <= 2, cc
    assert min(cc) >= 3  # actually committing


def test_adsp_no_waiting():
    _, res_adsp = run(make_policy("adsp", search=False, gamma=20.0), seconds=300)
    _, res_bsp = run(make_policy("bsp"), seconds=300)
    assert res_adsp.waiting_fraction < 0.05
    assert res_bsp.waiting_fraction > 0.3
    # no-waiting ⇒ strictly more training steps in the same wall time
    assert res_adsp.total_steps > 1.5 * res_bsp.total_steps


def test_adsp_bandwidth_between_adacomm_and_bsp():
    """Appendix D Fig. 10(a): bytes(ADACOMM) ≤ bytes(ADSP) ≤ bytes(BSP)."""
    _, r_fixed = run(make_policy("fixed_adacomm", tau=16), seconds=300)
    _, r_adsp = run(make_policy("adsp", search=False, gamma=20.0), seconds=300)
    _, r_bsp = run(make_policy("bsp"), seconds=300)
    assert r_fixed.bytes_to_ps <= r_adsp.bytes_to_ps * 1.2
    assert r_adsp.bytes_to_ps < r_bsp.bytes_to_ps


def test_batchtune_equalizes_step_times():
    sim, res = run(make_policy("batchtune_bsp"), seconds=200)
    # batch ∝ speed ⇒ all step times equal ⇒ barrier wait ≈ comm only
    assert res.waiting_fraction < 0.25
    steps = [w.steps for w in sim.workers]
    assert max(steps) - min(steps) <= 1


def test_determinism():
    r1 = run(make_policy("adsp", search=False, gamma=20.0), seconds=150)[1]
    r2 = run(make_policy("adsp", search=False, gamma=20.0), seconds=150)[1]
    np.testing.assert_array_equal(r1.losses, r2.losses)
    assert r1.total_steps == r2.total_steps
    assert r1.commit_counts == r2.commit_counts


def test_heterogeneity_profiles_match_H():
    from repro.control.theory import heterogeneity_degree
    from repro.edgesim.profiles import heterogeneity_profiles

    for H in (1.0, 1.6, 2.4, 3.2):
        profs = heterogeneity_profiles(6, H)
        assert heterogeneity_degree([p.v for p in profs]) == pytest.approx(H)
