"""Pooled-slot decode must be bit-identical to solo decode (DESIGN.md
§14), per model family.

A request served from a continuous-batching slot pool shares its decode
step with whatever else occupies the pool, lands in whichever slot the
free list hands it (including slots previously used and evicted — the
pool never clears state between occupants), and sees per-row positional
handling. None of that may change its tokens: every request's stream
must equal the reference single-request decode at the same cache
capacity, argmax for argmax.

Pinned per family because the cache mechanics differ: ring-buffer K/V
(attention), wkv matrix state (rwkv6), LRU hidden + conv tail (rglru).
The trace uses more requests than slots so slot eviction + backfill
reuse is on the tested path, and mixed prompt lengths so rows sit at
different sequence offsets (the learned-pos per-row gather regression).
"""

import jax
import pytest

from repro.configs import get_smoke
from repro.models import lm
from repro.serve import (
    ServeConfig,
    ServeEngine,
    TraceConfig,
    make_trace,
    solo_decode,
)

FAMILIES = ["granite-3-8b", "rwkv6-3b", "recurrentgemma-9b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_pool_decode_matches_solo(arch):
    cfg = get_smoke(arch)
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    # 7 requests through 2 slots → at least 5 insertions into
    # previously-used slots; mixed prompt lengths → mixed row offsets
    trace = make_trace("poisson", TraceConfig(
        n_requests=7, rate=50.0, prompt_lens=(4, 8), max_new=(2, 6),
        slo_ms=2000.0, seed=11))
    engine = ServeEngine(cfg, params, ServeConfig(slots=2), trace)
    rep = engine.run()
    assert len(rep.records) == 7
    assert rep.inserts > engine.pool.n_slots  # slot reuse exercised
    cap = engine.pool.capacity
    for r in trace:
        solo = solo_decode(cfg, params, engine.prompt_tokens(r),
                           r.max_new, cap)
        assert rep.tokens_by_rid[r.rid] == solo, (
            f"{arch} rid={r.rid}: pooled {rep.tokens_by_rid[r.rid]} "
            f"!= solo {solo}")


@pytest.mark.parametrize("arch", FAMILIES)
def test_pool_parity_survives_eos_eviction(arch):
    """Early EOS evictions reshuffle which requests share steps; token
    streams must still match solo decode with the same EOS rule."""
    cfg = get_smoke(arch)
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    trace = make_trace("poisson", TraceConfig(
        n_requests=5, rate=50.0, prompt_lens=(4, 8), max_new=(6, 6),
        slo_ms=2000.0, seed=12))
    free = ServeEngine(cfg, params, ServeConfig(slots=2), trace).run()
    eos = free.tokens_by_rid[trace[0].rid][1]  # occurs mid-stream
    engine = ServeEngine(cfg, params, ServeConfig(slots=2, eos_id=eos), trace)
    rep = engine.run()
    cap = engine.pool.capacity
    for r in trace:
        solo = solo_decode(cfg, params, engine.prompt_tokens(r),
                           r.max_new, cap, eos_id=eos)
        assert rep.tokens_by_rid[r.rid] == solo


@pytest.mark.parametrize("arch", FAMILIES)
def test_chunked_prefill_matches_solo(arch):
    """Chunked prefill (ragged chunks, shared lane dispatches) must be
    bit-identical to the monolithic path per family: the same trace
    through a chunked engine yields the same token streams as the solo
    oracle — which prefills each prompt in one lm_prefill call. Chunk 3
    over prompt lengths 4/8/13 exercises ragged final chunks everywhere
    and lanes that finish at different dispatches."""
    cfg = get_smoke(arch)
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    trace = make_trace("poisson", TraceConfig(
        n_requests=7, rate=50.0, prompt_lens=(4, 8, 13), max_new=(2, 6),
        slo_ms=2000.0, seed=11))
    engine = ServeEngine(cfg, params, ServeConfig(
        slots=2, prefill_chunk=3, prefill_batch=2), trace)
    rep = engine.run()
    assert rep.chunk_dispatches > 0
    cap = engine.pool.capacity
    for r in trace:
        solo = solo_decode(cfg, params, engine.prompt_tokens(r),
                           r.max_new, cap)
        assert rep.tokens_by_rid[r.rid] == solo, (
            f"{arch} rid={r.rid}: chunked {rep.tokens_by_rid[r.rid]} "
            f"!= solo {solo}")


@pytest.mark.parametrize("arch", FAMILIES)
def test_chunked_parity_survives_eos_eviction(arch):
    """Resume-after-eviction on the chunked path: early EOS evictions
    free decode slots mid-run, so lanes drain into previously-used slots
    while other lanes are still mid-prompt. Token streams must still
    match solo decode with the same EOS rule."""
    cfg = get_smoke(arch)
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    trace = make_trace("poisson", TraceConfig(
        n_requests=5, rate=50.0, prompt_lens=(4, 8), max_new=(6, 6),
        slo_ms=2000.0, seed=12))
    free = ServeEngine(cfg, params, ServeConfig(
        slots=2, prefill_chunk=3, prefill_batch=2), trace).run()
    eos = free.tokens_by_rid[trace[0].rid][1]  # occurs mid-stream
    engine = ServeEngine(cfg, params, ServeConfig(
        slots=2, prefill_chunk=3, prefill_batch=2, eos_id=eos), trace)
    rep = engine.run()
    cap = engine.pool.capacity
    assert any(len(rep.tokens_by_rid[r.rid]) < r.max_new for r in trace)
    for r in trace:
        solo = solo_decode(cfg, params, engine.prompt_tokens(r),
                           r.max_new, cap, eos_id=eos)
        assert rep.tokens_by_rid[r.rid] == solo
