"""Tests for the online-search reward (§4.2) and Alg. 1."""

import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="dev extra; pip install -e .[dev]")
from hypothesis import given, settings, strategies as st

from repro.control.reward import fit_loss_curve, reward
from repro.control.search import decide_commit_rate


def _curve(a1_sq, a2, a3, t):
    return 1.0 / (a1_sq * t + a2) + a3


def test_fit_recovers_synthetic_curve():
    t = np.linspace(0, 60, 12)
    loss = _curve(0.05, 0.4, 0.3, t)
    fit = fit_loss_curve(t, loss)
    assert fit.ok
    pred = fit.predict(t)
    assert np.max(np.abs(pred - loss)) < 0.02


@given(st.floats(0.01, 0.2), st.floats(0.05, 0.5))
@settings(max_examples=50, deadline=None)
def test_reward_orders_decay_speed(a1_slow, extra):
    """A strictly faster-decaying loss curve must earn a larger reward."""
    t = np.linspace(0, 60, 10)
    a1_fast = a1_slow + extra
    slow = _curve(a1_slow, 0.5, 0.2, t)
    fast = _curve(a1_fast, 0.5, 0.2, t)
    ref = 0.25  # shared loss reference above the common asymptote
    assert reward(t, fast, ref) > reward(t, slow, ref)


def test_reward_slope_fallback_on_flat_window():
    t = np.linspace(0, 60, 10)
    rising = 1.0 + 0.01 * t  # loss increasing: 1/t fit invalid
    r = reward(t, rising)
    assert np.isfinite(r)
    assert r <= 0  # negative slope reward


class PeakedSystem:
    """Mock OnlineSystem whose loss-decay speed peaks at C_target=opt.

    Decay per probe window is a few percent — the quasi-stationary regime
    the paper's short online probes operate in (a probe is ~1 minute of a
    multi-hour run)."""

    def __init__(self, opt=5, m=3):
        self.opt = opt
        self._counts = [0] * m
        self.t = 0.0
        self.loss = 10.0
        self.probes = []

    def commit_counts(self):
        return self._counts

    def evaluate(self, c_target, probe_seconds):
        self.probes.append(c_target)
        rate = 2e-3 * np.exp(-0.5 * (c_target - self.opt) ** 2 / 4.0)
        ts, ls = [], []
        for i in range(4):
            ts.append(self.t)
            ls.append(self.loss)
            self.t += probe_seconds / 3
            self.loss *= np.exp(-rate * probe_seconds / 3)
        self._counts = [c + max(c_target - c, 1) for c in self._counts]
        return ts, ls


def test_decide_commit_rate_climbs_to_peak():
    sys = PeakedSystem(opt=5)
    chosen, trace = decide_commit_rate(sys, probe_seconds=30.0, max_probes=12)
    # starts at max(c)+1 = 1 and must climb toward the peak at 5 (stops at
    # the first non-improving step, so 4..6 is a pass).
    assert 4 <= chosen <= 6, (chosen, trace.candidates, trace.rewards)
    assert trace.candidates[0] == 1
    assert chosen == trace.chosen


def test_decide_commit_rate_stops_immediately_past_peak():
    sys = PeakedSystem(opt=1)
    chosen, _ = decide_commit_rate(sys, probe_seconds=30.0, max_probes=12)
    assert chosen <= 2
