"""End-to-end behaviour tests for the ADSP system (the paper's headline
claims, at test scale)."""

import pytest

from repro.cluster import make_policy
from repro.edgesim import SimConfig, Simulator
from repro.edgesim.profiles import ratio_profiles
from repro.edgesim.tasks import cnn_task, svm_task


@pytest.fixture(scope="module")
def profiles():
    return ratio_profiles((1, 1, 3), base_v=1.0, o=0.2)


def _run(task, profiles, policy, target_loss, max_seconds=3000):
    cfg = SimConfig(gamma=20.0, epoch_seconds=200.0, base_batch=32,
                    target_loss=target_loss, max_seconds=max_seconds,
                    local_lr=0.05)
    sim = Simulator(task, profiles, policy, cfg)
    return sim, sim.train()


@pytest.mark.slow
def test_adsp_beats_bsp_and_fixed_adacomm_on_cnn(profiles):
    """Fig. 4: ADSP converges faster in wall-clock than BSP and Fixed
    ADACOMM under 1:1:3 heterogeneity (test-scale CNN)."""
    task = cnn_task(3, width=8)
    _, res_adsp = _run(task, profiles, make_policy(
        "adsp", search=True, gamma=20.0, probe_seconds=20.0, max_probes=8),
        target_loss=0.6)
    _, res_bsp = _run(task, profiles, make_policy("bsp"), target_loss=0.6)
    _, res_fixed = _run(task, profiles, make_policy("fixed_adacomm", tau=8),
                        target_loss=0.6)
    assert res_adsp.converged
    assert res_adsp.convergence_time < res_bsp.convergence_time
    assert res_adsp.convergence_time < res_fixed.convergence_time
    assert res_adsp.waiting_fraction < 0.05 < res_bsp.waiting_fraction


def test_adsp_end_to_end_svm(profiles):
    """Full pipeline (scheduler + search + timers) on the fast SVM task."""
    task = svm_task(3)
    sim, res = _run(task, profiles, make_policy(
        "adsp", search=True, gamma=20.0, probe_seconds=20.0, max_probes=4),
        target_loss=0.02, max_seconds=900)
    assert res.converged
    assert max(res.commit_counts) - min(res.commit_counts) <= 2
    assert res.losses[-1] <= 0.03
    # the online search ran and recorded traces
    assert sim.policy.traces and sim.policy.traces[0].chosen >= 1


def test_loss_decreases_under_all_policies(profiles):
    task = svm_task(3)
    for name, kw in (("bsp", {}), ("ssp", {}), ("tap", {}),
                     ("fixed_adacomm", {"tau": 4}),
                     ("adsp", {"search": False, "gamma": 20.0})):
        _, res = _run(task, profiles, make_policy(name, **kw),
                      target_loss=None, max_seconds=250)
        assert res.losses[-1] < res.losses[0] * 0.7, name
