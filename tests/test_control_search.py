"""The repro.control subsystem: SearchSession state machine, pre-refactor
parity of the epoch-mode search, ε-tie patience, reward-model registry,
and drift-triggered mid-epoch re-search (hypothesis-free, runs in the
bare container).
"""

import numpy as np
import pytest

from repro.cluster import ADSP, ClusterEngine, ChurnSchedule, make_policy, speed
from repro.control import (
    DriftDetector,
    SearchSession,
    SearchTrace,
    decide_commit_rate,
    get_reward_model,
    log_slope_reward,
    reward_model_names,
)
from repro.control.theory import WorkerProfile
from repro.edgesim import SimConfig, Simulator
from repro.edgesim.profiles import ratio_profiles
from repro.edgesim.tasks import svm_task

PROFILES = ratio_profiles((1, 1, 3), base_v=1.0, o=0.2)


# ---------------------------------------------------------------------------
# Pre-refactor parity: the SearchSession-driven epoch search must reproduce
# the blocking decide_commit_rate loop bit for bit on default links.
# ---------------------------------------------------------------------------


def _pre_refactor_decide(system, probe_seconds=60.0, max_probes=16):
    """Verbatim pre-refactor decide_commit_rate (the blocking Alg. 1 loop
    retired by the repro.control migration) — the parity oracle."""
    trace = SearchTrace()
    c_target = int(max(system.commit_counts())) + 1

    t1, l1 = system.evaluate(c_target, probe_seconds)
    trace.candidates.append(c_target)

    probes = 1
    while probes < max_probes:
        t2, l2 = system.evaluate(c_target + 1, probe_seconds)
        probes += 1
        r1 = log_slope_reward(t1, l1)
        r2 = log_slope_reward(t2, l2)
        if not trace.rewards:
            trace.rewards.append(r1)
        trace.candidates.append(c_target + 1)
        trace.rewards.append(r2)
        if r2 > r1:
            c_target, t1, l1 = c_target + 1, t2, l2
        else:
            break
    trace.chosen = c_target
    if not trace.rewards:  # max_probes == 1
        trace.rewards.append(log_slope_reward(t1, l1))
    return c_target, trace


def _make_sim(max_probes):
    policy = make_policy("adsp", gamma=20.0, search=True,
                         probe_seconds=20.0, max_probes=max_probes)
    cfg = SimConfig(gamma=20.0, epoch_seconds=200.0, base_batch=32,
                    max_seconds=2000.0, local_lr=0.05)
    return Simulator(svm_task(len(PROFILES)), PROFILES, policy, cfg)


@pytest.mark.parametrize("max_probes", [1, 4, 8])
def test_epoch_search_parity_with_pre_refactor_loop(max_probes):
    """Two identically-seeded simulators, default (infinite-bandwidth)
    links: the event-driven SearchSession search and the retired blocking
    loop must produce the same probes, the same SearchTrace — candidates,
    rewards bit for bit — and the same chosen C_target."""
    sim_new = _make_sim(max_probes)
    sim_new.engine.epoch_end()  # Search command → SearchSession
    assert len(sim_new.policy.traces) == 1
    new = sim_new.policy.traces[0]

    sim_old = _make_sim(max_probes)
    chosen, old = _pre_refactor_decide(
        sim_old.engine, probe_seconds=20.0, max_probes=max_probes
    )
    sim_old.engine.set_c_target(chosen)  # the engine did this after _run_search

    assert new.candidates == old.candidates
    assert new.rewards == old.rewards  # exact float equality
    assert new.chosen == old.chosen == chosen
    assert new.restarts == 0 and not new.aborted
    assert sim_new.policy.c_target == sim_old.policy.c_target
    # both consumed the same probe windows of virtual time
    assert sim_new.now == sim_old.now
    assert sim_new.loss_history[-1] == sim_old.loss_history[-1]


def test_decide_commit_rate_wrapper_matches_oracle():
    """The blocking convenience wrapper drives a session to the same
    result as the oracle loop."""
    sim_a, sim_b = _make_sim(6), _make_sim(6)
    c_new, tr_new = decide_commit_rate(sim_a.engine, 20.0, 6)
    c_old, tr_old = _pre_refactor_decide(sim_b.engine, 20.0, 6)
    assert (c_new, tr_new.candidates, tr_new.rewards, tr_new.chosen) == (
        c_old, tr_old.candidates, tr_old.rewards, tr_old.chosen)


# ---------------------------------------------------------------------------
# SearchSession state machine + ε-tie patience
# ---------------------------------------------------------------------------


class ScriptedSystem:
    """OnlineSystem whose windows carry a scripted reward per candidate:
    the window is a flat line at the scripted value and the reward model
    reads it straight off, so climb decisions are exactly controlled."""

    def __init__(self, rewards_by_candidate, counts=(0, 0, 0)):
        self.rewards = dict(rewards_by_candidate)
        self._counts = list(counts)
        self.probed = []

    @staticmethod
    def reward_model(ts, ls):
        return float(ls[0])

    def commit_counts(self):
        return self._counts

    def evaluate(self, c_target, probe_seconds):
        self.probed.append(c_target)
        r = self.rewards[c_target]
        return [0.0, 1.0, 2.0], [r, r, r]


def test_patience_zero_breaks_on_first_miss():
    sys = ScriptedSystem({1: 1.0, 2: 0.98, 3: 1.2})
    chosen, trace = decide_commit_rate(sys, 1.0, 8,
                                       reward_model=ScriptedSystem.reward_model)
    assert chosen == 1
    assert sys.probed == [1, 2]  # the dip ended the climb immediately
    assert trace.candidates == [1, 2]
    assert trace.rewards == [1.0, 0.98]


def test_patience_survives_one_noisy_probe():
    """Regression (the docstring's promised patience guard): one noisy
    near-tie probe must not end the climb — with patience the search sees
    past the dip and finds the better candidate behind it."""
    sys = ScriptedSystem({1: 1.0, 2: 0.98, 3: 1.2, 4: 0.5})
    chosen, trace = decide_commit_rate(sys, 1.0, 8, patience=1, eps_tie=0.05,
                                       reward_model=ScriptedSystem.reward_model)
    assert chosen == 3  # climbed through the noisy probe at 2
    assert sys.probed == [1, 2, 3, 4]
    assert trace.candidates == [1, 2, 3, 4]
    assert trace.rewards == [1.0, 0.98, 1.2, 0.5]
    assert trace.chosen == 3


def test_patience_exhausts_on_sustained_plateau():
    """A *sustained* plateau spends all patience and ends the climb — the
    guard bounds noisy plateaus in both directions."""
    sys = ScriptedSystem({1: 1.0, 2: 0.99, 3: 0.985, 4: 0.98, 5: 2.0})
    chosen, _ = decide_commit_rate(sys, 1.0, 16, patience=2, eps_tie=0.05,
                                   reward_model=ScriptedSystem.reward_model)
    assert chosen == 1
    assert sys.probed == [1, 2, 3, 4]  # 2 misses tolerated, 3rd ends it


def test_patience_large_drop_ends_climb_despite_patience():
    sys = ScriptedSystem({1: 1.0, 2: 0.5, 3: 9.0})
    chosen, _ = decide_commit_rate(sys, 1.0, 8, patience=3, eps_tie=0.05,
                                   reward_model=ScriptedSystem.reward_model)
    assert chosen == 1  # 50% drop is no tie: stop at once
    assert sys.probed == [1, 2]


def test_session_max_probes_caps_climb():
    sys = ScriptedSystem({c: float(c) for c in range(1, 20)})
    chosen, trace = decide_commit_rate(sys, 1.0, 5,
                                       reward_model=ScriptedSystem.reward_model)
    assert chosen == 5  # ever-improving, capped by the probe budget
    assert trace.candidates == [1, 2, 3, 4, 5]
    assert len(trace.rewards) == 5


def test_session_single_probe_budget():
    sys = ScriptedSystem({1: 0.7})
    chosen, trace = decide_commit_rate(sys, 1.0, 1,
                                       reward_model=ScriptedSystem.reward_model)
    assert chosen == 1
    assert trace.candidates == [1] and trace.rewards == [0.7]


def test_session_churn_restart_and_abort():
    s = SearchSession(probe_seconds=1.0, max_probes=8, max_restarts=1,
                      reward_model=ScriptedSystem.reward_model)
    assert s.begin([0, 0]) == 1
    s.notify_churn()
    assert s.churned
    with pytest.raises(RuntimeError, match="invalidated by churn"):
        s.probe_window_complete([0.0, 1.0], [1.0, 1.0])
    # restart on the new fleet: climb starts over at max(counts)+1
    assert s.restart([2, 2]) == 3
    assert s.trace.restarts == 1 and s.active and not s.churned
    # a clean probe now scores
    assert s.probe_window_complete([0.0, 1.0, 2.0], [1.0, 1.0, 1.0]) == 4
    # churn again: restart budget exhausted → abort, keep best-so-far
    s.notify_churn()
    assert s.restart([5, 5]) is None
    assert s.state == "aborted" and s.trace.aborted
    assert s.trace.chosen == 3  # the only candidate actually scored
    assert s.trace.candidates == [3]


def test_session_abort_before_any_probe_keeps_start_candidate():
    s = SearchSession(max_probes=4, max_restarts=0)
    s.begin([1, 1])
    s.notify_churn()
    assert s.restart([1, 1]) is None
    assert s.trace.aborted and s.trace.chosen == 2  # max(counts)+1


def test_reward_model_registry():
    assert set(reward_model_names()) >= {"curve_fit", "log_slope"}
    assert get_reward_model("log_slope") is log_slope_reward
    assert get_reward_model(None) is log_slope_reward
    fn = lambda ts, ls: 1.0  # noqa: E731
    assert get_reward_model(fn) is fn
    with pytest.raises(KeyError, match="unknown reward model"):
        get_reward_model("magic")


# ---------------------------------------------------------------------------
# DriftDetector
# ---------------------------------------------------------------------------


def test_drift_detector_fleet_drift_metric():
    d = DriftDetector(threshold=0.25, cooldown=0.0)
    d.rebaseline({0: 0.2, 1: 0.2, 2: 0.6}, now=0.0)
    assert d.fleet_drift({0: 0.2, 1: 0.2, 2: 0.6}) == pytest.approx(0.0)
    # a worker leaving moves its whole share
    assert d.fleet_drift({0: 0.5, 1: 0.5}) == pytest.approx(0.6)
    assert not d.should_search({0: 0.21, 1: 0.19, 2: 0.6}, now=1.0)
    assert d.should_search({0: 0.5, 1: 0.5}, now=2.0)


def test_drift_detector_cooldown_limits_trigger_rate():
    d = DriftDetector(threshold=0.1, cooldown=100.0)
    d.rebaseline({0: 0.5, 1: 0.5}, now=0.0)
    shifted = {0: 0.9, 1: 0.1}
    assert d.should_search(shifted, now=10.0)
    assert not d.should_search(shifted, now=50.0)  # still cooling down
    assert d.should_search(shifted, now=120.0)


def test_drift_detector_loss_regression_triggers():
    d = DriftDetector(threshold=0.9, loss_rise_tol=0.1, cooldown=0.0)
    base = {0: 0.5, 1: 0.5}
    d.rebaseline(base, now=0.0)
    d.observe_loss(1.0)
    d.observe_loss(0.8)
    assert not d.should_search(base, now=1.0)
    d.observe_loss(0.95)  # regressed >10% above the best (0.8)
    assert d.should_search(base, now=2.0)


def test_drift_detector_first_fleet_adopted_silently():
    d = DriftDetector(threshold=0.1, cooldown=0.0)
    assert not d.should_search({0: 1.0}, now=0.0)  # baselines, no trigger
    assert d.should_search({0: 0.5, 1: 0.5}, now=1.0)


# ---------------------------------------------------------------------------
# Drift-triggered re-search, end to end on both backends
# ---------------------------------------------------------------------------


def test_drift_mode_researches_mid_epoch_on_speed_shift():
    """--search-mode drift: a mid-run speed shift triggers Alg. 1 *before*
    any epoch boundary (epoch_seconds is never reached here)."""
    policy = make_policy("adsp", gamma=20.0, search=True, search_mode="drift",
                        drift_threshold=0.25, drift_cooldown=10.0,
                        probe_seconds=10.0, max_probes=3)
    cfg = SimConfig(gamma=20.0, epoch_seconds=1e9, base_batch=32,
                    max_seconds=4000.0, local_lr=0.05)
    churn = ChurnSchedule([speed(30.0, worker=0, v=0.1)])  # fast worker throttled 10x
    sim = Simulator(svm_task(len(PROFILES)), PROFILES, policy, cfg, churn=churn)
    sim.run(25.0)
    assert policy.traces == []  # no drift yet, and no epoch clock at all
    sim.run(75.0)
    assert len(policy.traces) >= 1, "speed shift did not trigger a re-search"
    tr = policy.traces[0]
    assert tr.chosen >= 1
    assert tr.t_start == 30.0  # triggered by the shift itself, mid-epoch
    # later checkpoints may advance c_target past the chosen value, but
    # never below it
    assert policy.c_target >= tr.chosen


def test_epoch_mode_does_not_search_mid_epoch():
    policy = make_policy("adsp", gamma=20.0, search=True, search_mode="epoch",
                        probe_seconds=10.0, max_probes=3)
    cfg = SimConfig(gamma=20.0, epoch_seconds=1e9, base_batch=32,
                    max_seconds=4000.0, local_lr=0.05)
    churn = ChurnSchedule([speed(30.0, worker=0, v=0.1)])
    sim = Simulator(svm_task(len(PROFILES)), PROFILES, policy, cfg, churn=churn)
    sim.run(100.0)
    assert policy.traces == []  # only the epoch clock may search


def test_drift_mode_on_mesh_backend_speed_shift():
    """The same drift wiring drives the real mesh loop: a set_speed on the
    MeshBackend triggers a mid-run re-search through the engine."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.cluster.mesh_backend import MeshBackend, MeshTask

    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(4, 1)).astype(np.float32)

    def loss_fn(params, mb):
        x, y = mb
        return jnp.mean((x @ params["w"] - y) ** 2)

    def make_microbatches(round_idx, tau, n_workers):
        r = np.random.default_rng(round_idx + 1)
        x = r.normal(size=(tau, 64, 4)).astype(np.float32)
        return jnp.asarray(x), jnp.asarray(x @ w_true)

    task = MeshTask({"w": jnp.zeros((4, 1), jnp.float32)}, loss_fn,
                    make_microbatches)
    mesh = jax.make_mesh((1,), ("data",))
    backend = MeshBackend(task, mesh, worker_axes=("data",), tau=4,
                          local_lr=0.1, global_lr=1.0,
                          batch_spec=jax.sharding.PartitionSpec(None, "data"))
    policy = ADSP(gamma=8.0, search=True, search_mode="drift",
                  drift_threshold=0.25, drift_cooldown=1.0,
                  probe_seconds=2.0, max_probes=2)
    engine = ClusterEngine(policy, backend)
    backend.train(rounds=5, check_period=policy.gamma)
    assert policy.traces == []
    backend.set_speed(0, 0.1)  # single worker: fraction stays 1.0 → no drift
    assert policy.traces == []
    # loss regression is the other drift signal: against a primed (much
    # lower) best-since-baseline, the next checkpoint's observed loss
    # reads as regressed and must trigger a mid-run search on the mesh
    policy.drift._best_loss = backend.recent_global_loss() / 100.0
    engine.checkpoint()
    assert len(policy.traces) >= 1
    assert policy.c_target == policy.traces[-1].chosen


def test_search_during_probe_window_is_not_reentrant():
    """A drift trigger firing during a search's own probe window must not
    open a nested session."""
    policy = make_policy("adsp", gamma=20.0, search=True, search_mode="both",
                        drift_threshold=0.01, drift_cooldown=0.0,
                        probe_seconds=30.0, max_probes=3)
    cfg = SimConfig(gamma=20.0, epoch_seconds=200.0, base_batch=32,
                    max_seconds=4000.0, local_lr=0.05)
    # speed shifts landing inside the epoch-end search's probe windows
    churn = ChurnSchedule([speed(10.0, worker=2, v=0.3),
                           speed(40.0, worker=2, v=3.0)])
    sim = Simulator(svm_task(len(PROFILES)), PROFILES, policy, cfg, churn=churn)
    sim.engine.epoch_end()
    assert not sim.engine.search_active
    # every trace is complete and self-consistent
    for tr in policy.traces:
        assert tr.chosen in tr.candidates


def test_worker_profile_sanity():
    with pytest.raises(ValueError):
        WorkerProfile(v=0.0)


def test_checkpoint_triggered_search_does_not_refire_checkpoint():
    """Re-entrancy regression: a drift Search fired from inside a
    checkpoint handler runs probe windows through a nested event loop —
    the checkpoint that triggered it must not fire a second time in the
    nested frame, and no later checkpoint may be skipped."""
    fired = []

    @__import__("dataclasses").dataclass
    class LoggingADSP(ADSP):
        def on_checkpoint(self, view):
            fired.append(view.now)
            return super().on_checkpoint(view)

    policy = LoggingADSP(gamma=20.0, search=True, search_mode="drift",
                         drift_threshold=0.9, drift_cooldown=0.0,
                         probe_seconds=10.0, max_probes=2)
    cfg = SimConfig(gamma=20.0, epoch_seconds=1e9, base_batch=32,
                    max_seconds=4000.0, local_lr=0.05)
    sim = Simulator(svm_task(len(PROFILES)), PROFILES, policy, cfg)
    sim.run(30.0)
    # prime the detector so the NEXT checkpoint's loss reads as regressed
    policy.drift._best_loss = sim.recent_global_loss() / 100.0
    sim.run(90.0)
    assert len(policy.traces) >= 1  # the checkpoint did trigger a search
    assert fired == sorted(fired)
    assert len(fired) == len(set(fired)), f"checkpoint double-fired: {fired}"
    # every Γ boundary up to the clock fired exactly once — none skipped
    expect = [t for t in np.arange(20.0, sim.now + 1e-9, 20.0)]
    assert fired == expect, (fired, expect)


def test_probe_windows_counts_abandoned_climb_windows():
    """SearchTrace.probe_windows must count every window the backend ran:
    scored windows of abandoned climbs and the churn-discarded one, not
    just the final climb's length."""
    s = SearchSession(probe_seconds=1.0, max_probes=8, max_restarts=2,
                      reward_model=ScriptedSystem.reward_model)
    s.begin([0, 0])
    # climb scores 2 windows...
    assert s.probe_window_complete([0, 1, 2], [1.0, 1.0, 1.0]) == 2
    assert s.probe_window_complete([0, 1, 2], [2.0, 2.0, 2.0]) == 3
    # ...then churn invalidates the 3rd window and restarts the climb
    s.notify_churn()
    assert s.restart([4, 4]) == 5
    # the new climb scores 1 window and stops on a miss in the 2nd
    assert s.probe_window_complete([0, 1, 2], [3.0, 3.0, 3.0]) == 6
    assert s.probe_window_complete([0, 1, 2], [0.1, 0.1, 0.1]) is None
    assert s.trace.chosen == 5
    assert len(s.trace.candidates) == 2  # the final climb only
    assert s.trace.probe_windows == 5  # 2 scored + 1 discarded + 2 scored


def test_aborted_search_keeps_drift_baseline_armed():
    """An ABORTED search (sustained churn) must not rebaseline the
    DriftDetector: its choice was never scored against the fleet, and in
    pure drift mode no epoch clock exists to retry — the standing drift
    must re-trigger after the cooldown."""
    policy = make_policy("adsp", gamma=20.0, search=True, search_mode="drift",
                        drift_threshold=0.25, drift_cooldown=0.0,
                        probe_seconds=1.0, max_probes=2)

    class View:
        now = 100.0
        workers = ()

        @staticmethod
        def recent_global_loss():
            return None

    policy.drift = DriftDetector(threshold=0.25, cooldown=0.0)
    policy.drift.rebaseline({0: 0.5, 1: 0.5}, now=0.0)
    baseline = dict(policy.drift._baseline)
    aborted = SearchTrace(candidates=[3], chosen=3, restarts=2, aborted=True)
    policy.on_search_done(View(), aborted)
    assert policy.drift._baseline == baseline  # untouched: signal stays armed
    assert policy.drift.should_search({0: 0.9, 1: 0.1}, now=101.0)
    done = SearchTrace(candidates=[3, 4], chosen=4)
    policy.on_search_done(View(), done)
    assert policy.drift._baseline == {}  # empty View fleet adopted


def test_nested_search_does_not_pop_events_past_its_end():
    """Stale-peek regression: when a drift search (triggered by churn
    inside _run_until) overruns the outer run()'s horizon, the outer
    frame must re-evaluate the heap instead of popping an event scheduled
    after the search's end — the clock must stop exactly at the last
    probe window's boundary."""
    policy = make_policy("adsp", gamma=20.0, search=True, search_mode="drift",
                        drift_threshold=0.25, drift_cooldown=10.0,
                        probe_seconds=10.0, max_probes=3)
    cfg = SimConfig(gamma=20.0, epoch_seconds=1e9, base_batch=32,
                    max_seconds=4000.0, local_lr=0.05)
    churn = ChurnSchedule([speed(30.0, worker=0, v=0.1)])
    sim = Simulator(svm_task(len(PROFILES)), PROFILES, policy, cfg, churn=churn)
    sim.run(35.0)  # churn at 30 triggers a search overrunning t_end=35
    assert len(policy.traces) == 1
    tr = policy.traces[0]
    assert tr.t_start == 30.0
    # the clock stopped exactly where the last probe window ended — no
    # event beyond the search's end was processed
    assert sim.now == pytest.approx(30.0 + 10.0 * tr.windows)
    assert sim.now == tr.t_end
