"""The unified cluster runtime: one policy, two backends, elastic churn.

Acceptance criteria of the control-plane redesign (DESIGN.md):
  * the same event-driven ADSP policy converges on the virtual-clock
    simulator backend AND on the single-host mesh backend;
  * commit counts follow the rate rule ΔC_i = C_target − c_i on both;
  * removing/adding a worker mid-run re-derives rates and still converges.
"""

import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (
    ADSP,
    ArmTimer,
    Block,
    ChurnSchedule,
    ClusterEngine,
    Commit,
    Resume,
    SetRate,
    join,
    leave,
    make_policy,
    speed,
)
from repro.cluster.mesh_backend import MeshBackend, MeshTask
from repro.control.theory import WorkerProfile
from repro.edgesim import SimConfig, Simulator
from repro.edgesim.profiles import ratio_profiles
from repro.edgesim.tasks import svm_task

ROOT = pathlib.Path(__file__).resolve().parent.parent
PROFILES = ratio_profiles((1, 1, 3), base_v=1.0, o=0.2)


def _rate_rule_holds(engine, policy):
    """After a checkpoint dispatch, every worker's ΔC_i must equal the
    Alg. 2 rate rule max(1, C_target − c_i)."""
    engine.checkpoint()
    for w in engine.workers:
        assert w.delta_c_target == max(1, policy.c_target - w.commits), (
            w.index, w.delta_c_target, policy.c_target, w.commits)


# ---------------------------------------------------------------------------
# Protocol-level: policies are pure event → command functions
# ---------------------------------------------------------------------------


def test_adsp_checkpoint_emits_rate_commands():
    sim = Simulator(svm_task(3), PROFILES,
                    make_policy("adsp", search=False, gamma=20.0),
                    SimConfig(max_seconds=100.0, base_batch=32, gamma=20.0))
    policy = sim.policy
    from repro.cluster.protocol import Checkpoint

    cmds = policy.handle(sim.engine, Checkpoint(now=sim.now))
    rates = [c for c in cmds if isinstance(c, SetRate)]
    timers = [c for c in cmds if isinstance(c, ArmTimer)]
    assert {c.worker for c in rates} == {w.index for w in sim.workers}
    assert len(timers) == len(sim.workers)
    for c in rates:
        w = sim.engine.worker(c.worker)
        assert c.delta_c == max(1, policy.c_target - w.commits)


def test_ssp_gating_emits_block_and_resume():
    sim = Simulator(svm_task(3), PROFILES, make_policy("ssp", s=2),
                    SimConfig(max_seconds=60.0, base_batch=32, gamma=20.0))
    sim.run(40.0)
    from repro.cluster.protocol import StepDone

    fast = sim.workers[0]
    cmds = sim.policy.handle(sim.engine, StepDone(fast.index))
    kinds = {type(c) for c in cmds}
    assert Commit in kinds  # SSP commits every step
    assert Block in kinds or Resume in kinds  # gating always recomputed


# ---------------------------------------------------------------------------
# Backend 1: virtual-clock simulator
# ---------------------------------------------------------------------------


def test_adsp_sim_backend_converges_and_follows_rate_rule():
    policy = make_policy("adsp", search=False, gamma=20.0)
    cfg = SimConfig(gamma=20.0, epoch_seconds=80.0, base_batch=32,
                    target_loss=0.02, max_seconds=600.0)
    sim = Simulator(svm_task(3), PROFILES, policy, cfg)
    res = sim.train()
    assert res.converged
    assert max(res.commit_counts) - min(res.commit_counts) <= 2
    _rate_rule_holds(sim.engine, policy)


# ---------------------------------------------------------------------------
# Backend 2: single-host mesh (the real fused commit step)
# ---------------------------------------------------------------------------


def _quad_mesh_task(tau: int, batch: int = 64) -> MeshTask:
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(4, 1)).astype(np.float32)

    def loss_fn(params, mb):
        x, y = mb
        return jnp.mean((x @ params["w"] - y) ** 2)

    def make_microbatches(round_idx, tau_, n_workers):
        r = np.random.default_rng(round_idx + 1)
        x = r.normal(size=(tau_, batch, 4)).astype(np.float32)
        y = x @ w_true
        return jnp.asarray(x), jnp.asarray(y)

    return MeshTask(init_params={"w": jnp.zeros((4, 1), jnp.float32)},
                    loss_fn=loss_fn, make_microbatches=make_microbatches,
                    name="quad")


def test_adsp_mesh_backend_converges_and_follows_rate_rule():
    mesh = jax.make_mesh((1,), ("data",))
    task = _quad_mesh_task(tau=4)
    backend = MeshBackend(task, mesh, worker_axes=("data",), tau=4,
                          local_lr=0.1, global_lr=1.0,
                          batch_spec=jax.sharding.PartitionSpec(None, "data"))
    policy = ADSP(search=False, gamma=8.0)
    engine = ClusterEngine(policy, backend)
    backend.train(rounds=30, check_period=policy.gamma)
    losses = [l for _, l in backend.losses]
    assert losses[-1] < 0.05 * losses[0]  # converged
    assert all(w.commits == 30 for w in backend.workers)
    _rate_rule_holds(engine, policy)


def test_same_policy_object_drives_both_backends():
    """One ADSP instance steers the simulator, then (state carried over)
    the mesh backend — the control plane is backend-agnostic."""
    policy = make_policy("adsp", search=False, gamma=20.0)
    cfg = SimConfig(gamma=20.0, epoch_seconds=80.0, base_batch=32,
                    target_loss=0.02, max_seconds=400.0)
    sim = Simulator(svm_task(3), PROFILES, policy, cfg)
    res = sim.train()
    assert res.converged

    mesh = jax.make_mesh((1,), ("data",))
    backend = MeshBackend(_quad_mesh_task(tau=4), mesh, worker_axes=("data",),
                          tau=4, local_lr=0.1, global_lr=1.0,
                          batch_spec=jax.sharding.PartitionSpec(None, "data"))
    engine = ClusterEngine(policy, backend)
    backend.train(rounds=20, check_period=policy.gamma)
    losses = [l for _, l in backend.losses]
    assert losses[-1] < 0.1 * losses[0]
    _rate_rule_holds(engine, policy)


@pytest.mark.slow
def test_mesh_backend_multiworker_subprocess(tmp_path):
    """4 fake host devices, heterogeneous virtual speeds: the fused commit
    step + engine keep commit counts equal while τ_i tracks v_i."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import json, sys
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.cluster import ADSP, ClusterEngine
        from repro.cluster.mesh_backend import MeshBackend, MeshTask
        from repro.control.theory import WorkerProfile

        rng = np.random.default_rng(0)
        w_true = rng.normal(size=(4, 1)).astype(np.float32)

        def loss_fn(params, mb):
            x, y = mb
            return jnp.mean((x @ params["w"] - y) ** 2)

        def make_microbatches(round_idx, tau, n_workers):
            r = np.random.default_rng(round_idx + 1)
            x = r.normal(size=(tau, 64, 4)).astype(np.float32)
            return jnp.asarray(x), jnp.asarray(x @ w_true)

        task = MeshTask({"w": jnp.zeros((4, 1), jnp.float32)}, loss_fn,
                        make_microbatches)
        mesh = jax.make_mesh((4,), ("data",))
        speeds = [2.0, 1.0, 1.0, 0.5]
        backend = MeshBackend(task, mesh, worker_axes=("data",), tau=8,
                              local_lr=0.05, global_lr=1.0,
                              profiles=[WorkerProfile(v=v, o=0.0) for v in speeds],
                              batch_spec=jax.sharding.PartitionSpec(None, "data"))
        policy = ADSP(search=False, gamma=8.0)
        engine = ClusterEngine(policy, backend)
        backend.train(rounds=25, check_period=policy.gamma)
        engine.checkpoint()
        taus = backend.tau_per_worker()
        out = {
            "losses": [l for _, l in backend.losses],
            "commits": [w.commits for w in backend.workers],
            "rate_rule_ok": all(
                w.delta_c_target == max(1, policy.c_target - w.commits)
                for w in backend.workers),
            "taus": taus.tolist(),
        }
        print(json.dumps(out))
    """)
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env, cwd=ROOT,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    import json

    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["losses"][-1] < 0.05 * out["losses"][0]
    assert len(set(out["commits"])) == 1  # fused round: counts stay equal
    assert out["rate_rule_ok"]
    # τ_i tracks v_i: fastest worker runs ≥ the slowest worker's local steps
    assert out["taus"][0] >= out["taus"][3]
    assert max(out["taus"]) <= 8


# ---------------------------------------------------------------------------
# Elastic churn (the §6 adaptability claim, previously untestable here)
# ---------------------------------------------------------------------------


def test_churn_leave_join_speed_still_converges():
    policy = make_policy("adsp", search=False, gamma=20.0)
    cfg = SimConfig(gamma=20.0, epoch_seconds=80.0, base_batch=32,
                    target_loss=0.02, max_seconds=900.0)
    churn = ChurnSchedule([
        leave(8.0, worker=2),                        # the slow worker dies
        join(12.0, WorkerProfile(v=1.0, o=0.2)),     # a fresh one arrives
        speed(16.0, worker=0, v=0.5),                # worker 0 throttled
    ])
    sim = Simulator(svm_task(3), PROFILES, policy, cfg, churn=churn)
    res = sim.train()
    assert res.converged, res
    assert sim.num_workers == 3  # 3 − 1 + 1
    ids = {w.index for w in sim.workers}
    assert 2 not in ids and 3 in ids  # stable ids: joiner got a fresh id
    # the engine re-derived rates over the *current* fleet
    _rate_rule_holds(sim.engine, policy)
    # control-plane counts (incl. ramp-in credit) stay equalized
    cc = [w.commits for w in sim.workers]
    assert max(cc) - min(cc) <= 3, cc
    # reported counts subtract the joiner's credit: only real commits
    for w in sim.workers:
        reported = res.commit_counts[[x.index for x in sim.workers].index(w.index)]
        assert reported == w.commits - w.commit_credit


def test_churn_speed_shift_rebalances_commit_intervals():
    """Halving a worker's speed must not break commit-count equality —
    ADSP compensates through the timers (more wall time per step, same
    commit cadence)."""
    policy = make_policy("adsp", search=False, gamma=20.0)
    cfg = SimConfig(gamma=20.0, epoch_seconds=80.0, base_batch=32,
                    max_seconds=300.0)
    churn = ChurnSchedule([speed(100.0, worker=0, v=0.25)])
    sim = Simulator(svm_task(3), PROFILES, policy, cfg, churn=churn)
    sim.run(280.0)
    cc = [w.commits for w in sim.workers]
    assert max(cc) - min(cc) <= 2, cc
    assert sim.workers[0].profile.v == 0.25


def test_churn_join_does_not_stall_ssp_veterans():
    """A late joiner starts with the minimum peer step count as credit, so
    SSP's staleness bound doesn't park every veteran behind it."""
    policy = make_policy("ssp", s=4)
    cfg = SimConfig(gamma=20.0, epoch_seconds=80.0, base_batch=32,
                    max_seconds=120.0)
    churn = ChurnSchedule([join(30.0, WorkerProfile(v=1.0, o=0.2))])
    sim = Simulator(svm_task(3), PROFILES, policy, cfg, churn=churn)
    sim.run(60.0)
    steps_at_join_era = {w.index: w.steps for w in sim.workers}
    sim.run(40.0)
    veterans = [w for w in sim.workers if w.index < 3]
    assert all(w.steps > steps_at_join_era[w.index] for w in veterans), (
        "veterans stalled behind the joiner")
    joiner = sim.engine.worker(3)
    assert joiner.step_credit > 0
    assert joiner.steps - joiner.step_credit > 0  # and it really trained


def test_churn_late_join_commit_credit_reporting():
    """A joiner arriving after the fleet has committed gets nonzero commit
    credit for the rate rule, but SimResult reports only real commits."""
    policy = make_policy("adsp", search=False, gamma=20.0)
    cfg = SimConfig(gamma=20.0, epoch_seconds=80.0, base_batch=32,
                    max_seconds=300.0)
    churn = ChurnSchedule([join(90.0, WorkerProfile(v=1.0, o=0.2))])
    sim = Simulator(svm_task(3), PROFILES, policy, cfg, churn=churn)
    sim.run(200.0)
    res = sim.result()
    joiner = sim.engine.worker(3)
    assert joiner.commit_credit > 0
    reported = dict(zip([w.index for w in sim.workers], res.commit_counts))
    assert reported[3] == joiner.commits - joiner.commit_credit
    assert sum(res.commit_counts) <= sim.total_commits


def test_churn_determinism():
    def run():
        policy = make_policy("adsp", search=False, gamma=20.0)
        cfg = SimConfig(gamma=20.0, epoch_seconds=80.0, base_batch=32,
                        max_seconds=200.0)
        churn = ChurnSchedule([
            leave(40.0, worker=1),
            join(80.0, WorkerProfile(v=2.0, o=0.1)),
        ])
        sim = Simulator(svm_task(3), PROFILES, policy, cfg, churn=churn)
        sim.run(180.0)
        return sim.result()

    r1, r2 = run(), run()
    np.testing.assert_array_equal(r1.losses, r2.losses)
    assert r1.total_steps == r2.total_steps
    assert r1.commit_counts == r2.commit_counts


def test_barrier_release_spares_mid_step_joiner():
    """Barrier + churn regression: when a leave releases the barrier while
    an elastic joiner is still computing its first step, (a) the veterans
    are pulled immediately instead of stalling until the joiner commits,
    and (b) the joiner is NOT pulled — previously every alive worker got
    a pull_done, zeroing the joiner's update, counting a phantom commit,
    and double-scheduling its next step."""
    policy = make_policy("bsp")
    cfg = SimConfig(base_batch=32)
    profiles = [WorkerProfile(v=1.0, o=0.2), WorkerProfile(v=1.0, o=0.2),
                WorkerProfile(v=0.5, o=0.2)]
    churn = ChurnSchedule([
        join(1.3, WorkerProfile(v=0.25, o=0.2)),  # slow joiner, step ends 5.3
        leave(1.5, worker=2),                     # releases the {0,1} barrier
    ])
    sim = Simulator(svm_task(3), profiles, policy, cfg, churn=churn)
    sim.run(3.0)
    w0, w1 = sim.engine.worker(0), sim.engine.worker(1)
    joiner = sim.engine.worker(3)
    # veterans were released at the leave (old code: stalled on the joiner
    # until t=5.4, so commits would still be 0 here)
    assert w0.commits == 1 and w1.commits == 1
    # the joiner kept computing untouched: no phantom commit, no zeroed
    # update, no second in-flight step
    assert joiner.status == "computing"
    assert joiner.steps == 0 and joiner.commits == 0
    # next round folds the joiner in as a member: the barrier now waits
    # for it, then everyone (including the joiner) commits exactly once
    sim.run(3.0)  # t=6: release happened at 5.4
    assert joiner.steps == 1 and joiner.commits == 1
    assert w0.commits == 2 and w1.commits == 2
    assert sim.total_commits == 5  # 2 (first round) + 3 (second round)


def test_barrier_churn_no_phantom_commits_long_run():
    """Commit accounting stays exact under barrier + heavy churn: every
    reported commit corresponds to an applied update."""
    policy = make_policy("fixed_adacomm", tau=2)
    cfg = SimConfig(base_batch=32)
    profiles = [WorkerProfile(v=1.0, o=0.2), WorkerProfile(v=1.0, o=0.1),
                WorkerProfile(v=0.5, o=0.4)]
    churn = ChurnSchedule([
        join(5.3, WorkerProfile(v=0.4, o=0.3)),
        leave(9.7, worker=2),
        join(12.1, WorkerProfile(v=2.0, o=0.1)),
        leave(14.9, worker=0),
    ])
    sim = Simulator(svm_task(3), profiles, policy, cfg, churn=churn)
    sim.run(60.0)
    # real pulls only: joiners inherit commit_credit for the rate rule
    pulled = sum(w.commits - w.commit_credit for w in sim.workers)
    pulled += sum(w.commits - w.commit_credit for w, _ in sim._departed)
    # applied-but-not-yet-pulled commits may be in flight at cutoff
    assert 0 <= sim.total_commits - pulled <= sim.num_workers
    for w in sim.workers:
        # a real commit requires a finished real step
        assert w.commits - w.commit_credit <= w.steps - w.step_credit
