"""Serving subsystem (repro.serve, DESIGN.md §14): traces, slot pool,
continuous-batching engine, PS sync, metrics round-trip, launcher
regressions.

The behaviors pinned here:

  * open-loop traces are seeded/deterministic and respect their bounds;
  * the engine completes every request of a trace (continuous AND
    static modes), generating exactly ``max_new`` tokens (or stopping
    at EOS), with eviction + backfill reusing slots;
  * EDF admission reorders a queue that FCFS would serve
    arrival-first;
  * static rebatching never backfills mid-batch (inserts happen only
    when the pool is fully drained);
  * ``ServeRecord``/``PullRecord`` round-trip losslessly through
    to_dict/from_dict and JSONL;
  * ``pull_stale`` pulls exactly the version-stale shards, bit-exact;
  * the one-shot launcher with ``--new-tokens 1`` reports the decode
    loop as skipped instead of fabricating a ms/token figure;
  * ``tools/fleet_report.py`` summarizes a serve stream.
"""

import importlib.util
import json
import math
import pathlib

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.fleet import (
    JsonlSink,
    MetricsLog,
    PullRecord,
    ServeRecord,
    from_dict,
    load_jsonl,
    to_dict,
)
from repro.launch import serve as serve_launch
from repro.models import lm
from repro.ps.sharding import ShardPlan
from repro.ps.state import AdspState
from repro.serve import (
    CachePool,
    CostModel,
    LoadBalancer,
    ReplicaSync,
    Request,
    ServeConfig,
    ServeEngine,
    ShardedTrainer,
    TraceConfig,
    family_of,
    get_router,
    get_scheduler,
    make_trace,
    pull_stale,
    router_names,
    scheduler_names,
    shard_versions_of,
    trace_names,
)

ARCH = "rwkv6-3b"  # cheapest family on CPU; parity across families is
# pinned separately in test_serve_parity.py


@pytest.fixture(scope="module")
def smoke():
    cfg = get_smoke(ARCH)
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _trace(**kw):
    defaults = dict(n_requests=10, rate=20.0, prompt_lens=(4, 8),
                    max_new=(2, 6), slo_ms=800.0, seed=1)
    defaults.update(kw)
    return make_trace("poisson", TraceConfig(**defaults))


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------


def test_trace_registry():
    assert set(trace_names()) >= {"poisson", "bursty"}
    with pytest.raises(KeyError):
        make_trace("nope", TraceConfig())


def test_trace_deterministic_and_bounded():
    tc = TraceConfig(n_requests=50, rate=10.0, prompt_lens=(4, 16),
                     max_new=(2, 8), slo_ms=500.0, seed=7)
    for name in ("poisson", "bursty"):
        a, b = make_trace(name, tc), make_trace(name, tc)
        assert a == b
        assert len(a) == 50
        assert [r.rid for r in a] == list(range(50))
        arr = [r.arrival for r in a]
        assert arr == sorted(arr) and arr[0] >= 0.0
        for r in a:
            assert 4 <= r.prompt_len <= 16
            assert 2 <= r.max_new <= 8
            assert r.deadline == pytest.approx(r.arrival + r.slo)
    assert make_trace("poisson", tc) != make_trace(
        "poisson", TraceConfig(**{**tc.__dict__, "seed": 8}))


def test_bursty_trace_is_bursty():
    tc = TraceConfig(n_requests=400, rate=10.0, seed=3,
                     burst_factor=6.0, burst_duty=0.2, burst_period=4.0)
    tr = make_trace("bursty", tc)
    # arrivals concentrate in the burst windows: the densest quarter of
    # each period holds well above its uniform share
    in_burst = sum(1 for r in tr if (r.arrival % 4.0) < 0.8)
    assert in_burst / len(tr) > 0.4


# ---------------------------------------------------------------------------
# cache pool
# ---------------------------------------------------------------------------


def test_family_of():
    assert family_of(get_smoke("rwkv6-3b")) == "rwkv6"
    assert family_of(get_smoke("recurrentgemma-9b")) in ("rglru", "hybrid")
    assert family_of(get_smoke("granite-3-8b")) == "attention"


def test_cache_pool_occupancy(smoke):
    cfg, params = smoke
    pool = CachePool(cfg, 3, 16)
    _, caches = lm.lm_prefill(
        cfg, params, {"tokens": np.zeros((1, 4), np.int32)}, reserve=12)
    assert pool.insert(7, caches) == 0  # LIFO free list → slot 0 first
    assert pool.insert(9, caches) == 1
    assert pool.n_active == 2 and pool.n_free == 1
    with pytest.raises(ValueError):
        pool.insert(7, caches)  # already resident
    assert pool.evict(7) == 0
    assert pool.insert(11, caches) == 0  # freed slot reused
    pool.insert(13, caches)
    with pytest.raises(RuntimeError):
        pool.insert(15, caches)  # full
    nb = pool.slot_nbytes()
    assert nb["recurrent"] > 0  # rwkv6: constant-size state
    assert nb["kv"] == 0


# ---------------------------------------------------------------------------
# schedulers
# ---------------------------------------------------------------------------


def test_scheduler_registry():
    assert set(scheduler_names()) >= {"fcfs", "deadline"}
    with pytest.raises(KeyError):
        get_scheduler("nope")


def test_edf_reorders_fcfs():
    early_arrival_late_deadline = Request(
        rid=0, arrival=0.0, prompt_len=4, max_new=2, slo=10.0)
    late_arrival_tight_deadline = Request(
        rid=1, arrival=0.1, prompt_len=4, max_new=2, slo=0.5)
    queue = [early_arrival_late_deadline, late_arrival_tight_deadline]
    assert get_scheduler("fcfs").pick(queue, 0.2) == 0
    assert get_scheduler("deadline").pick(queue, 0.2) == 1


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


def test_engine_completes_all_requests(smoke):
    cfg, params = smoke
    trace = _trace()
    log = MetricsLog()
    rep = ServeEngine(cfg, params, ServeConfig(slots=3), trace,
                      metrics=log).run()
    assert len(rep.records) == len(trace)
    assert sorted(rep.tokens_by_rid) == [r.rid for r in trace]
    for r in trace:
        assert len(rep.tokens_by_rid[r.rid]) == r.max_new
    # eviction + backfill actually reused slots (10 requests, 3 slots)
    assert rep.inserts == rep.evictions
    assert rep.inserts > 3
    assert len(log.of("serve")) == len(trace)
    for rec in log.of("serve"):
        assert rec.total == pytest.approx(
            rec.queue + rec.prefill + rec.decode, abs=1e-9)
        assert rec.slo_ok == (rec.total <= 800.0 / 1e3 + 1e-12)
    assert rep.slo_attainment == pytest.approx(
        sum(r.slo_ok for r in rep.records) / len(trace))
    assert rep.goodput > 0 and rep.tokens_per_s > 0


def test_engine_deterministic(smoke):
    cfg, params = smoke
    trace = _trace()
    r1 = ServeEngine(cfg, params, ServeConfig(slots=3), trace).run()
    r2 = ServeEngine(cfg, params, ServeConfig(slots=3), trace).run()
    assert r1.tokens_by_rid == r2.tokens_by_rid
    assert r1.t_end == r2.t_end
    assert [to_dict(a) for a in r1.records] == [to_dict(b) for b in r2.records]


def test_engine_static_mode_no_backfill(smoke):
    cfg, params = smoke
    trace = _trace(n_requests=8, max_new=(2, 8))
    events = []

    class SpyPool(CachePool):
        def insert(self, rid, src):
            events.append(("insert", rid, self.n_active))
            return super().insert(rid, src)

        def evict(self, rid):
            events.append(("evict", rid, self.n_active))
            return super().evict(rid)

    eng = ServeEngine(cfg, params, ServeConfig(slots=3, mode="static"), trace)
    eng.pool = SpyPool(cfg, 3, eng.pool.capacity)
    rep_s = eng.run()
    assert len(rep_s.records) == 8
    # static: inserts happen only in fill runs that start from an empty
    # pool — never as backfill after an eviction mid-batch
    prev = None
    occupancy = 0
    for kind, _, _ in events:
        if kind == "insert":
            assert occupancy == 0 or prev == "insert"
            occupancy += 1
        else:
            occupancy -= 1
        prev = kind
    # continuous on the same trace finishes no later than static
    rep_c = ServeEngine(cfg, params, ServeConfig(slots=3), trace).run()
    assert rep_c.t_end <= rep_s.t_end + 1e-9


def test_engine_eos_evicts_early(smoke):
    cfg, params = smoke
    trace = _trace(n_requests=6, max_new=(8, 8))
    free = ServeEngine(cfg, params, ServeConfig(slots=2), trace).run()
    # pick a token that actually occurs mid-stream so EOS fires
    eos = free.tokens_by_rid[trace[0].rid][2]
    rep = ServeEngine(cfg, params, ServeConfig(slots=2, eos_id=eos), trace).run()
    assert len(rep.records) == 6
    by_rid = {r.req: r for r in rep.records}
    for r in trace:
        toks = rep.tokens_by_rid[r.rid]
        assert len(toks) <= r.max_new
        if len(toks) < r.max_new:
            assert toks[-1] == eos
        assert by_rid[r.rid].tokens == len(toks)
    assert any(len(rep.tokens_by_rid[r.rid]) < r.max_new for r in trace)


def test_engine_rejects_bad_config(smoke):
    cfg, params = smoke
    with pytest.raises(ValueError):
        ServeConfig(slots=0)
    with pytest.raises(ValueError):
        ServeConfig(mode="adaptive")
    with pytest.raises(ValueError):
        ServeEngine(cfg, params, ServeConfig(sync_every=2), _trace())
    with pytest.raises(ValueError):  # capacity below trace requirement
        ServeEngine(cfg, params, ServeConfig(capacity=2), _trace())


def test_cost_model_monotone():
    cm = CostModel()
    assert cm.prefill(32) > cm.prefill(8) > 0
    assert cm.decode(8) > cm.decode(1) > 0


# ---------------------------------------------------------------------------
# metrics round-trip
# ---------------------------------------------------------------------------


def test_serve_records_roundtrip(tmp_path):
    recs = [
        ServeRecord(t=1.25, req=3, queue=0.01, prefill=0.004, decode=0.05,
                    total=0.064, tokens=9, slo=0.8, slo_ok=True, version=12),
        PullRecord(t=1.5, stale_shards=2, n_shards=4, nbytes=1024.0),
    ]
    for r in recs:
        assert from_dict(to_dict(r)) == r
        assert json.loads(json.dumps(to_dict(r))) == to_dict(r)
    path = tmp_path / "serve.jsonl"
    with JsonlSink(path) as sink:
        for r in recs:
            sink.record(r)
    assert load_jsonl(path) == recs


def test_engine_streams_to_jsonl(smoke, tmp_path):
    cfg, params = smoke
    trace = _trace(n_requests=5)
    path = tmp_path / "stream.jsonl"
    with JsonlSink(path) as sink:
        ServeEngine(cfg, params, ServeConfig(slots=2), trace,
                    metrics=sink).run()
    loaded = load_jsonl(path)
    assert len(loaded) == 5
    assert all(r.kind == "serve" for r in loaded)


# ---------------------------------------------------------------------------
# sync
# ---------------------------------------------------------------------------


def _tiny_params(seed=0):
    rng = np.random.default_rng(seed)
    # 4 leaves so a 4-way ShardPlan is actually 4-way (build clamps to
    # the leaf count)
    return {"a": rng.normal(size=(8, 4)).astype(np.float32),
            "b": rng.normal(size=(16,)).astype(np.float32),
            "c": rng.normal(size=(4, 4)).astype(np.float32),
            "d": rng.normal(size=(8,)).astype(np.float32)}


def test_pull_stale_exact_shards():
    params = _tiny_params()
    state = AdspState.create(_tiny_params(1), n_shards=4)
    plan = ShardPlan.build(params, 4)
    versions = np.zeros(4, np.int64)

    p2, stale, nbytes = pull_stale(params, state, plan, versions)
    assert stale == [] and nbytes == 0  # all fresh at version 0

    state.shard_versions = state.shard_versions.at[2].add(1)
    p2, stale, nbytes = pull_stale(params, state, plan, versions)
    assert stale == [2] and nbytes == plan.shard_nbytes()[2]
    assert versions[2] == 1 and versions.sum() == 1
    # pulled shard now bit-equal to PS; untouched shards unchanged
    want = plan.merge(params, 2, plan.slice(state.params, 2))
    for k in params:
        np.testing.assert_array_equal(np.asarray(p2[k]), np.asarray(want[k]))
    # second poll: nothing stale
    _, stale, nbytes = pull_stale(p2, state, plan, versions)
    assert stale == [] and nbytes == 0


def test_shard_versions_of_monolithic():
    state = AdspState.create(_tiny_params())
    state.step = 5
    assert shard_versions_of(state, 1).tolist() == [5]
    with pytest.raises(ValueError):
        shard_versions_of(state, 4)


def test_replica_sync_accounting():
    params = _tiny_params()
    state = AdspState.create(_tiny_params(1), n_shards=2)
    sync = ReplicaSync(params, lambda: state, n_shards=2, bandwidth=1e6)
    p, n, nb, secs = sync.poll(params)
    assert (n, nb, secs) == (0, 0, 0.0)
    state.shard_versions = state.shard_versions.at[0].add(1)
    p, n, nb, secs = sync.poll(p)
    assert n == 1 and nb == sync.plan.shard_nbytes()[0]
    assert secs == pytest.approx(nb / 1e6)
    assert sync.version == 1
    assert sync.bytes_pulled == nb
    assert sync.full_bytes_equiv == sync.total_nbytes  # dense baseline
    assert sync.polls == 2 and sync.pulls == 1


@pytest.mark.slow
def test_track_training_improves_loss(smoke):
    cfg, params = smoke
    trace = _trace(n_requests=12, rate=30.0, max_new=(3, 8), seed=2)
    trainer = ShardedTrainer(cfg, params, n_shards=4, commit_every=0.05)
    sync = ReplicaSync(params, lambda: trainer.state, n_shards=4)
    log = MetricsLog()
    loss0 = trainer.eval_loss(params)
    eng = ServeEngine(cfg, params, ServeConfig(slots=3, sync_every=2), trace,
                      metrics=log, sync=sync,
                      tick=lambda e, t: trainer.advance(t))
    rep = eng.run()
    assert trainer.eval_loss(eng.params) < loss0
    assert 0 < rep.pull_bytes < rep.full_pull_bytes
    assert len(log.of("pull")) == rep.sync_pulls
    # served versions are non-decreasing over completion order
    versions = [r.version for r in rep.records]
    assert versions == sorted(versions)
    assert versions[-1] > 0


# ---------------------------------------------------------------------------
# launcher regressions
# ---------------------------------------------------------------------------


def test_oneshot_new_tokens_1_skips_decode(capsys):
    stats = serve_launch.main([
        "--arch", ARCH, "--smoke", "--batch", "2",
        "--prompt-len", "8", "--new-tokens", "1"])
    out = capsys.readouterr().out
    assert stats["n_decoded"] == 0
    assert stats["decode_ms_per_token"] is None
    assert stats["decode_tok_s"] is None
    assert stats["generated"].shape == (2, 1)
    assert "skipped" in out
    assert "ms/token" not in out


def test_oneshot_decode_counts_exclude_prefill_token(capsys):
    stats = serve_launch.main([
        "--arch", ARCH, "--smoke", "--batch", "2",
        "--prompt-len", "8", "--new-tokens", "4"])
    capsys.readouterr()
    assert stats["n_decoded"] == 3  # first token came from prefill
    assert stats["generated"].shape == (2, 4)
    assert stats["decode_tok_s"] == pytest.approx(
        2 * 3 / stats["t_decode"], rel=1e-6)


def test_launcher_engine_mode(capsys, tmp_path):
    path = tmp_path / "m.jsonl"
    out = serve_launch.main([
        "--arch", ARCH, "--smoke", "--trace", "poisson",
        "--requests", "5", "--rate", "20", "--slots", "2",
        "--scheduler", "deadline", "--slo-ms", "800",
        "--metrics", str(path)])
    text = capsys.readouterr().out
    assert len(out["report"].records) == 5
    assert "SLO attainment" in text
    assert len(load_jsonl(path)) == 5


# ---------------------------------------------------------------------------
# fleet_report serve summary
# ---------------------------------------------------------------------------


def _load_fleet_report():
    spec = importlib.util.spec_from_file_location(
        "fleet_report",
        pathlib.Path(__file__).resolve().parent.parent / "tools" / "fleet_report.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_fleet_report_serve_summary(smoke):
    cfg, params = smoke
    trace = _trace(n_requests=6)
    trainer = ShardedTrainer(cfg, params, n_shards=2, commit_every=0.05)
    sync = ReplicaSync(params, lambda: trainer.state, n_shards=2)
    log = MetricsLog()
    ServeEngine(cfg, params, ServeConfig(slots=2, sync_every=1), trace,
                metrics=log, sync=sync,
                tick=lambda e, t: trainer.advance(t)).run()
    fr = _load_fleet_report()
    s = fr.summarize(log.records)
    assert s["serve"]["requests"] == 6
    assert s["serve"]["tokens"] == sum(
        r.tokens for r in log.of("serve"))
    assert s["serve"]["slo_ok"] <= 6
    assert s["pulls"]["polls"] == len(log.of("pull"))
    assert s["pulls"]["n_shards"] == 2 or s["pulls"]["polls"] == 0
    report = fr.format_report(s)
    assert "serving: 6 requests" in report
    assert "SLO attainment" in report
    assert math.isfinite(s["serve"]["t_last"])


# ---------------------------------------------------------------------------
# chunked prefill (§17)
# ---------------------------------------------------------------------------


def test_chunked_config_validation(smoke):
    cfg, params = smoke
    with pytest.raises(ValueError):
        ServeConfig(prefill_chunk=-1)
    with pytest.raises(ValueError):
        ServeConfig(prefill_batch=0)
    with pytest.raises(ValueError):  # static mode cannot interleave
        ServeConfig(mode="static", prefill_chunk=4)


def test_cost_model_chunk_pricing():
    """One dispatch over all lanes pays the base once (the batching
    win); m chunks pay it m times (the interleaving price)."""
    cm = CostModel()
    plen = 32
    assert cm.chunk(plen) == pytest.approx(cm.prefill(plen))
    two_chunks = cm.chunk(16) + cm.chunk(16)
    assert two_chunks == pytest.approx(cm.prefill(32) + cm.prefill_base)
    # batched: two 16-token prompts in one dispatch cost one base
    assert cm.chunk(32) < cm.prefill(16) + cm.prefill(16)
    cheap = CostModel(chunk_base=1e-4)
    assert cheap.chunk(16) < cheap.prefill(16)


def test_chunked_engine_matches_monolithic_tokens(smoke):
    """Chunked prefill changes *when* work happens, never the tokens:
    same trace, same streams, and a chunk-dispatch count that reflects
    ceil(plen / chunk) per request (minus batching overlap)."""
    cfg, params = smoke
    trace = _trace(n_requests=8, prompt_lens=(4, 8, 13), rate=40.0)
    mono = ServeEngine(cfg, params, ServeConfig(slots=3), trace).run()
    eng = ServeEngine(cfg, params, ServeConfig(
        slots=3, prefill_chunk=4, prefill_batch=2), trace)
    chunked = eng.run()
    assert chunked.tokens_by_rid == mono.tokens_by_rid
    assert len(chunked.records) == len(trace)
    assert chunked.chunk_dispatches > 0 and mono.chunk_dispatches == 0
    for rec in chunked.records:
        assert rec.total == pytest.approx(
            rec.queue + rec.prefill + rec.decode, abs=1e-9)


def test_chunked_engine_deterministic(smoke):
    cfg, params = smoke
    trace = _trace(n_requests=8, prompt_lens=(4, 13), rate=40.0)
    sc = ServeConfig(slots=2, prefill_chunk=4, prefill_batch=2)
    r1 = ServeEngine(cfg, params, sc, trace).run()
    r2 = ServeEngine(cfg, params, sc, trace).run()
    assert [to_dict(a) for a in r1.records] == [to_dict(b) for b in r2.records]
    assert r1.t_end == r2.t_end


def test_prefill_jit_cache_buckets_by_pow2(smoke):
    """Monolithic prefill dispatches are jit-cached by the prompt length
    rounded up to a power of two — a trace with many distinct lengths
    compiles one fn per *bucket*, not one per length."""
    cfg, params = smoke
    lens = (3, 4, 5, 6, 7, 8, 9, 12, 13, 15)
    trace = _trace(n_requests=20, prompt_lens=lens, rate=40.0)
    eng = ServeEngine(cfg, params, ServeConfig(slots=3), trace)
    eng.run()
    seen = {r.prompt_len for r in trace}
    buckets = {1 << (n - 1).bit_length() if n > 1 else 1 for n in seen}
    assert set(eng._prefill_fns) == buckets
    assert len(eng._prefill_fns) < len(seen)


# ---------------------------------------------------------------------------
# multi-replica load balancing (§17)
# ---------------------------------------------------------------------------


def test_router_registry():
    assert set(router_names()) >= {"round_robin", "least_queue",
                                   "deadline_slack"}
    with pytest.raises(KeyError):
        get_router("nope")


@pytest.mark.parametrize("router", ["round_robin", "least_queue",
                                    "deadline_slack"])
def test_balancer_deterministic(smoke, router):
    """Same trace + seed ⇒ identical per-request records, with EDF
    honored within each replica (deadline scheduler throughout)."""
    cfg, params = smoke
    trace = _trace(n_requests=10, rate=40.0, slo_ms=(400.0))
    sc = ServeConfig(slots=2, scheduler="deadline", seed=1)
    a = LoadBalancer(cfg, params, sc, trace, n_replicas=2,
                     router=router).run()
    b = LoadBalancer(cfg, params, sc, trace, n_replicas=2,
                     router=router).run()
    assert [to_dict(x) for x in a.merged.records] == \
        [to_dict(x) for x in b.merged.records]
    assert a.merged.t_end == b.merged.t_end
    # every request served exactly once, somewhere
    assert sorted(a.merged.tokens_by_rid) == [r.rid for r in trace]
    assert {r.replica for r in a.merged.records} <= {0, 1}
    assert sum(a.per_replica_requests) == len(trace)
    # per-replica token streams match the single-engine ones (routing
    # never changes a request's tokens, only where/when it runs)
    solo = ServeEngine(cfg, params, sc, trace).run()
    assert a.merged.tokens_by_rid == solo.tokens_by_rid


def test_balancer_round_robin_alternates(smoke):
    cfg, params = smoke
    trace = _trace(n_requests=6, rate=40.0)
    out = LoadBalancer(cfg, params, ServeConfig(slots=2), trace,
                       n_replicas=2, router="round_robin").run()
    by_rid = {r.req: r.replica for r in out.merged.records}
    arrivals = sorted(trace, key=lambda r: (r.arrival, r.rid))
    assert [by_rid[r.rid] for r in arrivals] == [0, 1, 0, 1, 0, 1]


def test_balancer_spreads_load_over_idle_replica(smoke):
    """least_queue routes around a busy replica: a burst of arrivals
    lands on both replicas instead of queueing on one."""
    cfg, params = smoke
    trace = _trace(n_requests=8, rate=200.0)  # near-simultaneous burst
    out = LoadBalancer(cfg, params, ServeConfig(slots=2), trace,
                       n_replicas=2, router="least_queue").run()
    assert min(out.per_replica_requests) >= 2


def test_balancer_rejects_bad_config(smoke):
    cfg, params = smoke
    with pytest.raises(ValueError):
        LoadBalancer(cfg, params, ServeConfig(), _trace(), n_replicas=0)
    with pytest.raises(KeyError):
        LoadBalancer(cfg, params, ServeConfig(), _trace(), router="nope")
    with pytest.raises(ValueError):  # sync_every needs a factory
        LoadBalancer(cfg, params, ServeConfig(sync_every=2), _trace())


def test_fleet_report_per_replica(smoke):
    cfg, params = smoke
    trace = _trace(n_requests=8, rate=40.0)
    log = MetricsLog()
    LoadBalancer(cfg, params, ServeConfig(slots=2), trace, n_replicas=2,
                 router="round_robin", metrics=log).run()
    fr = _load_fleet_report()
    s = fr.summarize(log.records)
    assert s["serve"]["requests"] == 8
    assert set(s["per_replica"]) == {0, 1}
    assert sum(rp["requests"] for rp in s["per_replica"].values()) == 8
    report = fr.format_report(s)
    assert "replica" in report


def test_launcher_balancer_mode(capsys):
    out = serve_launch.main([
        "--arch", ARCH, "--smoke", "--trace", "poisson",
        "--requests", "6", "--rate", "30", "--slots", "2",
        "--replicas", "2", "--router", "least_queue",
        "--prefill-chunk", "4", "--prefill-batch", "2"])
    text = capsys.readouterr().out
    assert len(out["report"].records) == 6
    assert out["balance"] is not None
    assert "router=least_queue" in text
    assert "chunked prefill" in text
