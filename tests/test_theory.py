"""Property tests for the paper's analytical results (core.theory)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="dev extra; pip install -e .[dev]")
from hypothesis import given, settings, strategies as st

from repro.control import theory

pos_floats = st.floats(0.1, 100.0, allow_nan=False)


@given(
    st.lists(st.floats(0.5, 50.0), min_size=2, max_size=20),
    st.lists(st.floats(1.0, 32.0), min_size=2, max_size=20),
    st.floats(1.0, 600.0),
)
@settings(max_examples=200, deadline=None)
def test_staleness_p_in_unit_interval(v, dc, gamma):
    m = min(len(v), len(dc))
    p = theory.staleness_p(dc[:m], v[:m], gamma)
    assert 0.0 < p <= 1.0
    assert 0.0 <= theory.mu_implicit(dc[:m], v[:m], gamma) < 1.0


@given(st.floats(1.0, 20.0), st.floats(1.0, 300.0))
@settings(max_examples=100, deadline=None)
def test_mu_implicit_decreases_with_commit_rate(v, gamma):
    """Fig. 3(b): higher ΔC_target ⇒ smaller implicit momentum."""
    mus = [
        theory.mu_implicit([dc, dc, dc], [v, v, v], gamma) for dc in (1, 2, 4, 8, 16)
    ]
    assert all(a > b for a, b in zip(mus, mus[1:]))


def test_eqn3_exact_value():
    # hand-computed: m=2, Γ=60, ΔC=[2,3], v=[1,2] →
    # sum = 60/(2·1) + 60/(3·2) = 30 + 10 = 40 ; p = 1/(1+0.5·40) = 1/21
    p = theory.staleness_p([2, 3], [1, 2], 60.0)
    assert np.isclose(p, 1 / 21)


@given(st.integers(1, 50), st.lists(st.integers(0, 40), min_size=1, max_size=16))
@settings(max_examples=100, deadline=None)
def test_commit_rates_floor(c_target, counts):
    rates = theory.commit_rates_from_target(c_target, counts)
    assert (rates >= 1).all()
    for r, c in zip(rates, counts):
        assert r == max(c_target - c, 1)


@given(
    st.lists(
        st.tuples(st.floats(0.2, 10.0), st.floats(0.0, 2.0)), min_size=2, max_size=12
    ),
    st.integers(1, 32),
)
@settings(max_examples=100, deadline=None)
def test_speed_ordering_appendix_c(profs, tau):
    """V_BSP ≤ V_Fixed(τ) — commit amortization never hurts (App. C)."""
    profiles = [theory.WorkerProfile(v=v, o=o) for v, o in profs]
    assert theory.speed_bsp(profiles) <= theory.speed_fixed_adacomm(profiles, tau) + 1e-12


def test_adsp_speed_beats_bsp_under_heterogeneity():
    profiles = [
        theory.WorkerProfile(v=1.0, o=0.2),
        theory.WorkerProfile(v=1.0, o=0.2),
        theory.WorkerProfile(v=1 / 3, o=0.2),
    ]
    v_adsp = theory.speed_adsp(profiles, gamma=60.0, delta_c=[2, 2, 2])
    assert v_adsp > theory.speed_bsp(profiles)
    # ADSP's average speed = mean of worker capacities 1/(t_i + O_i/τ_i)
    # with τ_i = (Γ/ΔC − O_i)·v_i: fast τ=29.8, slow τ=29.8/3.
    expect = (2 * 1 / (1 + 0.2 / 29.8) + 1 / (3 + 0.2 / (29.8 / 3))) / 3
    assert v_adsp == pytest.approx(expect, rel=0.02)


def test_heterogeneity_degree():
    assert theory.heterogeneity_degree([2.0, 2.0, 1.0]) == pytest.approx(5 / 3)
    with pytest.raises(ValueError):
        theory.heterogeneity_degree([1.0, -1.0])


def test_local_steps_between_commits():
    prof = theory.WorkerProfile(v=2.0, o=0.5)
    # Γ/ΔC − O = 60/4 − 0.5 = 14.5 s → 29 steps
    assert theory.local_steps_between_commits(prof, 60.0, 4) == 29
    # overload: interval floor keeps ≥1 step
    prof2 = theory.WorkerProfile(v=2.0, o=100.0)
    assert theory.local_steps_between_commits(prof2, 60.0, 4) == 1
