"""Dry-run smoke: the full lower+compile+roofline pipeline in a subprocess
(the dry-run needs 512 placeholder devices — jax locks device count at
first init, so it must not run in the test process) with REDUCED configs.

The production-size 40-combo sweep is run separately
(`python -m repro.launch.dryrun --all --mesh both`); its results are
checked by test_dryrun_results when present."""

import json
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("granite-3-8b", "train_4k"),
    ("rwkv6-3b", "decode_32k"),
])
def test_dryrun_smoke_subprocess(arch, shape, tmp_path):
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
    import os

    env.update({k: v for k, v in os.environ.items() if k not in env})
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--smoke",
         "--out", str(tmp_path)],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    files = list(tmp_path.glob("*.json"))
    assert len(files) == 1
    d = json.loads(files[0].read_text())
    assert d["status"] == "ok", d
    assert d["n_chips"] == 256
    rl = d["roofline"]
    assert rl["hlo_flops"] > 0 and rl["hlo_bytes"] > 0
    assert rl["bottleneck"] in ("compute", "memory", "collective")


def test_dryrun_results_if_present():
    """Validate the production sweep output: every (arch × shape × mesh)
    must be ok or an allowed skip."""
    outdir = ROOT / "results" / "dryrun"
    if not outdir.exists() or not list(outdir.glob("*.json")):
        pytest.skip("production dry-run results not generated yet")
    allowed_skips = {("whisper_small", "long_500k")}
    bad = []
    for fp in outdir.glob("*.json"):
        d = json.loads(fp.read_text())
        if d["status"] == "ok":
            assert d["roofline"]["hlo_flops"] > 0
            continue
        if d["status"] == "skipped" and (d["arch"], d["shape"]) in allowed_skips:
            continue
        bad.append((fp.name, d["status"], d.get("error", d.get("reason", ""))[:80]))
    assert not bad, bad
