"""Hypothesis sweeps for the transport codecs: error-feedback round-trip
and fused-vs-reference parity over ragged shapes and bf16/float32 updates
(fixed-case versions run without hypothesis in test_transport.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev extra; pip install -e .[dev]")
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.transport import get_codec


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 1e-5


def _tree(n, m, dtype, seed):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(n,)), dtype),
        "b": {"c": jnp.asarray(rng.normal(size=(m, 5)), dtype)},
    }


@given(
    n=st.integers(1, 40_000),
    m=st.integers(1, 9),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    name=st.sampled_from(["identity", "int8", "bf16", "top_k"]),
)
@settings(max_examples=24, deadline=None)
def test_error_feedback_round_trip(n, m, dtype, name):
    """decode(encode(u + r)) + r' == u + r for every codec on ragged
    bf16/f32 pytrees (r = 0 at the first commit)."""
    u = _tree(n, m, dtype, n * 13 + m)
    codec = get_codec(name)
    state = codec.init(u)
    enc, state1 = codec.encode(u, state)
    dec = codec.decode(enc, u)
    res = state1 if jax.tree.leaves(state1) else jax.tree.map(jnp.zeros_like, u)
    for d, r, ul in zip(jax.tree.leaves(dec), jax.tree.leaves(res),
                        jax.tree.leaves(u)):
        assert_allclose(np.asarray(d, np.float32) + np.asarray(r, np.float32),
                        np.asarray(ul, np.float32),
                        atol=_tol(dtype), rtol=_tol(dtype))


@given(
    n=st.integers(1, 40_000),
    m=st.integers(1, 9),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    name=st.sampled_from(["int8", "bf16"]),
)
@settings(max_examples=16, deadline=None)
def test_fused_backends_agree(n, m, dtype, name):
    """The Pallas-fused encode/decode matches the reference within dtype
    tolerance on ragged pytrees."""
    u = _tree(n, m, dtype, n * 7 + m)
    ref = get_codec(name, backend="reference")
    fus = get_codec(name, backend="fused")
    s0 = ref.init(u)
    enc_r, st_r = ref.encode(u, s0)
    enc_f, st_f = fus.encode(u, s0)
    for a, b in zip(jax.tree.leaves((enc_r, st_r)), jax.tree.leaves((enc_f, st_f))):
        assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                        atol=1e-6, rtol=1e-6)
    for a, b in zip(jax.tree.leaves(ref.decode(enc_r, u)),
                    jax.tree.leaves(fus.decode(enc_f, u))):
        assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                        atol=1e-6, rtol=1e-6)
