"""repro.analysis (DESIGN.md §15): the reprolint rule catalogue, the
baseline/inline suppression machinery, the CLI, and the event-trace race
validator.

Every rule is pinned by a fails-without-fix fixture: a tmp project
carrying the *pre-fix* form of a bug this repo actually had (the rwkv6
``.item()`` host syncs, the layers.py broad except, the PR 6 double
WorkerLeft race) must fire the rule, and the allowlisted/handled twin
must not. The merged tree itself must be clean under ``--strict`` — that
is the CI gate this package exists for.
"""

import json
import pathlib
import textwrap

import pytest

from repro.analysis import (
    Baseline,
    BaselineEntry,
    Finding,
    Project,
    get_rule,
    rule_names,
    run_rules,
    validate_jsonl,
    validate_records,
)
from repro.analysis.cli import main as cli_main
from repro.analysis.core import find_repo_root
from repro.cluster import ChurnSchedule, churn, make_policy
from repro.edgesim import SimConfig, Simulator
from repro.edgesim.profiles import ratio_profiles
from repro.edgesim.tasks import svm_task
from repro.fleet import (
    ChurnRecord,
    CommitRecord,
    FleetConfig,
    LeaseConfig,
    LeaseRecord,
    MetricsLog,
)

REPO = find_repo_root(pathlib.Path(__file__).resolve())


def make_project(tmp_path, files):
    """A throwaway repo: marker file + the given {rel: source} files."""
    (tmp_path / "pyproject.toml").write_text("[project]\nname = 'x'\n")
    paths = []
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
        paths.append(p)
    return Project(tmp_path, paths)


def hits(project, rule_name):
    return [f for f in run_rules(project, [get_rule(rule_name)])
            if f.rule == rule_name]


# ---------------------------------------------------------------------------
# rule catalogue
# ---------------------------------------------------------------------------


def test_rule_catalogue_complete():
    assert set(rule_names()) >= {
        "wall-clock-in-sim", "host-sync-in-hot-path",
        "handler-exhaustiveness", "registry-parity", "frozen-protocol",
        "broad-except", "mutable-default", "tracer-branch",
        "separate-dispatch-in-commit-path",
    }
    with pytest.raises(KeyError):
        get_rule("nonexistent-rule")


def test_wall_clock_in_sim(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/edgesim/bad.py": """\
            import time
            import numpy as np

            def step():
                t0 = time.time()
                rng = np.random.default_rng()
                x = np.random.normal()
                return t0, rng, x
            """,
        # launch/ times the host on purpose: allowlisted by scope
        "src/repro/launch/timer.py": """\
            import time

            def wall():
                return time.time()
            """,
        # a *seeded* generator in sim scope is the sanctioned form
        "src/repro/edgesim/good.py": """\
            import numpy as np

            def make(seed):
                return np.random.default_rng(seed)
            """,
    })
    found = hits(project, "wall-clock-in-sim")
    assert len(found) == 3
    assert all(f.path == "src/repro/edgesim/bad.py" for f in found)
    msgs = " ".join(f.message for f in found)
    assert "time.time" in msgs and "default_rng" in msgs and "global RNG" in msgs


def test_host_sync_in_hot_path(tmp_path):
    project = make_project(tmp_path, {
        # the exact pre-fix rwkv6 pattern this PR removed
        "src/repro/models/bad.py": """\
            import jax
            import jax.numpy as jnp
            import numpy as np

            def fwd(k, n, x):
                k = k * (1.0 / np.sqrt(n)).astype(jnp.float32).item()
                host = jax.device_get(x)
                x.block_until_ready()
                return k, np.asarray(host)
            """,
        # benchmarks/launch may sync the host freely
        "src/repro/launch/report.py": """\
            def wall(x):
                return x.item()
            """,
    })
    found = hits(project, "host-sync-in-hot-path")
    assert len(found) == 4
    assert all(f.path == "src/repro/models/bad.py" for f in found)
    assert any(".item()" in f.message for f in found)


def test_host_sync_on_logits_in_serve_loop(tmp_path):
    """The serving decode loop's narrower contract: shipping small
    token-id arrays per step is fine; any host sync whose expression
    touches logits is the (slots, vocab)-per-step copy the device-side
    argmax removed — exactly the pre-fix engine pattern."""
    project = make_project(tmp_path, {
        "src/repro/serve/engine.py": """\
            import numpy as np

            def decode_step(decode, params, toks, caches):
                logits, caches = decode(params, toks, caches)
                # pre-fix: argmax on host over the full logits tensor
                next_tok = np.argmax(np.asarray(logits[:, 0]), axis=-1)
                return next_tok, caches

            def decode_step_fixed(decode, params, toks, caches):
                tok_ids, caches = decode(params, toks, caches)
                next_tok = np.asarray(tok_ids)  # (slots,) ids: allowed
                return next_tok, caches
            """,
        "src/repro/serve/balance.py": """\
            import jax

            def probe(logits):
                return jax.device_get(logits)
            """,
        # trace generation is not a decode loop: out of scope
        "src/repro/serve/trace.py": """\
            import numpy as np

            def gen(logits):
                return np.asarray(logits)
            """,
    })
    found = hits(project, "host-sync-in-hot-path")
    assert len(found) == 2
    assert {f.path for f in found} == {
        "src/repro/serve/engine.py", "src/repro/serve/balance.py"}
    assert all("logits" in f.message for f in found)


def test_separate_dispatch_in_commit_path(tmp_path):
    project = make_project(tmp_path, {
        # the pre-§16 shape: decode the payload, then apply the commit
        # rule — two dispatches where the combined rule does one
        "src/repro/ps/train_step.py": """\
            def commit(codec, rule, params, cstate, enc, momentum):
                u = codec.decode(enc, params)
                return rule.apply(params, cstate, u, momentum)
            """,
        # fusion-aware fallback: mentions fused, so it deliberately chains
        "src/repro/launch/steps.py": """\
            def commit(codec, rule, params, cstate, enc, momentum, fused_rule):
                if fused_rule is not None:  # fused path handles decode+apply
                    return fused_rule.apply(params, cstate, enc, momentum)
                u = codec.decode(enc, params)
                return rule.apply(params, cstate, u, momentum)
            """,
        # same two-call shape outside the commit-path files: out of scope
        "src/repro/transport/replay.py": """\
            def replay(codec, rule, params, cstate, enc, momentum):
                u = codec.decode(enc, params)
                return rule.apply(params, cstate, u, momentum)
            """,
    })
    found = hits(project, "separate-dispatch-in-commit-path")
    assert len(found) == 1
    assert found[0].path == "src/repro/ps/train_step.py"
    assert found[0].line == 2  # the decode call
    assert "fused_codec" in found[0].message
    assert get_rule("separate-dispatch-in-commit-path").severity == "warning"


def test_handler_exhaustiveness(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/cluster/protocol.py": """\
            import dataclasses

            class Event: pass
            class Command: pass

            @dataclasses.dataclass(frozen=True)
            class StepDone(Event):
                t: float

            @dataclasses.dataclass(frozen=True)
            class Orphan(Event):
                t: float
            """,
        "src/repro/cluster/engine.py": """\
            from .protocol import StepDone

            def dispatch(ev):
                if isinstance(ev, StepDone):
                    return "step"
                raise TypeError(ev)
            """,
    })
    found = hits(project, "handler-exhaustiveness")
    assert [f.message.split()[2] for f in found] == ["Orphan"]


def test_frozen_protocol(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/cluster/protocol.py": """\
            import dataclasses

            class Event: pass

            class Mutable(Event):
                pass
            """,
        "src/repro/fleet/metrics.py": """\
            import dataclasses

            @dataclasses.dataclass(frozen=True)
            class MetricRecord:
                t: float

            @dataclasses.dataclass
            class Unregistered(MetricRecord):
                x: int
            """,
    })
    found = hits(project, "frozen-protocol")
    # Mutable: not frozen; Unregistered: not frozen AND not registered
    assert len(found) == 3
    assert {f.path for f in found} == {
        "src/repro/cluster/protocol.py", "src/repro/fleet/metrics.py"}


def test_registry_parity(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/ps/rules.py": """\
            from .registry import register_local_rule

            @register_local_rule("grad_accum", "fused")
            def fused_impl():
                pass

            @register_local_rule("momentum_delta", "fused")
            def ok_fused():
                pass

            @register_local_rule("momentum_delta", "reference")
            def ok_ref():
                pass
            """,
        "tests/test_ps.py": """\
            NAMES = ["momentum_delta"]
            """,
    })
    found = hits(project, "registry-parity")
    # grad_accum: no reference twin AND no test names it
    assert len(found) == 2
    assert all("grad_accum" in f.message for f in found)
    assert any("no correctness contract" in f.message.replace("\n", " ")
               or "reference" in f.message for f in found)


def test_registry_parity_kernel_ops(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/kernels/ops.py": """\
            __all__ = ["mystery_op"]

            def mystery_op(x):
                return x
            """,
        "src/repro/kernels/ref.py": """\
            def other(x):
                return x
            """,
    })
    found = hits(project, "registry-parity")
    assert len(found) == 2  # no reference twin, no test reference
    assert all("mystery_op" in f.message for f in found)


def test_broad_except(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/util.py": """\
            def swallow():
                try:
                    work()
                except Exception:
                    return None

            def bare():
                try:
                    work()
                except:
                    pass

            def reraises():
                try:
                    work()
                except Exception:
                    raise

            def records(log):
                try:
                    work()
                except Exception as e:
                    log(type(e).__name__)

            def narrow():
                try:
                    work()
                except ValueError:
                    return None
            """,
    })
    found = hits(project, "broad-except")
    assert len(found) == 2
    assert {f.line for f in found} == {4, 10}


def test_mutable_default(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/cfg.py": """\
            import dataclasses

            def f(xs=[]):
                return xs

            def g(m={}, *, s=set()):
                return m, s

            def ok(xs=None, n=3, name="x"):
                return xs

            @dataclasses.dataclass
            class Cfg:
                tags: dict = {}
                n: int = 0
            """,
    })
    found = hits(project, "mutable-default")
    assert len(found) == 4
    assert any("default_factory" in f.message for f in found)


def test_tracer_branch(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/kernels/bad.py": """\
            def kernel(x_ref, o_ref, *, causal=True):
                v = x_ref[0]
                scaled = v * 2.0
                if scaled > 0:
                    o_ref[0] = scaled
                while v:
                    v = v - 1
                if causal:
                    o_ref[0] = 0.0
            """,
        # same code outside kernels/ is not in scope
        "src/repro/models/host.py": """\
            def f(x_ref):
                v = x_ref[0]
                if v > 0:
                    return v
            """,
    })
    found = hits(project, "tracer-branch")
    assert len(found) == 2  # `if scaled` and `while v`; `if causal:` is fine
    assert all(f.path == "src/repro/kernels/bad.py" for f in found)
    assert {f.line for f in found} == {4, 6}


def test_parse_error_is_a_finding(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/broken.py": "def f(:\n",
    })
    found = run_rules(project)
    assert [f.rule for f in found] == ["parse_error"]
    assert found[0].severity == "error"


# ---------------------------------------------------------------------------
# suppression: inline + baseline
# ---------------------------------------------------------------------------


def test_inline_ignore(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/edgesim/t.py": """\
            import time

            a = time.time()  # reprolint: ignore[wall-clock-in-sim]
            b = time.time()  # reprolint: ignore
            c = time.time()  # reprolint: ignore[other-rule]
            d = time.time()
            """,
    })
    found = hits(project, "wall-clock-in-sim")
    assert {f.line for f in found} == {5, 6}  # c (wrong rule) and d


def test_baseline_round_trip_and_staleness(tmp_path):
    f1 = Finding(rule="r", severity="error", path="a.py", line=3, message="m1")
    f2 = Finding(rule="r", severity="error", path="a.py", line=9, message="m2")
    bl = Baseline([BaselineEntry.from_finding(f1, "known, tracked in #7")])
    path = tmp_path / "baseline.json"
    bl.save(path)
    loaded = Baseline.load(path)
    assert loaded.entries == bl.entries
    assert loaded.entries[0].justification == "known, tracked in #7"

    kept, suppressed, stale = loaded.apply([f1, f2])
    assert kept == [f2] and suppressed == [f1] and stale == []
    # the suppression keys off (rule, path, message) — not the line
    moved = Finding(rule="r", severity="error", path="a.py", line=99, message="m1")
    kept, suppressed, _ = loaded.apply([moved, f2])
    assert suppressed == [moved]
    # nothing matching m1 anymore → the entry is stale
    _, _, stale = loaded.apply([f2])
    assert [e.message for e in stale] == ["m1"]

    assert Baseline.load(tmp_path / "missing.json").entries == []
    (tmp_path / "bad.json").write_text("[]")
    with pytest.raises(ValueError):
        Baseline.load(tmp_path / "bad.json")


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_json_strict_and_baseline_flow(tmp_path, capsys):
    make_project(tmp_path, {
        "src/repro/edgesim/t.py": """\
            import time

            now = time.time()
            """,
    })
    src = str(tmp_path / "src")

    # findings present → exit 1, JSON carries them
    out_json = tmp_path / "report.json"
    assert cli_main([src, "--json", str(out_json)]) == 1
    report = json.loads(out_json.read_text())
    assert [f["rule"] for f in report["findings"]] == ["wall-clock-in-sim"]
    assert report["suppressed"] == [] and report["stale_baseline"] == []
    capsys.readouterr()

    # --update-baseline suppresses them; the gate goes green
    assert cli_main([src, "--update-baseline"]) == 0
    assert cli_main([src, "--strict"]) == 0
    out = capsys.readouterr().out
    assert "OK:" in out and "1 baseline-suppressed" in out

    # fixing the code strands the entry: plain run warns, --strict fails
    (tmp_path / "src/repro/edgesim/t.py").write_text("now = 0.0\n")
    assert cli_main([src]) == 0
    assert cli_main([src, "--strict"]) == 1


def test_repo_is_clean_under_strict():
    """The merged tree passes its own gate: zero unsuppressed findings
    and zero stale baseline entries over src/benchmarks/tools."""
    paths = [str(REPO / p) for p in ("src", "benchmarks", "tools")]
    assert cli_main([*paths, "--strict"]) == 0


# ---------------------------------------------------------------------------
# dynamic: event-trace race validator
# ---------------------------------------------------------------------------


def _commit(t, worker=0, versions=(), n_shards=1):
    return CommitRecord(t=t, worker=worker, latency=0.1, push_bytes=8.0,
                        pull_bytes=8.0, stale_shards=1,
                        n_shards=n_shards or len(versions), versions=versions)


def test_validator_clean_synthetic_trace():
    records = [
        ChurnRecord(t=0.0, worker=0, event="join", discovered=False),
        _commit(1.0, worker=0, versions=(1,)),
        _commit(2.0, worker=0, versions=(2,)),
        ChurnRecord(t=3.0, worker=0, event="leave", discovered=True),
        ChurnRecord(t=5.0, worker=0, event="join", discovered=True),
        _commit(6.0, worker=0, versions=(3,)),
    ]
    assert validate_records(records) == []


def test_validator_catches_each_injected_race():
    clock = [_commit(2.0), _commit(1.0)]
    assert [v.check for v in validate_records(clock)] == ["clock"]

    double_leave = [
        ChurnRecord(t=1.0, worker=3, event="leave", discovered=True),
        ChurnRecord(t=1.0, worker=3, event="leave", discovered=False),
    ]
    vs = validate_records(double_leave)
    assert [v.check for v in vs] == ["dedupe"] and vs[0].worker == 3

    stale_gen = [
        ChurnRecord(t=1.0, worker=2, event="leave", discovered=True),
        _commit(2.0, worker=2),
    ]
    assert [v.check for v in validate_records(stale_gen)] == ["stale-gen"]

    regress = [
        _commit(1.0, versions=(3, 4), n_shards=2),
        _commit(2.0, versions=(2, 5), n_shards=2),
    ]
    vs = validate_records(regress)
    assert [v.check for v in vs] == ["shard-version"]
    assert "shard 0" in vs[0].message

    short = [_commit(1.0, versions=(3,), n_shards=2)]
    assert [v.check for v in validate_records(short)] == ["shard-version"]


def test_validator_lease_rejoin_is_not_a_race():
    """The lease layer legitimately reports on dead workers (expired /
    rejoined); only commit/capability/assign in the dead window count."""
    records = [
        ChurnRecord(t=1.0, worker=0, event="leave", discovered=True),
        LeaseRecord(t=2.0, worker=0, event="expired"),
        LeaseRecord(t=4.0, worker=0, event="rejoined"),
        ChurnRecord(t=4.0, worker=0, event="join", discovered=True),
        _commit(5.0, worker=0),
    ]
    assert validate_records(records) == []


def test_validator_jsonl_round_trip(tmp_path):
    log = MetricsLog.from_records([
        _commit(1.0, versions=(1, 1), n_shards=2),
        _commit(2.0, versions=(1, 2), n_shards=2),
        _commit(3.0, versions=(0, 2), n_shards=2),  # shard 0 regressed
    ])
    path = tmp_path / "trace.jsonl"
    log.to_jsonl(path)
    vs = validate_jsonl(path)
    assert [v.check for v in vs] == ["shard-version"] and vs[0].index == 2


def test_validator_green_on_real_lease_run(tmp_path):
    """End to end: the PR 6 race scenario (scripted leave racing a lease
    expiry) through the real simulator produces a trace the validator
    accepts — and an injected duplicate WorkerLeft in that same trace is
    caught."""
    profiles = ratio_profiles((1.0, 1.0, 1.0), base_v=1.0, o=0.2)
    cfg = SimConfig(gamma=20.0, epoch_seconds=200.0, base_batch=32,
                    max_seconds=300.0, local_lr=0.05)
    log = MetricsLog()
    sim = Simulator(svm_task(3), profiles, make_policy("bsp"), cfg,
                    churn=ChurnSchedule([churn.stall(30.0, 1),
                                         churn.leave(34.0, 1)]),
                    fleet=FleetConfig(
                        lease=LeaseConfig(ttl=6.0, heartbeat_period=2.0)),
                    metrics=log)
    sim.train()
    assert len(log) > 0
    assert [r for r in log.of("churn") if r.event == "leave"]
    assert validate_records(log.records) == []

    path = tmp_path / "trace.jsonl"
    log.to_jsonl(path)
    assert validate_jsonl(path) == []

    leave = next(r for r in log.records
                 if r.kind == "churn" and r.event == "leave")
    injected = list(log.records)
    injected.insert(injected.index(leave) + 1, leave)
    assert "dedupe" in {v.check for v in validate_records(injected)}
