"""Architecture configs: exact assigned hyper-parameters + invariants."""

import pytest

from repro.configs import ARCH_IDS, all_configs, get_config, get_smoke
from repro.launch.specs import SHAPES, shape_supported

EXPECTED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "recurrentgemma_9b": (38, 4096, 16, 1, 12288, 256000),
    "whisper_small": (12, 768, 12, 12, 3072, 51865),
    "granite_3_8b": (40, 4096, 32, 8, 12800, 49155),
    "llama4_maverick_400b_a17b": (48, 5120, 40, 8, 8192, 202048),
    "rwkv6_3b": (32, 2560, 40, 40, 8960, 65536),
    "qwen2_5_32b": (64, 5120, 40, 8, 27648, 152064),
    "internlm2_20b": (48, 6144, 48, 8, 16384, 92544),
    "phi_3_vision_4_2b": (32, 3072, 32, 32, 8192, 32064),
    "starcoder2_7b": (32, 4608, 36, 4, 18432, 49152),
    "qwen2_moe_a2_7b": (24, 2048, 16, 16, 1408, 151936),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_assigned_hyperparameters_exact(arch):
    c = get_config(arch)
    exp = EXPECTED[arch]
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == exp
    assert c.source  # citation present


def test_moe_configs():
    l4 = get_config("llama4_maverick_400b_a17b").moe
    assert (l4.num_experts, l4.top_k) == (128, 1)
    q = get_config("qwen2_moe_a2_7b").moe
    assert (q.num_experts, q.top_k, q.num_shared_experts) == (60, 4, 4)


def test_param_counts_in_model_card_range():
    c = all_configs()
    assert 8.5e9 < c["recurrentgemma_9b"].total_params() < 11e9
    assert 0.2e9 < c["whisper_small"].total_params() < 0.4e9
    assert 7e9 < c["granite_3_8b"].total_params() < 9.5e9
    assert 350e9 < c["llama4_maverick_400b_a17b"].total_params() < 450e9
    assert 12e9 < c["llama4_maverick_400b_a17b"].active_params() < 20e9
    assert 2.5e9 < c["rwkv6_3b"].total_params() < 3.6e9
    assert 30e9 < c["qwen2_5_32b"].total_params() < 35e9
    assert 18e9 < c["internlm2_20b"].total_params() < 22e9
    assert 3e9 < c["phi_3_vision_4_2b"].total_params() < 4.6e9
    assert 6.5e9 < c["starcoder2_7b"].total_params() < 8e9
    assert 12e9 < c["qwen2_moe_a2_7b"].total_params() < 16e9
    assert 1.8e9 < c["qwen2_moe_a2_7b"].active_params() < 3.5e9


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_layer_groups_cover_all_layers(arch):
    c = get_config(arch)
    total = sum(len(pat) * reps for pat, reps in c.layer_groups)
    assert total == c.num_layers


def test_vocab_padding_multiple_of_256():
    for c in all_configs().values():
        assert c.padded_vocab % 256 == 0
        assert 0 <= c.padded_vocab - c.vocab_size < 256


def test_long_500k_support_policy():
    assert not shape_supported(get_config("whisper_small"), "long_500k")[0]
    ok, note = shape_supported(get_config("rwkv6_3b"), "long_500k")
    assert ok and note == ""
    ok, note = shape_supported(get_config("recurrentgemma_9b"), "long_500k")
    assert ok and note == ""
    ok, note = shape_supported(get_config("starcoder2_7b"), "long_500k")
    assert ok and note == ""  # native sliding window
    ok, note = shape_supported(get_config("granite_3_8b"), "long_500k")
    assert ok and "sliding_window" in note  # beyond-paper variant


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_variants_reduced(arch):
    s = get_smoke(arch)
    assert s.d_model <= 512
    assert s.vocab_size <= 512
    if s.moe:
        assert s.moe.num_experts <= 4
    # same family: pattern kinds preserved
    assert set(s.layer_pattern) <= set(get_config(arch).layer_pattern)


def test_assigned_shapes():
    assert SHAPES["train_4k"].seq == 4096 and SHAPES["train_4k"].batch == 256
    assert SHAPES["prefill_32k"].seq == 32768 and SHAPES["prefill_32k"].batch == 32
    assert SHAPES["decode_32k"].seq == 32768 and SHAPES["decode_32k"].batch == 128
    assert SHAPES["long_500k"].seq == 524288 and SHAPES["long_500k"].batch == 1
