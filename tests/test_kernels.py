"""Per-kernel allclose vs ref.py oracles — shape/dtype sweeps (hypothesis)
and fixed hard cases. All Pallas kernels run in interpret=True on CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="dev extra; pip install -e .[dev]")
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.kernels import ops, ref


def _rand(rng, shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,hq,hkv,d,win,dtype", [
    (2, 128, 4, 2, 32, 0, jnp.float32),
    (1, 96, 4, 1, 16, 32, jnp.float32),
    (2, 64, 8, 8, 32, 0, jnp.bfloat16),
    (1, 200, 6, 2, 64, 50, jnp.float32),
    (1, 33, 2, 1, 8, 7, jnp.float32),  # ragged padding path
])
def test_flash_attention_matches_ref(b, s, hq, hkv, d, win, dtype):
    rng = np.random.default_rng(0)
    q = _rand(rng, (b, s, hq, d), dtype)
    k = _rand(rng, (b, s, hkv, d), dtype)
    v = _rand(rng, (b, s, hkv, d), dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=win, block_q=64, block_k=64)
    expect = ref.flash_attention(q, k, v, causal=True, window=win)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert_allclose(np.asarray(out, np.float32), np.asarray(expect, np.float32),
                    atol=tol, rtol=tol)


@given(
    s=st.integers(16, 160),
    hkv=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2, 3]),
    win=st.sampled_from([0, 16, 33]),
)
@settings(max_examples=12, deadline=None)
def test_flash_attention_property(s, hkv, group, win):
    rng = np.random.default_rng(s)
    b, d = 1, 16
    q = _rand(rng, (b, s, hkv * group, d))
    k = _rand(rng, (b, s, hkv, d))
    v = _rand(rng, (b, s, hkv, d))
    out = ops.flash_attention(q, k, v, window=win, block_q=32, block_k=32)
    expect = ref.flash_attention(q, k, v, window=win)
    assert_allclose(np.asarray(out), np.asarray(expect), atol=3e-5, rtol=3e-5)


def test_flash_attention_matches_jax_scan_impl():
    """The pure-JAX blockwise impl (models.attention) and the Pallas kernel
    implement the same algorithm — cross-check all three."""
    from repro.models.attention import flash_attention_jax

    rng = np.random.default_rng(3)
    b, s, hq, hkv, d = 2, 80, 4, 2, 32
    q = _rand(rng, (b, s, hq, d))
    k = _rand(rng, (b, s, hkv, d))
    v = _rand(rng, (b, s, hkv, d))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    a = ops.flash_attention(q, k, v, window=17, block_q=32, block_k=32)
    c = flash_attention_jax(q, k, v, pos, window=17, block_q=32, block_k=32)
    e = ref.flash_attention(q, k, v, window=17)
    assert_allclose(np.asarray(a), np.asarray(e), atol=3e-5, rtol=3e-5)
    assert_allclose(np.asarray(c), np.asarray(e), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

@given(
    bsz=st.integers(1, 3),
    s=st.integers(3, 120),
    w=st.integers(4, 80),
)
@settings(max_examples=15, deadline=None)
def test_rglru_scan_property(bsz, s, w):
    rng = np.random.default_rng(s * 31 + w)
    a = jnp.asarray(rng.uniform(0.7, 0.999, size=(bsz, s, w)), jnp.float32)
    b = _rand(rng, (bsz, s, w), scale=0.1)
    h = ops.rglru_scan(a, b, block_w=32, block_s=32)
    he = ref.rglru_scan(a, b)
    assert_allclose(np.asarray(h), np.asarray(he), atol=1e-5, rtol=1e-5)


def test_rglru_decay_bounds():
    """|h| stays bounded by |b|/(1−a_max) for stable decays."""
    rng = np.random.default_rng(0)
    a = jnp.full((1, 200, 16), 0.95, jnp.float32)
    b = _rand(rng, (1, 200, 16), scale=0.1)
    h = ops.rglru_scan(a, b, block_s=64, block_w=16)
    assert float(jnp.max(jnp.abs(h))) <= 0.1 * 4 / (1 - 0.95)


# ---------------------------------------------------------------------------
# RWKV6 scan
# ---------------------------------------------------------------------------

@given(
    s=st.integers(3, 100),
    h=st.integers(1, 3),
    n=st.sampled_from([4, 8, 16]),
)
@settings(max_examples=15, deadline=None)
def test_rwkv6_scan_property(s, h, n):
    rng = np.random.default_rng(s * 7 + n)
    b = 2
    r = _rand(rng, (b, s, h, n), scale=0.5)
    k = _rand(rng, (b, s, h, n), scale=0.5)
    v = _rand(rng, (b, s, h, n), scale=0.5)
    w = jnp.asarray(rng.uniform(0.85, 0.999, size=(b, s, h, n)), jnp.float32)
    u = _rand(rng, (h, n), scale=0.1)
    out, st_ = ops.rwkv6_scan(r, k, v, w, u, block_s=32)
    oute, ste = ref.rwkv6_scan(r, k, v, w, u)
    assert_allclose(np.asarray(out), np.asarray(oute), atol=1e-4, rtol=1e-4)
    assert_allclose(np.asarray(st_), np.asarray(ste), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused commit ops
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 40_000))
@settings(max_examples=20, deadline=None)
def test_accumulate_tree_property(n):
    rng = np.random.default_rng(n)
    u = {"x": _rand(rng, (n,)), "y": {"z": _rand(rng, (3, 5))}}
    g = jax.tree.map(lambda x: x * 0.5 + 1.0, u)
    got = ops.accumulate_tree(u, g, 0.07)
    exp = jax.tree.map(lambda a, b: ref.fused_accumulate(a, b, 0.07), u, g)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(exp)):
        assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6, rtol=1e-6)


def test_ps_apply_tree_matches_ref():
    rng = np.random.default_rng(1)
    w = {"a": _rand(rng, (100, 33)), "b": _rand(rng, (7,))}
    d = jax.tree.map(lambda x: x * 0.1, w)
    u = jax.tree.map(lambda x: x * 0.2 + 0.3, w)
    nw, nd = ops.ps_apply_tree(w, d, u, 0.5, 0.9)
    for wl, dl, ul, nwl, ndl in zip(*map(jax.tree.leaves, (w, d, u, nw, nd))):
        ew, ed = ref.fused_ps_apply(wl, dl, ul, 0.5, 0.9)
        assert_allclose(np.asarray(nwl), np.asarray(ew), atol=1e-6, rtol=1e-6)
        assert_allclose(np.asarray(ndl), np.asarray(ed), atol=1e-6, rtol=1e-6)


def test_ps_apply_equals_sgd_momentum_optimizer():
    """kernels' PS apply ≡ optim.sgd_momentum single step (shared semantics)."""
    from repro.optim import sgd_momentum, SGDState

    rng = np.random.default_rng(2)
    w = {"a": _rand(rng, (64, 64))}
    g = jax.tree.map(lambda x: x * 0.3, w)
    init, update = sgd_momentum(lr=0.2, momentum=0.9)
    st0 = init(w)
    st0 = SGDState(jax.tree.map(lambda x: x * 0.05, w), st0.step)  # nonzero δ
    ref_w, ref_st = update(g, st0, w)
    nw, nd = ops.ps_apply_tree(w, st0.prev_delta, g, 0.2, 0.9)
    assert_allclose(np.asarray(nw["a"]), np.asarray(ref_w["a"]), atol=1e-6)
    assert_allclose(np.asarray(nd["a"]), np.asarray(ref_st.prev_delta["a"]), atol=1e-6)
